#!/usr/bin/env python3
"""Payload-regression gate over bench_codec's measured frame lengths.

Compares the frame-byte column of a freshly generated BENCH_codec.json
against the committed baseline (ci/BENCH_codec_baseline.json) and fails
when any encoded frame grew by more than the tolerance (default 3%).

Frame lengths are deterministic — the bench workload is PCG-seeded and
the codecs are pure functions of the data — so this is a real gate, not
a flaky perf assertion: the tolerance only absorbs deliberate small
format evolutions, and throughput numbers are ignored entirely (they
belong to the bench-smoke artifacts, not a gate).

Usage: ci/bench_gate.py <current.json> <baseline.json> [tolerance]

Exit status: 0 = no regression, 1 = regression or missing rows.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) < 3:
        print(__doc__)
        return 1
    current_path, baseline_path = sys.argv[1], sys.argv[2]
    tolerance = float(sys.argv[3]) if len(sys.argv) > 3 else 0.03

    with open(current_path) as f:
        current = {r["name"]: r for r in json.load(f)["results"]}
    with open(baseline_path) as f:
        baseline_doc = json.load(f)
    baseline = {r["name"]: r for r in baseline_doc["results"]}

    failures = []
    improvements = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current bench output")
            continue
        b, c = base["frame_bytes"], cur["frame_bytes"]
        limit = b * (1.0 + tolerance)
        status = "ok"
        if c > limit:
            status = "REGRESSION"
            failures.append(
                f"{name}: {c} bytes > baseline {b} (+{100.0 * (c - b) / b:.2f}%, "
                f"tolerance {100.0 * tolerance:.0f}%)"
            )
        elif c < b * (1.0 - tolerance):
            status = "improved"
            improvements.append(f"{name}: {b} -> {c} bytes")
        print(f"  {name:<32} baseline={b:>8} current={c:>8}  {status}")

    extra = sorted(set(current) - set(baseline))
    if extra:
        print(f"note: {len(extra)} bench rows not in the baseline (new legs?): "
              + ", ".join(extra))
    if improvements:
        print(f"note: {len(improvements)} rows improved beyond tolerance — "
              "consider refreshing ci/BENCH_codec_baseline.json to lock in the win:")
        for line in improvements:
            print(f"  {line}")
    if failures:
        print("\nPAYLOAD REGRESSION:")
        for line in failures:
            print(f"  {line}")
        return 1
    print("\nbench-gate: no payload regression "
          f"({len(baseline)} rows within {100.0 * tolerance:.0f}%)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
