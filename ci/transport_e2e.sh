#!/usr/bin/env bash
# Transport-lane determinism gate, runnable locally and in CI.
#
# Runs a REAL multi-process loopback session — one `coordinator` process
# plus two `client` processes talking length-prefixed checksummed frames
# over 127.0.0.1 TCP — and byte-diffs its outputs against the in-process
# `fedpayload train` lane:
#
#   1. the f32 reference leg, at threads 1 and 4: round dumps AND
#      journal bytes from the socket run must equal the in-process
#      run's exactly (transport timing lives only in trace `"t":{...}`
#      fields, which the round dump and journal never carry),
#   2. the stateful codec leg (vq8 + full entropy + codebook-reuse
#      auto on the stable-Q strategy-full workload), at threads 1 and
#      4: the cross-round codebook session state machine survives the
#      hop onto sockets bit-for-bit,
#   3. and across the lanes' own thread counts: the TCP dumps at
#      threads 1 and 4 are diffed against each other, same as the
#      in-process contract in ci/determinism.sh.
#
# Every process's stdout/stderr lands in a *.log file in the workdir so
# the CI artifact upload ships the evidence even when a leg goes red.
#
# Usage:  ci/transport_e2e.sh [workdir]
#   BIN=...    overrides the in-process binary
#   COORD=...  overrides the coordinator binary
#   CLIENT=... overrides the client binary
#   (defaults: target/release/{fedpayload,coordinator,client})

set -euo pipefail

REPO_ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
BIN="${BIN:-$REPO_ROOT/target/release/fedpayload}"
COORD="${COORD:-$REPO_ROOT/target/release/coordinator}"
CLIENT="${CLIENT:-$REPO_ROOT/target/release/client}"
for b in "$BIN" "$COORD" "$CLIENT"; do
  test -x "$b" || { echo "missing binary: $b (build with: cargo build --release --bin fedpayload --bin coordinator --bin client)"; exit 1; }
done
WORKDIR="${1:-$(mktemp -d)}"
mkdir -p "$WORKDIR"
cd "$WORKDIR" || exit 1
echo "transport e2e workdir: $WORKDIR"
echo "  fedpayload:  $BIN"
echo "  coordinator: $COORD"
echo "  client:      $CLIENT"

CLIENTS=2

# Training flags shared verbatim by every process in a leg: the
# handshake rejects any client whose resolved config fingerprints
# differently from the coordinator's, naming the first differing key.
ARGS=(--dataset synthetic-small --backend reference
      --iterations 6 --payload-fraction 0.25 --seed 2027
      --set dataset.users=96 --set dataset.items=128
      --set dataset.interactions=3000 --set train.theta=96
      --set train.eval_every=2)

run_inproc() { # run_inproc <tag> <threads> [codec flags...]
  local tag="$1" threads="$2"; shift 2
  "$BIN" train "${ARGS[@]}" "$@" --threads "$threads" \
      --dump-rounds "inproc_${tag}.csv" --journal "inproc_${tag}.jsonl" \
      > "inproc_${tag}.log" 2>&1
  echo "  ran: inproc_${tag} (threads=$threads $*)"
}

run_transport() { # run_transport <tag> <threads> [codec flags...]
  local tag="$1" threads="$2"; shift 2
  local port_file="port_${tag}"
  rm -f "$port_file"
  "$COORD" train "${ARGS[@]}" "$@" --threads "$threads" \
      --listen 127.0.0.1:0 --port-file "$port_file" \
      --transport-clients "$CLIENTS" --connect-timeout-secs 60 \
      --dump-rounds "tcp_${tag}.csv" --journal "tcp_${tag}.jsonl" \
      > "coordinator_${tag}.log" 2>&1 &
  local coord_pid=$!
  local pids=()
  local i
  for i in $(seq 1 "$CLIENTS"); do
    "$CLIENT" run "${ARGS[@]}" "$@" --threads "$threads" \
        --port-file "$port_file" --connect-timeout-secs 60 \
        > "client_${tag}_${i}.log" 2>&1 &
    pids+=("$!")
  done
  local failed=0
  wait "$coord_pid" || { echo "coordinator_${tag} exited non-zero"; failed=1; }
  local pid
  for pid in "${pids[@]}"; do
    wait "$pid" || { echo "a client_${tag} process exited non-zero"; failed=1; }
  done
  if [ "$failed" -ne 0 ]; then
    echo "--- coordinator_${tag}.log (tail) ---"
    tail -n 20 "coordinator_${tag}.log" || true
    for i in $(seq 1 "$CLIENTS"); do
      echo "--- client_${tag}_${i}.log (tail) ---"
      tail -n 20 "client_${tag}_${i}.log" || true
    done
    return 1
  fi
  echo "  ran: tcp_${tag} (1 coordinator + $CLIENTS clients, threads=$threads $*)"
}

check_leg() { # check_leg <tag>
  local tag="$1"
  diff "inproc_${tag}.csv" "tcp_${tag}.csv"
  diff "inproc_${tag}.jsonl" "tcp_${tag}.jsonl"
  echo "   ok: $tag — dump and journal bytes identical across lanes"
}

SESSION=(--codec vq8 --entropy full --codebook-reuse auto --strategy full)

echo "== f32 reference leg =="
for threads in 1 4; do
  tag="f32_t${threads}"
  run_inproc "$tag" "$threads"
  run_transport "$tag" "$threads"
  check_leg "$tag"
done

echo "== vq8 codebook-session leg (stateful cross-round codec) =="
for threads in 1 4; do
  tag="sess_t${threads}"
  run_inproc "$tag" "$threads" "${SESSION[@]}"
  run_transport "$tag" "$threads" "${SESSION[@]}"
  check_leg "$tag"
done

echo "== thread-count invariance on the TCP lane itself =="
diff tcp_f32_t1.csv tcp_f32_t4.csv
diff tcp_sess_t1.csv tcp_sess_t4.csv
echo "   ok"

echo "transport e2e: all checks passed"
