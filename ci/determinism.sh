#!/usr/bin/env bash
# Determinism contract checks, runnable locally and in CI.
#
# Proves, on the synthetic-small e2e workload:
#   1. threads = 1 trains bit-identically across repeat runs (no
#      nondeterminism unrelated to threading),
#   2. threads = 4 trains bit-identically to threads = 1 (the fleet
#      executor's batch-order merge contract), for the f32, int8+full
#      and vq8+full codecs,
#   3. the entropy layer changes only measured bytes, never training:
#      the metric columns of an int8+full (resp. vq8+full) round dump
#      equal its own plain int8 (resp. plain vq8) dump,
#   4. the byte ladder: entropy coding strictly shrinks int8 downloads,
#      and the vq8 quantizer lands strictly under int8 — plain vs plain
#      and full vs full (the PR acceptance comparison),
#   5. codebook sessions (wire::vq::session): `codebook_reuse=delta`
#      trains bit-identically to the stateless vq8 path (a delta frame
#      reconstructs the fresh codebook exactly), `codebook_reuse=auto`
#      is threads-1/4 bit-identical like everything else, and on the
#      stable-Q strategy-full workload auto moves strictly fewer
#      download bytes than the per-frame-codebook baseline,
#   6. the flight recorder: a full-level `--trace-out` decision trace
#      digests (`fedpayload trace-digest`: the trailing `"t":{...}`
#      wall-clock objects stripped) byte-identically at threads 1 and
#      4, and the `--metrics-out` Prometheus snapshot — decision-side
#      counters only — is byte-identical across thread counts outright,
#   7. the round journal (server::journal): `fedpayload journal-dump`
#      re-derives the golden round-dump text from the journal alone (no
#      retraining), a run killed mid-way and `--resume`d converges to
#      the uninterrupted run's dump AND journal bytes — at threads 1
#      and 4, and on the stateful codebook-session codec,
#   8. per-round participant sampling (`--theta-sample`, a dedicated
#      PCG stream keyed off the master seed): sampled runs are
#      threads-1/4 bit-identical in dumps, decision-trace digests AND
#      journal bytes, the sampled trajectory genuinely diverges from
#      the legacy full-Θ one (the streams are independent), and a
#      sampled run killed mid-way resumes — at threads 1 and 4 — to
#      the uninterrupted sampled run's dump and journal bytes, which
#      requires the resume replay to re-verify the journaled
#      participant sets against the sampler stream.
#   9. the transport lane (rust/src/transport): a real multi-process
#      loopback session — one `coordinator` + two `client` processes
#      over 127.0.0.1 TCP — produces round dumps and journal bytes
#      identical to the in-process lane, on the f32 and stateful
#      vq8-session codecs at threads 1 and 4 (delegated to
#      ci/transport_e2e.sh; skipped with a notice when the bin pair
#      has not been built).
#  10. per-client payload policies (server::policy) and upload-delta
#      sessions (wire::upload): `--policy budget` and `--policy bandit`
#      trajectories are bit-identical across repeat runs and thread
#      counts while genuinely diverging from the uniform path (the
#      decisions come from a dedicated tagged PCG stream, not the
#      training RNG); `--upload-delta` re-frames the exact plane the
#      batch carried, so the metric columns match the non-delta run
#      and only the byte columns may move; and on the stable-Q
#      strategy-full workload the session actually ships delta frames
#      with zero resyncs (first contact is a Full frame, not a fault).
#
# Usage:  ci/determinism.sh [workdir]
#   BIN=path/to/fedpayload overrides the binary (default:
#   target/release/fedpayload relative to the repo root); §9 also
#   honours COORD= and CLIENT= for the transport bin pair.

set -euo pipefail

REPO_ROOT="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
BIN="${BIN:-$REPO_ROOT/target/release/fedpayload}"
BIN="$(cd "$(dirname "$BIN")" && pwd)/$(basename "$BIN")"
WORKDIR="${1:-$(mktemp -d)}"
mkdir -p "$WORKDIR"
cd "$WORKDIR" || exit 1
echo "determinism workdir: $WORKDIR (binary: $BIN)"

ARGS=(train --dataset synthetic-small --backend reference
      --iterations 8 --payload-fraction 0.25 --seed 2027
      --set dataset.users=192 --set dataset.items=256
      --set dataset.interactions=6000 --set train.theta=160
      --set train.eval_every=2)

run() { # run <dump-file> [extra args...]
  local dump="$1"; shift
  "$BIN" "${ARGS[@]}" "$@" --dump-rounds "$dump" >/dev/null
  echo "  ran: $dump ($*)"
}

metrics_cols() { grep -v '^totals' "$1" | cut -d, -f1-10; }
down_bytes()   { grep '^totals' "$1" | sed 's/.*down_bytes=\([0-9]*\).*/\1/'; }

echo "== running the e2e legs =="
run rounds_t1_a.csv         --threads 1
run rounds_t1_b.csv         --threads 1
run rounds_t4.csv           --threads 4
run rounds_int8_full_t1.csv --codec int8 --entropy full --threads 1
run rounds_int8_full_t4.csv --codec int8 --entropy full --threads 4
run rounds_int8_plain.csv   --codec int8 --threads 1
run rounds_vq8_full_t1.csv  --codec vq8 --entropy full --threads 1
run rounds_vq8_full_t4.csv  --codec vq8 --entropy full --threads 4
run rounds_vq8_plain.csv    --codec vq8 --threads 1
run rounds_vq8_delta_t1.csv --codec vq8 --entropy full --codebook-reuse delta --threads 1
run rounds_vq8_auto_t1.csv  --codec vq8 --entropy full --codebook-reuse auto \
                            --strategy full --threads 1
run rounds_vq8_auto_t4.csv  --codec vq8 --entropy full --codebook-reuse auto \
                            --strategy full --threads 4
run rounds_vq8_sf_off.csv   --codec vq8 --entropy full --strategy full --threads 1

echo "== 1+2: round records must be bit-identical across runs and thread counts =="
diff rounds_t1_a.csv rounds_t1_b.csv
diff rounds_t1_a.csv rounds_t4.csv
diff rounds_int8_full_t1.csv rounds_int8_full_t4.csv
diff rounds_vq8_full_t1.csv rounds_vq8_full_t4.csv
echo "   ok"

echo "== 3: entropy coding must not change training, only bytes =="
diff <(metrics_cols rounds_int8_plain.csv) <(metrics_cols rounds_int8_full_t1.csv)
diff <(metrics_cols rounds_vq8_plain.csv) <(metrics_cols rounds_vq8_full_t1.csv)
echo "   ok"

echo "== 4: the download byte ladder =="
INT8_PLAIN=$(down_bytes rounds_int8_plain.csv)
INT8_FULL=$(down_bytes rounds_int8_full_t1.csv)
VQ8_PLAIN=$(down_bytes rounds_vq8_plain.csv)
VQ8_FULL=$(down_bytes rounds_vq8_full_t1.csv)
echo "   down_bytes: int8=$INT8_PLAIN int8+full=$INT8_FULL vq8=$VQ8_PLAIN vq8+full=$VQ8_FULL"
test "$INT8_FULL" -lt "$INT8_PLAIN"   # entropy shrinks int8 downloads
test "$VQ8_PLAIN" -lt "$INT8_PLAIN"   # the vq quantizer lands under int8
test "$VQ8_FULL"  -lt "$INT8_FULL"    # ... and stays under with entropy on (acceptance)
test "$VQ8_FULL"  -lt "$VQ8_PLAIN"    # low-entropy indices: range coding bites on vq
echo "   ok"

echo "== 5: codebook sessions =="
# auto is threads-invariant like every other codec config
diff rounds_vq8_auto_t1.csv rounds_vq8_auto_t4.csv
# delta frames reconstruct the fresh codebook exactly (post-requant):
# training is bit-identical to the stateless vq8+full run — only the
# byte columns may differ
diff <(metrics_cols rounds_vq8_full_t1.csv) <(metrics_cols rounds_vq8_delta_t1.csv)
# stable-Q workload (strategy full: same rows every round, Adam-step
# drift): auto reuses/deltas its way strictly under the stateless
# per-frame-codebook bytes at matched settings
AUTO_DOWN=$(down_bytes rounds_vq8_auto_t1.csv)
SF_OFF_DOWN=$(down_bytes rounds_vq8_sf_off.csv)
echo "   down_bytes: vq8+full strategy-full off=$SF_OFF_DOWN auto=$AUTO_DOWN"
test "$AUTO_DOWN" -lt "$SF_OFF_DOWN"
echo "   ok"

echo "== 6: flight-recorder trace digests and metrics snapshots =="
# the stable-Q session codec config exercises every event type:
# bandit_select, codec_choice, resyncs (rotating participation at
# theta < users means returning clients hit stale generations), lane
# spans at full level, reward updates, round roll-ups
"$BIN" "${ARGS[@]}" --codec vq8 --entropy full --codebook-reuse auto \
       --strategy full --threads 1 --trace-out trace_t1.jsonl \
       --trace-level full --metrics-out metrics_t1.prom >/dev/null
"$BIN" "${ARGS[@]}" --codec vq8 --entropy full --codebook-reuse auto \
       --strategy full --threads 4 --trace-out trace_t4.jsonl \
       --trace-level full --metrics-out metrics_t4.prom >/dev/null
echo "  ran: trace_t1.jsonl trace_t4.jsonl"
# raw traces carry wall-clock timing objects (they are the point)...
grep -q ',"t":{' trace_t1.jsonl
grep -q ',"t":{' trace_t4.jsonl
# ... the digests strip them and nothing else
"$BIN" trace-digest trace_t1.jsonl > digest_t1.txt
"$BIN" trace-digest trace_t4.jsonl > digest_t4.txt
if grep -q ',"t":{' digest_t1.txt; then
  echo "timing object leaked into the digest"; exit 1
fi
test "$(wc -l < trace_t1.jsonl)" -eq "$(wc -l < digest_t1.txt)"
# the decision trace is thread-count invariant
diff digest_t1.txt digest_t4.txt
# every event layer made it into the trace
for ev in run_start bandit_select codec_choice resync lane_span \
          reward_update round_end run_end; do
  grep -q "^{\"ev\":\"$ev\"" digest_t1.txt || { echo "missing event: $ev"; exit 1; }
done
# metrics snapshots hold decision-side series only: byte-identical
# across thread counts, no digesting needed
diff metrics_t1.prom metrics_t4.prom
grep -q '^# TYPE fedpayload_rounds_total counter' metrics_t1.prom
grep -q '^fedpayload_rounds_total 8$' metrics_t1.prom
echo "   ok"

echo "== 7: the round journal — record, replay, resume =="
# journaled full run: the journal re-renders the §1 golden dump exactly,
# with no dataset, no model, no retraining
run rounds_j_full.csv --threads 1 --journal journal_full.jsonl
diff rounds_j_full.csv rounds_t1_a.csv
"$BIN" journal-dump journal_full.jsonl > rounds_from_journal.csv
diff rounds_from_journal.csv rounds_t1_a.csv
# kill-and-resume: stop after 5 of 8 rounds (later --iterations wins),
# resume, and both the dump and the journal bytes converge
"$BIN" "${ARGS[@]}" --threads 1 --iterations 5 \
       --journal journal_part.jsonl >/dev/null
echo "  ran: journal_part.jsonl (killed after 5 rounds)"
run rounds_j_resumed.csv --threads 1 --resume journal_part.jsonl
diff rounds_j_resumed.csv rounds_t1_a.csv
diff journal_part.jsonl journal_full.jsonl
# the same resume at threads=4 replays and continues bit-identically
"$BIN" "${ARGS[@]}" --threads 4 --iterations 5 \
       --journal journal_part_t4.jsonl >/dev/null
run rounds_j_resumed_t4.csv --threads 4 --resume journal_part_t4.jsonl
diff rounds_j_resumed_t4.csv rounds_t1_a.csv
diff journal_part_t4.jsonl journal_full.jsonl
# the stateful codebook-session codec resumes too: the replay must
# reconstruct the generation-tagged codebook cache exactly
"$BIN" "${ARGS[@]}" --codec vq8 --entropy full --codebook-reuse auto \
       --strategy full --threads 1 --iterations 5 \
       --journal journal_sess_part.jsonl >/dev/null
run rounds_j_sess.csv --codec vq8 --entropy full --codebook-reuse auto \
                      --strategy full --threads 1 \
                      --resume journal_sess_part.jsonl
diff rounds_j_sess.csv rounds_vq8_auto_t1.csv
echo "   ok"

echo "== 8: theta-sample — sampled runs: invariance, divergence, resume =="
# sampled full runs (96 of theta=160 participants per round) at both
# thread counts, with journals and full-level traces
"$BIN" "${ARGS[@]}" --theta-sample 96 --threads 1 \
       --journal journal_ts_full.jsonl --trace-out trace_ts_t1.jsonl \
       --trace-level full --dump-rounds rounds_ts_t1.csv >/dev/null
"$BIN" "${ARGS[@]}" --theta-sample 96 --threads 4 \
       --journal journal_ts_full_t4.jsonl --trace-out trace_ts_t4.jsonl \
       --trace-level full --dump-rounds rounds_ts_t4.csv >/dev/null
echo "  ran: rounds_ts_t1.csv rounds_ts_t4.csv (sampled, journaled, traced)"
# sampled runs keep the whole determinism contract: dumps, trace
# digests and journal bytes all byte-identical at threads 1 vs 4
diff rounds_ts_t1.csv rounds_ts_t4.csv
"$BIN" trace-digest trace_ts_t1.jsonl > digest_ts_t1.txt
"$BIN" trace-digest trace_ts_t4.jsonl > digest_ts_t4.txt
diff digest_ts_t1.txt digest_ts_t4.txt
diff journal_ts_full.jsonl journal_ts_full_t4.jsonl
# the sampler stream is independent of the legacy path: a sampled run
# must NOT reproduce the full-Θ trajectory
if diff -q rounds_ts_t1.csv rounds_t1_a.csv >/dev/null; then
  echo "theta-sample run unexpectedly matched the legacy full-theta run"; exit 1
fi
# kill-and-resume on the sampled path: stop after 5 of 8 rounds, then
# resume — replay re-verifies the journaled participant sets against
# the dedicated sampler stream before continuing. Dump and journal
# bytes converge to the uninterrupted sampled run, at both thread
# counts.
"$BIN" "${ARGS[@]}" --theta-sample 96 --threads 1 --iterations 5 \
       --journal journal_ts_part.jsonl >/dev/null
echo "  ran: journal_ts_part.jsonl (killed after 5 rounds)"
"$BIN" "${ARGS[@]}" --theta-sample 96 --threads 1 \
       --resume journal_ts_part.jsonl \
       --dump-rounds rounds_ts_resumed.csv >/dev/null
diff rounds_ts_resumed.csv rounds_ts_t1.csv
diff journal_ts_part.jsonl journal_ts_full.jsonl
"$BIN" "${ARGS[@]}" --theta-sample 96 --threads 4 --iterations 5 \
       --journal journal_ts_part_t4.jsonl >/dev/null
"$BIN" "${ARGS[@]}" --theta-sample 96 --threads 4 \
       --resume journal_ts_part_t4.jsonl \
       --dump-rounds rounds_ts_resumed_t4.csv >/dev/null
diff rounds_ts_resumed_t4.csv rounds_ts_t1.csv
diff journal_ts_part_t4.jsonl journal_ts_full.jsonl
echo "   ok"

echo "== 9: transport lane — multi-process loopback vs in-process =="
COORD="${COORD:-$REPO_ROOT/target/release/coordinator}"
CLIENT="${CLIENT:-$REPO_ROOT/target/release/client}"
if [ -x "$COORD" ] && [ -x "$CLIENT" ]; then
  # already cd'd into $WORKDIR — nest the transport leg's evidence here
  BIN="$BIN" COORD="$COORD" CLIENT="$CLIENT" \
    "$REPO_ROOT/ci/transport_e2e.sh" transport
  echo "   ok"
else
  echo "   skipped: coordinator/client bins not built (cargo build --release builds them; the transport-e2e CI job runs this leg regardless)"
fi

echo "== 10: payload policies and upload-delta sessions =="
# per-client policies: budget and bandit trajectories are bit-identical
# across repeat runs and thread counts — the cohort exchange folds in
# fixed (arm, top_k) key order, so the merge is batch-order stable like
# everything else — and they genuinely diverge from the uniform path
run rounds_pol_budget_t1.csv --policy budget --threads 1
run rounds_pol_budget_t4.csv --policy budget --threads 4
run rounds_pol_bandit_b.csv  --policy bandit --threads 1
"$BIN" "${ARGS[@]}" --policy bandit --threads 1 \
       --journal journal_pol_t1.jsonl --trace-out trace_pol_t1.jsonl \
       --trace-level full --dump-rounds rounds_pol_bandit_t1.csv >/dev/null
"$BIN" "${ARGS[@]}" --policy bandit --threads 4 \
       --journal journal_pol_t4.jsonl --trace-out trace_pol_t4.jsonl \
       --trace-level full --dump-rounds rounds_pol_bandit_t4.csv >/dev/null
echo "  ran: rounds_pol_bandit_t1.csv rounds_pol_bandit_t4.csv (journaled, traced)"
diff rounds_pol_budget_t1.csv rounds_pol_budget_t4.csv
diff rounds_pol_bandit_t1.csv rounds_pol_bandit_t4.csv
diff rounds_pol_bandit_t1.csv rounds_pol_bandit_b.csv
# the whole evidence chain is thread-invariant: journal bytes (incl.
# the per-round policy/upload state digests) and decision-trace digests
diff journal_pol_t1.jsonl journal_pol_t4.jsonl
"$BIN" trace-digest trace_pol_t1.jsonl > digest_pol_t1.txt
"$BIN" trace-digest trace_pol_t4.jsonl > digest_pol_t4.txt
diff digest_pol_t1.txt digest_pol_t4.txt
grep -q '"ev":"policy_decide"' digest_pol_t1.txt
grep -q '"policy_mode":"bandit"' journal_pol_t1.jsonl
if diff -q rounds_pol_bandit_t1.csv rounds_t1_a.csv >/dev/null; then
  echo "bandit policy run unexpectedly matched the uniform run"; exit 1
fi
# upload-delta sessions re-frame the exact value plane the batch frame
# carried: turning them on must not change one bit of training — only
# the upload ledger — and the delta run is threads-1/4 bit-identical
# outright (the attribution walks participants in batch order)
run rounds_up_delta_t1.csv --codec int8 --entropy full --upload-delta --threads 1
run rounds_up_delta_t4.csv --codec int8 --entropy full --upload-delta --threads 4
diff rounds_up_delta_t1.csv rounds_up_delta_t4.csv
diff <(metrics_cols rounds_int8_full_t1.csv) <(metrics_cols rounds_up_delta_t1.csv)
# stable-Q strategy-full workload: consecutive uploads resemble each
# other, so the session genuinely ships delta frames (a delta only
# ships when it range-codes strictly smaller than the full frame), and
# a fault-free run counts zero resyncs — first contact is a Full frame
# by design, not a recovery
"$BIN" "${ARGS[@]}" --codec int8 --entropy full --upload-delta \
       --strategy full --threads 1 \
       --dump-rounds rounds_up_delta_sf.csv > up_delta_sf.out
echo "  ran: rounds_up_delta_sf.csv (strategy full, upload-delta)"
grep '^upload session:' up_delta_sf.out
UP_DELTA_FRAMES=$(sed -n 's|^upload session: [0-9]* full / \([0-9]*\) delta frames.*|\1|p' up_delta_sf.out)
UP_RESYNCS=$(sed -n 's|^upload session: .* \([0-9]*\) resyncs.*|\1|p' up_delta_sf.out)
test "$UP_DELTA_FRAMES" -ge 1
test "$UP_RESYNCS" -eq 0
echo "   ok"

echo "determinism: all checks passed"
