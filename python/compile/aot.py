"""AOT compile path: lower every L2 graph to HLO TEXT for the rust runtime.

HLO *text* (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowering goes stablehlo -> XlaComputation (return_tuple=True, so
rust unwraps with to_tupleN) -> as_hlo_text().

Run once via `make artifacts`:
    cd python && python -m compile.aot --out-dir ../artifacts

Also writes artifacts/manifest.txt — a key=value file the rust runtime
parses to learn the geometry (B, K, tiles) and the baked hyper-parameters,
and to verify it is running against the artifacts it expects.
"""

from __future__ import annotations

import argparse
import hashlib
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    lines = [
        "version=1",
        f"B={model.B}",
        f"K={model.K}",
        f"tiles={','.join(str(t) for t in model.TILES)}",
        f"alpha={model.ALPHA}",
        f"lam={model.LAM}",
        f"eta={model.ETA}",
        f"beta1={model.BETA1}",
        f"beta2={model.BETA2}",
        f"eps={model.EPS}",
        f"cg_iters={model.CG_ITERS}",
    ]

    for name, fn, example_args in model.artifact_specs():
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        n_in = len(example_args)
        lines.append(f"artifact={name} inputs={n_in} sha256={digest}")
        print(f"wrote {path}: {len(text)} chars, {n_in} inputs")

    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
