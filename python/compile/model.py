"""L2: the FCF client compute graph in JAX, calling the L1 Pallas kernels.

These are the functions the rust coordinator executes (after AOT lowering
to HLO text by aot.py). Python never runs on the request path; this module
exists only at artifact-build time and in pytest.

Graphs (all static-shaped; rust tiles/pads around them):

  client_accum(Q_t, X_t, mask)            -> (A_partial, b_partial)
  solve_p(A, b)                           -> P            (Eq. 3, CG)
  client_grad(P, umask, Q_t, X_t, mask)   -> G_t          (Eq. 5-6)
  client_scores(P, Q_t)                   -> S_t          (x* = p^T Q)
  adam_step(Q_t, G_t, m, v, t)            -> (Q', m', v') (Eq. 4 + Adam)

Hyper-parameters (alpha, lam, Adam betas/eta/eps — Table 3) are baked into
the artifacts at lowering time and recorded in artifacts/manifest.txt; the
rust config asserts it matches.

The solve uses CONJUGATE GRADIENTS in pure jnp instead of
jnp.linalg.solve: on CPU, LAPACK solves lower to a custom-call the PJRT
text-loader cannot execute, while CG lowers to pure HLO (a fori_loop of
matmuls). A + lam*I is SPD with eigenvalues >= lam = 1, and K = 25, so
CG_ITERS = 2K converges to f32 round-off (pinned by pytest vs numpy).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import accum as accum_k
from .kernels import grad as grad_k
from .kernels import scores as scores_k

# ---------------------------------------------------------------------------
# Paper hyper-parameters (Table 3) baked into the artifacts.
ALPHA = 4.0      # implicit-confidence weight, c = 1 + alpha x
LAM = 1.0        # L2 regularization
ETA = 0.01       # Adam learning rate
BETA1 = 0.1      # Adam beta_1 (paper uses 0.1)
BETA2 = 0.99     # Adam beta_2
EPS = 1e-8       # Adam epsilon

# Artifact geometry. B = user batch, K = latent factors (Table 3), tiles =
# item-axis widths emitted (rust picks the best fit per call).
B = 64
K = 25
TILES = (512, 2048)
CG_ITERS = 2 * K


def client_accum(q, x, mask):
    """(A, b) partial sums for one item tile (Eq. 3 ingredients)."""
    return accum_k.accum(q, x, mask, alpha=ALPHA)


def solve_p(a, b):
    """Batched CG solve of (A + lam I) p = b over the user batch (Eq. 3)."""

    def matvec(v):
        return jnp.einsum("bij,bj->bi", a, v) + LAM * v

    x0 = jnp.zeros_like(b)
    r0 = b                                  # b - matvec(0)
    rs0 = jnp.sum(r0 * r0, axis=-1)         # (B,)
    tiny = 1e-20

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = jnp.sum(p * ap, axis=-1)
        alpha = rs / (denom + tiny)
        x = x + alpha[:, None] * p
        r = r - alpha[:, None] * ap
        rs_new = jnp.sum(r * r, axis=-1)
        beta = rs_new / (rs + tiny)
        p = r + beta[:, None] * p
        return (x, r, p, rs_new)

    x, _, _, _ = jax.lax.fori_loop(0, CG_ITERS, body, (x0, r0, r0, rs0))
    return x


def client_update(q, x, mask):
    """Single-tile fused client update: accum + solve in one artifact.

    Valid when the whole selected item set fits one tile (the common case
    at >= 90% payload reduction). For multi-tile item sets rust runs
    client_accum per tile, sums, then solve_p.
    """
    a, b = client_accum(q, x, mask)
    return solve_p(a, b)


def client_grad(p, umask, q, x, mask):
    """Aggregated Eq. 5-6 gradient for one item tile."""
    return grad_k.grad(p, umask, q, x, mask, alpha=ALPHA, lam=LAM)


def client_scores(p, q):
    """Predicted affinities for evaluation (top-N recommendation)."""
    return scores_k.scores(p, q)


def adam_step(q, g, m, v, t):
    """Server-side Adam update on one (K, T) tile of the global model.

    t is a float32 scalar (1-based global update count for this item set).
    Kept as an artifact so the L3 hot loop can run the whole round on the
    PJRT device; rust/src/optim mirrors it for differential testing.
    """
    m2 = BETA1 * m + (1.0 - BETA1) * g
    v2 = BETA2 * v + (1.0 - BETA2) * g * g
    mhat = m2 / (1.0 - BETA1**t)
    vhat = v2 / (1.0 - BETA2**t)
    q2 = q - ETA * mhat / (jnp.sqrt(vhat) + EPS)
    return q2, m2, v2


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn, example-arg builder). aot.py iterates this.


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """Yield (name, fn, example_args) for every artifact to emit."""
    specs = []
    for t in TILES:
        specs.append(
            (f"accum_t{t}", client_accum, (_f32(K, t), _f32(B, t), _f32(t)))
        )
        specs.append(
            (
                f"grad_t{t}",
                client_grad,
                (_f32(B, K), _f32(B), _f32(K, t), _f32(B, t), _f32(t)),
            )
        )
        specs.append((f"scores_t{t}", client_scores, (_f32(B, K), _f32(K, t))))
        specs.append(
            (
                f"adam_t{t}",
                adam_step,
                (_f32(K, t), _f32(K, t), _f32(K, t), _f32(K, t), _f32()),
            )
        )
    specs.append((f"solve", solve_p, (_f32(B, K, K), _f32(B, K))))
    t0 = TILES[0]
    specs.append(
        (f"update_t{t0}", client_update, (_f32(K, t0), _f32(B, t0), _f32(t0)))
    )
    return specs
