"""L1 Pallas kernel: confidence-weighted Gram accumulation (Eq. 3 lhs/rhs).

Computes, for a batch of B users over an item tile of width T:

    A_i = Q* C^i Q*^T        (B, K, K)   [lambda*I added later, in solve]
    b_i = Q* C^i x_i         (B, K)

The kernel is tiled over the item axis: the grid streams (K, TK) slices of
Q and (B, TK) slices of X from HBM into VMEM while the (B, K, K)
accumulator block stays resident across the whole grid — the TPU analogue
of a threadblock-resident partial sum. With (B, K, TK) = (64, 25, 128) the
per-step VMEM working set is ~230 KB, far under the ~16 MB budget, leaving
headroom for double-buffering on a real TPU.

interpret=True is mandatory here: the artifacts must execute on the CPU
PJRT client in rust, and a real Mosaic lowering emits a custom-call that
client cannot run (see DESIGN.md section Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Inner Pallas tile along the item axis. The artifact-level tile T (512 or
# 2048, see aot.py) must be a multiple of this.
#
# Perf note (EXPERIMENTS.md §Perf): TK=128 lowers (via interpret mode) to a
# 16-step HLO loop per 2048-tile that XLA CPU cannot fuse across — the
# compiled accum ran at ~6 GFLOP/s. TK=512 (X tile 64·512·4 B = 128 KB,
# accumulator 160 KB — still far under the ~16 MB VMEM budget with double
# buffering) quarters the grid steps and nearly doubled end-to-end round
# throughput on the CPU PJRT backend.
TK = 512


def _accum_kernel(q_ref, x_ref, mask_ref, a_ref, b_ref, *, alpha):
    """One grid step: fold an item sub-tile into the (A, b) accumulators."""
    step = pl.program_id(0)

    q = q_ref[...]                      # (K, TK)
    x = x_ref[...]                      # (B, TK)
    m = mask_ref[...]                   # (TK,)

    # c_ij = 1 + alpha x_ij (Eq. 2); masked columns contribute nothing.
    c = (1.0 + alpha * x) * m[None, :]  # (B, TK)

    # A += einsum('kt,bt,jt->bkj', q, c, q), reformulated as ONE large
    # GEMM instead of B small (K x TK)@(TK x K) products: materialize the
    # per-column outer products op[(k,j), t] = q[k,t] q[j,t] (K²·TK, ~3 MB
    # at TK=512 — VMEM-sized) and contract the tile axis against Cᵀ in a
    # single (K², TK) x (TK, B) product. On the CPU PJRT backend this runs
    # ~4x faster than the batched-small-GEMM form (EXPERIMENTS.md §Perf);
    # on a real TPU it is one well-shaped MXU contraction per grid step.
    k_dim = q.shape[0]
    op = (q[:, None, :] * q[None, :, :]).reshape(k_dim * k_dim, -1)  # (K², TK)
    a_cols = jax.lax.dot_general(
        op,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),          # (K², B)
        preferred_element_type=jnp.float32,
    )
    a_part = jnp.transpose(a_cols, (1, 0)).reshape(c.shape[0], k_dim, k_dim)
    b_part = (c * x) @ q.T                                   # (B, K)

    @pl.when(step == 0)
    def _init():
        a_ref[...] = jnp.zeros_like(a_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    a_ref[...] += a_part
    b_ref[...] += b_part


def accum(q, x, mask, *, alpha):
    """Pallas-tiled (A, b) accumulation over one (K, T) item tile.

    Args:
      q:    (K, T) float32 item factors, T % TK == 0.
      x:    (B, T) float32 implicit interactions.
      mask: (T,)   float32 item-column validity.
      alpha: python float, baked at lowering time (Table 3: alpha = 4).

    Returns:
      (A, b): (B, K, K) and (B, K) partial sums (no lambda*I).
    """
    k_dim, t_dim = q.shape
    b_dim = x.shape[0]
    tk = min(TK, t_dim)  # small tiles (tests) run as a single grid step
    assert t_dim % tk == 0, f"tile width {t_dim} not a multiple of {tk}"
    grid = (t_dim // tk,)

    return pl.pallas_call(
        functools.partial(_accum_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k_dim, tk), lambda i: (0, i)),     # Q tile
            pl.BlockSpec((b_dim, tk), lambda i: (0, i)),     # X tile
            pl.BlockSpec((tk,), lambda i: (i,)),             # mask tile
        ],
        out_specs=[
            pl.BlockSpec((b_dim, k_dim, k_dim), lambda i: (0, 0, 0)),
            pl.BlockSpec((b_dim, k_dim), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, k_dim, k_dim), jnp.float32),
            jax.ShapeDtypeStruct((b_dim, k_dim), jnp.float32),
        ],
        interpret=True,
    )(q, x, mask)
