"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness spec).

Every Pallas kernel in this package has an oracle here implementing the
same math directly from the paper's equations. pytest asserts allclose
between the two; the rust runtime's differential tests re-implement these
formulas a third time in rust (rust/src/runtime/reference.rs).

Paper: Khan et al., "A Payload Optimization Method for Federated
Recommender Systems", RecSys 2021. Equation numbers below refer to it.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ref_accum(q, x, mask, alpha):
    """Confidence-weighted Gram accumulation for the user solve (Eq. 3).

    Args:
      q:    (K, T) item-factor tile Q*.
      x:    (B, T) implicit interactions for a batch of users.
      mask: (T,)   1.0 for valid item columns, 0.0 for padding.
      alpha: implicit confidence weight, c_ij = 1 + alpha * x_ij (Eq. 2).

    Returns:
      A: (B, K, K) partial  Q C^i Q^T   (WITHOUT the lambda*I term)
      b: (B, K)    partial  Q C^i x_i
    """
    c = (1.0 + alpha * x) * mask[None, :]          # (B, T)
    a = jnp.einsum("kt,bt,jt->bkj", q, c, q)
    b = jnp.einsum("kt,bt->bk", q, c * x)
    return a, b


def ref_solve(a, b, lam, _cg_iters=None):
    """Batched exact solve of (A + lam I) p = b  (Eq. 3), via numpy."""
    k = a.shape[-1]
    lhs = np.asarray(a) + lam * np.eye(k, dtype=np.asarray(a).dtype)
    return np.linalg.solve(lhs, np.asarray(b)[..., None])[..., 0]


def ref_grad(p, q, x, mask, umask, alpha, lam):
    """Aggregated item-factor gradient over a user batch (Eq. 5-6).

    Per user i and item j:
      dJ_i/dq_j = -2 c_ij (x_ij - p_i^T q_j) p_i + 2 lam q_j
    The server aggregates the SUM over the contributing users (Eq. 4), so
    the lambda term appears once per (unmasked) user.

    Args:
      p:     (B, K) user factors for the batch.
      q:     (K, T) item-factor tile.
      x:     (B, T) interactions.
      mask:  (T,)   item-column validity.
      umask: (B,)   user-row validity (padding users contribute nothing).

    Returns:
      g: (K, T) sum over the batch of per-user gradients, zero on masked
         item columns.
    """
    s = p @ q                                       # (B, T) predicted
    c = 1.0 + alpha * x
    w = umask[:, None] * c * (x - s)                # (B, T)
    n_users = jnp.sum(umask)
    g = -2.0 * (p.T @ w) + 2.0 * lam * n_users * q  # (K, T)
    return g * mask[None, :]


def ref_scores(p, q):
    """Predicted affinities x* = p_i^T Q (Section 2.2). (B,K)x(K,T)->(B,T)."""
    return p @ q


def ref_adam(q, g, m, v, t, eta, beta1, beta2, eps):
    """Server-side Adam step on the item factors (Eq. 4 + Kingma & Ba).

    All of (q, g, m, v) are (K, T); t is the 1-based step count.
    Returns (q', m', v'). Oracle for the rust optimizer, used by pytest to
    pin the exact update the coordinator must apply.
    """
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    q2 = q - eta * mhat / (jnp.sqrt(vhat) + eps)
    return q2, m2, v2
