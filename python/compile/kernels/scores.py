"""L1 Pallas kernel: batched recommendation scores x* = p_i^T Q (Sec. 2.2).

Plain (B, K) @ (K, T) tile matmul used on the evaluation path (top-10 of a
100-item recommendation list). Kept as a Pallas kernel so the whole client
compute path lowers through the same machinery.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .accum import TK


def _scores_kernel(p_ref, q_ref, s_ref):
    s_ref[...] = jax.lax.dot_general(
        p_ref[...],
        q_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def scores(p, q):
    """(B, K) x (K, T) -> (B, T) predicted affinities, Pallas-tiled."""
    b_dim, k_dim = p.shape
    t_dim = q.shape[1]
    tk = min(TK, t_dim)  # small tiles (tests) run as a single grid step
    assert t_dim % tk == 0, f"tile width {t_dim} not a multiple of {tk}"
    grid = (t_dim // tk,)

    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_dim, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((k_dim, tk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b_dim, tk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b_dim, t_dim), jnp.float32),
        interpret=True,
    )(p, q)
