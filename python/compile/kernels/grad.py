"""L1 Pallas kernel: fused item-factor gradient tile (Eq. 5-6, batch-summed).

For a batch of B users and an item tile of width T, computes the SUM over
the batch of the per-user gradients the clients would transmit:

    g_j = sum_i umask_i * ( -2 c_ij (x_ij - p_i^T q_j) p_i + 2 lam q_j )

fused in one pass per tile: the predicted scores s = P Q_t, the weighted
residual w = c * (x - s), and the two matmuls feeding the MXU. P (B, K)
and umask (B,) stay VMEM-resident across the grid; (K, TK) q-slices and
(B, TK) x-slices stream through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .accum import TK


def _grad_kernel(p_ref, umask_ref, q_ref, x_ref, mask_ref, g_ref, *, alpha, lam):
    p = p_ref[...]                       # (B, K)
    u = umask_ref[...]                   # (B,)
    q = q_ref[...]                       # (K, TK)
    x = x_ref[...]                       # (B, TK)
    m = mask_ref[...]                    # (TK,)

    s = jax.lax.dot_general(             # (B, TK) predicted scores
        p, q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    c = 1.0 + alpha * x                  # Eq. 2 confidence
    w = u[:, None] * (c * (x - s))       # (B, TK) masked weighted residual
    n_users = jnp.sum(u)

    # Eq. 6 summed over users: -2 P^T W + 2 lam n_users Q
    g = -2.0 * jax.lax.dot_general(
        p, w, dimension_numbers=(((0,), (0,)), ((), ())),    # (K, TK)
        preferred_element_type=jnp.float32,
    ) + (2.0 * lam) * n_users * q
    g_ref[...] = g * m[None, :]


def grad(p, umask, q, x, mask, *, alpha, lam):
    """Pallas-tiled aggregated gradient over one (K, T) item tile.

    Args:
      p:     (B, K) user factors (output of the solve artifact).
      umask: (B,)   user-row validity (0 rows contribute nothing).
      q:     (K, T) item factors, T % TK == 0.
      x:     (B, T) interactions.
      mask:  (T,)   item-column validity.
      alpha, lam: python floats baked at lowering time (Table 3).

    Returns:
      g: (K, T) batch-summed gradient, zero on masked columns.
    """
    b_dim, k_dim = p.shape
    t_dim = q.shape[1]
    tk = min(TK, t_dim)  # small tiles (tests) run as a single grid step
    assert t_dim % tk == 0, f"tile width {t_dim} not a multiple of {tk}"
    grid = (t_dim // tk,)

    return pl.pallas_call(
        functools.partial(_grad_kernel, alpha=alpha, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_dim, k_dim), lambda i: (0, 0)),  # P (resident)
            pl.BlockSpec((b_dim,), lambda i: (0,)),          # umask (resident)
            pl.BlockSpec((k_dim, tk), lambda i: (0, i)),     # Q tile
            pl.BlockSpec((b_dim, tk), lambda i: (0, i)),     # X tile
            pl.BlockSpec((tk,), lambda i: (i,)),             # mask tile
        ],
        out_specs=pl.BlockSpec((k_dim, tk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k_dim, t_dim), jnp.float32),
        interpret=True,
    )(p, umask, q, x, mask)
