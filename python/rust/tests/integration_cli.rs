// placeholder — filled in later
