"""Adam artifact vs oracle, plus convergence sanity on a toy quadratic."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=1, max_value=50),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_adam_step_matches_ref(k, t, step, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(k, t)).astype(np.float32)
    g = rng.normal(size=(k, t)).astype(np.float32)
    m = rng.normal(scale=0.1, size=(k, t)).astype(np.float32)
    v = np.abs(rng.normal(scale=0.1, size=(k, t))).astype(np.float32)
    out = model.adam_step(q, g, m, v, np.float32(step))
    exp = ref.ref_adam(
        q, g, m, v, step, model.ETA, model.BETA1, model.BETA2, model.EPS
    )
    for got, want in zip(out, exp):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
        )


def test_adam_descends_quadratic():
    """Iterating the artifact's update on grad(0.5||q||^2)=q must shrink q."""
    q = np.full((2, 8), 5.0, np.float32)
    m = np.zeros_like(q)
    v = np.zeros_like(q)
    norms = [float(np.abs(q).max())]
    # Adam's bias-corrected step is ~eta per iteration on a normalized
    # gradient, so |q| decreases ~linearly from 5.0 at eta = 0.01.
    for t in range(1, 800):
        q, m, v = (np.asarray(z) for z in model.adam_step(q, q, m, v, np.float32(t)))
        norms.append(float(np.abs(q).max()))
    assert norms[-1] < 0.1 * norms[0], norms[-1]
