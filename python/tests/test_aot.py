"""AOT path smoke tests: artifacts lower, text is parseable HLO, manifest
agrees with model.py, and there are no CPU custom-calls the rust PJRT
loader cannot execute (the reason solve uses CG instead of LAPACK)."""

from __future__ import annotations

import os
import re

import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_every_artifact_lowers_to_hlo_text():
    for name, fn, example_args in model.artifact_specs():
        text = aot.to_hlo_text(fn, example_args)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_no_custom_calls_in_any_artifact():
    for name, fn, example_args in model.artifact_specs():
        text = aot.to_hlo_text(fn, example_args)
        assert "custom-call" not in text, (
            f"{name} lowered to a custom-call; the rust CPU PJRT loader "
            "cannot execute it"
        )


def test_artifact_names_unique_and_cover_tiles():
    names = [n for n, _, _ in model.artifact_specs()]
    assert len(names) == len(set(names))
    for t in model.TILES:
        for stem in ("accum", "grad", "scores", "adam"):
            assert f"{stem}_t{t}" in names
    assert "solve" in names


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_model():
    with open(os.path.join(ART_DIR, "manifest.txt")) as f:
        kv = dict(
            line.strip().split("=", 1)
            for line in f
            if "=" in line and not line.startswith("artifact=")
        )
    assert int(kv["B"]) == model.B
    assert int(kv["K"]) == model.K
    assert kv["tiles"] == ",".join(str(t) for t in model.TILES)
    assert float(kv["alpha"]) == model.ALPHA
    assert float(kv["lam"]) == model.LAM

    with open(os.path.join(ART_DIR, "manifest.txt")) as f:
        arts = [l for l in f if l.startswith("artifact=")]
    listed = {re.match(r"artifact=(\S+)", l).group(1) for l in arts}
    expected = {n for n, _, _ in model.artifact_specs()}
    assert listed == expected
    for n in expected:
        assert os.path.exists(os.path.join(ART_DIR, f"{n}.hlo.txt"))
