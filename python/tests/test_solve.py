"""L2 solve correctness: batched CG vs exact numpy solve (Eq. 3).

The CG solve is the one place we deviate from the obvious implementation
(jnp.linalg.solve) for PJRT-loadability reasons, so it gets its own
focused suite: random SPD systems, ill-conditioned systems, the
production path through client_accum, and the fused update artifact.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def random_spd_case(b_dim, k, scale, seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(scale=scale, size=(b_dim, k, 2 * k)).astype(np.float32)
    a = np.einsum("bij,bkj->bik", g, g)      # PSD; + lam I makes it SPD
    rhs = rng.normal(size=(b_dim, k)).astype(np.float32)
    return a, rhs


def test_solve_identity():
    b_dim, k = model.B, model.K
    a = np.zeros((b_dim, k, k), np.float32)  # (0 + lam I) p = b -> p = b/lam
    rhs = np.arange(b_dim * k, dtype=np.float32).reshape(b_dim, k)
    p = np.asarray(model.solve_p(a, rhs))
    np.testing.assert_allclose(p, rhs / model.LAM, rtol=1e-5, atol=1e-5)


def test_solve_production_path():
    """accum -> solve against the numpy exact solve, production geometry."""
    rng = np.random.default_rng(3)
    t = model.TILES[0]
    q = rng.normal(scale=0.3, size=(model.K, t)).astype(np.float32)
    x = (rng.random((model.B, t)) < 0.05).astype(np.float32)
    mask = np.ones(t, np.float32)
    a, b = model.client_accum(q, x, mask)
    p = np.asarray(model.solve_p(a, b))
    pr = ref.ref_solve(np.asarray(a), np.asarray(b), model.LAM)
    np.testing.assert_allclose(p, pr, rtol=1e-3, atol=1e-4)


def test_fused_update_equals_pipeline():
    rng = np.random.default_rng(4)
    t = model.TILES[0]
    q = rng.normal(scale=0.3, size=(model.K, t)).astype(np.float32)
    x = (rng.random((model.B, t)) < 0.1).astype(np.float32)
    mask = np.ones(t, np.float32)
    mask[300:] = 0.0
    fused = np.asarray(model.client_update(q, x, mask))
    a, b = model.client_accum(q, x, mask)
    staged = np.asarray(model.solve_p(a, b))
    np.testing.assert_allclose(fused, staged, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=16),
    st.integers(min_value=1, max_value=30),
    st.floats(min_value=0.05, max_value=3.0),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_solve_hypothesis_spd(b_dim, k, scale, seed):
    a, rhs = random_spd_case(b_dim, k, scale, seed)
    p = np.asarray(model.solve_p(a, rhs))
    pr = ref.ref_solve(a, rhs, model.LAM)
    # relative error in the residual metric — robust to conditioning
    denom = np.maximum(np.abs(pr).max(), 1e-3)
    assert np.abs(p - pr).max() / denom < 5e-3


def test_solve_ill_conditioned():
    """Many repeated interactions -> large eigenvalue spread; CG must hold."""
    rng = np.random.default_rng(9)
    k = model.K
    g = rng.normal(scale=5.0, size=(4, k, k)).astype(np.float32)
    a = np.einsum("bij,bkj->bik", g, g)
    rhs = rng.normal(size=(4, k)).astype(np.float32)
    p = np.asarray(model.solve_p(a, rhs))
    pr = ref.ref_solve(a, rhs, model.LAM)
    resid = np.einsum("bij,bj->bi", a + model.LAM * np.eye(k, dtype=np.float32), p) - rhs
    # residual must be tiny relative to the rhs scale
    assert np.abs(resid).max() < 1e-2 * max(1.0, np.abs(rhs).max()), np.abs(resid).max()
    assert np.abs(p - pr).max() < 5e-2 * max(1.0, np.abs(pr).max())
