"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

This is the CORE correctness signal for the compute layer: everything the
rust coordinator executes is lowered from exactly these functions.
Hypothesis sweeps shapes (B, K, T), sparsity and masks; fixed-seed cases
pin the production geometry (B=64, K=25, T in {512, 2048}).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.accum import TK, accum
from compile.kernels.grad import grad
from compile.kernels.scores import scores

RNG = np.random.default_rng(12345)


def make_case(b, k, t, density, rng=RNG):
    q = rng.normal(scale=0.3, size=(k, t)).astype(np.float32)
    x = (rng.random((b, t)) < density).astype(np.float32)
    mask = np.zeros(t, np.float32)
    valid = rng.integers(1, t + 1)
    mask[:valid] = 1.0
    umask = np.zeros(b, np.float32)
    uvalid = rng.integers(1, b + 1)
    umask[:uvalid] = 1.0
    p = rng.normal(scale=0.3, size=(b, k)).astype(np.float32)
    return q, x, mask, umask, p


# ---------------------------------------------------------------------------
# Fixed production-geometry cases


@pytest.mark.parametrize("t", list(model.TILES))
def test_accum_production_geometry(t):
    q, x, mask, _, _ = make_case(model.B, model.K, t, 0.05)
    a, b = accum(q, x, mask, alpha=model.ALPHA)
    ar, br = ref.ref_accum(q, x, mask, model.ALPHA)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("t", list(model.TILES))
def test_grad_production_geometry(t):
    q, x, mask, umask, p = make_case(model.B, model.K, t, 0.05)
    g = grad(p, umask, q, x, mask, alpha=model.ALPHA, lam=model.LAM)
    gr = ref.ref_grad(p, q, x, mask, umask, model.ALPHA, model.LAM)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("t", list(model.TILES))
def test_scores_production_geometry(t):
    q, _, _, _, p = make_case(model.B, model.K, t, 0.05)
    s = scores(p, q)
    np.testing.assert_allclose(np.asarray(s), p @ q, rtol=1e-5, atol=1e-5)


def test_accum_masked_columns_contribute_nothing():
    q, x, mask, _, _ = make_case(16, 8, 256, 0.2)
    mask[:] = 1.0
    mask[100:] = 0.0
    a1, b1 = accum(q, x, mask, alpha=model.ALPHA)
    # zero out the masked columns entirely: result must be identical
    q2, x2 = q.copy(), x.copy()
    q2[:, 100:] = 777.0
    x2[:, 100:] = 1.0
    a2, b2 = accum(q2, x2, mask, alpha=model.ALPHA)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=1e-5, atol=1e-4)


def test_grad_masked_users_contribute_nothing():
    q, x, mask, umask, p = make_case(16, 8, 256, 0.2)
    umask[:] = 1.0
    umask[5:] = 0.0
    g1 = grad(p, umask, q, x, mask, alpha=model.ALPHA, lam=model.LAM)
    p2, x2 = p.copy(), x.copy()
    p2[5:] = 123.0  # padding users: factors must not matter
    g2 = grad(p2, umask, q, x2, mask, alpha=model.ALPHA, lam=model.LAM)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-3)


def test_grad_matches_finite_difference():
    """Eq. 6 is the true gradient of Eq. 2 w.r.t. q_j — check numerically."""
    rng = np.random.default_rng(7)
    b_dim, k, t = 4, 5, 128
    q, x, mask, umask, p = make_case(b_dim, k, t, 0.3, rng)
    mask[:] = 1.0
    umask[:] = 1.0

    def loss(qm):
        s = p @ qm
        c = 1.0 + model.ALPHA * x
        se = np.sum(c * (x - s) ** 2)
        # per-user lambda penalty on q (appears once per user, Eq. 2 per i)
        reg = model.LAM * (b_dim * np.sum(qm**2) + np.sum(p**2))
        return se + reg

    g = np.asarray(grad(p, umask, q, x, mask, alpha=model.ALPHA, lam=model.LAM))
    eps = 1e-3
    for idx in [(0, 0), (2, 64), (4, 127), (1, 33)]:
        qp, qm_ = q.copy(), q.copy()
        qp[idx] += eps
        qm_[idx] -= eps
        fd = (loss(qp) - loss(qm_)) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2 * max(1.0, abs(fd)), (idx, fd, g[idx])


# ---------------------------------------------------------------------------
# Hypothesis shape/density sweeps (interpret-mode recompiles per shape —
# keep example counts modest).

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=32),          # B
    st.integers(min_value=2, max_value=31),          # K
    st.sampled_from([TK, 2 * TK, 4 * TK]),           # T
    st.floats(min_value=0.0, max_value=0.5),         # density
    st.integers(min_value=0, max_value=2**31 - 1),   # seed
)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_accum_hypothesis(case):
    b_dim, k, t, density, seed = case
    rng = np.random.default_rng(seed)
    q, x, mask, _, _ = make_case(b_dim, k, t, density, rng)
    a, b = accum(q, x, mask, alpha=model.ALPHA)
    ar, br = ref.ref_accum(q, x, mask, model.ALPHA)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(b), np.asarray(br), rtol=1e-4, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_grad_hypothesis(case):
    b_dim, k, t, density, seed = case
    rng = np.random.default_rng(seed)
    q, x, mask, umask, p = make_case(b_dim, k, t, density, rng)
    g = grad(p, umask, q, x, mask, alpha=model.ALPHA, lam=model.LAM)
    gr = ref.ref_grad(p, q, x, mask, umask, model.ALPHA, model.LAM)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(shape_strategy)
def test_scores_hypothesis(case):
    b_dim, k, t, density, seed = case
    rng = np.random.default_rng(seed)
    q, _, _, _, p = make_case(b_dim, k, t, density, rng)
    s = scores(p, q)
    np.testing.assert_allclose(np.asarray(s), p @ q, rtol=1e-4, atol=1e-4)
