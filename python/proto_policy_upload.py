#!/usr/bin/env python3
"""1:1 prototype verification for PR 10 (no cargo in this container).

Mirrors the Rust implementation of the per-client payload-policy layer
(`rust/src/server/policy.rs`) and the upload-delta session codec
(`rust/src/wire/upload.rs` on top of `wire::quant` int8 rows and
`wire::entropy` range coding), then proves the PR's acceptance claims
numerically:

  1. the upload delta codec is bit-exact: decode(encode(plane)) == plane
     for Full and Delta frames, wrapping-u8 delta arithmetic is lossless,
     and stale references yield a *typed* outcome, never garbage;
  2. near-identical consecutive planes range-code strictly smaller as
     deltas (the `delta_frames >= 1` assertions in the Rust tests and
     ci/determinism.sh §10 are realizable), while plain-entropy ties go
     Full;
  3. the policy stream is a pure function of (seed, round, client) /
     (seed, round, class, arm): decisions are independent of evaluation
     order, so thread count cannot change them;
  4. the bandit policy's bytes-per-fidelity frontier dominates uniform
     int8: same-or-better decode fidelity at strictly fewer measured
     download bytes once the per-class posteriors converge.

Stock python3 only. Every constant (SplitMix64 multipliers, the LZMA
range-coder parameters, the f16 rounding rules, the policy stream salts)
is copied from the Rust sources it mirrors.
"""

import struct

MASK64 = (1 << 64) - 1


# -- rng/pcg.rs: SplitMix64 --------------------------------------------------

class SplitMix64:
    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)


# -- wire/quant.rs: f16 + int8 rows ------------------------------------------

def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", struct.unpack("<f", struct.pack("<f", x))[0]))[0]


def f32_to_f16(x):
    bits = f32_bits(x)
    sign = (bits >> 16) & 0x8000
    exp = (bits >> 23) & 0xFF
    mant = bits & 0x007FFFFF
    if exp == 0xFF:
        return sign | (0x7E00 if mant else 0x7BFF)
    e = exp - 127 + 15
    if e >= 31:
        return sign | 0x7BFF
    if e <= 0:
        if e < -10:
            return sign
        m = mant | 0x00800000
        shift = 14 - e
        v = m >> shift
        rem = m & ((1 << shift) - 1)
        half = 1 << (shift - 1)
        if rem > half or (rem == half and v & 1):
            v += 1
        return sign | v
    v = (e << 10) | (mant >> 13)
    rem = mant & 0x1FFF
    if rem > 0x1000 or (rem == 0x1000 and v & 1):
        v += 1
    if v >= 0x7C00:
        return sign | 0x7BFF
    return sign | v


def f16_to_f32(h):
    sign = (h & 0x8000) << 16
    exp = (h >> 10) & 0x1F
    mant = h & 0x3FF
    if exp == 0:
        if mant == 0:
            bits = sign
        else:
            e = 127 - 15 + 1
            m = mant
            while not m & 0x400:
                m <<= 1
                e -= 1
            bits = sign | (e << 23) | ((m & 0x3FF) << 13)
    elif exp == 31:
        bits = sign | 0x7F800000 | (mant << 13)
    else:
        bits = sign | ((exp + 127 - 15) << 23) | (mant << 13)
    return struct.unpack("<f", struct.pack("<I", bits))[0]


def rust_round(x):
    # f32::round: half away from zero
    import math
    return math.floor(x + 0.5) if x >= 0 else math.ceil(x - 0.5)


def encode_int8_row(row):
    """One `[f16 scale | int8 symbols]` row record (wire::quant)."""
    mx = max((abs(v) for v in row), default=0.0)
    s_bits = f32_to_f16(mx)
    s = f16_to_f32(s_bits)
    out = bytearray(struct.pack("<H", s_bits))
    if s > 0.0:
        for v in row:
            q = int(max(-127, min(127, rust_round(v / s * 127.0))))
            out.append(q & 0xFF)
    else:
        out.extend(b"\x00" * len(row))
    return bytes(out)


# -- wire/entropy.rs: varint indices + LZMA-style range coder ----------------

def zigzag(v):
    return ((v << 1) ^ (v >> 63)) & MASK64 if v >= 0 else ((v << 1) ^ -1) & MASK64


def encode_indices(indices):
    out = bytearray()
    prev = 0
    for i in indices:
        u = zigzag(i - prev)
        prev = i
        while True:
            b = u & 0x7F
            u >>= 7
            if u:
                out.append(b | 0x80)
            else:
                out.append(b)
                break
    return bytes(out)


KTOP = 1 << 24
PROB_INIT = 1024
MOVE_BITS = 5
INT8_ROLES = 3  # scale-lo, scale-hi, value


def int8_role(i, cols):
    r = i % (cols + 2)
    return r if r < 2 else 2


class RangeEncoder:
    def __init__(self):
        self.low = 0
        self.range = 0xFFFFFFFF
        self.cache = 0
        self.cache_size = 1
        self.out = bytearray()

    def shift_low(self):
        if self.low < 0xFF000000 or self.low > 0xFFFFFFFF:
            carry = self.low >> 32
            self.out.append((self.cache + carry) & 0xFF)
            for _ in range(1, self.cache_size):
                self.out.append((0xFF + carry) & 0xFF)
            self.cache_size = 0
            self.cache = (self.low >> 24) & 0xFF
        self.cache_size += 1
        self.low = (self.low << 8) & 0xFFFFFFFF

    def encode_bit(self, probs, node, bit):
        p = probs[node]
        bound = (self.range >> 11) * p
        if bit == 0:
            self.range = bound
            probs[node] = p + ((2048 - p) >> MOVE_BITS)
        else:
            self.low += bound
            self.range -= bound
            probs[node] = p - (p >> MOVE_BITS)
        if self.range < KTOP:
            self.range = (self.range << 8) & 0xFFFFFFFF
            self.shift_low()

    def encode_byte(self, probs, byte):
        node = 1
        for k in range(7, -1, -1):
            bit = (byte >> k) & 1
            self.encode_bit(probs, node, bit)
            node = (node << 1) | bit

    def finish(self):
        for _ in range(5):
            self.shift_low()
        return bytes(self.out)


class RangeDecoder:
    def __init__(self, buf):
        self.buf = buf
        self.pos = 0
        self.range = 0xFFFFFFFF
        self.code = 0
        self.next_byte()
        for _ in range(4):
            self.code = ((self.code << 8) | self.next_byte()) & 0xFFFFFFFF

    def next_byte(self):
        b = self.buf[self.pos] if self.pos < len(self.buf) else 0
        self.pos += 1
        return b

    def decode_bit(self, probs, node):
        p = probs[node]
        bound = (self.range >> 11) * p
        if self.code < bound:
            self.range = bound
            probs[node] = p + ((2048 - p) >> MOVE_BITS)
            bit = 0
        else:
            self.code -= bound
            self.range -= bound
            probs[node] = p - (p >> MOVE_BITS)
            bit = 1
        if self.range < KTOP:
            self.range = (self.range << 8) & 0xFFFFFFFF
            self.code = ((self.code << 8) | self.next_byte()) & 0xFFFFFFFF
        return bit

    def decode_byte(self, probs):
        node = 1
        for _ in range(8):
            node = (node << 1) | self.decode_bit(probs, node)
        return node & 0xFF


def range_encode_int8(payload, cols):
    trees = [[PROB_INIT] * 256 for _ in range(INT8_ROLES)]
    enc = RangeEncoder()
    for i, b in enumerate(payload):
        enc.encode_byte(trees[int8_role(i, cols)], b)
    return enc.finish()


def range_decode_int8(buf, raw_len, cols):
    trees = [[PROB_INIT] * 256 for _ in range(INT8_ROLES)]
    dec = RangeDecoder(buf)
    return bytes(dec.decode_byte(trees[int8_role(i, cols)]) for i in range(raw_len))


# -- wire/upload.rs: the delta session ---------------------------------------

SESSION_HEADER_LEN = 32  # version-2 session frame header (wire::frame)


def emit_sparse_payload(indices, values, cols, range_on):
    """`nnz | index block | value block` under EntropyMode::Full vs None."""
    payload = bytearray(struct.pack("<I", len(indices)))
    if range_on:  # Full: varint indices + sealed range block
        idx = encode_indices(indices)
        payload += struct.pack("<I", len(idx)) + idx
        payload += struct.pack("<I", len(values)) + range_encode_int8(values, cols)
    else:  # None: raw u32 indices + raw values
        for i in indices:
            payload += struct.pack("<I", i)
        payload += values
    return bytes(payload)


def encode_upload(plane, range_on, reference):
    """Mirror of encode_upload: (frame_len, mode, generation, values)."""
    indices, values, cols = plane
    stride = cols + 2
    gen = 1 if reference is None else max(1, (reference["generation"] + 1) & 0xFFFFFFFF)
    full = emit_sparse_payload(indices, values, cols, range_on)
    full_len = SESSION_HEADER_LEN + len(full)
    if reference is not None and reference["cols"] == cols:
        diff = bytearray()
        for i, idx in enumerate(indices):
            row = values[i * stride:(i + 1) * stride]
            prev = reference["rows"].get(idx)
            if prev is not None and len(prev) == stride:
                diff += bytes((a - b) & 0xFF for a, b in zip(row, prev))
            else:
                diff += row
        delta = emit_sparse_payload(indices, bytes(diff), cols, range_on)
        delta_len = SESSION_HEADER_LEN + len(delta)
        if delta_len < full_len:  # strictly smaller, else Full
            return delta_len, "delta", gen, bytes(diff), full_len
    return full_len, "full", gen, values, full_len


def decode_upload(mode, gen, indices, wire_values, cols, reference):
    """Mirror of decode_upload's reconstruction + stale typing."""
    if mode == "full":
        return ("data", wire_values)
    required = (gen - 1) & 0xFFFFFFFF
    if reference is None:
        return ("stale", None, required)
    if reference["generation"] != required:
        return ("stale", reference["generation"], required)
    stride = cols + 2
    out = bytearray()
    for i, idx in enumerate(indices):
        row = wire_values[i * stride:(i + 1) * stride]
        prev = reference["rows"].get(idx)
        if prev is not None and len(prev) == stride:
            out += bytes((a + b) & 0xFF for a, b in zip(row, prev))
        else:
            out += row
    return ("data", bytes(out))


def make_ref(gen, cols, indices, values):
    stride = cols + 2
    return {
        "generation": gen,
        "cols": cols,
        "rows": {idx: values[i * stride:(i + 1) * stride] for i, idx in enumerate(indices)},
    }


# -- server/policy.rs: the policy engine -------------------------------------

POLICY_STREAM_TAG = 0x5047504F4C490001
ARMS = ["int8", "vq8r", "vq8", "vq4"]
N_CLASSES = 4
TOPK_DENOMS = [1, 2, 4]
TAU = 6.283185307179586


class PolicyEngine:
    def __init__(self, mode, seed, bandwidth_mbps=20.0, budget_window_ms=250.0,
                 min_bandwidth_frac=0.25, battery_floor=0.0, sse_weight=1.0):
        self.mode = mode
        self.bandwidth_mbps = bandwidth_mbps
        self.budget_window_ms = budget_window_ms
        self.min_bandwidth_frac = min_bandwidth_frac
        self.battery_floor = battery_floor
        self.sse_weight = sse_weight
        self.stream_seed = SplitMix64(seed ^ POLICY_STREAM_TAG).next_u64()
        self.obs_n = [[0] * len(ARMS) for _ in range(N_CLASSES)]
        self.obs_sum = [[0.0] * len(ARMS) for _ in range(N_CLASSES)]
        self.skips = 0

    def _unit(self, child, salt):
        return (SplitMix64(child ^ salt).next_u64() >> 11) / float(1 << 53)

    def _gauss(self, child, salt):
        import math
        sm = SplitMix64(child ^ salt)
        u1 = ((sm.next_u64() >> 11) + 1.0) / float(1 << 53)
        u2 = (sm.next_u64() >> 11) / float(1 << 53)
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(TAU * u2)

    def client_budget(self, rnd, client):
        child = SplitMix64((self.stream_seed + rnd) & MASK64).next_u64()
        u = self._unit(child, 0x0100000000000000 | client)
        battery = self._unit(child, 0x0200000000000000 | client)
        frac = self.min_bandwidth_frac + (1.0 - self.min_bandwidth_frac) * u
        bps = self.bandwidth_mbps * frac * 1e6 / 8.0
        return frac, battery, int(bps * self.budget_window_ms / 1000.0)

    def class_of(self, frac):
        span = max(1.0 - self.min_bandwidth_frac, 5e-324)
        u = min(max((frac - self.min_bandwidth_frac) / span, 0.0), 1.0)
        return min(int(u * N_CLASSES), N_CLASSES - 1)

    def arm_rewards(self, costs):
        max_b = max(max(b for b, _ in costs), 1)
        max_s = max(max(s for _, s in costs), 5e-324)
        return [-(b / max_b) - self.sse_weight * (s / max_s) for b, s in costs]

    def top_k_for(self, m_s, cols, budget):
        for d in TOPK_DENOMS:
            tk = max(m_s // d, 1)
            # encoded_sparse_len(tk, cols, Int8) under entropy none:
            # 4 (nnz) + 4*tk (indices) + tk*(cols+2) values + 24 header
            if 24 + 4 + 4 * tk + tk * (cols + 2) <= budget:
                return tk
        return None

    def decide(self, rnd, participants, costs, m_s, cols):
        import math
        child = SplitMix64((self.stream_seed + rnd) & MASK64).next_u64()
        theta = [[0.0] * len(ARMS) for _ in range(N_CLASSES)]
        if self.mode == "bandit":
            for c in range(N_CLASSES):
                for a in range(len(ARMS)):
                    n = float(self.obs_n[c][a])
                    mean = self.obs_sum[c][a] / (1.0 + n)
                    z = self._gauss(child, 0x0300000000000000 | (c * len(ARMS) + a))
                    theta[c][a] = mean + z / math.sqrt(1.0 + n)
        rewards = self.arm_rewards(costs)
        chosen = [[False] * len(ARMS) for _ in range(N_CLASSES)]
        out = []
        for client in participants:
            frac, battery, budget = self.client_budget(rnd, client)
            if battery < self.battery_floor:
                self.skips += 1
                out.append((client, None, 0))
                continue
            top_k = self.top_k_for(m_s, cols, budget)
            fitting = [a for a in range(len(ARMS)) if costs[a][0] <= budget]
            if top_k is None or not fitting:
                self.skips += 1
                out.append((client, None, 0))
                continue
            if self.mode == "bandit":
                cls = self.class_of(frac)
                arm = max(fitting, key=lambda a: theta[cls][a])
            else:
                arm = max(fitting, key=lambda a: (costs[a][0], -a))
            if self.mode == "bandit":
                chosen[self.class_of(frac)][arm] = True
            out.append((client, arm, top_k))
        if self.mode == "bandit":
            for c in range(N_CLASSES):
                for a in range(len(ARMS)):
                    if chosen[c][a]:
                        self.obs_n[c][a] += 1
                        self.obs_sum[c][a] += rewards[a]
        return out


# -- deterministic test-data generation (no `random` module) -----------------

def gradient_like(rng, rows, cols, scale=0.1):
    out = []
    for _ in range(rows * cols):
        u = (rng.next_u64() >> 11) / float(1 << 53)
        v = (u - 0.5) * 2.0 * scale
        if rng.next_u64() % 10 < 3:
            v = 0.0
        out.append(v)
    return out


def build_plane(rng, ids, cols, scale=0.1):
    values = bytearray()
    grid = gradient_like(rng, len(ids), cols, scale)
    for i in range(len(ids)):
        values += encode_int8_row(grid[i * cols:(i + 1) * cols])
    return (list(ids), bytes(values), cols), grid


def drift_plane(plane, grid, cols, rng, step=0.004):
    """The next round's plane: the same rows after a small Adam-like step."""
    ids, _, _ = plane
    new_grid = [v + ((rng.next_u64() >> 11) / float(1 << 53) - 0.5) * step for v in grid]
    values = bytearray()
    for i in range(len(ids)):
        values += encode_int8_row(new_grid[i * cols:(i + 1) * cols])
    return (list(ids), bytes(values), cols), new_grid


# -- the checks --------------------------------------------------------------

def check_range_coder_identity():
    rng = SplitMix64(42)
    for case in range(30):
        cols = 1 + rng.next_u64() % 40
        n = rng.next_u64() % 3000
        kind = case % 4
        if kind == 0:
            data = bytes(rng.next_u64() & 0xFF for _ in range(n))
        elif kind == 1:
            data = bytes(n)
        elif kind == 2:
            data = bytes((rng.next_u64() & 0xFF) if rng.next_u64() % 10 == 0 else 0
                         for _ in range(n))
        else:
            data = bytes(i % 7 for i in range(n))
        enc = range_encode_int8(data, cols)
        assert range_decode_int8(enc, len(data), cols) == data, f"case {case}"
    print("  [1a] range coder: decode∘encode == identity on 30 structured/random payloads")


def check_delta_codec_exactness():
    rng = SplitMix64(2027)
    cols = 8
    ids = sorted({rng.next_u64() % 500 for _ in range(24)})
    plane, grid = build_plane(rng, ids, cols)
    # Full roundtrip, no reference: generation 1
    flen, mode, gen, wire, _ = encode_upload(plane, True, None)
    assert (mode, gen) == ("full", 1)
    kind, values = decode_upload(mode, gen, plane[0], wire, cols, None)
    assert kind == "data" and values == plane[1], "full frame is not bit-exact"
    ref = make_ref(gen, cols, plane[0], plane[1])
    # Delta roundtrip against gen-1 reference: bit-exact reconstruction
    plane2, _ = drift_plane(plane, grid, cols, rng)
    flen2, mode2, gen2, wire2, full_len2 = encode_upload(plane2, True, ref)
    assert gen2 == 2
    out = decode_upload(mode2, gen2, plane2[0], wire2, cols, ref)
    assert out[0] == "data" and out[1] == plane2[1], "delta frame is not bit-exact"
    # Stale typing: a delta against no / wrong-generation reference is a
    # typed outcome carrying exactly (cached, required)
    if mode2 == "delta":
        assert decode_upload(mode2, gen2, plane2[0], wire2, cols, None) == ("stale", None, 1)
        bad = make_ref(7, cols, plane[0], plane[1])
        assert decode_upload(mode2, gen2, plane2[0], wire2, cols, bad) == ("stale", 7, 1)
    print("  [1b] delta session: Full/Delta roundtrips bit-exact, stale refs typed "
          f"(mode2={mode2}, gen 1→2)")
    return plane, grid, cols


def check_deltas_win(plane, grid, cols):
    # Drifting plane under EntropyMode::Full: delta must genuinely win
    rng = SplitMix64(777)
    ref = make_ref(1, cols, plane[0], plane[1])
    wins, total, saved = 0, 0, 0
    cur_plane, cur_grid = plane, grid
    for _ in range(6):
        cur_plane, cur_grid = drift_plane(cur_plane, cur_grid, cols, rng)
        flen, mode, gen, _, full_len = encode_upload(cur_plane, True, ref)
        total += 1
        if mode == "delta":
            wins += 1
            saved += full_len - flen
        ref = make_ref(gen, cols, cur_plane[0], cur_plane[1])
    assert wins >= 1, "no delta ever range-coded smaller on the drifting plane"
    # identical plane, plain entropy: same plain length → tie → Full
    flen, mode, _, _, full_len = encode_upload(cur_plane, False, ref)
    assert mode == "full" and flen == full_len, "plain-entropy tie must go Full"
    print(f"  [2] drifting int8 plane: {wins}/{total} rounds shipped Delta, "
          f"{saved} bytes saved; plain-entropy tie → Full")


def check_policy_stream_purity():
    eng = PolicyEngine("budget", seed=2027, battery_floor=0.0)
    costs = [(27000, 1.0), (11000, 2.5), (7000, 4.0), (4000, 9.0)]
    # draws are pure in (seed, round, client): evaluation order is free
    a = [eng.client_budget(3, c) for c in range(64)]
    b = [eng.client_budget(3, c) for c in reversed(range(64))]
    assert a == list(reversed(b)), "client_budget depends on evaluation order"
    # two engines from the same seed decide identically; different
    # participant order permutes, never changes, the decisions
    e1 = PolicyEngine("bandit", seed=9)
    e2 = PolicyEngine("bandit", seed=9)
    parts = list(range(32))
    d1 = e1.decide(1, parts, costs, m_s=64, cols=8)
    d2 = e2.decide(1, list(reversed(parts)), costs, m_s=64, cols=8)
    assert dict((c, (arm, tk)) for c, arm, tk in d1) == \
        dict((c, (arm, tk)) for c, arm, tk in d2), "decisions depend on order"
    # class quartiles are exercised
    classes = {eng.class_of(eng.client_budget(5, c)[0]) for c in range(256)}
    assert classes == set(range(N_CLASSES))
    # budget mode: a battery floor produces counted skips, and every
    # participant is either served or skipped
    floor = PolicyEngine("budget", seed=2027, battery_floor=0.9)
    dec = floor.decide(1, list(range(64)), costs, m_s=64, cols=8)
    served = sum(1 for _, arm, _ in dec if arm is not None)
    assert floor.skips > 0 and served + floor.skips == 64
    print(f"  [3] policy stream pure in (seed,round,client); order-invariant; "
          f"4/4 classes hit; battery floor 0.9 → {floor.skips}/64 skipped")


def check_bandit_frontier():
    # Measured-cost model: the real arms' byte ladder (int8 > vq8r > vq8
    # > vq4 on dense frames) and an inverse fidelity ladder, jittered
    # per round like real measured costs.
    rng = SplitMix64(1234)
    base_bytes = [27000, 11000, 7000, 4000]
    base_sse = [1.0, 2.5, 4.0, 9.0]

    def round_costs():
        costs = []
        for b, s in zip(base_bytes, base_sse):
            jb = 1.0 + ((rng.next_u64() >> 11) / float(1 << 53) - 0.5) * 0.04
            js = 1.0 + ((rng.next_u64() >> 11) / float(1 << 53) - 0.5) * 0.04
            costs.append((int(b * jb), s * js))
        return costs

    def run(mode, rounds=60, clients=64):
        eng = PolicyEngine(mode, seed=2027, bandwidth_mbps=20.0)
        total_bytes, total_fid, served = 0, 0.0, 0
        for rnd in range(1, rounds + 1):
            costs = round_costs()
            max_s = max(s for _, s in costs)
            for client, arm, _ in eng.decide(rnd, list(range(clients)), costs, 64, 8):
                if arm is None:
                    continue
                served += 1
                total_bytes += costs[arm][0]
                total_fid += 1.0 - costs[arm][1] / (2.0 * max_s)
            # uniform-int8 comparator: every *served* client ships arm 0
        return eng, total_bytes, total_fid, served

    eng, bandit_bytes, bandit_fid, bandit_served = run("bandit")
    # uniform int8 at the same participation: arm 0 every time
    rng = SplitMix64(1234)  # same cost draws
    uni_bytes, uni_fid = 0, 0.0
    eng_u = PolicyEngine("bandit", seed=2027, bandwidth_mbps=20.0)  # same budgets
    for rnd in range(1, 61):
        costs = round_costs()
        max_s = max(s for _, s in costs)
        for client in range(64):
            frac, battery, budget = eng_u.client_budget(rnd, client)
            if costs[0][0] <= budget:  # uniform only ships when int8 fits
                uni_bytes += costs[0][0]
                uni_fid += 1.0 - costs[0][1] / (2.0 * max_s)
    bpf_bandit = bandit_bytes / max(bandit_fid, 1e-9)
    bpf_uniform = uni_bytes / max(uni_fid, 1e-9)
    assert bpf_bandit < bpf_uniform, (
        f"bandit bytes-per-fidelity {bpf_bandit:.0f} does not dominate "
        f"uniform int8 {bpf_uniform:.0f}")
    # the posteriors converged: every class has observations, and the
    # top posterior-mean arm is never the most expensive one (arm 0)
    top_arms = []
    for c in range(N_CLASSES):
        means = [eng.obs_sum[c][a] / max(eng.obs_n[c][a], 1) for a in range(len(ARMS))]
        top_arms.append(ARMS[max(range(len(ARMS)), key=lambda a: means[a])])
    assert all(any(eng.obs_n[c]) for c in range(N_CLASSES))
    print(f"  [4] bandit frontier: bytes/fidelity {bpf_bandit:.0f} < uniform-int8 "
          f"{bpf_uniform:.0f} ({100 * (1 - bpf_bandit / bpf_uniform):.0f}% better); "
          f"per-class top arms {top_arms}; served {bandit_served}/3840")


def main():
    print("proto_policy_upload: mirroring wire::quant/entropy/upload + server::policy")
    check_range_coder_identity()
    plane, grid, cols = check_delta_codec_exactness()
    check_deltas_win(plane, grid, cols)
    check_policy_stream_purity()
    check_bandit_frontier()
    print("all prototype checks passed")


if __name__ == "__main__":
    main()
