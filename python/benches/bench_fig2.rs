fn main() {}
