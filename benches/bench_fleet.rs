//! Fleet-scale memory/throughput bench: clients × threads → rounds/sec,
//! peak RSS and bytes/round for arena-backed fleets up to Θ = 10^6
//! clients under `fleet.theta_sample` participant sampling.
//!
//! Three claims this target proves every run (ISSUE 8):
//!
//! 1. **Flat per-client memory.** The fixed per-client state (arena
//!    offsets + factor-slot map + download-generation map) is a few
//!    dozen bytes per client, independent of fleet size. The exact byte
//!    counts are deterministic — pure functions of the synthetic layout —
//!    so they ship as gated `frame_bytes` rows against
//!    `ci/BENCH_fleet_baseline.json`.
//! 2. **Round cost scales with participants, not fleet size.** Each
//!    round touches theta_sample clients; a 100× larger fleet changes
//!    rounds/sec only marginally (the rows record the curve).
//! 3. **Sampled runs are thread-count invariant.** A theta_sample run at
//!    threads = 1 and threads = 4 produces byte-identical round dumps,
//!    decision-trace digests and journal files; the bench asserts this
//!    inline and aborts (failing CI) on any divergence.
//!
//! Clients are generated directly as sorted id rows — NOT through
//! `data::synthetic::generate`, whose planted-factor scoring is
//! O(users × items) and would dwarf everything else at 10^6 users.
//! Throughput numbers and VmHWM ride in the JSON un-gated (wall-clock
//! facts); only the deterministic byte columns gate.

use fedpayload::config::RunConfig;
use fedpayload::data::{Interactions, Split};
use fedpayload::server::{round_dump_string, Trainer};
use fedpayload::telemetry::trace::trace_digest;
use fedpayload::telemetry::{bench, TraceLevel, Tracer};

/// Catalog size — small enough that per-round solve cost is dominated by
/// the participant batch math, as in the paper's payload-limited regime.
const ITEMS: usize = 256;
/// Train interactions per client. Offsets j*31 are distinct mod 256, so
/// every client gets exactly 8 sorted-unique train items.
const TRAIN_PER_CLIENT: usize = 8;
/// Test interactions per client (offsets 7, 38 — never collide with the
/// train offsets {0, 31, 62, ..., 217}).
const TEST_PER_CLIENT: usize = 2;

/// Deterministic fleet layout: client `c` trains on items
/// `(c + j·31) mod 256` and holds out `(c + 7) mod 256`, `(c + 38) mod
/// 256`. Exact nnz counts (8n train, 2n test) make the arena byte
/// totals hand-computable for the committed baseline.
fn synth_split(clients: usize) -> Split {
    let mut train_pairs = Vec::with_capacity(clients * TRAIN_PER_CLIENT);
    let mut test_pairs = Vec::with_capacity(clients * TEST_PER_CLIENT);
    for c in 0..clients {
        for j in 0..TRAIN_PER_CLIENT {
            train_pairs.push((c as u32, ((c + j * 31) % ITEMS) as u32));
        }
        for j in 0..TEST_PER_CLIENT {
            test_pairs.push((c as u32, ((c + 7 + j * 31) % ITEMS) as u32));
        }
    }
    Split {
        train: Interactions::from_pairs(clients, ITEMS, train_pairs).unwrap(),
        test: Interactions::from_pairs(clients, ITEMS, test_pairs).unwrap(),
    }
}

/// Sampled-fleet config: Θ budget 512, theta_sample 256 → 4 batches of
/// B = 64 per round, enough for a threads = 4 leg to race all lanes.
fn fleet_cfg(clients: usize, threads: usize) -> RunConfig {
    let mut cfg = RunConfig::paper_defaults();
    cfg.dataset.name = "synthetic".into();
    cfg.dataset.users = clients;
    cfg.dataset.items = ITEMS;
    cfg.dataset.interactions = clients * (TRAIN_PER_CLIENT + TEST_PER_CLIENT);
    cfg.train.theta = 512;
    cfg.fleet.theta_sample = Some(256);
    cfg.train.payload_fraction = 0.25;
    cfg.train.iterations = 4;
    cfg.train.eval_every = 1_000_000; // timing stays on the compute path
    cfg.runtime.backend = "reference".into();
    cfg.runtime.threads = threads;
    cfg
}

/// Peak resident set (VmHWM) in kB from /proc/self/status; 0 when the
/// platform does not expose it. Monotonic over the process lifetime —
/// a wall-clock-style fact, never gated.
fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines().find(|l| l.starts_with("VmHWM:")).and_then(|l| {
                l.split_whitespace().nth(1).and_then(|v| v.parse().ok())
            })
        })
        .unwrap_or(0)
}

/// The deterministic fixed per-client bytes: the arena's four buffers
/// plus the factor-slot and download-generation maps (4 bytes each per
/// client). Excludes `factor_data`, which grows with *participants*.
fn fixed_state_bytes(tr: &Trainer) -> usize {
    tr.fleet().view().arena().heap_bytes() + tr.fleet().len() * 2 * std::mem::size_of::<u32>()
}

/// Sampled t1-vs-t4 identity: byte-equal dumps, digests and journals.
fn assert_sampled_thread_invariance(dir: &std::path::Path) {
    let split = synth_split(10_000);
    let mut artifacts: Vec<(String, String, Vec<u8>)> = Vec::new();
    for threads in [1usize, 4] {
        let mut cfg = fleet_cfg(10_000, threads);
        cfg.train.eval_every = 2; // identity must cover the eval path too
        let jpath = dir.join(format!("fleet_t{threads}.jsonl"));
        cfg.journal.path = Some(jpath.to_string_lossy().into_owned());
        let mut tr = Trainer::with_split(&cfg, split.clone()).unwrap();
        tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
        let report = tr.run().unwrap();
        let trace = tr.tracer().unwrap().lines().join("\n");
        drop(tr); // flush the journal
        artifacts.push((
            round_dump_string(&report),
            trace_digest(&trace),
            std::fs::read(&jpath).unwrap(),
        ));
    }
    let (d1, g1, j1) = &artifacts[0];
    let (d4, g4, j4) = &artifacts[1];
    assert_eq!(d1, d4, "sampled round dumps diverge between t1 and t4");
    assert_eq!(g1, g4, "sampled trace digests diverge between t1 and t4");
    assert_eq!(j1, j4, "sampled journal bytes diverge between t1 and t4");
    println!("identity: sampled t1 == t4 (dumps, digests, journal bytes)");
}

fn main() {
    let tmp = std::env::temp_dir().join(format!("fedpayload_bench_fleet_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    println!("=== fleet scaling (theta_sample=256 of theta=512, m_s=64, reference backend) ===");
    assert_sampled_thread_invariance(&tmp);

    let mut rows: Vec<String> = Vec::new();
    for clients in [10_000usize, 100_000, 1_000_000] {
        let split = synth_split(clients);
        for threads in [1usize, 4] {
            let cfg = fleet_cfg(clients, threads);
            let mut trainer = Trainer::with_split(&cfg, split.clone()).unwrap();
            trainer.round().unwrap(); // warm the pool + allocator
            let r = bench(&format!("fleet_round_c{clients}_t{threads}"), || {
                trainer.round().unwrap()
            });
            // bytes/round: diff the ledger around one more round (the
            // bench harness's own warm-up iterations make a totals/rounds
            // quotient unreliable)
            let before = trainer.ledger().total_bytes();
            trainer.round().unwrap();
            let bytes_per_round = trainer.ledger().total_bytes() - before;
            rows.push(format!(
                "    {{\"name\": \"fleet_round_c{clients}_t{threads}\", \"clients\": {clients}, \
                 \"threads\": {threads}, \"mean_ns\": {:.0}, \"p50_ns\": {:.0}, \
                 \"rounds_per_sec\": {:.2}, \"bytes_per_round\": {bytes_per_round}, \
                 \"vm_hwm_kb\": {}}}",
                r.mean_ns,
                r.p50_ns,
                1e9 / r.mean_ns,
                peak_rss_kb()
            ));
            if threads == 1 {
                // flat-memory gate row: deterministic fixed bytes, and the
                // documented ceiling of 64 fixed bytes per client
                let fixed = fixed_state_bytes(&trainer);
                let per_client = fixed as f64 / clients as f64;
                assert!(
                    per_client <= 64.0,
                    "fixed per-client state {per_client:.1} B exceeds the 64 B budget"
                );
                // factor data grows with participants, never with fleet
                // size: slots ≤ rounds × theta_sample (rounds recovered
                // exactly from the ledger — every round downloads to
                // exactly 256 participants; the bench harness's warm-up
                // iterations are invisible in `r.iters`)
                let rounds = (trainer.ledger().down_msgs / 256) as usize;
                assert!(
                    trainer.fleet().participated_clients() <= rounds * 256,
                    "participant slots exceeded rounds x theta_sample"
                );
                println!(
                    "memory: c={clients} fixed={fixed} B ({per_client:.1} B/client), \
                     participated={} of {clients}, VmHWM={} kB",
                    trainer.fleet().participated_clients(),
                    peak_rss_kb()
                );
                rows.push(format!(
                    "    {{\"name\": \"fleet_mem_fixed_c{clients}\", \"clients\": {clients}, \
                     \"frame_bytes\": {fixed}, \"per_client_bytes\": {per_client:.2}, \
                     \"vm_hwm_kb\": {}}}",
                    peak_rss_kb()
                ));
            }
        }
    }

    let mut json = String::from("{\n  \"bench\": \"fleet_scale\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"items\": {ITEMS}, \"train_per_client\": {TRAIN_PER_CLIENT}, \
         \"test_per_client\": {TEST_PER_CLIENT}, \"theta\": 512, \"theta_sample\": 256, \
         \"m_s\": 64, \"batch\": 64, \"backend\": \"reference\"}},\n  \"results\": [\n"
    ));
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    let out = std::env::var("FEDPAYLOAD_BENCH_JSON").unwrap_or_else(|_| "BENCH_fleet.json".into());
    std::fs::write(&out, json).unwrap();
    std::fs::remove_dir_all(&tmp).ok();
    println!("\nwrote {out}");
}
