//! Bench + reproduction of paper Figure 2 (reduced scale): the payload
//! sweep for one dataset per run. Prints the metric-vs-reduction series
//! and times one full (strategy × reduction) training cell.
//!
//! Dataset via FEDPAYLOAD_BENCH_DATASET (default movielens); smoke scale
//! keeps `cargo bench` minutes-fast — `make experiments` / the
//! `experiments fig2` subcommand produce the full CSVs.

use fedpayload::config::Strategy;
use fedpayload::experiments::{run_rebuilds, Scale};
use fedpayload::telemetry::bench;

fn main() {
    let dataset = std::env::var("FEDPAYLOAD_BENCH_DATASET").unwrap_or_else(|_| "movielens".into());
    let backend = if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt"
    } else {
        "reference"
    };
    let scale = Scale::smoke();

    println!("=== Figure 2 (smoke scale) — {dataset} ===");
    let full = run_rebuilds(&dataset, &scale, backend, &[Strategy::Full], 1.0).unwrap();
    println!(
        "{:<12} {:>8} {}",
        "fcf", "-", full.by_strategy["full"].mean()
    );
    println!("{:<12} {:>8} {}", "toplist", "-", full.toplist.mean());
    for red in [50u32, 75, 90, 95] {
        let f = 1.0 - red as f64 / 100.0;
        let out = run_rebuilds(
            &dataset,
            &scale,
            backend,
            &[Strategy::Bts, Strategy::Random],
            f,
        )
        .unwrap();
        println!("{:<12} {:>7}% {}", "fcf-bts", red, out.by_strategy["bts"].mean());
        println!("{:<12} {:>7}% {}", "fcf-random", red, out.by_strategy["random"].mean());
    }

    println!("\n=== cell timing ===");
    bench("fig2_cell_bts_90pct_smoke", || {
        run_rebuilds(&dataset, &scale, backend, &[Strategy::Bts], 0.10).unwrap()
    });
}
