//! Bench + reproduction of paper Figure 3 (convergence at 90% payload
//! reduction) at smoke scale: prints the smoothed-MAP trajectory for
//! FCF / FCF-BTS / FCF-Random on shared data and times the round loop by
//! training phase.

use fedpayload::config::Strategy;
use fedpayload::experiments::{experiment_config, Scale};
use fedpayload::rng::Rng;
use fedpayload::server::{load_dataset, Trainer};
use fedpayload::telemetry::bench;

fn main() {
    let backend = if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt"
    } else {
        "reference"
    };
    let mut scale = Scale::smoke();
    scale.iterations = 60;
    scale.eval_every = 2;

    let cfg = experiment_config("movielens", &scale, backend, 2021).unwrap();
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng).unwrap();
    let split = data.split(cfg.dataset.train_frac, &mut rng);

    println!("=== Figure 3 (smoke scale, movielens) ===");
    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    for (name, strategy, fraction) in [
        ("fcf", Strategy::Full, 1.0),
        ("fcf-bts", Strategy::Bts, 0.10),
        ("fcf-random", Strategy::Random, 0.10),
    ] {
        let mut c = cfg.clone();
        c.bandit.strategy = strategy;
        c.train.payload_fraction = fraction;
        let report = Trainer::with_split(&c, split.clone()).unwrap().run().unwrap();
        curves.push((
            name,
            report.history.iter().map(|r| r.smoothed.map).collect(),
        ));
    }
    println!("{:>6} {:>12} {:>12} {:>12}", "iter", "fcf", "fcf-bts", "fcf-random");
    for i in (9..scale.iterations).step_by(10) {
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>12.4}",
            i + 1,
            curves[0].1[i],
            curves[1].1[i],
            curves[2].1[i]
        );
    }

    println!("\n=== per-round timing (fcf-bts) ===");
    let mut c = cfg.clone();
    c.bandit.strategy = Strategy::Bts;
    c.train.payload_fraction = 0.10;
    let mut trainer = Trainer::with_split(&c, split).unwrap();
    bench("fig3_round_with_eval", || trainer.round().unwrap());
}
