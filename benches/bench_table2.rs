//! Bench + reproduction of paper Table 2: generate each calibrated
//! synthetic dataset (reduced scale by default; FEDPAYLOAD_BENCH_FULL=1
//! for paper scale) and report stats vs. the paper's numbers, timing the
//! generators and the split path.

use fedpayload::data::Interactions;
use fedpayload::experiments::{experiment_config, paper_table2, Scale, DATASETS};
use fedpayload::rng::Rng;
use fedpayload::server::load_dataset;
use fedpayload::telemetry::bench;

fn main() {
    let full = std::env::var("FEDPAYLOAD_BENCH_FULL").is_ok();
    let scale = if full { Scale::paper() } else { Scale::reduced() };
    println!("=== Table 2 reproduction (dataset scale {}) ===", scale.dataset);
    let mut generated: Vec<(&str, Interactions)> = Vec::new();
    for ds in DATASETS {
        let cfg = experiment_config(ds, &scale, "reference", 2021).unwrap();
        let mut rng = Rng::seed_from_u64(2021);
        let data = load_dataset(&cfg, &mut rng).unwrap();
        let stats = data.stats();
        let paper = paper_table2(ds).unwrap();
        println!("{ds:<10} ours : {stats}");
        println!("{ds:<10} paper: {paper}");
        generated.push((ds, data));
    }

    println!("\n=== generator + split timings ===");
    for ds in DATASETS {
        let cfg = experiment_config(ds, &Scale::reduced(), "reference", 2021).unwrap();
        bench(&format!("generate_{ds}_quarter_scale"), || {
            let mut rng = Rng::seed_from_u64(7);
            fedpayload::data::synthetic::generate(&cfg.dataset, &mut rng)
        });
    }
    let (_, data) = &generated[0];
    bench("split_80_20", || {
        let mut rng = Rng::seed_from_u64(9);
        data.split(0.8, &mut rng)
    });
    bench("popularity_ranking", || data.popularity_ranking());
}
