//! Encode/decode throughput of the wire payload codecs at Last-FM scale
//! (M_s = 1763 selected items × K = 25 at 90% reduction), plus the sparse
//! upload path. Prints frame sizes and compression ratios next to the
//! timings so the bandwidth/CPU trade-off of each precision is one read.

use fedpayload::rng::Rng;
use fedpayload::telemetry::bench;
use fedpayload::wire::{make_codec, Precision, SparsePolicy};

fn main() {
    let (rows, cols) = (1763usize, 25usize);
    let mut rng = Rng::seed_from_u64(7);
    let q: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect();
    // gradient-like upload: ~40% of rows zero
    let mut g = q.clone();
    for r in 0..rows {
        if r % 5 < 2 {
            g[r * cols..(r + 1) * cols].fill(0.0);
        }
    }
    let raw_mb = (rows * cols * 4) as f64 / 1e6;

    println!("=== dense download frames ({rows} x {cols}) ===");
    for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
        let codec = make_codec(p);
        let frame = codec.encode_dense(&q, rows, cols).unwrap();
        println!(
            "{:<5} frame = {:>7} bytes ({:.2}x vs f32 raw)",
            p.name(),
            frame.len(),
            (rows * cols * 4) as f64 / frame.len() as f64
        );
        let enc = bench(&format!("encode_dense_{}", p.name()), || {
            codec.encode_dense(&q, rows, cols).unwrap()
        });
        let dec = bench(&format!("decode_dense_{}", p.name()), || {
            codec.decode_dense(&frame).unwrap()
        });
        println!(
            "  throughput: encode {:.0} MB/s, decode {:.0} MB/s (f32-equivalent)",
            raw_mb / (enc.mean_ns / 1e9),
            raw_mb / (dec.mean_ns / 1e9)
        );
    }

    println!("\n=== sparse upload frames (40% zero rows) ===");
    for (label, policy) in [
        ("keep-all", SparsePolicy::default()),
        (
            "top176",
            SparsePolicy {
                top_k: rows / 10,
                threshold: 0.0,
            },
        ),
    ] {
        for p in [Precision::F32, Precision::Int8] {
            let codec = make_codec(p);
            let frame = codec.encode_sparse(&g, rows, cols, &policy).unwrap();
            println!("{:<5} {label}: frame = {} bytes", p.name(), frame.len());
            bench(&format!("encode_sparse_{}_{label}", p.name()), || {
                codec.encode_sparse(&g, rows, cols, &policy).unwrap()
            });
            bench(&format!("decode_sparse_{}_{label}", p.name()), || {
                codec.decode_sparse(&frame).unwrap()
            });
        }
    }
}
