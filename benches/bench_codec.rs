//! Encode/decode throughput of the wire payload codecs at Last-FM scale
//! (M_s = 1763 selected items × K = 25 at 90% reduction), plus the sparse
//! upload path, the entropy-coding legs (`wire::entropy`) and the
//! product-quantized download codecs (`wire::vq` — their encode numbers
//! include the per-frame seeded k-means). Prints frame sizes and
//! compression ratios next to the timings so the bandwidth/CPU trade-off
//! of each precision × entropy mode is one read, and writes
//! `BENCH_codec.json` (path overridable via `FEDPAYLOAD_BENCH_CODEC_JSON`)
//! so CI can archive the perf trajectory — and gate on it: the
//! `bench-gate` CI job diffs the frame-byte columns against
//! `ci/BENCH_codec_baseline.json` and fails on a >3% regression.

use fedpayload::rng::Rng;
use fedpayload::telemetry::bench;
use fedpayload::wire::{
    make_codec_with, EntropyMode, Precision, ReuseMode, SessionMode, SparsePolicy, VqClientState,
    VqSession,
};

struct Row {
    name: String,
    frame_bytes: usize,
    ratio_vs_plain: f64,
    encode_mbps: f64,
    decode_mbps: f64,
}

fn main() {
    let (rows, cols) = (1763usize, 25usize);
    let mut rng = Rng::seed_from_u64(7);
    let q: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect();
    // gradient-like upload: ~40% of rows zero
    let mut g = q.clone();
    for r in 0..rows {
        if r % 5 < 2 {
            g[r * cols..(r + 1) * cols].fill(0.0);
        }
    }
    let raw_mb = (rows * cols * 4) as f64 / 1e6;
    let mut results: Vec<Row> = Vec::new();

    println!("=== dense download frames ({rows} x {cols}) ===");
    for p in [
        Precision::F64,
        Precision::F32,
        Precision::F16,
        Precision::Int8,
        Precision::Vq8,
        Precision::Vq4,
        Precision::Vq8r,
    ] {
        let mut plain_len = 0usize;
        for e in [EntropyMode::None, EntropyMode::Range] {
            let codec = make_codec_with(p, e);
            let frame = codec.encode_dense(&q, rows, cols).unwrap();
            if e == EntropyMode::None {
                plain_len = frame.len();
            }
            let ratio = plain_len as f64 / frame.len() as f64;
            println!(
                "{:<5} entropy={:<6} frame = {:>7} bytes ({:.2}x vs f32 raw, {:.3}x vs plain)",
                p.name(),
                e.name(),
                frame.len(),
                (rows * cols * 4) as f64 / frame.len() as f64,
                ratio
            );
            let enc = bench(&format!("encode_dense_{}_{}", p.name(), e.name()), || {
                codec.encode_dense(&q, rows, cols).unwrap()
            });
            let dec = bench(&format!("decode_dense_{}_{}", p.name(), e.name()), || {
                codec.decode_dense(&frame).unwrap()
            });
            let (encode_mbps, decode_mbps) =
                (raw_mb / (enc.mean_ns / 1e9), raw_mb / (dec.mean_ns / 1e9));
            println!(
                "  throughput: encode {encode_mbps:.0} MB/s, decode {decode_mbps:.0} MB/s \
                 (f32-equivalent)"
            );
            results.push(Row {
                name: format!("dense_{}_{}", p.name(), e.name()),
                frame_bytes: frame.len(),
                ratio_vs_plain: ratio,
                encode_mbps,
                decode_mbps,
            });
        }
    }

    // --- vq8 codebook-session legs: the stable-Q two-round workload.
    // Round 1 opens the session (full codebook, generation 1); round 2
    // encodes Q after a small drift (0.002 ≈ a fraction of an Adam
    // step on these 0.1-scale factors), the steady state the session
    // machinery exists for. `auto` must reuse (frame = rows only);
    // the forced-`delta` leg measures the centroid-delta plane. These
    // frame lengths are deterministic and gated by ci/bench_gate.py —
    // the steady row landing under dense_vq8_* is the PR acceptance.
    println!("\n=== vq8 codebook-session frames (round-2 drift 0.002) ===");
    let mut rng2 = Rng::seed_from_u64(8);
    let q2: Vec<f32> = q.iter().map(|&v| v + rng2.normal() as f32 * 0.002).collect();
    let mut session_plain = [0usize; 3]; // open, steady, delta at entropy=none
    for e in [EntropyMode::None, EntropyMode::Range] {
        let mut auto = VqSession::new(Precision::Vq8, e, ReuseMode::Auto).unwrap();
        let open = auto.encode_dense(&q, rows, cols).unwrap();
        let steady_base = auto.clone();
        let steady = auto.encode_dense(&q2, rows, cols).unwrap();
        let mut delta_sess = VqSession::new(Precision::Vq8, e, ReuseMode::Delta).unwrap();
        delta_sess.encode_dense(&q, rows, cols).unwrap();
        let delta_base = delta_sess.clone();
        let delta = delta_sess.encode_dense(&q2, rows, cols).unwrap();
        // expected modes: full / reuse / delta. A bench must report, not
        // panic — if the mode-choice lands elsewhere the row lengths
        // shift and the bench-gate flags it against the baseline, which
        // is the honest failure signal.
        for (what, got, want) in [
            ("open", open.mode, SessionMode::Full),
            ("steady", steady.mode, SessionMode::Reuse),
            ("delta", delta.mode, SessionMode::Delta),
        ] {
            if got != want {
                eprintln!(
                    "WARNING: session {what} frame chose mode {} (expected {}) — \
                     the session_vq8_{what}_* rows measure that mode instead",
                    got.name(),
                    want.name()
                );
            }
        }
        // a client that decoded the open frame, for steady/delta decode
        let mut synced = VqClientState::new();
        synced.decode_dense(&open.frame).unwrap().into_data().unwrap();
        let legs: [(&str, &[u8]); 3] = [
            ("open", &open.frame),
            ("steady", &steady.frame),
            ("delta", &delta.frame),
        ];
        for (i, (leg, frame)) in legs.iter().enumerate() {
            if e == EntropyMode::None {
                session_plain[i] = frame.len();
            }
            let ratio = session_plain[i] as f64 / frame.len() as f64;
            println!(
                "session {leg:<6} entropy={:<6} frame = {:>7} bytes ({:.3}x vs plain)",
                e.name(),
                frame.len(),
                ratio
            );
            let enc = bench(&format!("encode_session_{leg}_{}", e.name()), || match i {
                0 => {
                    let mut s = VqSession::new(Precision::Vq8, e, ReuseMode::Auto).unwrap();
                    s.encode_dense(&q, rows, cols).unwrap().frame
                }
                1 => steady_base.clone().encode_dense(&q2, rows, cols).unwrap().frame,
                _ => delta_base.clone().encode_dense(&q2, rows, cols).unwrap().frame,
            });
            let dec = bench(&format!("decode_session_{leg}_{}", e.name()), || match i {
                0 => VqClientState::new().decode_dense(&open.frame).unwrap(),
                1 => synced.clone().decode_dense(&steady.frame).unwrap(),
                _ => synced.clone().decode_dense(&delta.frame).unwrap(),
            });
            results.push(Row {
                name: format!("session_vq8_{leg}_{}", e.name()),
                frame_bytes: frame.len(),
                ratio_vs_plain: ratio,
                encode_mbps: raw_mb / (enc.mean_ns / 1e9),
                decode_mbps: raw_mb / (dec.mean_ns / 1e9),
            });
        }
    }

    println!("\n=== sparse upload frames (40% zero rows) ===");
    for (label, policy) in [
        ("keep-all", SparsePolicy::default()),
        (
            "top176",
            SparsePolicy {
                top_k: rows / 10,
                threshold: 0.0,
                auto_topk: false,
            },
        ),
    ] {
        for p in [Precision::F32, Precision::Int8] {
            let mut plain_len = 0usize;
            for e in [EntropyMode::None, EntropyMode::Varint, EntropyMode::Full] {
                let codec = make_codec_with(p, e);
                let frame = codec.encode_sparse(&g, rows, cols, &policy).unwrap();
                if e == EntropyMode::None {
                    plain_len = frame.len();
                }
                let ratio = plain_len as f64 / frame.len() as f64;
                println!(
                    "{:<5} {label} entropy={:<6}: frame = {} bytes ({ratio:.3}x vs plain)",
                    p.name(),
                    e.name(),
                    frame.len()
                );
                let enc = bench(
                    &format!("encode_sparse_{}_{label}_{}", p.name(), e.name()),
                    || codec.encode_sparse(&g, rows, cols, &policy).unwrap(),
                );
                let dec = bench(
                    &format!("decode_sparse_{}_{label}_{}", p.name(), e.name()),
                    || codec.decode_sparse(&frame).unwrap(),
                );
                results.push(Row {
                    name: format!("sparse_{}_{label}_{}", p.name(), e.name()),
                    frame_bytes: frame.len(),
                    ratio_vs_plain: ratio,
                    encode_mbps: raw_mb / (enc.mean_ns / 1e9),
                    decode_mbps: raw_mb / (dec.mean_ns / 1e9),
                });
            }
        }
    }

    let mut json = String::from("{\n  \"bench\": \"codec\",\n");
    json.push_str(&format!(
        "  \"workload\": {{\"rows\": {rows}, \"cols\": {cols}, \"zero_row_pct\": 40}},\n  \
         \"results\": [\n"
    ));
    for (i, r) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"frame_bytes\": {}, \"ratio_vs_plain\": {:.4}, \
             \"encode_mbps\": {:.1}, \"decode_mbps\": {:.1}}}{}\n",
            r.name,
            r.frame_bytes,
            r.ratio_vs_plain,
            r.encode_mbps,
            r.decode_mbps,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = std::env::var("FEDPAYLOAD_BENCH_CODEC_JSON")
        .unwrap_or_else(|_| "BENCH_codec.json".into());
    std::fs::write(&out, json).unwrap();
    println!("\nwrote {out}");
}
