//! Micro-benches of the L3 hot paths feeding EXPERIMENTS.md §Perf:
//! bandit selection, reward engine, Adam, the backend kernels (PJRT when
//! artifacts are present, reference otherwise) and one full training
//! round at movielens scale.

use fedpayload::bandit::{BtsSelector, ItemSelector, RandomSelector};
use fedpayload::config::RunConfig;
use fedpayload::linalg::Mat;
use fedpayload::optim::Adam;
use fedpayload::reward::RewardEngine;
use fedpayload::rng::Rng;
use fedpayload::runtime::{pjrt::PjrtBackend, reference::ReferenceBackend, FcfRuntime};
use fedpayload::server::Trainer;
use fedpayload::telemetry::bench;

fn main() {
    let m = 17_632; // Last-FM catalog size
    let k = 25;
    let m_s = m / 10;
    let mut rng = Rng::seed_from_u64(1);

    println!("=== bandit ===");
    let mut bts = BtsSelector::new(m, 0.0, 10_000.0);
    let rewards: Vec<(u32, f64)> = (0..m_s as u32).map(|j| (j * 10, (j as f64).sin())).collect();
    bts.update(&rewards);
    bench(&format!("bts_select_{m_s}_of_{m}"), || {
        bts.select(m_s, &mut rng)
    });
    bench("bts_update_1763_rewards", || bts.update(&rewards));
    let mut rnd = RandomSelector::new(m);
    bench(&format!("random_select_{m_s}_of_{m}"), || {
        rnd.select(m_s, &mut rng)
    });

    println!("\n=== reward engine (Eq. 13-14) ===");
    let mut engine = RewardEngine::new(m, k, 0.999, 0.99);
    let grad: Vec<f32> = (0..k).map(|i| (i as f32 * 0.7).sin()).collect();
    bench("reward_observe_1763_items", || {
        for j in 0..m_s as u32 {
            engine.observe(j, 10, &grad);
        }
    });

    println!("\n=== optimizer ===");
    let cfg = RunConfig::paper_defaults();
    let mut adam = Adam::new(m, &cfg.model);
    let mut q = Mat::randn(m, k, 0.1, &mut rng);
    let selected: Vec<u32> = (0..m_s as u32).collect();
    let g = vec![0.01f32; m_s * k];
    bench("adam_step_1763_items_k25", || {
        adam.step_selected(&mut q, &selected, &g)
    });

    println!("\n=== backend kernels (B=64, K=25, T=512) ===");
    let have_artifacts = std::path::Path::new("artifacts/manifest.txt").exists();
    let backends: Vec<(&str, Box<dyn FnOnce() -> FcfRuntime>)> = if have_artifacts {
        vec![
            ("pjrt", Box::new(|| FcfRuntime::new(Box::new(PjrtBackend::load("artifacts").unwrap())))),
            ("reference", Box::new(|| {
                FcfRuntime::new(Box::new(ReferenceBackend::new(64, 25, vec![512, 2048], 4.0, 1.0)))
            })),
        ]
    } else {
        vec![("reference", Box::new(|| {
            FcfRuntime::new(Box::new(ReferenceBackend::new(64, 25, vec![512, 2048], 4.0, 1.0)))
        }))]
    };
    for (name, make) in backends {
        let mut rt = make();
        let m_sel = 1763usize;
        let q_sel: Vec<f32> = (0..m_sel * 25).map(|i| ((i % 97) as f32 - 48.0) * 0.01).collect();
        let rows: Vec<Vec<u32>> = (0..64)
            .map(|u| (0..m_sel as u32).filter(|j| (j + u) % 37 == 0).collect())
            .collect();
        let row_refs: Vec<&Vec<u32>> = rows.iter().collect();
        let p = rt.solve_users(&q_sel, &row_refs).unwrap();
        bench(&format!("{name}_solve_64users_1763items"), || {
            rt.solve_users(&q_sel, &row_refs).unwrap()
        });
        bench(&format!("{name}_grad_64users_1763items"), || {
            rt.grad_batch(&q_sel, &row_refs, &p).unwrap()
        });
        let q_full = Mat::randn(m, 25, 0.1, &mut rng);
        bench(&format!("{name}_scores_64users_17632items"), || {
            rt.scores_all(q_full.data(), &p).unwrap()
        });
    }

    println!("\n=== full round (movielens scale, Θ=100, 90% reduction) ===");
    let mut cfg = RunConfig::paper_defaults();
    cfg.apply_dataset_preset("movielens").unwrap();
    cfg.train.payload_fraction = 0.10;
    cfg.train.eval_every = 1;
    cfg.runtime.backend = if have_artifacts { "pjrt".into() } else { "reference".into() };
    let mut trainer = Trainer::from_config(&cfg).unwrap();
    bench("train_round_movielens_90pct", || trainer.round().unwrap());
    cfg.train.eval_every = usize::MAX; // isolate compute from evaluation
    let mut trainer2 = Trainer::from_config(&cfg).unwrap();
    bench("train_round_movielens_90pct_noeval", || {
        trainer2.round().unwrap()
    });
}
