//! Bench + reproduction of paper Table 1: payload arithmetic and the
//! simulated transfer model. Prints the table rows (the reproduction) and
//! times the payload accounting hot path (the bench).

use fedpayload::config::RunConfig;
use fedpayload::simnet::{human_bytes, payload_bytes, table1_rows, transfer_secs, TrafficLedger};
use fedpayload::telemetry::bench;

fn main() {
    println!("=== Table 1 reproduction ===");
    for (items, bytes) in table1_rows() {
        println!("{items:>10} items -> {:>12} ({})", bytes, human_bytes(bytes));
    }
    assert_eq!(table1_rows()[0].1, 625_920, "3912-item row must be ~625KB");

    println!("\n=== payload accounting hot path ===");
    let cfg = RunConfig::paper_defaults().simnet;
    bench("payload_bytes(1M items, K=20)", || {
        payload_bytes(1_000_000, 20, 64)
    });
    bench("transfer_secs(16MB over 4G)", || {
        transfer_secs(&cfg, 16_000_000)
    });
    bench("ledger_record_1k_clients", || {
        let mut ledger = TrafficLedger::new();
        for _ in 0..1000 {
            ledger.record_down(&cfg, 612_800);
            ledger.record_up(&cfg, 612_800);
        }
        ledger.total_bytes()
    });
}
