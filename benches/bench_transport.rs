//! Transport-lane microbench: what the TCP framing layer costs on top
//! of the payload codec. Four families of rows, all wall-clock facts
//! (never gated — byte determinism is the tests' job, not this bench's):
//!
//! 1. `frame_encode_*` / `frame_decode_*` — assemble/parse one framed
//!    message (magic + type + length + payload + FNV checksum) at
//!    device-frame, broadcast-frame, and near-cap payload sizes;
//! 2. `loopback_rtt_*` — one framed message to a loopback echo peer
//!    and its echo back: the floor for a download → ack exchange;
//! 3. `proto_roundtrip_download` — encode + decode of a realistic
//!    `Download` protocol message (64-item × k=25 f32 frame payload);
//! 4. `sched_schedule` — the download scheduler's per-transfer cost
//!    (BTreeMap upsert), which sits on the hot path of every paced
//!    download.
//!
//! Honours `FEDPAYLOAD_BENCH_BUDGET_SECS` (CI sets a small budget) and
//! `FEDPAYLOAD_BENCH_JSON` for the output path, like every other bench
//! target.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};

use fedpayload::telemetry::bench;
use fedpayload::transport::framing::{read_msg, write_msg, MSG_HEADER_LEN};
use fedpayload::transport::proto::Msg;
use fedpayload::transport::sched::DownloadScheduler;

/// Payload sizes: a 64-item × k=25 f32 device frame (~6.4 KiB), a
/// 2048-item broadcast frame (~200 KiB), and a 4 MiB stress frame.
const SIZES: &[(&str, usize)] = &[
    ("device_6k", 64 * 25 * 4),
    ("broadcast_200k", 2048 * 25 * 4),
    ("stress_4m", 4 << 20),
];

fn main() {
    let mut rows: Vec<String> = Vec::new();
    let mut push = |name: &str, bytes: usize, r: &fedpayload::telemetry::BenchResult| {
        let wire = (MSG_HEADER_LEN + bytes + 4) as f64;
        rows.push(format!(
            "    {{\"name\": \"{name}\", \"payload_bytes\": {bytes}, \
             \"mean_ns\": {:.0}, \"p50_ns\": {:.0}, \"p95_ns\": {:.0}, \
             \"mib_per_sec\": {:.1}}}",
            r.mean_ns,
            r.p50_ns,
            r.p95_ns,
            wire / (r.mean_ns / 1e9) / (1024.0 * 1024.0)
        ));
    };

    println!("=== transport framing (FPTL: 9 B header + payload + FNV-1a checksum) ===");
    for &(label, size) in SIZES {
        let payload = vec![0xA5u8; size];
        let mut buf = Vec::with_capacity(size + 64);
        let r = bench(&format!("frame_encode_{label}"), || {
            buf.clear();
            write_msg(&mut buf, 7, &payload).unwrap();
            buf.len()
        });
        push(&format!("frame_encode_{label}"), size, &r);

        let mut wire = Vec::new();
        write_msg(&mut wire, 7, &payload).unwrap();
        let r = bench(&format!("frame_decode_{label}"), || {
            read_msg(&mut &wire[..]).unwrap().unwrap().1.len()
        });
        push(&format!("frame_decode_{label}"), size, &r);
    }

    println!("=== loopback echo round-trip (std::net blocking TCP) ===");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let echo = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        let _ = conn.set_nodelay(true);
        while let Ok(Some((ty, payload))) = read_msg(&mut conn) {
            if write_msg(&mut conn, ty, &payload).is_err() {
                break;
            }
        }
    });
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        for &(label, size) in SIZES {
            let payload = vec![0x5Au8; size];
            let r = bench(&format!("loopback_rtt_{label}"), || {
                write_msg(&mut stream, 3, &payload).unwrap();
                read_msg(&mut stream).unwrap().unwrap().1.len()
            });
            push(&format!("loopback_rtt_{label}"), size, &r);
        }
        // dropping the stream sends EOF; the echo thread exits cleanly
    }
    echo.join().unwrap();

    println!("=== protocol message encode/decode ===");
    let frame: Vec<u8> = (0..64 * 25 * 4).map(|i| (i % 251) as u8).collect();
    let msg = Msg::Download {
        iter: 42,
        client: 1337,
        frame: frame.clone(),
    };
    let r = bench("proto_roundtrip_download", || {
        let (ty, payload) = msg.encode();
        Msg::decode(ty, &payload).unwrap()
    });
    push("proto_roundtrip_download", frame.len(), &r);

    println!("=== download scheduler (per-client pacing) ===");
    let mut sched = DownloadScheduler::new(1_000_000_000);
    let mut now = 0u64;
    let mut client = 0u64;
    let r = bench("sched_schedule", || {
        client = (client + 1) % 4096;
        now += 1_000;
        sched.schedule(client, 8192, now)
    });
    rows.push(format!(
        "    {{\"name\": \"sched_schedule\", \"clients\": 4096, \"mean_ns\": {:.0}, \
         \"p50_ns\": {:.0}, \"p95_ns\": {:.0}}}",
        r.mean_ns, r.p50_ns, r.p95_ns
    ));

    let mut json = String::from("{\n  \"bench\": \"transport\",\n  \"results\": [\n");
    json.push_str(&rows.join(",\n"));
    json.push_str("\n  ]\n}\n");
    let out =
        std::env::var("FEDPAYLOAD_BENCH_JSON").unwrap_or_else(|_| "BENCH_transport.json".into());
    std::fs::write(&out, json).unwrap();
    println!("\nwrote {out}");
}
