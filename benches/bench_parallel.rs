//! Round-loop scaling of the sharded client-fleet executor on the
//! reference backend: one synthetic workload with Θ = 512 participants
//! (8 batches of B = 64 per round), timed at 1/2/4/8 threads. Prints the
//! speedup ladder and writes `BENCH_parallel.json` (path overridable via
//! `FEDPAYLOAD_BENCH_JSON`) so CI can archive the perf trajectory.
//!
//! Acceptance target (ISSUE 2): ≥ 2× round-loop speedup at 4 threads on
//! this workload. Eval is effectively disabled so the timing isolates the
//! parallelized solve/grad/codec hot path.

use fedpayload::experiments::parallel_workload_cfg;
use fedpayload::rng::Rng;
use fedpayload::server::{load_dataset, Trainer};
use fedpayload::telemetry::{bench, BenchResult};

fn main() {
    // the same Θ = 512 workload `fedpayload experiments threads` sweeps
    let mut cfg = parallel_workload_cfg("reference");
    cfg.train.eval_every = 1_000_000; // keep the timing on the compute path
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let data = load_dataset(&cfg, &mut rng).unwrap();
    let split = data.split(cfg.dataset.train_frac, &mut rng);

    println!(
        "=== parallel round loop (theta=512, B=64 -> 8 batches, m_s=256, reference backend) ==="
    );
    let mut results: Vec<(usize, BenchResult)> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let mut cfg_run = cfg.clone();
        cfg_run.runtime.threads = threads;
        let mut trainer = Trainer::with_split(&cfg_run, split.clone()).unwrap();
        // warm the worker pool + allocator outside the timed region
        trainer.round().unwrap();
        let r = bench(&format!("round_theta512_t{threads}"), || {
            trainer.round().unwrap()
        });
        results.push((threads, r));
    }

    let base = results[0].1.mean_ns;
    println!("\nspeedup vs 1 thread:");
    for (threads, r) in &results {
        println!(
            "  threads={threads}: {:.2}x ({:.2} rounds/s)",
            base / r.mean_ns,
            1e9 / r.mean_ns
        );
    }

    let mut json = String::from("{\n  \"bench\": \"parallel_round\",\n");
    json.push_str(
        "  \"workload\": {\"theta\": 512, \"batch\": 64, \"m_s\": 256, \"k\": 25, \
         \"backend\": \"reference\"},\n  \"results\": [\n",
    );
    for (i, (threads, r)) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {threads}, \"mean_ns\": {:.0}, \"p50_ns\": {:.0}, \
             \"p95_ns\": {:.0}, \"iters\": {}, \"speedup_vs_1t\": {:.3}}}{}\n",
            r.mean_ns,
            r.p50_ns,
            r.p95_ns,
            r.iters,
            base / r.mean_ns,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    let out =
        std::env::var("FEDPAYLOAD_BENCH_JSON").unwrap_or_else(|_| "BENCH_parallel.json".into());
    std::fs::write(&out, json).unwrap();
    println!("\nwrote {out}");
}
