//! Bench + reproduction of paper Table 4 (90% payload reduction) at smoke
//! scale, including the Diff%/Impr% summary statistics (Eq. 15–16), plus
//! an ablation over the reward-engine interpretation switches
//! (DESIGN.md §1 faithfulness notes).

use fedpayload::config::Strategy;
use fedpayload::experiments::{experiment_config, run_rebuilds, Scale};
use fedpayload::metrics::{diff_pct, impr_pct};
use fedpayload::rng::Rng;
use fedpayload::server::{load_dataset, Trainer};
use fedpayload::telemetry::bench;

fn main() {
    let backend = if std::path::Path::new("artifacts/manifest.txt").exists() {
        "pjrt"
    } else {
        "reference"
    };
    let scale = Scale::smoke();

    println!("=== Table 4 (smoke scale) ===");
    for ds in ["movielens", "lastfm"] {
        let full = run_rebuilds(ds, &scale, backend, &[Strategy::Full], 1.0).unwrap();
        let opt = run_rebuilds(ds, &scale, backend, &[Strategy::Bts, Strategy::Random], 0.10).unwrap();
        let (f, b, r, t) = (
            full.by_strategy["full"].mean(),
            opt.by_strategy["bts"].mean(),
            opt.by_strategy["random"].mean(),
            full.toplist.mean(),
        );
        println!("{ds}:");
        println!("  FCF        {f}");
        println!("  FCF-BTS    {b}");
        println!("  FCF-Random {r}");
        println!("  TopList    {t}");
        println!(
            "  Diff% vs FCF: P={:.1} MAP={:.1} | Impr% vs Random: P={:.1} MAP={:.1}",
            diff_pct(b.precision, f.precision),
            diff_pct(b.map, f.map),
            impr_pct(b.precision, r.precision),
            impr_pct(b.map, r.map),
        );
    }

    println!("\n=== reward-interpretation ablation (lastfm smoke, BTS @90%) ===");
    for (label, overrides) in [
        ("default (per_item, power, norm)", vec![]),
        ("time_base=global", vec![("bandit.time_base", "\"global\"")]),
        ("cosine=literal", vec![("bandit.cosine_weight", "\"literal\"")]),
        ("no reward normalization", vec![("bandit.normalize_rewards", "false")]),
    ] {
        let mut cfg = experiment_config("lastfm", &scale, backend, 2021).unwrap();
        cfg.train.payload_fraction = 0.10;
        cfg.bandit.strategy = Strategy::Bts;
        for (key, val) in overrides {
            let mut doc = fedpayload::config::Doc::default();
            doc.apply_override(&format!("{key}={val}")).unwrap();
            // re-resolve just this key into the config
            match key {
                "bandit.time_base" => cfg.bandit.time_base = "global",
                "bandit.cosine_weight" => cfg.bandit.cosine_weight = "literal",
                "bandit.normalize_rewards" => cfg.bandit.normalize_rewards = false,
                _ => unreachable!(),
            }
        }
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let data = load_dataset(&cfg, &mut rng).unwrap();
        let split = data.split(cfg.dataset.train_frac, &mut rng);
        let report = Trainer::with_split(&cfg, split).unwrap().run().unwrap();
        println!("  {label:<35} {}", report.final_metrics);
    }

    println!("\n=== cell timing ===");
    bench("table4_full_cell_smoke", || {
        run_rebuilds("movielens", &scale, backend, &[Strategy::Full], 1.0).unwrap()
    });
}
