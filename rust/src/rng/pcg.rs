//! PCG64 (XSL-RR 128/64) and SplitMix64 generators.
//!
//! References: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014);
//! Steele et al., "Fast Splittable Pseudorandom Number Generators" (2014).

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// SplitMix64 — used to expand a 64-bit seed into PCG's 128-bit state and
/// stream, and as a cheap standalone mixer in tests.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Mixer starting from `seed`.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    incr: u128,
}

impl Pcg64 {
    /// Generator from explicit 128-bit state and stream values.
    pub fn new(state: u128, stream: u128) -> Self {
        let mut pcg = Pcg64 {
            state: 0,
            incr: (stream << 1) | 1,
        };
        pcg.state = pcg.state.wrapping_add(state);
        pcg.step();
        pcg
    }

    /// Generator from a 64-bit seed expanded through SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        Pcg64::new(s, inc)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.incr);
    }

    /// Next 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// The raw `(state, increment)` words. Exposed so the round journal
    /// can fingerprint the exact stream position without widening the
    /// mutation surface — there is deliberately no setter: recovery is
    /// replay, never state injection.
    pub fn state_words(&self) -> (u128, u128) {
        (self.state, self.incr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 (Vigna's splitmix64.c).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xe220a8397b1dcdaf);
        assert_eq!(sm.next_u64(), 0x6e789e6aa1b965f4);
        assert_eq!(sm.next_u64(), 0x06c45d188009454f);
    }

    #[test]
    fn pcg_streams_independent() {
        let mut a = Pcg64::new(1, 1);
        let mut b = Pcg64::new(1, 2);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn pcg_no_short_cycle() {
        let mut g = Pcg64::seed_from_u64(99);
        let first = g.next_u64();
        for _ in 0..100_000 {
            // astronomically unlikely to revisit the first output AND state
            let _ = g.next_u64();
        }
        assert_ne!(first, g.next_u64()); // smoke: not constant
    }

    #[test]
    fn bit_balance() {
        let mut g = Pcg64::seed_from_u64(5);
        let mut ones = 0u64;
        let n = 10_000;
        for _ in 0..n {
            ones += g.next_u64().count_ones() as u64;
        }
        let expected = n * 32;
        let dev = (ones as i64 - expected as i64).abs();
        assert!(dev < 4_000, "ones={ones}");
    }
}
