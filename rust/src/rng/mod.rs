//! Deterministic RNG substrate (no `rand` crate available offline).
//!
//! Provides the generators the system needs: [`SplitMix64`] for seeding,
//! [`Pcg64`] as the workhorse stream, Box–Muller [`Normal`] draws for the
//! BTS posterior sampling (paper Eq. 9), a [`CdfSampler`] for Zipf-like
//! item popularity in the synthetic datasets, and Fisher–Yates shuffling
//! for splits and client scheduling.
//!
//! Everything is seedable and stream-splittable so every experiment in
//! EXPERIMENTS.md is exactly reproducible.

mod pcg;
mod sampler;

pub use pcg::{Pcg64, SplitMix64};
pub use sampler::{CdfSampler, ParticipantSampler};

/// Uniform, normal and integer draws on top of a PCG stream.
#[derive(Debug, Clone)]
pub struct Rng {
    pcg: Pcg64,
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create from a 64-bit seed (expanded through SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng {
            pcg: Pcg64::seed_from_u64(seed),
            spare_normal: None,
        }
    }

    /// Derive an independent child stream; used to give every simulated
    /// client and every model rebuild its own reproducible stream.
    pub fn split(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }

    /// Next raw 64-bit draw from the underlying PCG stream.
    pub fn next_u64(&mut self) -> u64 {
        self.pcg.next_u64()
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        // 128-bit multiply rejection sampling: unbiased.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates on
    /// an index arena — O(n) memory, O(k) swaps).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut arena: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            arena.swap(i, j);
        }
        arena.truncate(k);
        arena
    }

    /// FNV-64 fingerprint of the exact stream position: the PCG state and
    /// increment words plus the cached Box–Muller spare (its presence
    /// *and* bits — two streams that agree on PCG state but differ on the
    /// spare produce different future normals). The round journal records
    /// this at round entry so a `--resume` replay detects RNG drift at
    /// the first diverging round instead of the final dump diff.
    pub fn state_fingerprint(&self) -> u64 {
        let (state, incr) = self.pcg.state_words();
        let mut h = crate::telemetry::Fnv64::new();
        h.write_u128(state);
        h.write_u128(incr);
        match self.spare_normal {
            Some(z) => {
                h.write_u8(1);
                h.write_f64(z);
            }
            None => h.write_u8(0),
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::seed_from_u64(7);
        let mut c1 = a.split();
        let mut c2 = a.split();
        let s1: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let s2: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(s1, s2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            let expected = n as f64 / 10.0;
            assert!((c as f64 - expected).abs() < 5.0 * expected.sqrt(), "{c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::seed_from_u64(5);
        let got = r.sample_indices(50, 20);
        assert_eq!(got.len(), 20);
        let mut s = got.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(got.iter().all(|&i| i < 50));
    }

    #[test]
    #[should_panic]
    fn sample_more_than_population_panics() {
        let mut r = Rng::seed_from_u64(6);
        r.sample_indices(3, 4);
    }

    #[test]
    fn state_fingerprint_tracks_stream_position() {
        let mut a = Rng::seed_from_u64(42);
        let b = Rng::seed_from_u64(42);
        assert_eq!(a.state_fingerprint(), b.state_fingerprint());
        let before = a.state_fingerprint();
        let _ = a.next_u64();
        assert_ne!(a.state_fingerprint(), before, "draws must advance the fingerprint");
        // clone preserves position exactly
        assert_eq!(a.clone().state_fingerprint(), a.state_fingerprint());
    }

    #[test]
    fn state_fingerprint_sees_the_boxmuller_spare() {
        let mut a = Rng::seed_from_u64(9);
        let mut b = a.clone();
        let _ = a.normal(); // leaves a cached spare in `a`
        let _ = b.normal();
        let _ = b.normal(); // consumes the spare in `b`
        assert_ne!(
            a.state_fingerprint(),
            b.state_fingerprint(),
            "same PCG position, different spare cache: must differ"
        );
    }
}
