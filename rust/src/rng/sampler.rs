//! Weighted discrete sampling via an explicit CDF + binary search, and
//! the fleet's dedicated per-round participant sampler.
//!
//! [`CdfSampler`] is used by the synthetic dataset generators
//! ([`crate::data::synthetic`]) for Zipf-like item popularity — the skew
//! that makes TopList strong on news-style data (paper §7, MIND) — and
//! by the TopList baseline tests. [`ParticipantSampler`] draws each
//! round's participant subset for `fleet.theta_sample` runs from its own
//! reproducible PCG stream, independent of the trainer's main stream.

use std::collections::HashSet;

use super::{Rng, SplitMix64};

/// Cumulative-distribution sampler over `n` weighted categories.
#[derive(Debug, Clone)]
pub struct CdfSampler {
    cdf: Vec<f64>,
}

impl CdfSampler {
    /// Build from non-negative weights. Panics if all weights are zero.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "CdfSampler: empty weights");
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0 && w.is_finite(), "CdfSampler: bad weight {w}");
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "CdfSampler: zero total weight");
        // normalize so the last entry is exactly 1.0
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        *cdf.last_mut().unwrap() = 1.0;
        CdfSampler { cdf }
    }

    /// Zipf(s) over ranks 1..=n: weight(rank) = rank^-s.
    pub fn zipf(n: usize, s: f64) -> Self {
        let weights: Vec<f64> = (1..=n).map(|r| (r as f64).powf(-s)).collect();
        CdfSampler::new(&weights)
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Is the category set empty? (Construction forbids it.)
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw one category index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // first index with cdf[i] > u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i,
        }
    }
}

/// Domain-separation tag mixed into the master seed so the participant
/// stream never collides with the trainer's main stream (which is
/// `Rng::seed_from_u64(cfg.seed)`) or any `split()` descendant of it.
const PARTICIPANT_STREAM_TAG: u64 = 0x5047_4c45_4554_0001; // "PG\x4cEET" + 1

/// Per-round participant sampling from a dedicated reproducible PCG
/// stream — the `fleet.theta_sample` mechanism.
///
/// Design constraints (all load-bearing for determinism and resume):
///
/// * **Stream independence.** Each round's draw is keyed purely by
///   `(master seed, round index)` — never by a shared mutable RNG — so
///   the participant sequence is identical regardless of thread count,
///   of how far the trainer's main stream has advanced, and of whether
///   earlier rounds were replayed from a journal or re-executed.
/// * **O(sample) memory.** Floyd's algorithm draws `k` distinct ids out
///   of `n` with `k` set insertions and zero O(n) scratch — the legacy
///   `Rng::sample_indices` allocates an `n`-entry index table, which at
///   `Theta = 10^6` would burn 8 MB per round just to pick 1000 ids.
/// * **Deterministic order.** The returned ids are in Floyd insertion
///   order (a pure function of the round's PCG draws), so batches form
///   identically on every replay.
#[derive(Debug, Clone)]
pub struct ParticipantSampler {
    stream_seed: u64,
}

impl ParticipantSampler {
    /// Build the sampler for a run: derives the dedicated stream seed
    /// from the run's master seed via a tagged SplitMix64 step.
    pub fn new(master_seed: u64) -> Self {
        let mut sm = SplitMix64::new(master_seed ^ PARTICIPANT_STREAM_TAG);
        ParticipantSampler {
            stream_seed: sm.next_u64(),
        }
    }

    /// Draw round `round`'s participant set: `k.min(n)` distinct client
    /// ids in `[0, n)`, a pure function of `(master seed, round)`.
    pub fn sample_round(&self, round: u64, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k == 0 {
            return Vec::new();
        }
        // per-round child stream: one more tagged SplitMix64 mix so
        // consecutive rounds land in unrelated PCG streams
        let mut sm = SplitMix64::new(self.stream_seed.wrapping_add(round));
        let mut rng = Rng::seed_from_u64(sm.next_u64());
        // Floyd's algorithm: for j in n-k..n pick t in [0, j]; insert t
        // unless already chosen, else insert j. Exactly k distinct ids,
        // uniform over k-subsets, O(k) memory.
        let mut chosen: HashSet<usize> = HashSet::with_capacity(k * 2);
        let mut order: Vec<usize> = Vec::with_capacity(k);
        for j in n - k..n {
            let t = rng.below(j + 1);
            let pick = if chosen.insert(t) { t } else { j };
            if pick != t {
                chosen.insert(pick);
            }
            order.push(pick);
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_weights() {
        let s = CdfSampler::new(&[1.0, 0.0, 3.0]);
        let mut rng = Rng::seed_from_u64(11);
        let mut counts = [0usize; 3];
        let n = 40_000;
        for _ in 0..n {
            counts[s.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let s = CdfSampler::zipf(1000, 1.1);
        let mut rng = Rng::seed_from_u64(12);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if s.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // top-10 of 1000 zipf(1.1) categories carry >> 1% of the mass
        assert!(head as f64 / n as f64 > 0.2, "head {head}");
    }

    #[test]
    fn covers_all_indices_in_range() {
        let s = CdfSampler::new(&[1.0; 7]);
        let mut rng = Rng::seed_from_u64(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[s.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    #[should_panic]
    fn zero_mass_panics() {
        CdfSampler::new(&[0.0, 0.0]);
    }

    #[test]
    fn participants_distinct_in_range_exact_count() {
        let s = ParticipantSampler::new(2027);
        for round in [0u64, 1, 7, 1000] {
            for (n, k) in [(10usize, 3usize), (100, 100), (1000, 1), (5, 9)] {
                let ids = s.sample_round(round, n, k);
                assert_eq!(ids.len(), k.min(n), "round {round} n={n} k={k}");
                let mut sorted = ids.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), ids.len(), "round {round}: duplicate id");
                assert!(ids.iter().all(|&i| i < n), "round {round}: out of range");
            }
        }
    }

    #[test]
    fn participants_pure_function_of_seed_and_round() {
        let a = ParticipantSampler::new(7);
        let b = ParticipantSampler::new(7);
        let c = ParticipantSampler::new(8);
        assert_eq!(a.sample_round(3, 1000, 50), b.sample_round(3, 1000, 50));
        assert_ne!(a.sample_round(3, 1000, 50), c.sample_round(3, 1000, 50));
        assert_ne!(
            a.sample_round(3, 1000, 50),
            a.sample_round(4, 1000, 50),
            "consecutive rounds must draw different subsets"
        );
        // repeat calls for the same round are identical (stateless)
        assert_eq!(a.sample_round(9, 64, 16), a.sample_round(9, 64, 16));
    }

    #[test]
    fn participants_independent_of_main_stream() {
        // advancing an unrelated Rng (the trainer's main stream) must
        // not perturb the participant draws
        let s = ParticipantSampler::new(42);
        let before = s.sample_round(5, 200, 20);
        let mut other = Rng::seed_from_u64(42);
        for _ in 0..1234 {
            other.next_u64();
        }
        assert_eq!(s.sample_round(5, 200, 20), before);
    }

    #[test]
    fn participants_roughly_uniform() {
        // every client id should be drawn sometimes across many rounds
        let s = ParticipantSampler::new(99);
        let n = 50;
        let mut counts = vec![0usize; n];
        for round in 0..400u64 {
            for id in s.sample_round(round, n, 10) {
                counts[id] += 1;
            }
        }
        // expectation 80 per id; a zero would mean a dead client
        assert!(counts.iter().all(|&c| c > 30), "counts {counts:?}");
    }

    #[test]
    fn empty_and_oversized_requests() {
        let s = ParticipantSampler::new(1);
        assert!(s.sample_round(0, 0, 5).is_empty());
        assert!(s.sample_round(0, 10, 0).is_empty());
        assert_eq!(s.sample_round(0, 3, 10).len(), 3);
    }
}
