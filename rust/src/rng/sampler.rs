//! Weighted discrete sampling via an explicit CDF + binary search.
//!
//! Used by the synthetic dataset generators ([`crate::data::synthetic`])
//! for Zipf-like item popularity — the skew that makes TopList strong on
//! news-style data (paper §7, MIND) — and by the TopList baseline tests.

use super::Rng;

/// Cumulative-distribution sampler over `n` weighted categories.
#[derive(Debug, Clone)]
pub struct CdfSampler {
    cdf: Vec<f64>,
}

impl CdfSampler {
    /// Build from non-negative weights. Panics if all weights are zero.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "CdfSampler: empty weights");
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0 && w.is_finite(), "CdfSampler: bad weight {w}");
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "CdfSampler: zero total weight");
        // normalize so the last entry is exactly 1.0
        for c in cdf.iter_mut() {
            *c /= acc;
        }
        *cdf.last_mut().unwrap() = 1.0;
        CdfSampler { cdf }
    }

    /// Zipf(s) over ranks 1..=n: weight(rank) = rank^-s.
    pub fn zipf(n: usize, s: f64) -> Self {
        let weights: Vec<f64> = (1..=n).map(|r| (r as f64).powf(-s)).collect();
        CdfSampler::new(&weights)
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Is the category set empty? (Construction forbids it.)
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw one category index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // first index with cdf[i] > u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => (i + 1).min(self.cdf.len() - 1),
            Err(i) => i,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_weights() {
        let s = CdfSampler::new(&[1.0, 0.0, 3.0]);
        let mut rng = Rng::seed_from_u64(11);
        let mut counts = [0usize; 3];
        let n = 40_000;
        for _ in 0..n {
            counts[s.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let s = CdfSampler::zipf(1000, 1.1);
        let mut rng = Rng::seed_from_u64(12);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if s.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // top-10 of 1000 zipf(1.1) categories carry >> 1% of the mass
        assert!(head as f64 / n as f64 > 0.2, "head {head}");
    }

    #[test]
    fn covers_all_indices_in_range() {
        let s = CdfSampler::new(&[1.0; 7]);
        let mut rng = Rng::seed_from_u64(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[s.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    #[should_panic]
    fn zero_mass_panics() {
        CdfSampler::new(&[0.0, 0.0]);
    }
}
