//! UCB1 ablation selector (Auer et al. 2002), adapted to top-M_s play.
//!
//! Not part of the paper — included so the ablation benches can compare
//! the BTS posterior against a frequentist index policy under the same
//! reward signal (DESIGN.md §4, ablations).

use crate::rng::Rng;

use super::{top_m, ArmStats, ItemSelector};

/// UCB1 over items: index = mean + sqrt(2 ln t / n); unplayed items get
/// +inf (forced exploration).
#[derive(Debug, Clone)]
pub struct Ucb1Selector {
    t: u64,
    n: Vec<u64>,
    mean: Vec<f64>,
    scratch: Vec<f64>,
}

impl Ucb1Selector {
    /// Selector over an `m`-item catalog.
    pub fn new(m: usize) -> Self {
        Ucb1Selector {
            t: 0,
            n: vec![0; m],
            mean: vec![0.0; m],
            scratch: vec![0.0; m],
        }
    }
}

impl ItemSelector for Ucb1Selector {
    fn select(&mut self, m_s: usize, _rng: &mut Rng) -> Vec<u32> {
        self.t += 1;
        let ln_t = (self.t.max(1) as f64).ln();
        for j in 0..self.n.len() {
            self.scratch[j] = if self.n[j] == 0 {
                f64::INFINITY
            } else {
                self.mean[j] + (2.0 * ln_t / self.n[j] as f64).sqrt()
            };
        }
        top_m(&self.scratch, m_s)
    }

    fn update(&mut self, rewards: &[(u32, f64)]) {
        for &(item, r) in rewards {
            let i = item as usize;
            self.n[i] += 1;
            self.mean[i] += (r - self.mean[i]) / self.n[i] as f64;
        }
    }

    fn name(&self) -> &'static str {
        "ucb1"
    }

    /// `mu` is the running mean; `sigma` reports the UCB1 exploration
    /// bonus `sqrt(2 ln t / n)` — the frequentist analogue of a
    /// posterior width (infinite-index unplayed arms report sigma 0
    /// with 0 pulls).
    fn arm_stats(&self, item: u32) -> Option<ArmStats> {
        let i = item as usize;
        let sigma = if self.n[i] == 0 || self.t == 0 {
            0.0
        } else {
            (2.0 * (self.t as f64).ln() / self.n[i] as f64).sqrt()
        };
        Some(ArmStats {
            mu: self.mean[i],
            sigma,
            pulls: self.n[i],
        })
    }

    fn state_digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_u64(self.t);
        for (&n, &mean) in self.n.iter().zip(&self.mean) {
            h.write_u64(n);
            h.write_f64(mean);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unplayed_items_explored_first() {
        let mut sel = Ucb1Selector::new(10);
        let mut rng = Rng::seed_from_u64(1);
        // reward items 0..3 heavily, leave 4..10 unplayed
        for _ in 0..5 {
            for j in 0..3u32 {
                sel.update(&[(j, 100.0)]);
            }
        }
        let picks = sel.select(7, &mut rng);
        // all 7 unplayed items (3..10) have infinite index -> all selected
        for j in 3..10u32 {
            assert!(picks.contains(&j), "missing unplayed {j}");
        }
    }

    #[test]
    fn converges_to_best_arm_once_all_played() {
        let mut sel = Ucb1Selector::new(5);
        let mut rng = Rng::seed_from_u64(2);
        for j in 0..5u32 {
            sel.update(&[(j, if j == 2 { 10.0 } else { 0.0 })]);
        }
        for _ in 0..50 {
            let picks = sel.select(1, &mut rng);
            sel.update(&[(picks[0], if picks[0] == 2 { 10.0 } else { 0.0 })]);
        }
        // arm 2 should dominate the pull counts
        assert!(sel.n[2] > 30, "{:?}", sel.n);
    }
}
