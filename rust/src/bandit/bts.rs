//! Bayesian Thompson Sampling over items (paper §3.1, Eq. 7–12).
//!
//! Per item j the reward model is `R^j ~ N(μ^j, 1)` (fixed precision
//! τ = 1, Eq. 7) with a conjugate Gaussian prior `μ^j ~ N(μ_θ, 1/τ_θ)`
//! (Eq. 8). After n^j observations with running mean Z(a^j) (Eq. 12) the
//! posterior (Eq. 9–11) is
//!
//! ```text
//! μ̂_θ^j = (τ_θ μ_θ + n^j Z) / (τ_θ + n^j)
//! τ̂_θ^j = τ_θ + n^j τ
//! ```
//!
//! Each round we draw μ^j from every posterior and take the top-M_s
//! sampled values (the multiple-play / top-M extension the paper cites).

use crate::rng::Rng;

use super::{top_m, ArmStats, ItemSelector};

/// Reward-model precision τ (paper fixes variance = 1).
const TAU: f64 = 1.0;

/// Per-item Gaussian posterior state.
#[derive(Debug, Clone)]
struct Arm {
    /// Times this item was part of Q* (n^j).
    n: u64,
    /// Running mean of observed rewards, Z_t(a^j) (Eq. 12).
    mean_reward: f64,
}

/// FCF-BTS item selector.
#[derive(Debug, Clone)]
pub struct BtsSelector {
    mu0: f64,
    tau0: f64,
    arms: Vec<Arm>,
    /// Scratch for posterior draws (avoids re-allocating every round).
    samples: Vec<f64>,
}

impl BtsSelector {
    /// Selector over an `m`-item catalog with prior `N(mu0, 1/tau0)`.
    pub fn new(m: usize, mu0: f64, tau0: f64) -> BtsSelector {
        assert!(tau0 > 0.0, "prior precision must be positive");
        BtsSelector {
            mu0,
            tau0,
            arms: vec![
                Arm {
                    n: 0,
                    mean_reward: 0.0,
                };
                m
            ],
            samples: vec![0.0; m],
        }
    }

    /// Posterior parameters (μ̂, τ̂) for an item (Eq. 10–11). Public for
    /// tests and the convergence diagnostics.
    pub fn posterior(&self, item: usize) -> (f64, f64) {
        let arm = &self.arms[item];
        let n = arm.n as f64;
        let mu_hat = (self.tau0 * self.mu0 + n * arm.mean_reward) / (self.tau0 + n);
        let tau_hat = self.tau0 + n * TAU;
        (mu_hat, tau_hat)
    }

    /// Selection count n^j.
    pub fn pulls(&self, item: usize) -> u64 {
        self.arms[item].n
    }
}

impl ItemSelector for BtsSelector {
    fn select(&mut self, m_s: usize, rng: &mut Rng) -> Vec<u32> {
        for (j, arm) in self.arms.iter().enumerate() {
            let n = arm.n as f64;
            let mu_hat = (self.tau0 * self.mu0 + n * arm.mean_reward) / (self.tau0 + n);
            let tau_hat = self.tau0 + n * TAU;
            // μ^j ~ N(μ̂, 1/τ̂) (Eq. 9)
            self.samples[j] = rng.normal_with(mu_hat, (1.0 / tau_hat).sqrt());
        }
        top_m(&self.samples, m_s)
    }

    fn update(&mut self, rewards: &[(u32, f64)]) {
        for &(item, r) in rewards {
            let arm = &mut self.arms[item as usize];
            arm.n += 1;
            // incremental running mean (Eq. 12)
            arm.mean_reward += (r - arm.mean_reward) / arm.n as f64;
        }
    }

    fn name(&self) -> &'static str {
        "bts"
    }

    fn arm_stats(&self, item: u32) -> Option<ArmStats> {
        let (mu, tau) = self.posterior(item as usize);
        Some(ArmStats {
            mu,
            sigma: (1.0 / tau).sqrt(),
            pulls: self.pulls(item as usize),
        })
    }

    fn state_digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_f64(self.mu0);
        h.write_f64(self.tau0);
        for arm in &self.arms {
            h.write_u64(arm.n);
            h.write_f64(arm.mean_reward);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_posterior_before_any_reward() {
        let bts = BtsSelector::new(4, 0.5, 100.0);
        let (mu, tau) = bts.posterior(2);
        assert_eq!(mu, 0.5);
        assert_eq!(tau, 100.0);
    }

    #[test]
    fn posterior_update_matches_eq_10_11() {
        let mut bts = BtsSelector::new(2, 0.0, 10.0);
        bts.update(&[(0, 2.0)]);
        bts.update(&[(0, 4.0)]);
        // n=2, Z = 3.0
        let (mu, tau) = bts.posterior(0);
        assert!((mu - (2.0 * 3.0) / (10.0 + 2.0)).abs() < 1e-12);
        assert_eq!(tau, 12.0);
        // item 1 untouched
        assert_eq!(bts.pulls(1), 0);
    }

    #[test]
    fn running_mean_is_exact() {
        let mut bts = BtsSelector::new(1, 0.0, 1.0);
        let rs = [1.0, -2.0, 0.5, 3.5, 0.0];
        for &r in &rs {
            bts.update(&[(0, r)]);
        }
        let expect: f64 = rs.iter().sum::<f64>() / rs.len() as f64;
        let n = rs.len() as f64;
        let (mu, _) = bts.posterior(0);
        assert!((mu - n * expect / (1.0 + n)).abs() < 1e-12);
    }

    #[test]
    fn rewarded_items_get_selected_more() {
        let mut bts = BtsSelector::new(100, 0.0, 1.0);
        let mut rng = Rng::seed_from_u64(99);
        // heavily reward items 0..10
        for _ in 0..50 {
            for j in 0..10u32 {
                bts.update(&[(j, 5.0)]);
            }
        }
        let mut hits = 0;
        for _ in 0..20 {
            let picks = bts.select(10, &mut rng);
            hits += picks.iter().filter(|&&p| p < 10).count();
        }
        // with strong posteriors nearly every pick should be 0..10
        assert!(hits > 150, "hits {hits}");
    }

    #[test]
    fn high_prior_precision_keeps_exploring() {
        // paper's τ_θ = 10000 makes all posteriors ~identical early on;
        // selection should then be near-uniform across rounds.
        let mut bts = BtsSelector::new(200, 0.0, 10_000.0);
        let mut rng = Rng::seed_from_u64(7);
        let mut seen = vec![false; 200];
        for _ in 0..200 {
            for p in bts.select(10, &mut rng) {
                seen[p as usize] = true;
            }
        }
        let coverage = seen.iter().filter(|&&b| b).count();
        assert!(coverage > 150, "coverage {coverage}");
    }

    #[test]
    fn select_returns_distinct_sorted_domain() {
        let mut bts = BtsSelector::new(50, 0.0, 10.0);
        let mut rng = Rng::seed_from_u64(3);
        let picks = bts.select(50, &mut rng);
        assert_eq!(picks.len(), 50);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 50);
    }
}
