//! Item-selection strategies for payload optimization (paper §3.1).
//!
//! [`ItemSelector`] is the server-side abstraction: each FL round the
//! coordinator asks for `M_s` item ids to include in Q*, and after the
//! global update it feeds back per-item rewards (Eq. 13). Implementations:
//!
//! * [`BtsSelector`] — Bayesian Thompson Sampling with Gaussian priors
//!   (Eq. 7–12), the paper's FCF-BTS.
//! * [`RandomSelector`] — FCF-Random baseline (uniform subsets).
//! * [`FullSelector`] — FCF (Original): the whole catalog, every round.
//! * [`EpsGreedySelector`], [`Ucb1Selector`] — ablations over the same
//!   reward signal (not in the paper; used by the ablation benches).

mod bts;
mod simple;

pub use bts::BtsSelector;
pub use simple::{EpsGreedySelector, FullSelector, RandomSelector};
pub use ucb::Ucb1Selector;
mod ucb;

use crate::config::{BanditConfig, Strategy};
use crate::rng::Rng;

/// Per-arm posterior/empirical summary, recorded by the flight
/// recorder alongside each selection ([`ItemSelector::arm_stats`]).
/// `mu` is the strategy's point estimate of the arm's reward (the BTS
/// posterior mean μ̂, or a running empirical mean), `sigma` its
/// uncertainty scale (BTS posterior std `sqrt(1/τ̂)`; the UCB1
/// exploration bonus; zero where the strategy keeps none), and
/// `pulls` the selection count n^j.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArmStats {
    /// Point estimate of the arm's reward.
    pub mu: f64,
    /// Uncertainty scale attached to `mu` (0 when the strategy has none).
    pub sigma: f64,
    /// Times the arm was selected (n^j).
    pub pulls: u64,
}

/// Server-side item selection strategy (one per training run).
pub trait ItemSelector: Send {
    /// Pick `m_s` distinct item ids for this round's Q*.
    fn select(&mut self, m_s: usize, rng: &mut Rng) -> Vec<u32>;

    /// Feed back the rewards of the *selected* items after the global
    /// update (Alg. 1 line 17). `rewards[i]` pairs an item id with its
    /// Eq. 13 reward.
    fn update(&mut self, rewards: &[(u32, f64)]);

    /// Strategy name for logs/CSV.
    fn name(&self) -> &'static str;

    /// Posterior/empirical summary of one arm for the flight recorder.
    /// `None` (the default) for strategies that keep no per-arm state
    /// (random, full); the trace then records the selection without a
    /// posterior block.
    fn arm_stats(&self, _item: u32) -> Option<ArmStats> {
        None
    }

    /// FNV-64 digest of the strategy's mutable state (priors, pull
    /// counts, running reward means — exact bit patterns, not values),
    /// recorded per round by the journal so a `--resume` replay can
    /// verify the reconstructed posteriors at every step. The default
    /// `0` is for stateless strategies (random, full): their selection
    /// is a pure function of the RNG stream, which the journal
    /// fingerprints separately.
    fn state_digest(&self) -> u64 {
        0
    }
}

/// Construct the selector for a strategy over an `m`-item catalog.
pub fn make_selector(
    strategy: Strategy,
    m: usize,
    cfg: &BanditConfig,
) -> Box<dyn ItemSelector> {
    match strategy {
        Strategy::Bts => Box::new(BtsSelector::new(m, cfg.mu0, cfg.tau0)),
        Strategy::Random => Box::new(RandomSelector::new(m)),
        Strategy::Full => Box::new(FullSelector::new(m)),
        Strategy::EpsGreedy => Box::new(EpsGreedySelector::new(m, cfg.eps_greedy)),
        Strategy::Ucb1 => Box::new(Ucb1Selector::new(m)),
    }
}

/// Top-`m_s` indices of `keys` (descending), via partial selection —
/// O(m) instead of O(m log m); ties break by index for determinism.
pub(crate) fn top_m(keys: &[f64], m_s: usize) -> Vec<u32> {
    let m = keys.len();
    let m_s = m_s.min(m);
    if m_s == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..m as u32).collect();
    if m_s < m {
        idx.select_nth_unstable_by(m_s - 1, |&a, &b| {
            keys[b as usize]
                .partial_cmp(&keys[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(m_s);
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn factory_builds_every_strategy() {
        let cfg = RunConfig::paper_defaults().bandit;
        for s in [
            Strategy::Bts,
            Strategy::Random,
            Strategy::Full,
            Strategy::EpsGreedy,
            Strategy::Ucb1,
        ] {
            let mut sel = make_selector(s, 50, &cfg);
            let mut rng = Rng::seed_from_u64(1);
            let picks = sel.select(10, &mut rng);
            let expect = if s == Strategy::Full { 50 } else { 10 };
            assert_eq!(picks.len(), expect, "{}", sel.name());
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), expect, "{} returned duplicates", sel.name());
            sel.update(&[(0, 1.0), (3, -0.5)]);
        }
    }

    #[test]
    fn arm_stats_cover_the_stateful_strategies() {
        let cfg = RunConfig::paper_defaults().bandit;
        for (s, has_stats) in [
            (Strategy::Bts, true),
            (Strategy::Ucb1, true),
            (Strategy::EpsGreedy, true),
            (Strategy::Random, false),
            (Strategy::Full, false),
        ] {
            let mut sel = make_selector(s, 20, &cfg);
            sel.update(&[(4, 2.0), (4, 4.0)]);
            let stats = sel.arm_stats(4);
            assert_eq!(stats.is_some(), has_stats, "{}", sel.name());
            if let Some(st) = stats {
                assert_eq!(st.pulls, 2, "{}", sel.name());
                assert!(st.mu.is_finite() && st.sigma >= 0.0);
            }
        }
        // BTS sigma is the posterior std and must shrink with pulls
        let mut bts = BtsSelector::new(4, 0.0, 1.0);
        let s0 = bts.arm_stats(0).unwrap();
        bts.update(&[(0, 1.0), (0, 1.0), (0, 1.0)]);
        let s3 = bts.arm_stats(0).unwrap();
        assert!(s3.sigma < s0.sigma);
        assert_eq!(s3.pulls, 3);
    }

    #[test]
    fn state_digest_tracks_updates_on_stateful_strategies() {
        let cfg = RunConfig::paper_defaults().bandit;
        for s in [Strategy::Bts, Strategy::EpsGreedy, Strategy::Ucb1] {
            let mut sel = make_selector(s, 20, &cfg);
            let fresh = make_selector(s, 20, &cfg);
            assert_eq!(
                sel.state_digest(),
                fresh.state_digest(),
                "{}: equal initial state must digest equally",
                sel.name()
            );
            let before = sel.state_digest();
            sel.update(&[(4, 2.0)]);
            assert_ne!(before, sel.state_digest(), "{}: update must move the digest", sel.name());
        }
        // ucb1 also mutates on select (its round counter t)
        let mut ucb = Ucb1Selector::new(8);
        let before = ucb.state_digest();
        let mut rng = Rng::seed_from_u64(1);
        let _ = ucb.select(3, &mut rng);
        assert_ne!(before, ucb.state_digest());
        // stateless strategies digest to the sentinel 0
        for s in [Strategy::Random, Strategy::Full] {
            assert_eq!(make_selector(s, 20, &cfg).state_digest(), 0);
        }
    }

    #[test]
    fn top_m_selects_largest() {
        let keys = vec![0.1, 5.0, 3.0, 4.0, 2.0];
        let mut got = top_m(&keys, 3);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn top_m_full_when_ms_ge_m() {
        let keys = vec![1.0, 2.0];
        assert_eq!(top_m(&keys, 5).len(), 2);
    }
}
