//! Item-selection strategies for payload optimization (paper §3.1).
//!
//! [`ItemSelector`] is the server-side abstraction: each FL round the
//! coordinator asks for `M_s` item ids to include in Q*, and after the
//! global update it feeds back per-item rewards (Eq. 13). Implementations:
//!
//! * [`BtsSelector`] — Bayesian Thompson Sampling with Gaussian priors
//!   (Eq. 7–12), the paper's FCF-BTS.
//! * [`RandomSelector`] — FCF-Random baseline (uniform subsets).
//! * [`FullSelector`] — FCF (Original): the whole catalog, every round.
//! * [`EpsGreedySelector`], [`Ucb1Selector`] — ablations over the same
//!   reward signal (not in the paper; used by the ablation benches).

mod bts;
mod simple;

pub use bts::BtsSelector;
pub use simple::{EpsGreedySelector, FullSelector, RandomSelector};
pub use ucb::Ucb1Selector;
mod ucb;

use crate::config::{BanditConfig, Strategy};
use crate::rng::Rng;

/// Server-side item selection strategy (one per training run).
pub trait ItemSelector: Send {
    /// Pick `m_s` distinct item ids for this round's Q*.
    fn select(&mut self, m_s: usize, rng: &mut Rng) -> Vec<u32>;

    /// Feed back the rewards of the *selected* items after the global
    /// update (Alg. 1 line 17). `rewards[i]` pairs an item id with its
    /// Eq. 13 reward.
    fn update(&mut self, rewards: &[(u32, f64)]);

    /// Strategy name for logs/CSV.
    fn name(&self) -> &'static str;
}

/// Construct the selector for a strategy over an `m`-item catalog.
pub fn make_selector(
    strategy: Strategy,
    m: usize,
    cfg: &BanditConfig,
) -> Box<dyn ItemSelector> {
    match strategy {
        Strategy::Bts => Box::new(BtsSelector::new(m, cfg.mu0, cfg.tau0)),
        Strategy::Random => Box::new(RandomSelector::new(m)),
        Strategy::Full => Box::new(FullSelector::new(m)),
        Strategy::EpsGreedy => Box::new(EpsGreedySelector::new(m, cfg.eps_greedy)),
        Strategy::Ucb1 => Box::new(Ucb1Selector::new(m)),
    }
}

/// Top-`m_s` indices of `keys` (descending), via partial selection —
/// O(m) instead of O(m log m); ties break by index for determinism.
pub(crate) fn top_m(keys: &[f64], m_s: usize) -> Vec<u32> {
    let m = keys.len();
    let m_s = m_s.min(m);
    if m_s == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..m as u32).collect();
    if m_s < m {
        idx.select_nth_unstable_by(m_s - 1, |&a, &b| {
            keys[b as usize]
                .partial_cmp(&keys[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(m_s);
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn factory_builds_every_strategy() {
        let cfg = RunConfig::paper_defaults().bandit;
        for s in [
            Strategy::Bts,
            Strategy::Random,
            Strategy::Full,
            Strategy::EpsGreedy,
            Strategy::Ucb1,
        ] {
            let mut sel = make_selector(s, 50, &cfg);
            let mut rng = Rng::seed_from_u64(1);
            let picks = sel.select(10, &mut rng);
            let expect = if s == Strategy::Full { 50 } else { 10 };
            assert_eq!(picks.len(), expect, "{}", sel.name());
            let mut sorted = picks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), expect, "{} returned duplicates", sel.name());
            sel.update(&[(0, 1.0), (3, -0.5)]);
        }
    }

    #[test]
    fn top_m_selects_largest() {
        let keys = vec![0.1, 5.0, 3.0, 4.0, 2.0];
        let mut got = top_m(&keys, 3);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn top_m_full_when_ms_ge_m() {
        let keys = vec![1.0, 2.0];
        assert_eq!(top_m(&keys, 5).len(), 2);
    }
}
