//! Non-Bayesian selectors: the paper's FCF-Random baseline, the FCF
//! (Original) full-payload upper bound, and an ε-greedy ablation.

use crate::rng::Rng;

use super::{top_m, ArmStats, ItemSelector};

/// FCF-Random: a uniformly random item subset each round (paper §6).
#[derive(Debug, Clone)]
pub struct RandomSelector {
    m: usize,
}

impl RandomSelector {
    /// Selector over an `m`-item catalog.
    pub fn new(m: usize) -> Self {
        RandomSelector { m }
    }
}

impl ItemSelector for RandomSelector {
    fn select(&mut self, m_s: usize, rng: &mut Rng) -> Vec<u32> {
        rng.sample_indices(self.m, m_s.min(self.m))
            .into_iter()
            .map(|i| i as u32)
            .collect()
    }

    fn update(&mut self, _rewards: &[(u32, f64)]) {}

    fn name(&self) -> &'static str {
        "random"
    }
}

/// FCF (Original): transmit the full catalog every round (upper bound —
/// no payload optimization).
#[derive(Debug, Clone)]
pub struct FullSelector {
    m: usize,
}

impl FullSelector {
    /// Selector over an `m`-item catalog.
    pub fn new(m: usize) -> Self {
        FullSelector { m }
    }
}

impl ItemSelector for FullSelector {
    fn select(&mut self, _m_s: usize, _rng: &mut Rng) -> Vec<u32> {
        (0..self.m as u32).collect()
    }

    fn update(&mut self, _rewards: &[(u32, f64)]) {}

    fn name(&self) -> &'static str {
        "full"
    }
}

/// ε-greedy ablation: (1-ε) of the budget goes to the items with the best
/// running mean reward, ε to uniform exploration.
#[derive(Debug, Clone)]
pub struct EpsGreedySelector {
    eps: f64,
    n: Vec<u64>,
    mean: Vec<f64>,
}

impl EpsGreedySelector {
    /// Selector over an `m`-item catalog exploring with probability `eps`.
    pub fn new(m: usize, eps: f64) -> Self {
        assert!((0.0..=1.0).contains(&eps));
        EpsGreedySelector {
            eps,
            n: vec![0; m],
            mean: vec![0.0; m],
        }
    }
}

impl ItemSelector for EpsGreedySelector {
    fn select(&mut self, m_s: usize, rng: &mut Rng) -> Vec<u32> {
        let m = self.n.len();
        let m_s = m_s.min(m);
        let n_explore = ((m_s as f64) * self.eps).round() as usize;
        let n_exploit = m_s - n_explore;
        let mut picks = top_m(&self.mean, n_exploit);
        // fill the explore share with uniform items not already picked
        let mut taken: Vec<bool> = vec![false; m];
        for &p in &picks {
            taken[p as usize] = true;
        }
        let mut guard = 0;
        while picks.len() < m_s && guard < 100 * m_s + 100 {
            guard += 1;
            let cand = rng.below(m);
            if !taken[cand] {
                taken[cand] = true;
                picks.push(cand as u32);
            }
        }
        picks
    }

    fn update(&mut self, rewards: &[(u32, f64)]) {
        for &(item, r) in rewards {
            let i = item as usize;
            self.n[i] += 1;
            self.mean[i] += (r - self.mean[i]) / self.n[i] as f64;
        }
    }

    fn name(&self) -> &'static str {
        "eps_greedy"
    }

    /// Running empirical mean; ε-greedy keeps no uncertainty estimate,
    /// so `sigma` is 0.
    fn arm_stats(&self, item: u32) -> Option<ArmStats> {
        let i = item as usize;
        Some(ArmStats {
            mu: self.mean[i],
            sigma: 0.0,
            pulls: self.n[i],
        })
    }

    fn state_digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_f64(self.eps);
        for (&n, &mean) in self.n.iter().zip(&self.mean) {
            h.write_u64(n);
            h.write_f64(mean);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_selects_distinct_in_range() {
        let mut sel = RandomSelector::new(30);
        let mut rng = Rng::seed_from_u64(5);
        let picks = sel.select(10, &mut rng);
        assert_eq!(picks.len(), 10);
        assert!(picks.iter().all(|&p| p < 30));
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn random_is_uniform_over_rounds() {
        let mut sel = RandomSelector::new(20);
        let mut rng = Rng::seed_from_u64(6);
        let mut counts = vec![0usize; 20];
        for _ in 0..2000 {
            for p in sel.select(5, &mut rng) {
                counts[p as usize] += 1;
            }
        }
        let expected = 2000.0 * 5.0 / 20.0;
        for &c in &counts {
            assert!((c as f64 - expected).abs() < 6.0 * expected.sqrt(), "{c}");
        }
    }

    #[test]
    fn full_returns_everything_always() {
        let mut sel = FullSelector::new(7);
        let mut rng = Rng::seed_from_u64(1);
        assert_eq!(sel.select(3, &mut rng), (0..7u32).collect::<Vec<_>>());
    }

    #[test]
    fn eps_greedy_exploits_best_items() {
        let mut sel = EpsGreedySelector::new(50, 0.2);
        for _ in 0..20 {
            for j in 0..5u32 {
                sel.update(&[(j, 10.0)]);
            }
        }
        let mut rng = Rng::seed_from_u64(8);
        let picks = sel.select(10, &mut rng);
        let exploit_hits = picks.iter().filter(|&&p| p < 5).count();
        assert!(exploit_hits >= 5, "{exploit_hits}");
        assert_eq!(picks.len(), 10);
    }

    #[test]
    fn eps_one_is_fully_random() {
        let mut sel = EpsGreedySelector::new(40, 1.0);
        sel.update(&[(0, 100.0)]);
        let mut rng = Rng::seed_from_u64(9);
        let mut zero_picked = 0;
        for _ in 0..100 {
            if sel.select(4, &mut rng).contains(&0) {
                zero_picked += 1;
            }
        }
        // pure exploration: item 0 should appear ~10% of rounds, not always
        assert!(zero_picked < 50, "{zero_picked}");
    }
}
