//! The paper's composite reward function (§3.2, Eq. 13–14).
//!
//! For each selected item j at FL iteration t, given the aggregated
//! gradient column g = ∇ʲQ_t* (a K-vector):
//!
//! ```text
//! r_t^j = w_cos(t) · cos(v_t^j, g)  +  (γ/t) · Σ_k |∇ʲQ_{t−1} − g|
//! ```
//!
//! with v updated first (Alg. 1 line 14) by Eq. 14:
//!
//! ```text
//! v_t^j = (β₂ v_{t−1}^j + (1−β₂) g²) / (1−β₂)
//! ```
//!
//! and ∇ʲQ_{t−1} = the gradient stored the *last time j was selected*
//! (Alg. 1 lines 5/18; zero before the first selection).
//!
//! ## Faithfulness notes (see DESIGN.md §1)
//!
//! * **Cosine weight.** The paper prints `(1 − γt)`, which is negative
//!   from t ≥ 2 at γ = 0.999, yet the text says the cosine term
//!   "increases the reward … with the increasing number of FL
//!   iterations" — the behaviour of `(1 − γ^t)`. We default to the
//!   textual behaviour ([`CosineWeight::Power`]) and expose the literal
//!   formula ([`CosineWeight::Literal`]) for the ablation bench.
//! * **Eq. 14's 1/(1−β₂).** Taken literally the update is
//!   `v_t = 99 v_{t−1} + g²` at β₂ = 0.99 — geometric growth that
//!   overflows f64 after ~150 selections. Cosine similarity is
//!   scale-invariant, so we store v in f64 and renormalize when its
//!   magnitude exceeds 1e50; rewards are unchanged. The Adam-style
//!   bias-corrected variant is exposed as [`VRule::Adam`] for ablation.

use crate::linalg::{cosine_sim_f64_f32, l1_dist};

/// Which cosine-term weighting to use (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CosineWeight {
    /// `1 − γ^t` — matches the paper's textual description (default).
    Power,
    /// `1 − γ·t` — the formula exactly as printed.
    Literal,
}

/// Which Eq. 14 variant maintains the squared-gradient trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VRule {
    /// `(β₂ v + (1−β₂) g²) / (1−β₂)` as printed, with renormalization.
    Literal,
    /// Adam's `v/(1−β₂^n)` bias correction (ablation).
    Adam,
}

/// What `t` means in Eq. 13's weights (the paper is ambiguous: γ "scaled
/// by the a factor t" with items entering Q* at different times).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeBase {
    /// `t` = this item's observation count n_j: each item's
    /// explore→exploit schedule advances with its own selections
    /// (default — see EXPERIMENTS.md §Calibration).
    PerItem,
    /// `t` = the global FL iteration, as a flat reading of Alg. 1.
    Global,
}

/// Reward engine: per-item gradient memory + squared-gradient trace.
#[derive(Debug, Clone)]
pub struct RewardEngine {
    k: usize,
    gamma: f64,
    beta2: f64,
    cosine_weight: CosineWeight,
    v_rule: VRule,
    time_base: TimeBase,
    /// v^j traces, item-major (M × K), f64 for headroom (see module docs).
    v: Vec<f64>,
    /// ∇ʲQ stored at the item's last selection (Alg. 1 line 18), M × K.
    last_grad: Vec<f32>,
    /// Per-item count of Eq. 14 applications (for the Adam variant).
    n: Vec<u32>,
}

/// Renormalization threshold for the literal Eq. 14 trace.
const V_RENORM_LIMIT: f64 = 1e50;

impl RewardEngine {
    /// Engine over an `m`-item catalog with `k` factors and the paper's
    /// γ / β₂ constants (defaults: power weighting, literal Eq. 14,
    /// per-item time base).
    pub fn new(m: usize, k: usize, gamma: f64, beta2: f64) -> RewardEngine {
        RewardEngine {
            k,
            gamma,
            beta2,
            cosine_weight: CosineWeight::Power,
            v_rule: VRule::Literal,
            time_base: TimeBase::PerItem,
            v: vec![0.0; m * k],
            last_grad: vec![0.0; m * k],
            n: vec![0; m],
        }
    }

    /// Select the cosine-term weighting (builder style).
    pub fn with_cosine_weight(mut self, w: CosineWeight) -> Self {
        self.cosine_weight = w;
        self
    }

    /// Select the Eq. 14 trace variant (builder style).
    pub fn with_v_rule(mut self, r: VRule) -> Self {
        self.v_rule = r;
        self
    }

    /// Select what `t` means in Eq. 13 (builder style).
    pub fn with_time_base(mut self, tb: TimeBase) -> Self {
        self.time_base = tb;
        self
    }

    fn cos_weight(&self, t: u64) -> f64 {
        match self.cosine_weight {
            CosineWeight::Power => 1.0 - self.gamma.powi(t as i32),
            CosineWeight::Literal => 1.0 - self.gamma * t as f64,
        }
    }

    /// Process one item's aggregated gradient at FL iteration `t`
    /// (1-based): update v (Eq. 14), compute r (Eq. 13), store the
    /// gradient (Alg. 1 line 18). Returns r_t^j.
    pub fn observe(&mut self, item: u32, t: u64, grad: &[f32]) -> f64 {
        assert_eq!(grad.len(), self.k, "gradient must be a K-vector");
        assert!(t >= 1, "FL iterations are 1-based");
        let i = item as usize;
        let vrow = &mut self.v[i * self.k..(i + 1) * self.k];

        // Eq. 14 (Alg. 1 line 14) — update the squared-gradient trace.
        self.n[i] += 1;
        match self.v_rule {
            VRule::Literal => {
                let inv = 1.0 / (1.0 - self.beta2);
                let mut maxabs = 0.0f64;
                for (vk, &g) in vrow.iter_mut().zip(grad) {
                    *vk = (self.beta2 * *vk + (1.0 - self.beta2) * (g as f64) * (g as f64)) * inv;
                    maxabs = maxabs.max(vk.abs());
                }
                if maxabs > V_RENORM_LIMIT {
                    // cosine is scale-invariant; keep direction only
                    for vk in vrow.iter_mut() {
                        *vk /= maxabs;
                    }
                }
            }
            VRule::Adam => {
                let bc = 1.0 - self.beta2.powi(self.n[i] as i32);
                for (vk, &g) in vrow.iter_mut().zip(grad) {
                    // store the raw EMA; bias-correct on read
                    *vk = self.beta2 * *vk + (1.0 - self.beta2) * (g as f64) * (g as f64);
                    let _ = bc;
                }
            }
        }

        // Eq. 13 — composite reward. Cosine computed in f64: the literal
        // Eq. 14 trace spans scales that overflow f32 (bias correction is
        // scale-only, so the Adam variant needs no extra factor here).
        let t_eff = match self.time_base {
            TimeBase::PerItem => self.n[i] as u64,
            TimeBase::Global => t,
        };
        let cos = cosine_sim_f64_f32(vrow, grad);
        let prev = &self.last_grad[i * self.k..(i + 1) * self.k];
        let l1 = l1_dist(prev, grad) as f64;
        let r = self.cos_weight(t_eff) * cos + (self.gamma / t_eff as f64) * l1;

        // Alg. 1 line 18 — remember this gradient for the next selection.
        self.last_grad[i * self.k..(i + 1) * self.k].copy_from_slice(grad);
        r
    }

    /// v trace for an item (tests/diagnostics).
    pub fn v_trace(&self, item: u32) -> &[f64] {
        let i = item as usize;
        &self.v[i * self.k..(i + 1) * self.k]
    }

    /// Last stored gradient for an item (tests/diagnostics).
    pub fn last_gradient(&self, item: u32) -> &[f32] {
        let i = item as usize;
        &self.last_grad[i * self.k..(i + 1) * self.k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(m: usize, k: usize) -> RewardEngine {
        RewardEngine::new(m, k, 0.999, 0.99)
    }

    #[test]
    fn first_observation_reward_is_l1_dominated() {
        let mut e = engine(2, 3);
        let g = [1.0f32, -2.0, 0.5];
        let r = e.observe(0, 1, &g);
        // t=1: cos weight = 1-0.999 = 0.001; v ∝ g² so cos(v, g) is some
        // value in [-1,1]; l1 term = 0.999 * (1+2+0.5) = 3.4965
        let l1_term = 0.999 * 3.5;
        assert!((r - l1_term).abs() < 0.01, "r={r}");
    }

    #[test]
    fn stable_gradients_earn_cosine_reward_late() {
        let mut e = engine(1, 4);
        let g = [0.5f32, 0.5, 0.5, 0.5];
        // repeated identical gradients: v ∝ g², cos(v,g)=1 (all positive
        // equal entries), l1 -> 0 after the first observation
        let mut last = 0.0;
        for t in 1..=500u64 {
            last = e.observe(0, t, &g);
        }
        // w_cos(500) = 1-0.999^500 ≈ 0.393; l1 = 0
        let expect = 1.0 - 0.999f64.powi(500);
        assert!((last - expect).abs() < 1e-3, "last={last} expect={expect}");
    }

    #[test]
    fn changing_gradients_earn_l1_reward_early() {
        let mut e = engine(1, 2);
        let r1 = e.observe(0, 1, &[10.0, -10.0]);
        let r2 = e.observe(0, 2, &[-10.0, 10.0]);
        // big immediate change: l1 = 40, weight γ/2
        assert!(r2 > 0.999 / 2.0 * 40.0 - 1.0, "r2={r2}");
        assert!(r1 > 0.0);
    }

    #[test]
    fn last_gradient_updates_only_for_observed_item() {
        let mut e = engine(3, 2);
        e.observe(1, 1, &[1.0, 2.0]);
        assert_eq!(e.last_gradient(1), &[1.0, 2.0]);
        assert_eq!(e.last_gradient(0), &[0.0, 0.0]);
        assert_eq!(e.last_gradient(2), &[0.0, 0.0]);
    }

    #[test]
    fn literal_v_rule_never_overflows() {
        let mut e = engine(1, 2).with_v_rule(VRule::Literal);
        for t in 1..=5000u64 {
            let r = e.observe(0, t, &[1.0, 1.0]);
            assert!(r.is_finite(), "t={t} r={r}");
        }
        assert!(e.v_trace(0).iter().all(|v| v.is_finite()));
    }

    #[test]
    fn literal_cosine_weight_goes_negative() {
        let mut e = engine(1, 2).with_cosine_weight(CosineWeight::Literal);
        assert!(e.cos_weight(1) > 0.0 - 1e-9);
        assert!(e.cos_weight(10) < 0.0);
        // reward still finite and dominated by l1 early
        let r = e.observe(0, 10, &[1.0, 1.0]);
        assert!(r.is_finite());
    }

    #[test]
    fn power_weight_increases_with_t() {
        let e = engine(1, 2);
        assert!(e.cos_weight(1) < e.cos_weight(10));
        assert!(e.cos_weight(10) < e.cos_weight(1000));
        assert!(e.cos_weight(1000) < 1.0);
    }

    #[test]
    fn adam_v_rule_matches_literal_direction() {
        // both rules produce v ∝ running square average direction; with a
        // constant gradient their cosine rewards converge to the same value
        let g = [0.3f32, 0.9];
        let mut lit = engine(1, 2).with_v_rule(VRule::Literal);
        let mut adam = engine(1, 2).with_v_rule(VRule::Adam);
        let mut rl = 0.0;
        let mut ra = 0.0;
        for t in 1..=200u64 {
            rl = lit.observe(0, t, &g);
            ra = adam.observe(0, t, &g);
        }
        assert!((rl - ra).abs() < 1e-6, "rl={rl} ra={ra}");
    }
}
