//! `coordinator` — the trainer with a real TCP round lane.
//!
//! Runs the identical training loop as `fedpayload train`, but every
//! round's downloads, uploads, and batch compute move over sockets to
//! `client` processes (`rust/src/bin/client.rs`). Fault-free, the
//! outputs — round dumps, trace digests, journals — are byte-identical
//! to the in-process bin's; `ci/transport_e2e.sh` diffs them.

use std::process::ExitCode;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use fedpayload::cli::{resolve_config, write_round_dump, Args};
use fedpayload::server::Trainer;
use fedpayload::simnet::human_bytes;
use fedpayload::telemetry;
use fedpayload::transport::TcpLane;

const USAGE: &str = "\
coordinator — fedpayload trainer over the TCP transport lane

USAGE:
  coordinator train [--listen HOST:PORT] [--port-file FILE]
                    [--transport-clients N] [--connect-timeout-secs S]
                    [--round-deadline-ms MS] [--bandwidth-cap BPS]
                    [--wait-rejoin] [--rejoin-wait-ms MS]
                    [...every `fedpayload train` option...]
  coordinator help

  Binds --listen (port 0 = ephemeral), writes the bound address to
  --port-file (atomically; clients poll for it), waits for
  --transport-clients client processes to handshake, then trains.
  Client processes must resolve the identical training config — the
  handshake rejects a mismatched determinism fingerprint, naming the
  first differing key. --round-deadline-ms bounds each round: what has
  not arrived by then is dropped and the round aggregates partially.
  --bandwidth-cap paces each client's downloads (logical schedule;
  bit-transparent). --wait-rejoin holds round starts until crashed
  slots reconnect instead of dropping their clients.
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    if let Some(level) = args.opt("log-level") {
        match telemetry::parse_level(level) {
            Some(l) => telemetry::set_log_level(l),
            None => bail!(
                "bad --log-level `{level}` (expected one of: {})",
                telemetry::LEVEL_NAMES
            ),
        }
    }
    match args.subcommand.as_deref() {
        Some("train") | None => cmd_train(&args),
        Some("help") => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = resolve_config(args)?;
    // The TCP lane carries one uniform codec per round and records
    // uploads at the batch barrier: per-client policy cohorts and
    // upload-delta attribution are in-process-lane features for now.
    // Refuse at startup, naming the keys, rather than training a round
    // whose accounting silently diverges from the in-process lane.
    ensure!(
        cfg.policy.mode == fedpayload::server::policy::PolicyMode::Uniform,
        "the TCP transport lane does not support per-client payload policies yet \
         (policy.mode = {}); run with policy.mode = \"uniform\" or use the in-process bin",
        cfg.policy.mode.name()
    );
    ensure!(
        !cfg.codec.upload_delta,
        "the TCP transport lane does not support upload-delta sessions yet \
         (codec.upload_delta = true); disable it or use the in-process bin"
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    let mut lane = TcpLane::bind(&cfg.transport, cfg.determinism_fingerprint())?;
    let addr = lane.local_addr();
    eprintln!(
        "coordinator: listening on {addr}, waiting for {} client process(es)",
        cfg.transport.clients
    );
    if let Some(path) = args.opt("port-file") {
        write_port_file(path, &addr.to_string())?;
    }
    let wait = Duration::from_secs(args.opt_or::<u64>("connect-timeout-secs", 60)?);
    lane.wait_for_fleet(wait)?;
    eprintln!("coordinator: fleet connected, training starts");
    trainer.install_lane(Box::new(lane));
    let report = trainer.run()?;
    println!(
        "run complete: strategy={} codec={} entropy={} codebook_reuse={} iterations={} \
         M={} M_s={} ({:.0}% payload reduction)",
        report.strategy,
        report.codec,
        report.entropy,
        report.codebook_reuse,
        report.iterations,
        report.m,
        report.m_s,
        report.payload_reduction_pct()
    );
    if let Some(s) = &report.session {
        println!(
            "codebook session: {} reuse / {} delta / {} full frames, {} resyncs \
             ({:+} extra bytes)",
            s.reuse_frames, s.delta_frames, s.full_frames, s.resync_msgs, s.resync_extra_bytes
        );
    }
    println!("final metrics (window mean): {}", report.final_metrics);
    println!(
        "traffic: down={} ({} msgs), up={} ({} msgs), simulated transfer {:.1}s",
        human_bytes(report.ledger.down_bytes),
        report.ledger.down_msgs,
        human_bytes(report.ledger.up_bytes),
        report.ledger.up_msgs,
        report.ledger.sim_secs
    );
    if let Some(t) = trainer.lane_mut().stats() {
        println!(
            "transport: {} rounds, {} msgs sent / {} recv ({} / {} on the wire), \
             {} resyncs served ({} requested), {} dropouts, {} rejoins, \
             {} deadline expiries, {:.3}s paced",
            t.rounds,
            t.msgs_sent,
            t.msgs_recv,
            human_bytes(t.bytes_sent),
            human_bytes(t.bytes_recv),
            t.resyncs_served,
            t.need_resync_reqs,
            t.dropouts,
            t.rejoins,
            t.deadline_expiries,
            t.paced_wait_ns as f64 / 1e9
        );
    }
    if let Some(path) = args.opt("dump-rounds") {
        write_round_dump(path, &report)?;
        println!("round records dumped to {path}");
    }
    if let Some(path) = cfg.journal.path.as_ref().or(cfg.journal.resume.as_ref()) {
        println!("round journal: {path}");
    }
    Ok(())
}

/// Publish the bound address atomically (write + rename) so a client
/// polling the path can never read a half-written file.
fn write_port_file(path: &str, addr: &str) -> Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, addr).with_context(|| format!("writing port file {tmp}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("publishing port file {path}"))?;
    Ok(())
}
