//! `client` — the device side of the TCP transport lane.
//!
//! Hosts one process slot's share of the simulated fleet: rebuilds the
//! dataset from the same config the coordinator resolved, decodes every
//! broadcast/download frame, computes its assigned client batches with
//! the same kernels the in-process executor runs, and ships encoded
//! gradients back. See `rust/src/transport/client_proc.rs`.

use std::process::ExitCode;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use fedpayload::cli::{resolve_config, Args};
use fedpayload::telemetry;
use fedpayload::transport::{connect_with_retry, ClientEngine, FaultPlan};

const USAGE: &str = "\
client — fedpayload client-process engine (TCP transport lane)

USAGE:
  client run [--connect HOST:PORT | --port-file FILE]
             [--connect-timeout-secs S]
             [--exit-after-round N] [--stall-in-round N]
             [...every `fedpayload train` option...]
  client help

  Resolves the SAME training config as the coordinator (same flags /
  config file — the handshake rejects a mismatched determinism
  fingerprint), dials --connect or the address published in
  --port-file, and serves rounds until the coordinator shuts the
  session down. --exit-after-round / --stall-in-round inject the
  dropout faults the e2e tests drive.
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    if let Some(level) = args.opt("log-level") {
        match telemetry::parse_level(level) {
            Some(l) => telemetry::set_log_level(l),
            None => bail!(
                "bad --log-level `{level}` (expected one of: {})",
                telemetry::LEVEL_NAMES
            ),
        }
    }
    match args.subcommand.as_deref() {
        Some("run") | None => cmd_run(&args),
        Some("help") => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = resolve_config(args)?;
    let timeout = Duration::from_secs(args.opt_or::<u64>("connect-timeout-secs", 30)?);
    let addr = match args.opt("port-file") {
        Some(path) => read_port_file(path, timeout)?,
        None => cfg.transport.connect.clone(),
    };
    let fault = FaultPlan {
        exit_after_round: args.opt_parse::<u64>("exit-after-round")?,
        stall_in_round: args.opt_parse::<u64>("stall-in-round")?,
    };
    let mut engine = ClientEngine::new(&cfg)?;
    let stream = connect_with_retry(&addr, timeout)?;
    let report = engine.run(stream, fault)?;
    println!(
        "client: slot {}/{} — {} rounds, {} batches, {} downloads acked, \
         {} mirror resyncs, {} hosted resyncs{}",
        report.slot,
        report.slots,
        report.rounds,
        report.batches,
        report.downloads,
        report.mirror_resyncs,
        report.hosted_resyncs,
        if report.crashed {
            " (fault-plan exit)"
        } else {
            ""
        }
    );
    Ok(())
}

/// Poll for the coordinator's port file (it is written atomically, so a
/// readable file is a complete address).
fn read_port_file(path: &str, timeout: Duration) -> Result<String> {
    let deadline = Instant::now() + timeout;
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim();
            if !s.is_empty() {
                return Ok(s.to_string());
            }
        }
        if Instant::now() >= deadline {
            bail!("port file {path} did not appear within {timeout:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}
