//! Pure-Rust reference backend: the same math as the artifacts
//! (`python/compile/kernels/ref.py`, re-derived from paper Eq. 3, 5–6),
//! implemented a third time for differential testing — and usable as a
//! fallback backend when `artifacts/` is absent.
//!
//! The accumulation exploits the classic implicit-ALS decomposition
//! `Q C Qᵀ = Q Qᵀ + α Q_{x=1} Q_{x=1}ᵀ`: the first Gram term is
//! user-independent and computed once per tile, the sparse correction
//! costs O(nnz·K²).

use anyhow::Result;

use crate::linalg::{cholesky_solve, Mat};

use super::ComputeBackend;

/// The pure-Rust fallback [`ComputeBackend`].
pub struct ReferenceBackend {
    b: usize,
    k: usize,
    tiles: Vec<usize>,
    alpha: f32,
    lam: f32,
}

impl ReferenceBackend {
    /// Backend with explicit geometry and math constants.
    pub fn new(b: usize, k: usize, mut tiles: Vec<usize>, alpha: f32, lam: f32) -> Self {
        tiles.sort_unstable();
        ReferenceBackend {
            b,
            k,
            tiles,
            alpha,
            lam,
        }
    }
}

impl ComputeBackend for ReferenceBackend {
    fn geometry(&self) -> (usize, usize, Vec<usize>) {
        (self.b, self.k, self.tiles.clone())
    }

    fn accum(
        &mut self,
        t: usize,
        q: &[f32],
        x: &[f32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (b, k) = (self.b, self.k);
        debug_assert_eq!(q.len(), k * t);
        debug_assert_eq!(x.len(), b * t);
        // Shared Gram over masked columns: G0[kj] = Σ_c mask_c q[k,c] q[j,c]
        let mut g0 = vec![0.0f32; k * k];
        for c in 0..t {
            if mask[c] == 0.0 {
                continue;
            }
            for kk in 0..k {
                let qk = q[kk * t + c];
                if qk == 0.0 {
                    continue;
                }
                for jj in 0..k {
                    g0[kk * k + jj] += qk * q[jj * t + c];
                }
            }
        }
        let mut a_out = vec![0.0f32; b * k * k];
        let mut b_out = vec![0.0f32; b * k];
        for u in 0..b {
            let a_u = &mut a_out[u * k * k..(u + 1) * k * k];
            a_u.copy_from_slice(&g0);
            let xrow = &x[u * t..(u + 1) * t];
            for c in 0..t {
                if xrow[c] == 0.0 || mask[c] == 0.0 {
                    continue;
                }
                let xv = xrow[c];
                let cv = self.alpha * xv; // c - 1 = alpha * x
                // A += alpha x q qᵀ ; b += (1 + alpha x) x q
                for kk in 0..k {
                    let qk = q[kk * t + c];
                    for jj in 0..k {
                        a_u[kk * k + jj] += cv * qk * q[jj * t + c];
                    }
                    b_out[u * k + kk] += (1.0 + self.alpha * xv) * xv * qk;
                }
            }
        }
        Ok((a_out, b_out))
    }

    fn solve(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let (bb, k) = (self.b, self.k);
        let mut out = vec![0.0f32; bb * k];
        for u in 0..bb {
            let a_u = Mat::from_vec(k, k, a[u * k * k..(u + 1) * k * k].to_vec());
            let b_u = &b[u * k..(u + 1) * k];
            let p = cholesky_solve(&a_u, self.lam, b_u);
            out[u * k..(u + 1) * k].copy_from_slice(&p);
        }
        Ok(out)
    }

    fn grad(
        &mut self,
        t: usize,
        p: &[f32],
        umask: &[f32],
        q: &[f32],
        x: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let (b, k) = (self.b, self.k);
        let n_users: f32 = umask.iter().sum();
        let mut g = vec![0.0f32; k * t];
        // -2 Pᵀ W  with W[u,c] = umask_u c_uc (x_uc - s_uc)
        for u in 0..b {
            if umask[u] == 0.0 {
                continue;
            }
            let prow = &p[u * k..(u + 1) * k];
            let xrow = &x[u * t..(u + 1) * t];
            for c in 0..t {
                if mask[c] == 0.0 {
                    continue;
                }
                let mut s = 0.0f32;
                for f in 0..k {
                    s += prow[f] * q[f * t + c];
                }
                let xv = xrow[c];
                let w = (1.0 + self.alpha * xv) * (xv - s);
                let wm2 = -2.0 * w;
                for f in 0..k {
                    g[f * t + c] += wm2 * prow[f];
                }
            }
        }
        // + 2 lam n_users Q on unmasked columns
        let reg = 2.0 * self.lam * n_users;
        for f in 0..k {
            for c in 0..t {
                if mask[c] != 0.0 {
                    g[f * t + c] += reg * q[f * t + c];
                }
            }
        }
        Ok(g)
    }

    fn scores(&mut self, t: usize, p: &[f32], q: &[f32]) -> Result<Vec<f32>> {
        let (b, k) = (self.b, self.k);
        let mut s = vec![0.0f32; b * t];
        for u in 0..b {
            let prow = &p[u * k..(u + 1) * k];
            let srow = &mut s[u * t..(u + 1) * t];
            for f in 0..k {
                let pf = prow[f];
                if pf == 0.0 {
                    continue;
                }
                let qrow = &q[f * t..(f + 1) * t];
                for c in 0..t {
                    srow[c] += pf * qrow[c];
                }
            }
        }
        Ok(s)
    }

    fn name(&self) -> &'static str {
        "reference"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_accum(
        b: usize,
        k: usize,
        t: usize,
        q: &[f32],
        x: &[f32],
        mask: &[f32],
        alpha: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut a = vec![0.0f32; b * k * k];
        let mut bv = vec![0.0f32; b * k];
        for u in 0..b {
            for c in 0..t {
                if mask[c] == 0.0 {
                    continue;
                }
                let xv = x[u * t + c];
                let cv = 1.0 + alpha * xv;
                for kk in 0..k {
                    for jj in 0..k {
                        a[u * k * k + kk * k + jj] += cv * q[kk * t + c] * q[jj * t + c];
                    }
                    bv[u * k + kk] += cv * xv * q[kk * t + c];
                }
            }
        }
        (a, bv)
    }

    #[test]
    fn accum_matches_naive_formula() {
        let (b, k, t) = (4, 3, 16);
        let mut backend = ReferenceBackend::new(b, k, vec![t], 4.0, 1.0);
        let mut rng = Rng::seed_from_u64(1);
        let q: Vec<f32> = (0..k * t).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..b * t).map(|_| if rng.chance(0.3) { 1.0 } else { 0.0 }).collect();
        let mut mask = vec![1.0f32; t];
        mask[12..].iter_mut().for_each(|v| *v = 0.0);
        let (a, bv) = backend.accum(t, &q, &x, &mask).unwrap();
        let (an, bn) = naive_accum(b, k, t, &q, &x, &mask, 4.0);
        for (i, (got, want)) in a.iter().zip(&an).enumerate() {
            assert!((got - want).abs() < 1e-4, "A[{i}]: {got} vs {want}");
        }
        for (got, want) in bv.iter().zip(&bn) {
            assert!((got - want).abs() < 1e-4);
        }
    }

    #[test]
    fn solve_residual_small() {
        let (b, k, t) = (64, 5, 32);
        let mut backend = ReferenceBackend::new(b, k, vec![t], 4.0, 1.0);
        let mut rng = Rng::seed_from_u64(2);
        let q: Vec<f32> = (0..k * t).map(|_| rng.normal() as f32 * 0.4).collect();
        let x: Vec<f32> = (0..b * t).map(|_| if rng.chance(0.2) { 1.0 } else { 0.0 }).collect();
        let mask = vec![1.0f32; t];
        let (a, bv) = backend.accum(t, &q, &x, &mask).unwrap();
        let p = backend.solve(&a, &bv).unwrap();
        // check (A + lam I) p = b for user 0
        for u in [0usize, 31, 63] {
            for i in 0..k {
                let mut r = -bv[u * k + i] + 1.0 * p[u * k + i];
                for j in 0..k {
                    r += a[u * k * k + i * k + j] * p[u * k + j];
                }
                assert!(r.abs() < 1e-3, "user {u} residual {r}");
            }
        }
    }

    #[test]
    fn grad_matches_per_user_sum() {
        let (b, k, t) = (3, 4, 8);
        let mut backend = ReferenceBackend::new(b, k, vec![t], 4.0, 1.0);
        let mut rng = Rng::seed_from_u64(3);
        let q: Vec<f32> = (0..k * t).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..b * t).map(|_| if rng.chance(0.4) { 1.0 } else { 0.0 }).collect();
        let p: Vec<f32> = (0..b * k).map(|_| rng.normal() as f32).collect();
        let mask = vec![1.0f32; t];
        let umask = vec![1.0, 1.0, 0.0]; // user 2 masked out
        let g = backend.grad(t, &p, &umask, &q, &x, &mask).unwrap();
        // naive: per user Eq. 6 then sum over unmasked users
        let mut gn = vec![0.0f32; k * t];
        for u in 0..2 {
            for c in 0..t {
                let mut s = 0.0f32;
                for f in 0..k {
                    s += p[u * k + f] * q[f * t + c];
                }
                let xv = x[u * t + c];
                let cv = 1.0 + 4.0 * xv;
                for f in 0..k {
                    gn[f * t + c] += -2.0 * cv * (xv - s) * p[u * k + f] + 2.0 * 1.0 * q[f * t + c] / 2.0 * 0.0;
                }
            }
        }
        // add the lambda term once per unmasked user
        for f in 0..k {
            for c in 0..t {
                gn[f * t + c] += 2.0 * 1.0 * 2.0 * q[f * t + c];
            }
        }
        for (i, (got, want)) in g.iter().zip(&gn).enumerate() {
            assert!((got - want).abs() < 1e-3, "g[{i}] {got} vs {want}");
        }
    }

    #[test]
    fn scores_is_matmul() {
        let (b, k, t) = (2, 3, 4);
        let mut backend = ReferenceBackend::new(b, k, vec![t], 4.0, 1.0);
        let p = vec![1.0, 0.0, 2.0, /* user1 */ 0.0, 1.0, -1.0];
        let q: Vec<f32> = (0..k * t).map(|i| i as f32).collect();
        let s = backend.scores(t, &p, &q).unwrap();
        // user0: 1*q0 + 2*q2 ; q row f occupies [f*t..]
        for c in 0..t {
            let want = q[c] + 2.0 * q[2 * t + c];
            assert!((s[c] - want).abs() < 1e-6);
            let want1 = q[t + c] - q[2 * t + c];
            assert!((s[t + c] - want1).abs() < 1e-6);
        }
    }
}
