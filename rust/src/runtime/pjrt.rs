//! PJRT backend: compile the HLO-text artifacts once, execute them from
//! the round loop.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax ≥ 0.5 emits 64-bit instruction-id
//! protos that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! Artifacts are lowered with `return_tuple=True`, so every execution
//! returns a single tuple literal we decompose.
//!
//! ## Why `execute_b`, not `execute`
//!
//! The published crate's `execute(&[Literal])` leaks every input: the C
//! wrapper does `BufferFromHostLiteral(..).release()` on each argument and
//! never frees the device buffer (~180 KB per accum call — a long
//! experiment sweep leaked tens of GB; EXPERIMENTS.md §Perf #5). We
//! instead create input `PjRtBuffer`s ourselves via
//! `buffer_from_host_buffer` — whose Rust wrapper owns and frees them —
//! and run `execute_b`, which borrows buffers without taking ownership.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::{ComputeBackend, Manifest};

/// The production [`ComputeBackend`]: AOT artifacts on the PJRT CPU client.
pub struct PjrtBackend {
    b: usize,
    k: usize,
    tiles: Vec<usize>,
    client: PjRtClient,
    // compiled executables per tile width
    accum: HashMap<usize, PjRtLoadedExecutable>,
    grad: HashMap<usize, PjRtLoadedExecutable>,
    scores: HashMap<usize, PjRtLoadedExecutable>,
    solve: PjRtLoadedExecutable,
    manifest: Manifest,
}

fn compile(client: &PjRtClient, dir: &Path, name: &str) -> Result<PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {name}: {e}"))
}

impl PjrtBackend {
    /// Load + compile every runtime artifact from `dir`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<PjrtBackend> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir).context("loading artifact manifest")?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;

        let mut accum = HashMap::new();
        let mut grad = HashMap::new();
        let mut scores = HashMap::new();
        for &t in &manifest.tiles {
            accum.insert(t, compile(&client, dir, &format!("accum_t{t}"))?);
            grad.insert(t, compile(&client, dir, &format!("grad_t{t}"))?);
            scores.insert(t, compile(&client, dir, &format!("scores_t{t}"))?);
        }
        let solve = compile(&client, dir, "solve")?;

        Ok(PjrtBackend {
            b: manifest.b,
            k: manifest.k,
            tiles: manifest.tiles.clone(),
            client,
            accum,
            grad,
            scores,
            solve,
            manifest,
        })
    }

    /// The manifest the artifacts were compiled against.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn host_buffer(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("staging {dims:?}: {e}"))
    }

    fn exe<'a>(
        map: &'a HashMap<usize, PjRtLoadedExecutable>,
        t: usize,
        what: &str,
    ) -> Result<&'a PjRtLoadedExecutable> {
        map.get(&t)
            .ok_or_else(|| anyhow!("no {what} artifact for tile {t}"))
    }
}

/// Execute and return the decomposed output tuple as f32 vectors.
fn run(exe: &PjRtLoadedExecutable, args: &[PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
    let result = exe.execute_b(args).map_err(|e| anyhow!("execute: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e}"))?;
    let parts = lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))?;
    parts
        .into_iter()
        .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}")))
        .collect()
}

impl ComputeBackend for PjrtBackend {
    fn geometry(&self) -> (usize, usize, Vec<usize>) {
        (self.b, self.k, self.tiles.clone())
    }

    fn accum(
        &mut self,
        t: usize,
        q: &[f32],
        x: &[f32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let args = [
            self.host_buffer(q, &[self.k, t])?,
            self.host_buffer(x, &[self.b, t])?,
            self.host_buffer(mask, &[t])?,
        ];
        let exe = Self::exe(&self.accum, t, "accum")?;
        let mut out = run(exe, &args)?;
        anyhow::ensure!(out.len() == 2, "accum returned {} outputs", out.len());
        let b_vec = out.pop().unwrap();
        let a_vec = out.pop().unwrap();
        Ok((a_vec, b_vec))
    }

    fn solve(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let args = [
            self.host_buffer(a, &[self.b, self.k, self.k])?,
            self.host_buffer(b, &[self.b, self.k])?,
        ];
        let mut out = run(&self.solve, &args)?;
        anyhow::ensure!(out.len() == 1, "solve returned {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    fn grad(
        &mut self,
        t: usize,
        p: &[f32],
        umask: &[f32],
        q: &[f32],
        x: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let args = [
            self.host_buffer(p, &[self.b, self.k])?,
            self.host_buffer(umask, &[self.b])?,
            self.host_buffer(q, &[self.k, t])?,
            self.host_buffer(x, &[self.b, t])?,
            self.host_buffer(mask, &[t])?,
        ];
        let exe = Self::exe(&self.grad, t, "grad")?;
        let mut out = run(exe, &args)?;
        anyhow::ensure!(out.len() == 1, "grad returned {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    fn scores(&mut self, t: usize, p: &[f32], q: &[f32]) -> Result<Vec<f32>> {
        let args = [
            self.host_buffer(p, &[self.b, self.k])?,
            self.host_buffer(q, &[self.k, t])?,
        ];
        let exe = Self::exe(&self.scores, t, "scores")?;
        let mut out = run(exe, &args)?;
        anyhow::ensure!(out.len() == 1, "scores returned {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
