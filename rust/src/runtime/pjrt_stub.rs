//! Stub PJRT backend, compiled when the crate is built **without** the
//! `xla` cargo feature (the offline default — the real backend in
//! `pjrt.rs` needs the `xla` crate, which cannot be fetched without
//! registry access).
//!
//! The stub keeps the `runtime::pjrt::PjrtBackend` path and type stable
//! so benches/tests that name it still compile; construction always fails
//! with a pointer at the reference backend, and the `ComputeBackend`
//! methods are unreachable because no value can be constructed.

use std::path::Path;

use anyhow::{bail, Result};

use super::ComputeBackend;

/// Placeholder for the PJRT backend; cannot be constructed in this build.
pub struct PjrtBackend {
    _unconstructible: std::convert::Infallible,
}

impl PjrtBackend {
    /// Always fails: this build has no XLA/PJRT support.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<PjrtBackend> {
        bail!(
            "pjrt backend unavailable: built without the `xla` cargo feature \
             (artifacts dir: {}). Rebuild with `--features xla` plus the `xla` \
             dependency, or set `runtime.backend = \"reference\"`",
            dir.as_ref().display()
        )
    }
}

impl ComputeBackend for PjrtBackend {
    fn geometry(&self) -> (usize, usize, Vec<usize>) {
        match self._unconstructible {}
    }

    fn accum(
        &mut self,
        _t: usize,
        _q: &[f32],
        _x: &[f32],
        _mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        match self._unconstructible {}
    }

    fn solve(&mut self, _a: &[f32], _b: &[f32]) -> Result<Vec<f32>> {
        match self._unconstructible {}
    }

    fn grad(
        &mut self,
        _t: usize,
        _p: &[f32],
        _umask: &[f32],
        _q: &[f32],
        _x: &[f32],
        _mask: &[f32],
    ) -> Result<Vec<f32>> {
        match self._unconstructible {}
    }

    fn scores(&mut self, _t: usize, _p: &[f32], _q: &[f32]) -> Result<Vec<f32>> {
        match self._unconstructible {}
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}
