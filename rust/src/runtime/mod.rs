//! Execution runtime: loads the AOT artifacts and runs the FCF client
//! compute from the L3 hot path.
//!
//! Two backends implement [`ComputeBackend`]:
//!
//! * [`pjrt::PjrtBackend`] — the production path: HLO-text artifacts
//!   (`artifacts/*.hlo.txt`, built once by `make artifacts`) compiled on
//!   the PJRT CPU client (`xla` crate) and executed with reused staging
//!   literals.
//! * [`reference::ReferenceBackend`] — a pure-Rust re-implementation of
//!   the same math, used for differential testing of the artifacts and as
//!   a no-artifacts fallback (`runtime.backend = "reference"`).
//!
//! [`FcfRuntime`] sits on top and handles what the static artifact shapes
//! cannot: tiling an arbitrary selected-item set over the compiled tile
//! widths, padding partial user batches, and packing/unpacking between
//! the coordinator's item-major layout and the artifacts' (K, T) layout.
//!
//! [`fleet`] runs the round's client batches across multiple such
//! runtimes in parallel — one backend per worker thread, built through a
//! [`BackendFactory`], merged through a deterministic per-batch
//! reduction so any `runtime.threads` value trains bit-identically.

pub mod fleet;
pub mod manifest;
/// The real PJRT backend (needs the `xla` crate — `--features xla`).
#[cfg(feature = "xla")]
pub mod pjrt;
/// Offline builds get a stub that fails at construction (same paths/types).
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod reference;

pub use fleet::{
    merge_outcomes, BackendFactory, BatchOutcome, BatchStat, FleetExecutor, RoundAggregate,
    RoundTask,
};
pub use manifest::Manifest;

use anyhow::{bail, Result};

use crate::config::RunConfig;

/// Dense-buffer compute interface at artifact granularity. All shapes are
/// the compiled static shapes: `B` users per batch, `K` factors, item
/// tiles of width `t` (one of the manifest's tile sizes).
///
/// Not `Send`: the PJRT client handle is thread-local (`Rc` internally);
/// parallel fleets create one backend per worker thread instead.
pub trait ComputeBackend {
    /// Geometry: (B, K, supported tile widths ascending).
    fn geometry(&self) -> (usize, usize, Vec<usize>);

    /// Gram accumulation (Eq. 3 ingredients): `q` is (K, t) column-major
    /// over the tile (i.e. `q[k*t + c]`), `x` is (B, t), `mask` (t).
    /// Returns (A, b) as (B*K*K, B*K) flattened.
    fn accum(&mut self, t: usize, q: &[f32], x: &[f32], mask: &[f32])
        -> Result<(Vec<f32>, Vec<f32>)>;

    /// Batched solve of `(A + λI) p = b` (Eq. 3). `a` is B*K*K, `b` B*K.
    fn solve(&mut self, a: &[f32], b: &[f32]) -> Result<Vec<f32>>;

    /// Aggregated item gradient (Eq. 5–6) for one tile. `p` is (B, K),
    /// `umask` (B), rest as in [`ComputeBackend::accum`]. Returns (K, t).
    fn grad(
        &mut self,
        t: usize,
        p: &[f32],
        umask: &[f32],
        q: &[f32],
        x: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>>;

    /// Predicted scores `P · Q_tile`: returns (B, t).
    fn scores(&mut self, t: usize, p: &[f32], q: &[f32]) -> Result<Vec<f32>>;

    /// Backend name for logs (`pjrt` / `reference`).
    fn name(&self) -> &'static str;
}

thread_local! {
    static RUNTIME_CACHE: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<std::cell::RefCell<FcfRuntime>>>,
    > = std::cell::RefCell::new(std::collections::HashMap::new());
}

/// Process-wide (per-thread) shared runtime for the config's backend.
///
/// Experiment sweeps construct hundreds of trainers; PJRT compilation is
/// expensive and xla_extension 0.5.1 retains compiled programs, so
/// re-loading the backend per run both wastes seconds and leaks ~0.5 GB
/// per load (EXPERIMENTS.md §Perf). The cache keys on backend + artifact
/// dir + model geometry + the reference backend's math constants (α, λ —
/// two configs differing only there must not share a runtime, or the
/// parallel fleet's per-thread backends would diverge from the cached
/// caller-lane runtime).
pub fn shared_runtime(
    cfg: &RunConfig,
) -> Result<std::rc::Rc<std::cell::RefCell<FcfRuntime>>> {
    let key = format!(
        "{}:{}:{}:{}:{}",
        cfg.runtime.backend, cfg.runtime.artifacts_dir, cfg.model.k, cfg.model.alpha, cfg.model.lam
    );
    RUNTIME_CACHE.with(|cache| {
        if let Some(rt) = cache.borrow().get(&key) {
            return Ok(rt.clone());
        }
        let rt = std::rc::Rc::new(std::cell::RefCell::new(FcfRuntime::new(make_backend(
            cfg,
        )?)));
        cache.borrow_mut().insert(key, rt.clone());
        Ok(rt)
    })
}

/// Build the backend selected by the config.
pub fn make_backend(cfg: &RunConfig) -> Result<Box<dyn ComputeBackend>> {
    match cfg.runtime.backend.as_str() {
        "pjrt" => Ok(Box::new(pjrt::PjrtBackend::load(&cfg.runtime.artifacts_dir)?)),
        "reference" => Ok(Box::new(reference::ReferenceBackend::new(
            64,
            cfg.model.k,
            vec![512, 2048],
            cfg.model.alpha,
            cfg.model.lam,
        ))),
        other => bail!("unknown backend `{other}`"),
    }
}

/// One tile-execution chunk of a selected-item set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    /// Offset into the selected-item list.
    pub start: usize,
    /// Valid items in this chunk (<= tile).
    pub len: usize,
    /// Compiled tile width used.
    pub tile: usize,
}

/// Greedy tile plan: largest tiles first, the remainder uses the smallest
/// tile that covers it (minimizing padding waste).
pub fn plan_chunks(m_s: usize, tiles: &[usize]) -> Vec<Chunk> {
    plan_chunks_capped(m_s, tiles, usize::MAX)
}

/// [`plan_chunks`] with the usable tile width capped at `max_tile`.
///
/// Perf (EXPERIMENTS.md §Perf): the compute-bound kernels (accum, grad)
/// run FASTER as 4 × t512 executions than 1 × t2048 on the CPU PJRT
/// backend (skinny-GEMM shapes), while the overhead-bound scores kernel
/// prefers the largest tile — so the runtime plans them differently.
pub fn plan_chunks_capped(m_s: usize, tiles: &[usize], max_tile: usize) -> Vec<Chunk> {
    assert!(!tiles.is_empty());
    let mut tiles: Vec<usize> = tiles.to_vec();
    tiles.sort_unstable();
    // keep at least the smallest tile even if the cap excludes everything
    let cap_idx = tiles.iter().filter(|&&t| t <= max_tile).count().max(1);
    tiles.truncate(cap_idx);
    let largest = *tiles.last().unwrap();
    let mut chunks = Vec::new();
    let mut start = 0;
    while m_s - start >= largest {
        chunks.push(Chunk {
            start,
            len: largest,
            tile: largest,
        });
        start += largest;
    }
    let rem = m_s - start;
    if rem > 0 {
        let tile = *tiles.iter().find(|&&t| t >= rem).unwrap_or(&largest);
        chunks.push(Chunk {
            start,
            len: rem,
            tile,
        });
    }
    chunks
}

/// Tile-width cap for the compute-bound kernels (see
/// [`plan_chunks_capped`]). Benchmarked on the CPU PJRT backend.
const COMPUTE_TILE_CAP: usize = 512;

/// A user's training interactions re-indexed into selected-item positions
/// (sorted ascending). Positions index the round's `selected` list.
pub type SelRow = Vec<u32>;

/// Tiled/padded execution of the FCF client math over arbitrary selected
/// sets and user counts.
pub struct FcfRuntime {
    backend: Box<dyn ComputeBackend>,
    /// Compiled user-batch width B.
    pub b: usize,
    /// Compiled latent factor count K.
    pub k: usize,
    tiles: Vec<usize>,
    // reusable staging buffers, keyed by tile width index
    q_stage: Vec<Vec<f32>>,
    x_stage: Vec<Vec<f32>>,
    mask_stage: Vec<Vec<f32>>,
}

impl FcfRuntime {
    /// Wrap a backend, allocating the per-tile staging buffers once.
    pub fn new(backend: Box<dyn ComputeBackend>) -> FcfRuntime {
        let (b, k, tiles) = backend.geometry();
        let q_stage = tiles.iter().map(|&t| vec![0.0; k * t]).collect();
        let x_stage = tiles.iter().map(|&t| vec![0.0; b * t]).collect();
        let mask_stage = tiles.iter().map(|&t| vec![0.0; t]).collect();
        FcfRuntime {
            backend,
            b,
            k,
            tiles,
            q_stage,
            x_stage,
            mask_stage,
        }
    }

    /// Name of the wrapped backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Compiled tile widths, ascending.
    pub fn tiles(&self) -> &[usize] {
        &self.tiles
    }

    fn tile_idx(&self, tile: usize) -> usize {
        self.tiles
            .iter()
            .position(|&t| t == tile)
            .expect("chunk tile not in geometry")
    }

    /// Stage a (K, tile) slice of `q_sel` (item-major `m_s × k`) for a chunk.
    fn stage_q(&mut self, chunk: &Chunk, q_sel: &[f32]) {
        let ti = self.tile_idx(chunk.tile);
        let t = chunk.tile;
        let k = self.k;
        let buf = &mut self.q_stage[ti];
        buf.iter_mut().for_each(|v| *v = 0.0);
        for c in 0..chunk.len {
            let item_row = &q_sel[(chunk.start + c) * k..(chunk.start + c + 1) * k];
            for f in 0..k {
                buf[f * t + c] = item_row[f];
            }
        }
        let mbuf = &mut self.mask_stage[ti];
        mbuf.iter_mut().for_each(|v| *v = 0.0);
        mbuf[..chunk.len].iter_mut().for_each(|v| *v = 1.0);
    }

    /// Stage the (B, tile) interaction slice for a user batch: `rows[u]`
    /// holds user u's interactions as selected-positions.
    fn stage_x(&mut self, chunk: &Chunk, rows: &[&SelRow]) {
        assert!(rows.len() <= self.b);
        let ti = self.tile_idx(chunk.tile);
        let t = chunk.tile;
        let buf = &mut self.x_stage[ti];
        buf.iter_mut().for_each(|v| *v = 0.0);
        let lo = chunk.start as u32;
        let hi = (chunk.start + chunk.len) as u32;
        for (u, row) in rows.iter().enumerate() {
            // row is sorted; find the sub-slice inside [lo, hi)
            let a = row.partition_point(|&p| p < lo);
            let z = row.partition_point(|&p| p < hi);
            for &pos in &row[a..z] {
                buf[u * t + (pos - lo) as usize] = 1.0;
            }
        }
    }

    /// Solve user factors for up to B users (Eq. 3).
    ///
    /// * `q_sel` — selected item factors, item-major (m_s × k).
    /// * `rows` — per-user interactions in selected-position space.
    ///
    /// Returns `rows.len() × k` user factors (padding rows dropped).
    pub fn solve_users(&mut self, q_sel: &[f32], rows: &[&SelRow]) -> Result<Vec<f32>> {
        let m_s = q_sel.len() / self.k;
        let n = rows.len();
        assert!(n <= self.b, "solve_users: batch {n} > B {}", self.b);
        let mut a_total = vec![0.0f32; self.b * self.k * self.k];
        let mut b_total = vec![0.0f32; self.b * self.k];
        for chunk in plan_chunks_capped(m_s, &self.tiles, COMPUTE_TILE_CAP) {
            self.stage_q(&chunk, q_sel);
            self.stage_x(&chunk, rows);
            let ti = self.tile_idx(chunk.tile);
            let (a, b) = self.backend.accum(
                chunk.tile,
                &self.q_stage[ti],
                &self.x_stage[ti],
                &self.mask_stage[ti],
            )?;
            for (acc, v) in a_total.iter_mut().zip(&a) {
                *acc += v;
            }
            for (acc, v) in b_total.iter_mut().zip(&b) {
                *acc += v;
            }
        }
        let p = self.backend.solve(&a_total, &b_total)?;
        Ok(p[..n * self.k].to_vec())
    }

    /// Aggregated gradient over a batch (Eq. 5–6 summed over `rows`).
    ///
    /// `p` is `rows.len() × k` (from [`FcfRuntime::solve_users`]). Returns
    /// the batch-summed gradient in item-major layout (m_s × k).
    pub fn grad_batch(&mut self, q_sel: &[f32], rows: &[&SelRow], p: &[f32]) -> Result<Vec<f32>> {
        let m_s = q_sel.len() / self.k;
        let n = rows.len();
        assert_eq!(p.len(), n * self.k);
        let mut p_pad = vec![0.0f32; self.b * self.k];
        p_pad[..p.len()].copy_from_slice(p);
        let mut umask = vec![0.0f32; self.b];
        umask[..n].iter_mut().for_each(|v| *v = 1.0);

        let mut g_out = vec![0.0f32; m_s * self.k];
        for chunk in plan_chunks_capped(m_s, &self.tiles, COMPUTE_TILE_CAP) {
            self.stage_q(&chunk, q_sel);
            self.stage_x(&chunk, rows);
            let ti = self.tile_idx(chunk.tile);
            let g = self.backend.grad(
                chunk.tile,
                &p_pad,
                &umask,
                &self.q_stage[ti],
                &self.x_stage[ti],
                &self.mask_stage[ti],
            )?;
            // unpack (K, tile) -> item-major rows
            let t = chunk.tile;
            for c in 0..chunk.len {
                let row = &mut g_out[(chunk.start + c) * self.k..(chunk.start + c + 1) * self.k];
                for f in 0..self.k {
                    row[f] = g[f * t + c];
                }
            }
        }
        Ok(g_out)
    }

    /// Dense scores of up to B users against an arbitrary item set
    /// (item-major `m × k`), for evaluation. Returns `rows × m`.
    pub fn scores_all(&mut self, q_items: &[f32], p: &[f32]) -> Result<Vec<f32>> {
        let m = q_items.len() / self.k;
        let n = p.len() / self.k;
        assert!(n <= self.b);
        let mut p_pad = vec![0.0f32; self.b * self.k];
        p_pad[..p.len()].copy_from_slice(p);
        let mut out = vec![0.0f32; n * m];
        for chunk in plan_chunks(m, &self.tiles) {
            self.stage_q(&chunk, q_items);
            let ti = self.tile_idx(chunk.tile);
            let s = self
                .backend
                .scores(chunk.tile, &p_pad, &self.q_stage[ti])?;
            let t = chunk.tile;
            for u in 0..n {
                for c in 0..chunk.len {
                    out[u * m + chunk.start + c] = s[u * t + c];
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_chunks_greedy() {
        let tiles = vec![512, 2048];
        let plan = plan_chunks(5000, &tiles);
        assert_eq!(
            plan,
            vec![
                Chunk { start: 0, len: 2048, tile: 2048 },
                Chunk { start: 2048, len: 2048, tile: 2048 },
                Chunk { start: 4096, len: 904, tile: 2048 },
            ]
        );
        let plan = plan_chunks(300, &tiles);
        assert_eq!(plan, vec![Chunk { start: 0, len: 300, tile: 512 }]);
        let plan = plan_chunks(512, &tiles);
        assert_eq!(plan, vec![Chunk { start: 0, len: 512, tile: 512 }]);
        let plan = plan_chunks(2600, &tiles);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[1].tile, 2048); // 552 > 512 -> needs the big tile
    }

    #[test]
    fn plan_covers_exactly() {
        for m_s in [1, 100, 511, 513, 2047, 2049, 10_000] {
            let plan = plan_chunks(m_s, &[512, 2048]);
            let mut covered = 0;
            for c in &plan {
                assert_eq!(c.start, covered);
                covered += c.len;
                assert!(c.len <= c.tile);
            }
            assert_eq!(covered, m_s, "m_s={m_s}");
        }
    }
}
