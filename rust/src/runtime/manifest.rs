//! Parse `artifacts/manifest.txt` (written by `python -m compile.aot`).
//!
//! The manifest pins the geometry (B, K, tile widths) and the
//! hyper-parameters baked into the artifacts; the trainer asserts its
//! config matches so a stale `artifacts/` cannot silently change the math.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Compiled user-batch width B.
    pub b: usize,
    /// Compiled latent factor count K.
    pub k: usize,
    /// Compiled item tile widths, ascending.
    pub tiles: Vec<usize>,
    /// Confidence weight α baked into the artifacts.
    pub alpha: f32,
    /// Ridge λ baked into the artifacts.
    pub lam: f32,
    /// Adam learning rate η baked into the artifacts.
    pub eta: f32,
    /// Adam β₁ baked into the artifacts.
    pub beta1: f32,
    /// Adam β₂ baked into the artifacts.
    pub beta2: f32,
    /// CG iteration count of the compiled solver.
    pub cg_iters: usize,
    /// artifact name -> declared input count.
    pub artifacts: BTreeMap<String, usize>,
}

impl Manifest {
    /// Read and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Manifest::parse(&text)
    }

    /// Parse manifest text (`key=value` lines plus `artifact.<name>`).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut kv = BTreeMap::new();
        let mut artifacts = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("manifest line `{line}`: expected key=value"))?;
            if key == "artifact" {
                // `artifact=<name> inputs=<n> sha256=<digest>`
                let mut name = None;
                let mut inputs = None;
                for (i, tok) in val.split_whitespace().enumerate() {
                    if i == 0 {
                        name = Some(tok.to_string());
                    } else if let Some(n) = tok.strip_prefix("inputs=") {
                        inputs = Some(n.parse::<usize>()?);
                    }
                }
                let name = name.ok_or_else(|| anyhow!("artifact line missing name"))?;
                let inputs =
                    inputs.ok_or_else(|| anyhow!("artifact `{name}` missing inputs="))?;
                artifacts.insert(name, inputs);
            } else {
                kv.insert(key.to_string(), val.to_string());
            }
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).ok_or_else(|| anyhow!("manifest missing `{k}`"))
        };
        let version: usize = get("version")?.parse()?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let tiles: Vec<usize> = get("tiles")?
            .split(',')
            .map(|t| t.trim().parse::<usize>().map_err(Into::into))
            .collect::<Result<_>>()?;
        if tiles.is_empty() {
            bail!("manifest has no tiles");
        }
        Ok(Manifest {
            b: get("B")?.parse()?,
            k: get("K")?.parse()?,
            tiles,
            alpha: get("alpha")?.parse()?,
            lam: get("lam")?.parse()?,
            eta: get("eta")?.parse()?,
            beta1: get("beta1")?.parse()?,
            beta2: get("beta2")?.parse()?,
            cg_iters: get("cg_iters")?.parse()?,
            artifacts,
        })
    }

    /// Assert the model hyper-parameters a config expects match what was
    /// baked into the artifacts.
    pub fn check_model(&self, model: &crate::config::ModelConfig) -> Result<()> {
        let close = |a: f32, b: f32| (a - b).abs() <= 1e-9 * a.abs().max(1.0);
        if self.k != model.k {
            bail!("artifacts baked K={} but config wants K={}; rebuild artifacts", self.k, model.k);
        }
        if !close(self.alpha, model.alpha) || !close(self.lam, model.lam) {
            bail!(
                "artifacts baked (alpha={}, lam={}) but config wants (alpha={}, lam={}); rebuild artifacts",
                self.alpha, self.lam, model.alpha, model.lam
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version=1
B=64
K=25
tiles=512,2048
alpha=4.0
lam=1.0
eta=0.01
beta1=0.1
beta2=0.99
eps=1e-08
cg_iters=50
artifact=accum_t512 inputs=3 sha256=abc
artifact=solve inputs=2 sha256=def
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.b, 64);
        assert_eq!(m.k, 25);
        assert_eq!(m.tiles, vec![512, 2048]);
        assert_eq!(m.alpha, 4.0);
        assert_eq!(m.cg_iters, 50);
        assert_eq!(m.artifacts["accum_t512"], 3);
        assert_eq!(m.artifacts["solve"], 2);
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse("version=1\nB=64\n").is_err());
    }

    #[test]
    fn wrong_version_errors() {
        let text = SAMPLE.replace("version=1", "version=9");
        assert!(Manifest::parse(&text).is_err());
    }

    #[test]
    fn check_model_catches_mismatch() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mut model = crate::config::RunConfig::paper_defaults().model;
        m.check_model(&model).unwrap();
        model.alpha = 2.0;
        assert!(m.check_model(&model).is_err());
        model.alpha = 4.0;
        model.k = 10;
        assert!(m.check_model(&model).is_err());
    }
}
