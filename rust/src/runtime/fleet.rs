//! Sharded, multi-threaded execution of the per-round client-fleet math —
//! the hot path of every FL iteration.
//!
//! The coordinator partitions a round's Θ participants into B-sized
//! batches (B is the compiled artifact batch width) and hands them to a
//! [`FleetExecutor`]: a persistent pool of worker threads that pull batch
//! indices from a shared queue. [`ComputeBackend`](super::ComputeBackend)
//! is deliberately not `Send` (the PJRT client handle is thread-local),
//! so each worker builds its **own** backend on its own thread through a
//! [`BackendFactory`] and keeps it for the life of the pool.
//!
//! ## Determinism
//!
//! `runtime.threads = N` must produce **bit-identical** training to
//! `threads = 1` — every table, figure, and regression baseline depends
//! on it. Two rules make that hold:
//!
//! 1. **Batch outcomes are pure.** A [`BatchOutcome`] is a deterministic
//!    function of the round inputs and the batch index alone — backends
//!    built from the same config compute identical floats, and no RNG
//!    runs off the coordinator thread — so it does not matter *which*
//!    lane computes a batch.
//! 2. **Reduction is at batch granularity, in batch-index order.**
//!    [`merge_outcomes`] folds gradients, metric accumulators, and
//!    traffic ledgers batch-by-batch in index order, never per-shard:
//!    shard boundaries depend on the thread count, batch boundaries do
//!    not. Floating-point addition is not associative, so this fixed
//!    fold shape is what keeps `threads = 4` bit-equal to `threads = 1`
//!    (the determinism CI job diffs dumped round records to enforce it).
//!
//! Work distribution itself is free to race (an atomic claim counter);
//! only the merge order is pinned.
//!
//! ## Per-client upload framing
//!
//! The batch's ∇Q* round-trips the sparse codec once per batch (the
//! backend aggregates a batch's gradients in a single execution, and the
//! *decoded* sum is what trains the server — dynamics unchanged), and the
//! ledger records one message per client at exactly that frame's length.
//! With entropy coding off that per-client length is **exact**, not an
//! approximation: the FCF implicit-feedback gradient is dense over the
//! selected set — every client contributes `(1 + αx)(x − s)` to every
//! selected item, x = 0 included, plus the regularizer — so a client's
//! own policy-sparsified upload carries the same surviving-row set as
//! the batch aggregate and encodes to the same length. (A frame indexed
//! by the client's *interacted* rows would both undercount the paper's
//! payload and leak the private interaction set the `client` module
//! promises never leaves the device.) With a range-coding entropy mode
//! the frame *structure* (rows, indices, per-row layout) is still
//! identical, but the coded length is data-dependent, so the batch
//! frame's length stands in for each client's own — the aggregate's
//! symbol statistics approximate a participant's (encoding Θ per-client
//! frames per round just to measure them would multiply the codec cost
//! by B). This discharges the ROADMAP follow-up on per-client upload
//! attribution for the lossless-length modes and documents the
//! approximation the entropy modes introduce.
//!
//! Under the `vq*` download codecs the upload value plane is int8
//! (`Precision::for_uploads` — a per-frame codebook has nothing to
//! amortize over on a one-shot uplink), so everything above applies
//! unchanged; the `--sparse-topk auto` tuner is likewise a pure
//! function of the batch gradient, so workers resolve it independently
//! without touching the determinism contract.
//!
//! ## Cross-round codebook sessions
//!
//! The first stateful wire feature (`wire::vq::session`, `[codec]
//! codebook_reuse = delta|auto`) deliberately lives **outside** this
//! executor: the dense download is encoded exactly once per round on
//! the coordinator lane, the session's codebook state is owned by the
//! `Trainer`, and what reaches [`RoundTask::q_sel`] is the already
//! *decoded* broadcast — so worker lanes never see session state, and
//! the batch-order merge contract (and with it threads = 1/N
//! bit-identity) is untouched by codebook reuse, deltas, or per-client
//! resyncs. Resync accounting (which stale client was served the
//! full-codebook frame) happens in the coordinator's download loop for
//! the same reason: it must not depend on which lane ran which batch.

use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(feature = "parallel")]
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::client::FleetView;
use crate::config::{RunConfig, SimNetConfig};
use crate::metrics::{rank_candidates, user_metrics, MetricAccumulator};
use crate::simnet::TrafficLedger;
#[cfg(feature = "parallel")]
use crate::wire::make_codec_with;
use crate::wire::{EntropyMode, PayloadCodec, Precision, SparsePolicy};
use crate::warn_log;

use super::{make_backend, ComputeBackend, FcfRuntime, SelRow};

/// Builds one [`ComputeBackend`] per worker thread. The trait is not
/// `Send`, so the factory (plain config data, `Send + Sync`) crosses the
/// thread boundary and construction happens on the owning thread.
#[derive(Clone)]
pub struct BackendFactory {
    cfg: RunConfig,
}

impl BackendFactory {
    /// Factory capturing the config a worker needs to build its backend.
    pub fn from_config(cfg: &RunConfig) -> BackendFactory {
        BackendFactory { cfg: cfg.clone() }
    }

    /// Backend name this factory builds (`pjrt` / `reference`).
    pub fn backend_name(&self) -> &str {
        &self.cfg.runtime.backend
    }

    /// Construct a fresh backend on the calling thread.
    pub fn build(&self) -> Result<Box<dyn ComputeBackend>> {
        make_backend(&self.cfg)
    }

    /// Construct a fresh tiled runtime on the calling thread.
    pub fn build_runtime(&self) -> Result<FcfRuntime> {
        Ok(FcfRuntime::new(self.build()?))
    }
}

/// Everything a worker needs to execute one round's batches. Immutable
/// once dispatched; shared across lanes behind an `Arc`.
#[derive(Clone)]
pub struct RoundTask {
    /// Decoded selected item factors, item-major (m_s × k).
    pub q_sel: Vec<f32>,
    /// Latent factor count K.
    pub k: usize,
    /// Full catalog size (eval score width).
    pub m: usize,
    /// Full model snapshot for evaluation scoring (empty when
    /// `!evaluate`). Owned copy by necessity: persistent workers need
    /// `'static` data and the coordinator mutates Q right after the
    /// barrier. The m × k copy is 1/B of a single batch's O(B·m·k)
    /// scoring work, so it is noise next to what it feeds.
    pub q_full: Vec<f32>,
    /// Compute contributing clients' test metrics this round (§6.2)?
    pub evaluate: bool,
    /// Per-participant interactions in selected-position space, aligned
    /// with `client_ids`.
    pub rows: Vec<SelRow>,
    /// Participating client ids, round order (batch i covers
    /// `client_ids[i*batch .. (i+1)*batch]`).
    pub client_ids: Vec<usize>,
    /// Batch width B of the compiled artifacts.
    pub batch: usize,
    /// Element precision of the upload codec (workers build their own
    /// codec instance from this — codecs are stateless).
    pub precision: Precision,
    /// Entropy coding mode of the upload codec (lossless; changes frame
    /// lengths, never decoded values).
    pub entropy: EntropyMode,
    /// Upload sparsification policy.
    pub sparse: SparsePolicy,
    /// Network model for the per-message simulated transfer time.
    pub simnet: SimNetConfig,
    /// Shared immutable per-client data (eval needs train/test items).
    pub fleet: FleetView,
    /// Upload-delta mode (`codec.upload_delta`): carry each batch's
    /// encoded ∇Q* frame through the merge instead of recording one
    /// ledger message per client here — the coordinator re-frames each
    /// client's upload against its cached reference plane and attributes
    /// the **exact** per-client session-frame bytes after the barrier
    /// (`wire::upload`). Workers stay stateless; the frames come out of
    /// [`RoundAggregate::up_frames`] in batch order.
    pub collect_up_frames: bool,
}

impl RoundTask {
    /// Selected item count this round.
    pub fn m_s(&self) -> usize {
        if self.k == 0 {
            0
        } else {
            self.q_sel.len() / self.k
        }
    }

    /// Number of B-sized batches the participants split into.
    pub fn num_batches(&self) -> usize {
        self.client_ids.len().div_ceil(self.batch)
    }

    fn batch_range(&self, index: usize) -> (usize, usize) {
        let lo = index * self.batch;
        let hi = (lo + self.batch).min(self.client_ids.len());
        (lo, hi)
    }
}

/// What one batch execution produces. Deterministic given the task and
/// batch index — independent of the lane that computed it.
#[derive(Debug, Clone, Default)]
pub struct BatchOutcome {
    /// Decoded batch-aggregated gradient (m_s × k).
    pub grad: Vec<f32>,
    /// Solved user factors, n × k in batch order.
    pub p: Vec<f32>,
    /// Upload traffic of this batch: one per-client sparse frame each.
    pub ledger: TrafficLedger,
    /// Eval metrics of this batch's clients (empty when `!evaluate`).
    pub metrics: MetricAccumulator,
    /// Busy nanoseconds per phase: solve, grad, codec, eval.
    pub phase_ns: [u128; 4],
    /// Compute lane that executed the batch (0 = the caller's thread,
    /// `w + 1` = fleet worker `w`). Pure observability: which lane ran a
    /// batch is racy by design, so this field must never feed the merge —
    /// the flight recorder quarantines it in timing-only trace fields.
    pub lane: usize,
    /// The batch's encoded ∇Q* frame, carried only when
    /// [`RoundTask::collect_up_frames`] is set (the coordinator's
    /// upload-delta loop consumes it after the barrier).
    pub up_frame: Option<Vec<u8>>,
}

/// Per-batch execution record carried out of the batch-order barrier for
/// the flight recorder: batch index, client count, the (racy) lane that
/// ran it, and its busy nanoseconds per phase.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchStat {
    /// Batch index within the round.
    pub batch: usize,
    /// Participants in this batch.
    pub clients: usize,
    /// Lane that executed it (0 = caller, `w + 1` = worker `w`).
    pub lane: usize,
    /// Busy nanoseconds per phase: solve, grad, codec, eval.
    pub phase_ns: [u128; 4],
}

/// The deterministic reduction of a round: per-batch outcomes folded in
/// batch-index order.
#[derive(Debug, Clone, Default)]
pub struct RoundAggregate {
    /// Σ batch gradients (m_s × k), summed in batch order.
    pub grad: Vec<f32>,
    /// Eval metrics merged across batches in batch order.
    pub metrics: MetricAccumulator,
    /// Upload traffic merged across batches in batch order.
    pub ledger: TrafficLedger,
    /// Participating client ids in round order, aligned with the flat
    /// `factors` buffer (slot `i` is `factors[i*k .. (i+1)*k]`). Two flat
    /// buffers instead of a `Vec<(usize, Vec<f32>)>`: a Θ-participant
    /// round used to make Θ separate K-sized heap allocations per round
    /// just to carry solved factors across the merge barrier.
    pub factor_ids: Vec<usize>,
    /// Solved p_i factors, flat K-sized slots aligned with `factor_ids`.
    pub factors: Vec<f32>,
    /// Busy nanoseconds per phase summed over batches (across lanes, so
    /// this can exceed wall-clock): solve, grad, codec, eval.
    pub phase_ns: [u128; 4],
    /// Per-batch execution records in batch-index order (the lane and
    /// timings inside are wall-clock facts, not decisions — the tracer
    /// emits them as timing-only fields the trace digest strips).
    pub batches: Vec<BatchStat>,
    /// Encoded ∇Q* batch frames in batch-index order — populated only
    /// under [`RoundTask::collect_up_frames`], empty otherwise.
    pub up_frames: Vec<Vec<u8>>,
}

/// Fold per-batch outcomes into the round aggregate **in batch-index
/// order**. This is the only reduction shape that is invariant to how
/// batches were assigned to shards (see module docs); the proptests pin
/// that invariance.
pub fn merge_outcomes(
    m_s: usize,
    k: usize,
    client_ids: &[usize],
    batch: usize,
    outcomes: &[BatchOutcome],
) -> Result<RoundAggregate> {
    ensure!(batch > 0, "batch width must be > 0");
    let expected = client_ids.len().div_ceil(batch);
    ensure!(
        outcomes.len() == expected,
        "merge: {} outcomes for {expected} batches",
        outcomes.len()
    );
    let mut agg = RoundAggregate {
        grad: vec![0.0f32; m_s * k],
        factor_ids: Vec::with_capacity(client_ids.len()),
        factors: Vec::with_capacity(client_ids.len() * k),
        ..RoundAggregate::default()
    };
    for (i, o) in outcomes.iter().enumerate() {
        ensure!(
            o.grad.len() == m_s * k,
            "merge: batch {i} gradient has {} values, expected {}",
            o.grad.len(),
            m_s * k
        );
        for (acc, v) in agg.grad.iter_mut().zip(&o.grad) {
            *acc += v;
        }
        agg.metrics.merge(&o.metrics);
        agg.ledger.merge(&o.ledger);
        let lo = i * batch;
        let hi = (lo + batch).min(client_ids.len());
        ensure!(
            o.p.len() == (hi - lo) * k,
            "merge: batch {i} has factors for {} values, expected {}",
            o.p.len(),
            (hi - lo) * k
        );
        agg.factor_ids.extend_from_slice(&client_ids[lo..hi]);
        agg.factors.extend_from_slice(&o.p[..(hi - lo) * k]);
        for (total, ns) in agg.phase_ns.iter_mut().zip(&o.phase_ns) {
            *total += ns;
        }
        agg.batches.push(BatchStat {
            batch: i,
            clients: hi - lo,
            lane: o.lane,
            phase_ns: o.phase_ns,
        });
        if let Some(f) = &o.up_frame {
            agg.up_frames.push(f.clone());
        }
    }
    Ok(agg)
}

/// Decode one batch's sparse ∇Q* upload frame exactly as every round
/// lane must: the server trains on the *decoded* gradient, so this is
/// the single decode path shared by the in-process executor and the TCP
/// coordinator (which receives the frame over a socket).
pub fn decode_upload(
    codec: &dyn PayloadCodec,
    up_frame: &[u8],
    m_s: usize,
    k: usize,
) -> Result<Vec<f32>> {
    let up = codec.decode_sparse(up_frame)?;
    ensure!(
        up.rows == m_s && up.cols == k,
        "upload frame decoded to {}x{}, expected {m_s}x{k}",
        up.rows,
        up.cols
    );
    Ok(up.data)
}

/// Execute one batch: solve → grad → sparse wire round-trip (+ per-client
/// upload accounting) → optional eval. Pure w.r.t. the task inputs.
/// Returns the outcome together with the encoded ∇Q* upload frame — the
/// TCP lane's client side ships that frame over the socket so the
/// coordinator decodes the identical bytes.
pub fn run_batch_framed(
    rt: &mut FcfRuntime,
    codec: &dyn PayloadCodec,
    task: &RoundTask,
    index: usize,
) -> Result<(BatchOutcome, Vec<u8>)> {
    let (lo, hi) = task.batch_range(index);
    let k = task.k;
    let m_s = task.m_s();
    let rows: Vec<&SelRow> = task.rows[lo..hi].iter().collect();

    let t0 = Instant::now();
    let p = rt.solve_users(&task.q_sel, &rows)?;
    let solve_ns = t0.elapsed().as_nanos();

    let t0 = Instant::now();
    let g_raw = rt.grad_batch(&task.q_sel, &rows, &p)?;
    let grad_ns = t0.elapsed().as_nanos();

    // The ∇Q* upload round-trips the sparse wire encoder at batch
    // granularity (the backend aggregates a batch in one execution); the
    // server trains on the *decoded* gradient, so sparsification and
    // value quantization stay part of the training dynamics.
    let t0 = Instant::now();
    let up_frame = codec.encode_sparse(&g_raw, m_s, k, &task.sparse)?;
    let grad = decode_upload(codec, &up_frame, m_s, k)?;
    // Per-client upload accounting: one message per participant at the
    // batch frame's length — each client's own frame length when entropy
    // is off (the implicit-feedback ∇Q* is dense over the selected set),
    // and the structural approximation of it under range coding (see
    // module docs; an interaction-indexed frame would undercount and
    // leak the client's private interaction rows). Under upload-delta
    // mode the coordinator attributes the exact session-frame bytes per
    // client after the barrier instead, so nothing is recorded here.
    let up_bytes = up_frame.len() as u64;
    let mut ledger = TrafficLedger::new();
    if !task.collect_up_frames {
        for _ in lo..hi {
            ledger.record_up(&task.simnet, up_bytes);
        }
    }
    let codec_ns = t0.elapsed().as_nanos();

    let mut metrics = MetricAccumulator::new();
    let mut eval_ns = 0u128;
    if task.evaluate {
        let t0 = Instant::now();
        let scores = rt.scores_all(&task.q_full, &p)?;
        let m = task.m;
        for (u, &cid) in task.client_ids[lo..hi].iter().enumerate() {
            let client = task.fleet.client(cid);
            if client.test_items.is_empty() {
                continue;
            }
            let ranked = rank_candidates(&scores[u * m..(u + 1) * m], &client.train_items);
            if let Some(ms) = user_metrics(&ranked, &client.test_items) {
                metrics.push(&ms);
            }
        }
        eval_ns = t0.elapsed().as_nanos();
    }

    Ok((
        BatchOutcome {
            grad,
            p,
            ledger,
            metrics,
            phase_ns: [solve_ns, grad_ns, codec_ns, eval_ns],
            lane: 0, // stamped by the draining lane
            up_frame: task.collect_up_frames.then(|| up_frame.clone()),
        },
        up_frame,
    ))
}

/// [`run_batch_framed`] for callers that don't need the upload frame
/// (the in-process executor's workers).
fn run_batch(
    rt: &mut FcfRuntime,
    codec: &dyn PayloadCodec,
    task: &RoundTask,
    index: usize,
) -> Result<BatchOutcome> {
    run_batch_framed(rt, codec, task, index).map(|(outcome, _)| outcome)
}

type BatchSlots = Mutex<Vec<Option<Result<BatchOutcome>>>>;

/// Shared state of one in-flight round: the task, the work queue (an
/// atomic claim counter over batch indices) and the outcome slots.
struct RoundState {
    task: RoundTask,
    n_batches: usize,
    next: AtomicUsize,
    slots: BatchSlots,
}

fn lock_slots(state: &RoundState) -> std::sync::MutexGuard<'_, Vec<Option<Result<BatchOutcome>>>> {
    // A poisoned mutex only means another lane panicked *outside* the
    // (assignment-only) critical section; the data is still valid.
    state.slots.lock().unwrap_or_else(|p| p.into_inner())
}

/// Claim-and-execute batches until the round's queue is empty. `lane`
/// identifies the draining thread for the flight recorder (0 = caller,
/// `w + 1` = worker `w`); it is stamped on each outcome but never read
/// by the deterministic merge.
fn drain_queue(state: &RoundState, rt: &mut FcfRuntime, codec: &dyn PayloadCodec, lane: usize) {
    loop {
        // Relaxed is enough: the counter only distributes work; outcome
        // visibility is ordered by the slots mutex + the done channel.
        let i = state.next.fetch_add(1, Ordering::Relaxed);
        if i >= state.n_batches {
            break;
        }
        let mut out = run_batch(rt, codec, &state.task, i);
        if let Ok(o) = out.as_mut() {
            o.lane = lane;
        }
        lock_slots(state)[i] = Some(out);
    }
}

#[cfg(feature = "parallel")]
enum WorkerMsg {
    Round(Arc<RoundState>),
    Shutdown,
}

#[cfg(feature = "parallel")]
struct Worker {
    tx: Sender<WorkerMsg>,
    handle: Option<std::thread::JoinHandle<()>>,
    alive: bool,
}

/// Sends exactly one round-completion signal, even if the worker panics
/// mid-batch (the unfinished batch is recomputed by the caller).
#[cfg(feature = "parallel")]
struct DoneGuard<'a>(&'a Sender<()>);

#[cfg(feature = "parallel")]
impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let _ = self.0.send(());
    }
}

#[cfg(feature = "parallel")]
fn worker_loop(id: usize, factory: BackendFactory, rx: Receiver<WorkerMsg>, done: Sender<()>) {
    let mut runtime: Option<FcfRuntime> = None;
    let mut build_failed = false;
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Round(state) => {
                let _guard = DoneGuard(&done);
                // Cheap racy peek: if the queue already drained (few
                // batches, fast caller lane), skip the — for pjrt,
                // expensive — lazy backend build entirely.
                if runtime.is_none() && state.next.load(Ordering::Relaxed) >= state.n_batches {
                    continue;
                }
                if runtime.is_none() && !build_failed {
                    match factory.build_runtime() {
                        Ok(rt) => runtime = Some(rt),
                        Err(e) => {
                            build_failed = true;
                            warn_log!(
                                "fleet worker {id}: `{}` backend unavailable on this thread \
                                 ({e:#}); its batches fall back to the caller",
                                factory.backend_name()
                            );
                        }
                    }
                }
                if let Some(rt) = runtime.as_mut() {
                    let codec = make_codec_with(state.task.precision, state.task.entropy);
                    drain_queue(&state, rt, codec.as_ref(), id + 1);
                }
            }
        }
    }
}

/// The persistent sharded round executor. `threads` is the total number
/// of compute lanes: the caller's thread plus `threads - 1` spawned
/// workers (lazily started at the first multi-threaded round). With
/// `threads = 1` — or without the `parallel` feature — every batch runs
/// inline on the caller's runtime, through the identical per-batch
/// merge, so results match the parallel path bit for bit.
pub struct FleetExecutor {
    factory: BackendFactory,
    threads: usize,
    #[cfg(feature = "parallel")]
    workers: Vec<Worker>,
    #[cfg(feature = "parallel")]
    spawned: bool,
    #[cfg(feature = "parallel")]
    done_tx: Sender<()>,
    #[cfg(feature = "parallel")]
    done_rx: Receiver<()>,
    #[cfg(not(feature = "parallel"))]
    warned_serial: bool,
}

impl FleetExecutor {
    /// Executor over `threads` total lanes building backends via `factory`
    /// (workers spawn lazily at the first multi-batch round).
    pub fn new(factory: BackendFactory, threads: usize) -> FleetExecutor {
        #[cfg(feature = "parallel")]
        let (done_tx, done_rx) = channel();
        FleetExecutor {
            factory,
            threads: threads.max(1),
            #[cfg(feature = "parallel")]
            workers: Vec::new(),
            #[cfg(feature = "parallel")]
            spawned: false,
            #[cfg(feature = "parallel")]
            done_tx,
            #[cfg(feature = "parallel")]
            done_rx,
            #[cfg(not(feature = "parallel"))]
            warned_serial: false,
        }
    }

    /// Total compute lanes (caller + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The factory worker lanes build their backends through.
    pub fn backend_factory(&self) -> &BackendFactory {
        &self.factory
    }

    #[cfg(feature = "parallel")]
    fn spawn_workers(&mut self) {
        if self.spawned {
            return;
        }
        self.spawned = true;
        for w in 0..self.threads - 1 {
            let (tx, rx) = channel();
            let factory = self.factory.clone();
            let done = self.done_tx.clone();
            match std::thread::Builder::new()
                .name(format!("fleet-{w}"))
                .spawn(move || worker_loop(w, factory, rx, done))
            {
                Ok(handle) => self.workers.push(Worker {
                    tx,
                    handle: Some(handle),
                    alive: true,
                }),
                Err(e) => warn_log!("could not spawn fleet worker {w}: {e}"),
            }
        }
    }

    /// Hand the round to the worker pool; returns how many workers will
    /// signal completion.
    #[cfg(feature = "parallel")]
    fn dispatch(&mut self, state: &Arc<RoundState>) -> usize {
        // a single-batch round has nothing for a second lane to claim —
        // don't wake workers (and with pjrt, don't trigger their
        // expensive lazy backend builds) for it
        if self.threads <= 1 || state.n_batches <= 1 {
            return 0;
        }
        self.spawn_workers();
        let mut sent = 0;
        for w in &mut self.workers {
            if w.alive && w.tx.send(WorkerMsg::Round(state.clone())).is_ok() {
                sent += 1;
            } else {
                w.alive = false;
            }
        }
        sent
    }

    #[cfg(not(feature = "parallel"))]
    fn dispatch(&mut self, _state: &Arc<RoundState>) -> usize {
        if self.threads > 1 && !self.warned_serial {
            self.warned_serial = true;
            warn_log!(
                "runtime.threads = {} but the `parallel` feature is disabled; \
                 executing the fleet on one thread",
                self.threads
            );
        }
        0
    }

    #[cfg(feature = "parallel")]
    fn wait(&self, expected: usize) {
        for _ in 0..expected {
            // Cannot disconnect (we hold a sender); every dispatched
            // worker signals via its DoneGuard even on panic.
            let _ = self.done_rx.recv();
        }
    }

    #[cfg(not(feature = "parallel"))]
    fn wait(&self, _expected: usize) {}

    /// Execute one round's batches across all lanes and reduce
    /// deterministically. `local` is the caller-lane runtime (the
    /// trainer's — shared/compiled once per sweep); `codec` the caller's
    /// codec instance.
    pub fn run_round(
        &mut self,
        task: RoundTask,
        local: &mut FcfRuntime,
        codec: &dyn PayloadCodec,
    ) -> Result<RoundAggregate> {
        let n_batches = task.num_batches();
        let state = Arc::new(RoundState {
            task,
            n_batches,
            next: AtomicUsize::new(0),
            slots: Mutex::new((0..n_batches).map(|_| None).collect()),
        });
        let expected = self.dispatch(&state);
        // The caller lane drains the queue alongside the workers.
        drain_queue(&state, local, codec, 0);
        self.wait(expected);
        let mut slots = std::mem::take(&mut *lock_slots(&state));
        // A lane that died mid-batch leaves its claimed slot empty;
        // recompute inline (identical by construction).
        for (i, slot) in slots.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = Some(run_batch(local, codec, &state.task, i));
            }
        }
        let mut outcomes = Vec::with_capacity(n_batches);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(out)) => outcomes.push(out),
                Some(Err(e)) => return Err(anyhow!("client batch {i}: {e:#}")),
                None => unreachable!("batch {i} left unexecuted"),
            }
        }
        merge_outcomes(
            state.task.m_s(),
            state.task.k,
            &state.task.client_ids,
            state.task.batch,
            &outcomes,
        )
    }
}

#[cfg(feature = "parallel")]
impl Drop for FleetExecutor {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(handle) = w.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::ClientData;
    use crate::wire::make_codec;

    fn small_cfg() -> RunConfig {
        let mut cfg = RunConfig::paper_defaults();
        cfg.runtime.backend = "reference".into();
        cfg.model.k = 8;
        cfg
    }

    /// A synthetic round over `n` clients and `m_s` selected items; every
    /// item is "selected" so rows are positions directly.
    fn tiny_task(cfg: &RunConfig, n: usize, m_s: usize, evaluate: bool) -> RoundTask {
        let k = cfg.model.k;
        let mut rng = crate::rng::Rng::seed_from_u64(42);
        let q_sel: Vec<f32> = (0..m_s * k).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut clients = Vec::new();
        let mut rows = Vec::new();
        for _ in 0..n {
            let mut train: Vec<u32> = (0..m_s as u32).filter(|_| rng.chance(0.3)).collect();
            if train.is_empty() {
                train.push(rng.below(m_s) as u32);
            }
            train.sort_unstable();
            let test: Vec<u32> = (0..m_s as u32)
                .filter(|i| train.binary_search(i).is_err())
                .take(3)
                .collect();
            rows.push(train.clone());
            clients.push(ClientData {
                train_items: train,
                test_items: test,
            });
        }
        RoundTask {
            q_full: q_sel.clone(),
            q_sel,
            k,
            m: m_s,
            evaluate,
            rows,
            client_ids: (0..n).collect(),
            batch: 64,
            precision: Precision::F32,
            entropy: EntropyMode::None,
            sparse: SparsePolicy::default(),
            simnet: cfg.simnet.clone(),
            fleet: FleetView::from_clients(clients),
            collect_up_frames: false,
        }
    }

    #[test]
    fn factory_builds_reference_runtime() {
        let cfg = small_cfg();
        let rt = BackendFactory::from_config(&cfg).build_runtime().unwrap();
        assert_eq!(rt.k, 8);
        assert_eq!(rt.b, 64);
    }

    #[test]
    fn executor_is_thread_count_invariant() {
        let cfg = small_cfg();
        let factory = BackendFactory::from_config(&cfg);
        let task = tiny_task(&cfg, 150, 40, true);
        let mut base: Option<RoundAggregate> = None;
        for threads in [1usize, 2, 4] {
            let mut local = factory.build_runtime().unwrap();
            let codec = make_codec(Precision::F32);
            let mut ex = FleetExecutor::new(factory.clone(), threads);
            let agg = ex.run_round(task.clone(), &mut local, codec.as_ref()).unwrap();
            match &base {
                None => base = Some(agg),
                Some(b) => {
                    assert_eq!(b.grad.len(), agg.grad.len());
                    for (x, y) in b.grad.iter().zip(&agg.grad) {
                        assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
                    }
                    assert_eq!(b.ledger.up_bytes, agg.ledger.up_bytes);
                    assert_eq!(b.ledger.up_msgs, agg.ledger.up_msgs);
                    assert_eq!(
                        b.ledger.sim_secs.to_bits(),
                        agg.ledger.sim_secs.to_bits(),
                        "threads={threads}"
                    );
                    assert_eq!(b.metrics.count(), agg.metrics.count());
                    assert_eq!(b.metrics.mean().map.to_bits(), agg.metrics.mean().map.to_bits());
                    assert_eq!(b.factor_ids, agg.factor_ids);
                    assert_eq!(b.factors.len(), agg.factors.len());
                    for (pa, pb) in b.factors.iter().zip(&agg.factors) {
                        assert_eq!(pa.to_bits(), pb.to_bits(), "threads={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn uploads_are_attributed_per_client() {
        let cfg = small_cfg();
        let factory = BackendFactory::from_config(&cfg);
        let task = tiny_task(&cfg, 70, 32, false);
        let mut local = factory.build_runtime().unwrap();
        let codec = make_codec(Precision::F32);
        let mut ex = FleetExecutor::new(factory, 1);
        let n = task.client_ids.len() as u64;
        let (m_s, k) = (task.m_s(), task.k);
        let agg = ex.run_round(task, &mut local, codec.as_ref()).unwrap();
        // one message per participant, each at its exact frame length:
        // bounded by the full-m_s frame (dense implicit-feedback ∇Q*)
        // and strictly larger than an empty frame
        assert_eq!(agg.ledger.up_msgs, n);
        let max_frame = crate::wire::encoded_sparse_len(m_s, k, Precision::F32) as u64;
        let empty_frame = crate::wire::encoded_sparse_len(0, k, Precision::F32) as u64;
        assert!(agg.ledger.up_bytes <= n * max_frame);
        assert!(agg.ledger.up_bytes > n * empty_frame);
    }

    #[test]
    fn collect_mode_passes_frames_through_and_defers_attribution() {
        let cfg = small_cfg();
        let factory = BackendFactory::from_config(&cfg);
        let mut task = tiny_task(&cfg, 150, 40, false);
        task.collect_up_frames = true;
        let n_batches = task.num_batches();
        let mut local = factory.build_runtime().unwrap();
        let codec = make_codec(Precision::F32);
        let mut ex = FleetExecutor::new(factory, 2);
        let agg = ex.run_round(task.clone(), &mut local, codec.as_ref()).unwrap();
        // no upload messages recorded at batch level — the coordinator
        // attributes exact per-client session bytes after the barrier
        assert_eq!(agg.ledger.up_msgs, 0);
        assert_eq!(agg.ledger.up_bytes, 0);
        // one frame per batch, batch order, each a decodable sparse frame
        assert_eq!(agg.up_frames.len(), n_batches);
        for f in &agg.up_frames {
            decode_upload(codec.as_ref(), f, task.m_s(), task.k).unwrap();
        }
        // the non-collect run keeps the legacy attribution
        task.collect_up_frames = false;
        let legacy = ex.run_round(task, &mut local, codec.as_ref()).unwrap();
        assert_eq!(legacy.ledger.up_msgs, 150);
        assert!(legacy.up_frames.is_empty());
    }

    #[test]
    fn merge_outcomes_orders_factors_and_sums() {
        let client_ids = vec![7usize, 3, 9, 1, 5];
        let (m_s, k, batch) = (2usize, 2usize, 2usize);
        let outcomes = vec![
            BatchOutcome {
                grad: vec![1.0, 2.0, 3.0, 4.0],
                p: vec![0.1, 0.2, 0.3, 0.4],
                ..BatchOutcome::default()
            },
            BatchOutcome {
                grad: vec![10.0, 20.0, 30.0, 40.0],
                p: vec![0.5, 0.6, 0.7, 0.8],
                ..BatchOutcome::default()
            },
            BatchOutcome {
                grad: vec![100.0, 200.0, 300.0, 400.0],
                p: vec![0.9, 1.0],
                ..BatchOutcome::default()
            },
        ];
        let agg = merge_outcomes(m_s, k, &client_ids, batch, &outcomes).unwrap();
        assert_eq!(agg.grad, vec![111.0, 222.0, 333.0, 444.0]);
        assert_eq!(agg.factor_ids, client_ids);
        // flat buffer: slot i is factors[i*k .. (i+1)*k]
        assert_eq!(agg.factors.len(), client_ids.len() * k);
        assert_eq!(&agg.factors[4 * k..5 * k], &[0.9, 1.0]);
        // per-batch stats come out in batch-index order with exact sizes
        assert_eq!(agg.batches.len(), 3);
        let order: Vec<usize> = agg.batches.iter().map(|b| b.batch).collect();
        assert_eq!(order, vec![0, 1, 2]);
        let sizes: Vec<usize> = agg.batches.iter().map(|b| b.clients).collect();
        assert_eq!(sizes, vec![2, 2, 1]);
        // wrong outcome count is rejected
        assert!(merge_outcomes(m_s, k, &client_ids, batch, &outcomes[..2]).is_err());
    }

    #[test]
    fn empty_round_produces_empty_aggregate() {
        let cfg = small_cfg();
        let factory = BackendFactory::from_config(&cfg);
        let mut task = tiny_task(&cfg, 10, 16, false);
        task.rows.clear();
        task.client_ids.clear();
        let mut local = factory.build_runtime().unwrap();
        let codec = make_codec(Precision::F32);
        let mut ex = FleetExecutor::new(factory, 4);
        let agg = ex.run_round(task, &mut local, codec.as_ref()).unwrap();
        assert_eq!(agg.grad, vec![0.0f32; 16 * 8]);
        assert!(agg.factor_ids.is_empty());
        assert!(agg.factors.is_empty());
        assert_eq!(agg.ledger.up_msgs, 0);
        assert_eq!(agg.metrics.count(), 0);
    }
}
