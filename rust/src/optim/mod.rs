//! Server-side Adam optimizer on the global item-factor matrix Q
//! (paper Eq. 4 + Kingma & Ba, used by FCF per Ammad-ud-din et al.).
//!
//! The payload-optimized variants only receive gradients for the selected
//! items, so the optimizer keeps **per-item** first/second-moment state
//! and a per-item step counter: an item's Adam state advances only when
//! that item was part of Q* (Alg. 1 lines 13–14 update only selected j).
//! This mirrors the paper's server behaviour and avoids momentum "ghost
//! updates" to items that were never transmitted.
//!
//! The arithmetic is pinned against the python oracle
//! (`python/compile/kernels/ref.py::ref_adam`) via the `adam` artifact and
//! the runtime differential tests.

use crate::config::ModelConfig;
use crate::linalg::Mat;

/// Adam with per-item (column-of-Q) state.
#[derive(Debug, Clone)]
pub struct Adam {
    k: usize,
    eta: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    /// First moment, laid out like Q: item-major `[item * k + f]`.
    m: Vec<f32>,
    /// Second moment, same layout.
    v: Vec<f32>,
    /// Per-item update count (bias correction uses this item's t).
    t: Vec<u32>,
}

impl Adam {
    /// Zero-state optimizer for a `num_items × k` model.
    pub fn new(num_items: usize, cfg: &ModelConfig) -> Adam {
        Adam {
            k: cfg.k,
            eta: cfg.eta,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            m: vec![0.0; num_items * cfg.k],
            v: vec![0.0; num_items * cfg.k],
            t: vec![0; num_items],
        }
    }

    /// Catalog size this optimizer tracks state for.
    pub fn num_items(&self) -> usize {
        self.t.len()
    }

    /// Updates an item appears to have received (diagnostics/tests).
    pub fn item_steps(&self, item: usize) -> u32 {
        self.t[item]
    }

    /// Apply one aggregated-gradient step to the selected items.
    ///
    /// * `q` — global model, item-major (`num_items × k`).
    /// * `selected` — item ids (columns of Q*, paper's M_s subset).
    /// * `grad` — aggregated gradient, `selected.len() × k`, laid out
    ///   `[s * k + f]` in the same item order as `selected`.
    pub fn step_selected(&mut self, q: &mut Mat, selected: &[u32], grad: &[f32]) {
        assert_eq!(q.cols(), self.k);
        assert_eq!(grad.len(), selected.len() * self.k);
        for (s, &item) in selected.iter().enumerate() {
            let item = item as usize;
            self.t[item] += 1;
            let t = self.t[item] as i32;
            let bc1 = 1.0 - self.beta1.powi(t);
            let bc2 = 1.0 - self.beta2.powi(t);
            let g = &grad[s * self.k..(s + 1) * self.k];
            let mrow = &mut self.m[item * self.k..(item + 1) * self.k];
            let vrow = &mut self.v[item * self.k..(item + 1) * self.k];
            let qrow = q.row_mut(item);
            for f in 0..self.k {
                mrow[f] = self.beta1 * mrow[f] + (1.0 - self.beta1) * g[f];
                vrow[f] = self.beta2 * vrow[f] + (1.0 - self.beta2) * g[f] * g[f];
                let mhat = mrow[f] / bc1;
                let vhat = vrow[f] / bc2;
                qrow[f] -= self.eta * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn cfg() -> ModelConfig {
        let mut c = RunConfig::paper_defaults().model;
        c.k = 4;
        c
    }

    #[test]
    fn first_step_matches_closed_form() {
        let c = cfg();
        let mut adam = Adam::new(3, &c);
        let mut q = Mat::zeros(3, 4);
        let grad = vec![1.0f32; 4];
        adam.step_selected(&mut q, &[1], &grad);
        // t=1: mhat = g, vhat = g^2 -> step = eta * g/(|g|+eps) = eta
        for f in 0..4 {
            assert!((q.get(1, f) + c.eta).abs() < 1e-6, "{}", q.get(1, f));
        }
        // untouched items stay zero
        assert_eq!(q.row(0), &[0.0; 4]);
        assert_eq!(q.row(2), &[0.0; 4]);
    }

    #[test]
    fn per_item_counters_advance_independently() {
        let c = cfg();
        let mut adam = Adam::new(4, &c);
        let mut q = Mat::zeros(4, 4);
        let g2 = vec![0.5f32; 8];
        adam.step_selected(&mut q, &[0, 2], &g2);
        adam.step_selected(&mut q, &[0, 3], &g2);
        assert_eq!(adam.item_steps(0), 2);
        assert_eq!(adam.item_steps(1), 0);
        assert_eq!(adam.item_steps(2), 1);
        assert_eq!(adam.item_steps(3), 1);
    }

    #[test]
    fn matches_python_oracle_sequence() {
        // Mirror ref_adam over 5 steps on one item and compare exactly.
        let c = RunConfig::paper_defaults().model; // k = 25
        let mut adam = Adam::new(1, &c);
        let mut q = Mat::zeros(1, c.k);
        for f in 0..c.k {
            q.set(0, f, 0.3 * (f as f32) - 1.0);
        }
        // independent re-implementation (the oracle's formula)
        let mut qe: Vec<f32> = (0..c.k).map(|f| 0.3 * (f as f32) - 1.0).collect();
        let mut me = vec![0.0f32; c.k];
        let mut ve = vec![0.0f32; c.k];
        for t in 1..=5 {
            let g: Vec<f32> = (0..c.k).map(|f| ((f + t) as f32 * 0.37).sin()).collect();
            adam.step_selected(&mut q, &[0], &g);
            for f in 0..c.k {
                me[f] = c.beta1 * me[f] + (1.0 - c.beta1) * g[f];
                ve[f] = c.beta2 * ve[f] + (1.0 - c.beta2) * g[f] * g[f];
                let mhat = me[f] / (1.0 - c.beta1.powi(t as i32));
                let vhat = ve[f] / (1.0 - c.beta2.powi(t as i32));
                qe[f] -= c.eta * mhat / (vhat.sqrt() + c.eps);
            }
        }
        for f in 0..c.k {
            assert!((q.get(0, f) - qe[f]).abs() < 1e-6);
        }
    }

    #[test]
    fn descends_a_quadratic() {
        let c = cfg();
        let mut adam = Adam::new(1, &c);
        let mut q = Mat::from_vec(1, 4, vec![5.0; 4]);
        for _ in 0..800 {
            let grad: Vec<f32> = q.row(0).to_vec(); // d/dq 0.5||q||^2
            adam.step_selected(&mut q, &[0], &grad);
        }
        assert!(q.row(0).iter().all(|x| x.abs() < 0.5), "{:?}", q.row(0));
    }

    #[test]
    #[should_panic]
    fn grad_shape_mismatch_panics() {
        let c = cfg();
        let mut adam = Adam::new(2, &c);
        let mut q = Mat::zeros(2, 4);
        adam.step_selected(&mut q, &[0, 1], &[0.0; 4]); // needs 8
    }
}
