//! Flat interaction arena — the fleet-scale client-state substrate.
//!
//! At `Theta ≈ 10^6` simulated clients, per-client `Vec<u32>` interaction
//! state costs 48 bytes of Vec headers plus two heap allocations per
//! client before a single item id is stored — ~100 MB of pure overhead
//! and a million-allocation build. The arena stores every client's
//! sorted train and test item ids in two shared contiguous buffers with
//! `u32` offset tables, so the marginal per-client cost is exactly two
//! integers (one train offset, one test offset) and construction is two
//! passes over the split's CSR rows.
//!
//! The arena is immutable after construction and lives behind an `Arc`
//! in [`crate::client::FleetView`], so the sharded round executor
//! (`runtime::fleet`) hands worker threads zero-copy borrowed slices.
//! Layout and the per-client budget table are documented in
//! docs/ARCHITECTURE.md §"Fleet scale".

use super::{Interactions, Split};

/// Shared flat storage for every client's sorted interaction ids.
///
/// Two parallel CSR-style blocks (train, test) over one client index:
/// `items[off[u] .. off[u + 1]]` is client `u`'s sorted id slice.
/// Offsets are `u32` — a single simulated fleet is capped at `2^32 - 1`
/// total interactions per block, far beyond any dataset this simulator
/// targets (MovieLens-1M is `10^6`, the fleet bench `~1.6 × 10^7`).
#[derive(Debug, Clone)]
pub struct InteractionArena {
    /// All clients' train item ids, concatenated in client order.
    train_items: Vec<u32>,
    /// Train offsets, `num_clients + 1` entries.
    train_off: Vec<u32>,
    /// All clients' held-out test item ids, concatenated in client order.
    test_items: Vec<u32>,
    /// Test offsets, `num_clients + 1` entries.
    test_off: Vec<u32>,
}

/// Concatenate one CSR matrix's rows into an (items, offsets) block.
fn pack(x: &Interactions) -> (Vec<u32>, Vec<u32>) {
    let n = x.num_users();
    assert!(
        x.nnz() <= u32::MAX as usize,
        "interaction arena block overflows u32 offsets ({} ids)",
        x.nnz()
    );
    let mut items = Vec::with_capacity(x.nnz());
    let mut off = Vec::with_capacity(n + 1);
    off.push(0u32);
    for u in 0..n {
        items.extend_from_slice(x.user_items(u));
        off.push(items.len() as u32);
    }
    (items, off)
}

impl InteractionArena {
    /// Build the arena from a per-user train/test split (the dataset
    /// loaders' output). Rows are already sorted in the CSR source, so
    /// this is a straight two-pass concatenation.
    pub fn from_split(split: &Split) -> InteractionArena {
        let (train_items, train_off) = pack(&split.train);
        let (test_items, test_off) = pack(&split.test);
        assert_eq!(train_off.len(), test_off.len(), "train/test user counts differ");
        InteractionArena {
            train_items,
            train_off,
            test_items,
            test_off,
        }
    }

    /// Build directly from per-client sorted id lists (test scaffolding
    /// and the fleet bench's synthetic-free 10^6-client construction,
    /// which must not pay the planted-factor generator's O(users × items)
    /// scoring pass).
    pub fn from_rows(train: &[Vec<u32>], test: &[Vec<u32>]) -> InteractionArena {
        assert_eq!(train.len(), test.len(), "train/test row counts differ");
        let pack_rows = |rows: &[Vec<u32>]| {
            let total: usize = rows.iter().map(Vec::len).sum();
            assert!(
                total <= u32::MAX as usize,
                "interaction arena block overflows u32 offsets ({total} ids)"
            );
            let mut items = Vec::with_capacity(total);
            let mut off = Vec::with_capacity(rows.len() + 1);
            off.push(0u32);
            for row in rows {
                debug_assert!(row.windows(2).all(|w| w[0] < w[1]), "row not sorted-unique");
                items.extend_from_slice(row);
                off.push(items.len() as u32);
            }
            (items, off)
        };
        let (train_items, train_off) = pack_rows(train);
        let (test_items, test_off) = pack_rows(test);
        InteractionArena {
            train_items,
            train_off,
            test_items,
            test_off,
        }
    }

    /// Number of clients the arena holds rows for.
    pub fn num_clients(&self) -> usize {
        self.train_off.len() - 1
    }

    /// Client `u`'s sorted train item ids (zero-copy).
    pub fn train_items(&self, u: usize) -> &[u32] {
        &self.train_items[self.train_off[u] as usize..self.train_off[u + 1] as usize]
    }

    /// Client `u`'s sorted held-out test item ids (zero-copy).
    pub fn test_items(&self, u: usize) -> &[u32] {
        &self.test_items[self.test_off[u] as usize..self.test_off[u + 1] as usize]
    }

    /// Total train interactions across the fleet.
    pub fn train_nnz(&self) -> usize {
        self.train_items.len()
    }

    /// Total test interactions across the fleet.
    pub fn test_nnz(&self) -> usize {
        self.test_items.len()
    }

    /// Exact heap footprint of the arena's four buffers in bytes — the
    /// number the fleet bench reports as `arena_bytes` and the scale
    /// test holds under its memory ceiling.
    pub fn heap_bytes(&self) -> usize {
        std::mem::size_of::<u32>()
            * (self.train_items.capacity()
                + self.train_off.capacity()
                + self.test_items.capacity()
                + self.test_off.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn toy_split() -> Split {
        let x = Interactions::from_pairs(
            4,
            8,
            vec![
                (0, 1),
                (0, 4),
                (0, 7),
                (1, 2),
                (2, 0),
                (2, 3),
                (2, 5),
                (2, 6),
                (3, 1),
            ],
        )
        .unwrap();
        let mut rng = Rng::seed_from_u64(11);
        x.split(0.8, &mut rng)
    }

    #[test]
    fn arena_rows_match_split_rows() {
        let s = toy_split();
        let a = InteractionArena::from_split(&s);
        assert_eq!(a.num_clients(), 4);
        assert_eq!(a.train_nnz(), s.train.nnz());
        assert_eq!(a.test_nnz(), s.test.nnz());
        for u in 0..4 {
            assert_eq!(a.train_items(u), s.train.user_items(u), "user {u} train");
            assert_eq!(a.test_items(u), s.test.user_items(u), "user {u} test");
        }
    }

    #[test]
    fn from_rows_matches_explicit_lists() {
        let train = vec![vec![1, 4], vec![], vec![0, 3, 5]];
        let test = vec![vec![2], vec![7], vec![]];
        let a = InteractionArena::from_rows(&train, &test);
        assert_eq!(a.num_clients(), 3);
        assert_eq!(a.train_items(0), &[1, 4]);
        assert_eq!(a.train_items(1), &[] as &[u32]);
        assert_eq!(a.train_items(2), &[0, 3, 5]);
        assert_eq!(a.test_items(1), &[7]);
        assert_eq!(a.test_items(2), &[] as &[u32]);
    }

    #[test]
    fn empty_fleet_is_representable() {
        let a = InteractionArena::from_rows(&[], &[]);
        assert_eq!(a.num_clients(), 0);
        assert_eq!(a.train_nnz(), 0);
    }

    #[test]
    fn heap_bytes_counts_all_four_buffers() {
        let a = InteractionArena::from_rows(&[vec![1, 2, 3]], &[vec![4]]);
        // at least the ids (4 total) + offsets (2 * 2) at 4 bytes each
        assert!(a.heap_bytes() >= 4 * (4 + 4));
    }
}
