//! Parsers for the paper's three dataset file formats (§5).
//!
//! The offline environment cannot download the real datasets, but these
//! loaders make them drop-in: point `dataset.name = "file"` plus
//! `dataset.path`/`dataset.format` at the downloaded files and the rest of
//! the system is unchanged. All formats collapse to binary implicit
//! feedback exactly as §5 prescribes (any rating/count/click -> 1).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Interactions;

/// Dense re-indexing of raw string/integer ids.
#[derive(Debug, Default)]
struct IdMap {
    map: HashMap<String, u32>,
}

impl IdMap {
    fn get_or_insert(&mut self, raw: &str) -> u32 {
        let next = self.map.len() as u32;
        *self.map.entry(raw.to_string()).or_insert(next)
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Load a dataset by format name (`movielens` | `lastfm` | `mind`).
pub fn load<P: AsRef<Path>>(format: &str, path: P) -> Result<Interactions> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse(format, &text)
}

/// Parse dataset text by format name (separated from [`load`] for tests).
pub fn parse(format: &str, text: &str) -> Result<Interactions> {
    match format {
        "movielens" => parse_movielens(text),
        "lastfm" => parse_lastfm(text),
        "mind" => parse_mind(text),
        other => bail!("unknown dataset format `{other}` (movielens|lastfm|mind)"),
    }
}

/// MovieLens-1M `ratings.dat`: `UserID::MovieID::Rating::Timestamp`.
/// Explicit ratings convert to implicit feedback (any rating -> 1, §5.1).
pub fn parse_movielens(text: &str) -> Result<Interactions> {
    let mut users = IdMap::default();
    let mut items = IdMap::default();
    let mut pairs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut f = line.split("::");
        let (u, i) = match (f.next(), f.next(), f.next()) {
            (Some(u), Some(i), Some(_rating)) => (u, i),
            _ => bail!("movielens line {}: expected `u::i::r::t`", lineno + 1),
        };
        pairs.push((users.get_or_insert(u), items.get_or_insert(i)));
    }
    Interactions::from_pairs(users.len(), items.len(), pairs)
}

/// Last-FM hetrec `user_artists.dat`: header line then
/// `userID\tartistID\tweight`. Counts convert to implicit feedback (§5.2).
pub fn parse_lastfm(text: &str) -> Result<Interactions> {
    let mut users = IdMap::default();
    let mut items = IdMap::default();
    let mut pairs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if lineno == 0 && line.to_lowercase().starts_with("user") {
            continue; // header
        }
        let mut f = line.split_whitespace();
        let (u, i) = match (f.next(), f.next()) {
            (Some(u), Some(i)) => (u, i),
            _ => bail!("lastfm line {}: expected `user artist weight`", lineno + 1),
        };
        pairs.push((users.get_or_insert(u), items.get_or_insert(i)));
    }
    Interactions::from_pairs(users.len(), items.len(), pairs)
}

/// MIND `behaviors.tsv`:
/// `ImpressionID\tUserID\tTime\tHistory\tImpressions` where History is
/// space-separated news ids and Impressions are `NewsID-{0,1}` pairs.
/// History items and clicked (`-1`) impressions become interactions (§5.3).
pub fn parse_mind(text: &str) -> Result<Interactions> {
    let mut users = IdMap::default();
    let mut items = IdMap::default();
    let mut pairs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        if fields.len() < 5 {
            bail!("mind line {}: expected 5 tab fields, got {}", lineno + 1, fields.len());
        }
        let u = users.get_or_insert(fields[1]);
        for news in fields[3].split_whitespace() {
            pairs.push((u, items.get_or_insert(news)));
        }
        for imp in fields[4].split_whitespace() {
            match imp.rsplit_once('-') {
                Some((news, "1")) => pairs.push((u, items.get_or_insert(news))),
                Some((_, "0")) => {}
                _ => bail!("mind line {}: bad impression `{imp}`", lineno + 1),
            }
        }
    }
    Interactions::from_pairs(users.len(), items.len(), pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn movielens_fixture() {
        let text = "1::10::5::978300760\n1::20::3::978302109\n2::10::1::978301968\n";
        let x = parse_movielens(text).unwrap();
        assert_eq!(x.num_users(), 2);
        assert_eq!(x.num_items(), 2);
        assert_eq!(x.nnz(), 3);
        // all ratings (5, 3, 1) collapsed to implicit 1s
        assert!(x.contains(0, 0) && x.contains(0, 1) && x.contains(1, 0));
    }

    #[test]
    fn movielens_bad_line() {
        assert!(parse_movielens("1::10\n").is_err());
    }

    #[test]
    fn lastfm_fixture_with_header() {
        let text = "userID\tartistID\tweight\n2\t51\t13883\n2\t52\t11690\n3\t51\t100\n";
        let x = parse_lastfm(text).unwrap();
        assert_eq!(x.num_users(), 2);
        assert_eq!(x.num_items(), 2);
        assert_eq!(x.nnz(), 3);
    }

    #[test]
    fn mind_fixture() {
        let text = "1\tU13740\t11/11/2019 9:05:58 AM\tN55189 N42782\tN55689-1 N35729-0\n\
                    2\tU91836\t11/12/2019 6:11:30 PM\t\tN20678-0 N39317-1\n";
        let x = parse_mind(text).unwrap();
        assert_eq!(x.num_users(), 2);
        // items: N55189, N42782 (history), N55689, N39317 (clicked);
        // non-clicked impressions are never registered
        assert_eq!(x.num_items(), 4);
        assert_eq!(x.nnz(), 4);
    }

    #[test]
    fn mind_bad_impression() {
        assert!(parse_mind("1\tU1\tt\t\tN1-7\n").is_err());
    }

    #[test]
    fn unknown_format_rejected() {
        assert!(parse("netflix", "").is_err());
    }

    #[test]
    fn load_roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join("fedpayload_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ratings.dat");
        std::fs::write(&p, "1::1::5::0\n2::1::4::0\n").unwrap();
        let x = load("movielens", &p).unwrap();
        assert_eq!(x.stats().interactions, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
