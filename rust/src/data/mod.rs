//! Dataset substrate: implicit-feedback interaction matrices, per-user
//! train/test splits, real-format loaders and calibrated synthetic
//! generators (paper §5, Table 2).
//!
//! The paper's three datasets (Movielens-1M, Last-FM, MIND-small) are
//! downloads we cannot perform offline; [`synthetic`] generates
//! statistically calibrated stand-ins (same user/item/interaction counts
//! and sparsity, Zipf popularity, planted low-rank structure) and
//! [`loaders`] parses the real file formats so the actual datasets drop in
//! unchanged. See DESIGN.md §Substitutions.

pub mod arena;
pub mod loaders;
pub mod synthetic;

pub use arena::InteractionArena;

use anyhow::{bail, Result};

use crate::rng::Rng;

/// Binary implicit-feedback interactions in CSR form (rows = users).
///
/// `x_ij = 1` iff user `i` interacted with item `j` (paper §2.1: all
/// ratings/counts collapse to 1; missing entries are 0).
#[derive(Debug, Clone)]
pub struct Interactions {
    num_users: usize,
    num_items: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
}

impl Interactions {
    /// Build from (user, item) pairs; duplicates collapse to one.
    pub fn from_pairs(
        num_users: usize,
        num_items: usize,
        mut pairs: Vec<(u32, u32)>,
    ) -> Result<Interactions> {
        for &(u, i) in &pairs {
            if u as usize >= num_users || i as usize >= num_items {
                bail!("interaction ({u}, {i}) out of bounds ({num_users} x {num_items})");
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut row_ptr = vec![0usize; num_users + 1];
        for &(u, _) in &pairs {
            row_ptr[u as usize + 1] += 1;
        }
        for u in 0..num_users {
            row_ptr[u + 1] += row_ptr[u];
        }
        let col_idx = pairs.into_iter().map(|(_, i)| i).collect();
        Ok(Interactions {
            num_users,
            num_items,
            row_ptr,
            col_idx,
        })
    }

    /// Number of users (matrix rows).
    pub fn num_users(&self) -> usize {
        self.num_users
    }

    /// Number of items (matrix columns).
    pub fn num_items(&self) -> usize {
        self.num_items
    }

    /// Total observed interactions.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Sorted item indices for one user.
    pub fn user_items(&self, u: usize) -> &[u32] {
        &self.col_idx[self.row_ptr[u]..self.row_ptr[u + 1]]
    }

    /// Number of interactions user `u` has.
    pub fn user_degree(&self, u: usize) -> usize {
        self.row_ptr[u + 1] - self.row_ptr[u]
    }

    /// Binary membership test (binary search on the sorted row).
    pub fn contains(&self, u: usize, item: u32) -> bool {
        self.user_items(u).binary_search(&item).is_ok()
    }

    /// Percentage of *unobserved* cells, as the paper's Table 2 reports.
    pub fn sparsity_pct(&self) -> f64 {
        let total = self.num_users as f64 * self.num_items as f64;
        100.0 * (1.0 - self.nnz() as f64 / total)
    }

    /// Interaction count per item (TopList ranking, Table 2 diagnostics).
    pub fn item_popularity(&self) -> Vec<u32> {
        let mut pop = vec![0u32; self.num_items];
        for &i in &self.col_idx {
            pop[i as usize] += 1;
        }
        pop
    }

    /// Items ranked by descending popularity (ties by index for
    /// determinism) — the TopList baseline's recommendation order.
    pub fn popularity_ranking(&self) -> Vec<u32> {
        let pop = self.item_popularity();
        let mut order: Vec<u32> = (0..self.num_items as u32).collect();
        order.sort_by(|&a, &b| {
            pop[b as usize]
                .cmp(&pop[a as usize])
                .then(a.cmp(&b))
        });
        order
    }

    /// Users with at least `min` interactions (paper: MIND keeps >= 5
    /// clicks). Returns a dataset re-indexed over the kept users.
    pub fn filter_min_user_interactions(&self, min: usize) -> Interactions {
        let kept: Vec<usize> = (0..self.num_users)
            .filter(|&u| self.user_degree(u) >= min)
            .collect();
        let mut pairs = Vec::with_capacity(self.nnz());
        for (new_u, &old_u) in kept.iter().enumerate() {
            for &i in self.user_items(old_u) {
                pairs.push((new_u as u32, i));
            }
        }
        Interactions::from_pairs(kept.len(), self.num_items, pairs)
            .expect("filtered pairs are in bounds")
    }

    /// Summary statistics in the shape of the paper's Table 2.
    pub fn stats(&self) -> DatasetStats {
        DatasetStats {
            users: self.num_users,
            items: self.num_items,
            interactions: self.nnz(),
            sparsity_pct: self.sparsity_pct(),
        }
    }

    /// Per-user random split into train/test (paper §6.2: 80% train).
    ///
    /// Every user keeps at least one train item; users with >= 2 items get
    /// at least one test item, matching the paper's per-user evaluation.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> Split {
        assert!((0.0..1.0).contains(&train_frac) && train_frac > 0.0);
        let mut train_pairs = Vec::new();
        let mut test_pairs = Vec::new();
        for u in 0..self.num_users {
            let mut items: Vec<u32> = self.user_items(u).to_vec();
            rng.shuffle(&mut items);
            let n = items.len();
            if n == 0 {
                continue;
            }
            let mut n_train = ((n as f64) * train_frac).round() as usize;
            n_train = n_train.clamp(1, n);
            if n >= 2 && n_train == n {
                n_train = n - 1; // guarantee a non-empty test set
            }
            for (idx, &i) in items.iter().enumerate() {
                if idx < n_train {
                    train_pairs.push((u as u32, i));
                } else {
                    test_pairs.push((u as u32, i));
                }
            }
        }
        Split {
            train: Interactions::from_pairs(self.num_users, self.num_items, train_pairs)
                .expect("train pairs in bounds"),
            test: Interactions::from_pairs(self.num_users, self.num_items, test_pairs)
                .expect("test pairs in bounds"),
        }
    }
}

/// Table 2-shaped dataset summary.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    /// Number of users.
    pub users: usize,
    /// Number of items.
    pub items: usize,
    /// Observed interactions.
    pub interactions: usize,
    /// Percentage of unobserved cells.
    pub sparsity_pct: f64,
}

impl std::fmt::Display for DatasetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "users={} items={} interactions={} sparsity={:.2}%",
            self.users, self.items, self.interactions, self.sparsity_pct
        )
    }
}

/// Per-user train/test split.
#[derive(Debug, Clone)]
pub struct Split {
    /// The training interactions.
    pub train: Interactions,
    /// The held-out test interactions.
    pub test: Interactions,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Interactions {
        // 3 users x 5 items
        Interactions::from_pairs(
            3,
            5,
            vec![(0, 1), (0, 3), (1, 0), (1, 1), (1, 2), (1, 4), (2, 4)],
        )
        .unwrap()
    }

    #[test]
    fn csr_layout() {
        let x = toy();
        assert_eq!(x.nnz(), 7);
        assert_eq!(x.user_items(0), &[1, 3]);
        assert_eq!(x.user_items(1), &[0, 1, 2, 4]);
        assert_eq!(x.user_items(2), &[4]);
        assert!(x.contains(0, 3));
        assert!(!x.contains(0, 2));
    }

    #[test]
    fn duplicates_collapse() {
        let x = Interactions::from_pairs(1, 3, vec![(0, 1), (0, 1), (0, 2)]).unwrap();
        assert_eq!(x.nnz(), 2);
    }

    #[test]
    fn out_of_bounds_rejected() {
        assert!(Interactions::from_pairs(1, 2, vec![(0, 2)]).is_err());
        assert!(Interactions::from_pairs(1, 2, vec![(1, 0)]).is_err());
    }

    #[test]
    fn sparsity_matches_formula() {
        let x = toy();
        let expected = 100.0 * (1.0 - 7.0 / 15.0);
        assert!((x.sparsity_pct() - expected).abs() < 1e-9);
    }

    #[test]
    fn popularity_ranking_descending() {
        let x = toy();
        let pop = x.item_popularity();
        assert_eq!(pop, vec![1, 2, 1, 1, 2]);
        let rank = x.popularity_ranking();
        assert_eq!(rank[0], 1); // pop 2, lower index first on ties
        assert_eq!(rank[1], 4);
    }

    #[test]
    fn filter_min_interactions() {
        let x = toy();
        let f = x.filter_min_user_interactions(2);
        assert_eq!(f.num_users(), 2);
        assert_eq!(f.user_items(0), &[1, 3]);
        assert_eq!(f.user_items(1), &[0, 1, 2, 4]);
    }

    #[test]
    fn split_preserves_interactions_and_disjoint() {
        let mut rng = Rng::seed_from_u64(1);
        let x = toy();
        let s = x.split(0.8, &mut rng);
        assert_eq!(s.train.nnz() + s.test.nnz(), x.nnz());
        for u in 0..3 {
            assert!(s.train.user_degree(u) >= 1);
            for &i in s.test.user_items(u) {
                assert!(!s.train.contains(u, i), "leak u={u} i={i}");
                assert!(x.contains(u, i));
            }
        }
        // user 1 has 4 items -> at least one test item
        assert!(s.test.user_degree(1) >= 1);
    }

    #[test]
    fn split_single_item_user_goes_to_train() {
        let mut rng = Rng::seed_from_u64(2);
        let x = Interactions::from_pairs(1, 3, vec![(0, 2)]).unwrap();
        let s = x.split(0.8, &mut rng);
        assert_eq!(s.train.nnz(), 1);
        assert_eq!(s.test.nnz(), 0);
    }
}
