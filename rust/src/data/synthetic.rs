//! Calibrated synthetic dataset generation (DESIGN.md §Substitutions).
//!
//! The generator plants the two structures the paper's evaluation hinges
//! on:
//!
//! 1. **Low-rank user-item affinity** — interactions are drawn
//!    preferentially from each user's top-affinity items under a planted
//!    factor model, so FCF can actually learn and test-set metrics are
//!    meaningful.
//! 2. **Zipf item popularity** — a popularity mixture concentrates
//!    interactions on few items, giving the regime where payload
//!    selection matters (relevant items are a small subset) and where the
//!    TopList baseline is strong (news-style data, paper §7 MIND).
//!
//! User activity is heterogeneous (lognormal-ish) with a floor of
//! `min_user_interactions`, matching the paper's MIND preprocessing
//! (users with >= 5 clicks).

use crate::config::DatasetConfig;
use crate::rng::{CdfSampler, Rng};

use super::Interactions;

/// Fraction of interactions drawn from pure popularity (vs. the user's
/// planted-affinity pool).
const POPULARITY_MIX: f64 = 0.5;

/// Size of each user's affinity candidate pool, as a multiple of their
/// interaction count (pool = top `POOL_FACTOR * n_u` affinity items).
const POOL_FACTOR: usize = 4;

/// Generate a calibrated implicit-feedback dataset.
pub fn generate(cfg: &DatasetConfig, rng: &mut Rng) -> Interactions {
    let n = cfg.users;
    let m = cfg.items;
    let rank = cfg.planted_rank.max(1);
    assert!(n > 0 && m > 0, "empty dataset config");

    // Planted factors: U (n x r), V (m x r).
    let mut u = vec![0.0f32; n * rank];
    let mut v = vec![0.0f32; m * rank];
    for x in u.iter_mut() {
        *x = rng.normal() as f32;
    }
    for x in v.iter_mut() {
        *x = rng.normal() as f32;
    }

    // Zipf popularity over a random permutation of items (so popular items
    // are spread across indices, not clustered at 0..).
    let mut perm: Vec<u32> = (0..m as u32).collect();
    rng.shuffle(&mut perm);
    let zipf = CdfSampler::zipf(m, cfg.zipf_s);

    // Heterogeneous per-user activity: lognormal weights scaled to the
    // target interaction total, floored at min_user_interactions.
    let floor = cfg.min_user_interactions.max(2);
    let mut weights: Vec<f64> = (0..n).map(|_| (rng.normal() * 1.0).exp()).collect();
    let wsum: f64 = weights.iter().sum();
    let target = cfg.interactions as f64;
    let mut counts: Vec<usize> = weights
        .iter_mut()
        .map(|w| ((*w / wsum * target).round() as usize).clamp(floor, m))
        .collect();
    // Rebalance to hit the target total (floor clamping skews the sum).
    let mut total: isize = counts.iter().sum::<usize>() as isize;
    let mut adjust_idx = 0usize;
    while total != cfg.interactions as isize {
        let i = adjust_idx % n;
        adjust_idx += 1;
        if total < cfg.interactions as isize {
            if counts[i] < m {
                counts[i] += 1;
                total += 1;
            }
        } else if counts[i] > floor {
            counts[i] -= 1;
            total -= 1;
        }
        if adjust_idx > 100 * n + 100 {
            break; // target unreachable (e.g. n*m too small) — keep best effort
        }
    }

    // Per-user item sampling: popularity mixture + affinity pool.
    let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(cfg.interactions + n);
    let mut scores: Vec<(f32, u32)> = Vec::with_capacity(m);
    for user in 0..n {
        let n_u = counts[user];
        // Top-affinity pool for this user under the planted model.
        scores.clear();
        let urow = &u[user * rank..(user + 1) * rank];
        for item in 0..m {
            let vrow = &v[item * rank..(item + 1) * rank];
            let mut s = 0.0f32;
            for r in 0..rank {
                s += urow[r] * vrow[r];
            }
            scores.push((s, item as u32));
        }
        let pool_size = (POOL_FACTOR * n_u).clamp(n_u, m);
        // partial select of the top pool_size affinities
        scores.select_nth_unstable_by(pool_size.min(m - 1), |a, b| {
            b.0.partial_cmp(&a.0).unwrap()
        });
        let pool = &scores[..pool_size];

        let mut chosen: Vec<u32> = Vec::with_capacity(n_u);
        let mut guard = 0usize;
        while chosen.len() < n_u && guard < 50 * n_u + 200 {
            guard += 1;
            let item = if rng.chance(POPULARITY_MIX) {
                perm[zipf.sample(rng)]
            } else {
                pool[rng.below(pool.len())].1
            };
            if !chosen.contains(&item) {
                chosen.push(item);
            }
        }
        for &item in &chosen {
            pairs.push((user as u32, item));
        }
    }

    Interactions::from_pairs(n, m, pairs).expect("generated pairs in bounds")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn small_cfg() -> DatasetConfig {
        let mut c = RunConfig::paper_defaults().dataset;
        c.users = 120;
        c.items = 300;
        c.interactions = 3_000;
        c.planted_rank = 8;
        c.min_user_interactions = 5;
        c
    }

    #[test]
    fn hits_calibration_targets() {
        let cfg = small_cfg();
        let mut rng = Rng::seed_from_u64(42);
        let x = generate(&cfg, &mut rng);
        let s = x.stats();
        assert_eq!(s.users, 120);
        assert_eq!(s.items, 300);
        // within 2% of the interaction target (dedup can only lose a little
        // because sampling is without replacement per user)
        let err = (s.interactions as f64 - 3_000.0).abs() / 3_000.0;
        assert!(err < 0.02, "interactions {}", s.interactions);
    }

    #[test]
    fn respects_min_user_interactions() {
        let cfg = small_cfg();
        let mut rng = Rng::seed_from_u64(43);
        let x = generate(&cfg, &mut rng);
        for u in 0..x.num_users() {
            assert!(x.user_degree(u) >= 5, "user {u} has {}", x.user_degree(u));
        }
    }

    #[test]
    fn popularity_is_skewed() {
        let cfg = small_cfg();
        let mut rng = Rng::seed_from_u64(44);
        let x = generate(&cfg, &mut rng);
        let mut pop = x.item_popularity();
        pop.sort_unstable_by(|a, b| b.cmp(a));
        let head: u32 = pop[..30].iter().sum(); // top 10% of items
        let total: u32 = pop.iter().sum();
        assert!(
            head as f64 / total as f64 > 0.25,
            "head share {}",
            head as f64 / total as f64
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg();
        let a = generate(&cfg, &mut Rng::seed_from_u64(7));
        let b = generate(&cfg, &mut Rng::seed_from_u64(7));
        assert_eq!(a.nnz(), b.nnz());
        for u in 0..a.num_users() {
            assert_eq!(a.user_items(u), b.user_items(u));
        }
    }

    #[test]
    fn planted_structure_is_learnable_signal() {
        // Users' interactions should overlap their affinity pool far more
        // than chance: verify mean planted affinity of interacted items
        // exceeds the global mean by a margin.
        let cfg = small_cfg();
        let mut rng = Rng::seed_from_u64(45);
        // regenerate factors the same way generate() does (same rng stream)
        let x = generate(&cfg, &mut Rng::seed_from_u64(45));
        let rank = cfg.planted_rank;
        let mut u = vec![0.0f32; cfg.users * rank];
        let mut v = vec![0.0f32; cfg.items * rank];
        for e in u.iter_mut() {
            *e = rng.normal() as f32;
        }
        for e in v.iter_mut() {
            *e = rng.normal() as f32;
        }
        let aff = |usr: usize, itm: usize| -> f32 {
            (0..rank).map(|r| u[usr * rank + r] * v[itm * rank + r]).sum()
        };
        let mut on = 0.0f64;
        let mut n_on = 0usize;
        for usr in 0..cfg.users {
            for &itm in x.user_items(usr) {
                on += aff(usr, itm as usize) as f64;
                n_on += 1;
            }
        }
        // global mean affinity is ~0 by construction
        assert!(on / n_on as f64 > 0.3, "mean planted affinity {}", on / n_on as f64);
    }
}
