//! Client-process engine: the device side of the TCP transport lane.
//!
//! One process connects to the coordinator, occupies one hosting
//! *slot*, and simulates every fleet client `cid` with
//! `cid % slots == slot`. The engine rebuilds the exact dataset and
//! fleet the coordinator holds — same seed, same [`load_dataset`] call,
//! same split — so the compute plane needs no bulk data transfer: a
//! round travels as the selected ids, the participant list, and the
//! encoded frames, and any process can compute any batch.
//!
//! ## Two decode planes
//!
//! * **Process mirror** — one [`VqClientState`] that decodes every
//!   broadcast frame so the engine can stage the round's
//!   [`RoundTask`]. A mirror that missed rounds (a restarted process)
//!   answers a delta/reuse frame with [`SessionDecode::Stale`] and
//!   requests a [`Msg::Resync`] with `client = `[`MIRROR`] — the
//!   stale-session path driven by a real network event rather than a
//!   test hook.
//! * **Hosted devices** — one [`VqClientState`] per hosted client id,
//!   fed by the per-participant [`Msg::Download`] frames. Each decode
//!   is bit-verified against the mirror's broadcast decode before the
//!   [`Msg::DownloadAck`] goes back, so a divergent decoder can never
//!   silently contribute.
//!
//! ## Determinism
//!
//! Batch outcomes come from [`run_batch_framed`] — the same function
//! the in-process executor runs — and gradients travel *encoded* (the
//! `up_frame` bytes), so quantization stays part of the training
//! dynamics on both lanes. With the `parallel` feature the engine
//! computes its assigned batches on scoped worker threads; outcomes
//! are pure per batch and [`Msg::BatchDone`] is sent in assignment
//! order, so thread count never reaches the wire.
//!
//! ## Fault injection
//!
//! [`FaultPlan`] drives the dropout e2e tests: `exit_after_round`
//! drops the socket after a round completes (a crash the coordinator
//! detects at the next round's start), `stall_in_round` goes silent at
//! the `Assign` phase until the coordinator's round deadline cuts the
//! connection (mid-round dropout with partial aggregation).

use std::collections::BTreeMap;
use std::io::Read;
use std::net::TcpStream;
#[cfg(feature = "parallel")]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(feature = "parallel")]
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::client::Fleet;
use crate::config::{RunConfig, SimNetConfig};
use crate::rng::Rng;
use crate::runtime::fleet::{run_batch_framed, BackendFactory, BatchOutcome, RoundTask};
use crate::runtime::{FcfRuntime, SelRow};
use crate::server::load_dataset;
use crate::transport::framing;
use crate::transport::proto::{Msg, MIRROR, NO_GENERATION, PROTO_VERSION};
use crate::wire::frame;
use crate::wire::{make_codec_with, PayloadCodec, SessionDecode, SparsePolicy, VqClientState};

/// Failure injection for the dropout/reconnect e2e tests. Default is
/// fault-free.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Drop the connection (no [`Msg::Bye`]) after this round's
    /// [`Msg::RoundEnd`] — a crash between rounds.
    pub exit_after_round: Option<u64>,
    /// Go silent at this round's [`Msg::Assign`] (never send a
    /// [`Msg::BatchDone`]) until the coordinator's deadline cuts the
    /// socket — a mid-round stall.
    pub stall_in_round: Option<u64>,
}

/// What one engine run did, for the `client` bin's summary line and
/// the e2e assertions.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineReport {
    /// Slot this process occupied.
    pub slot: u32,
    /// Total process slots in the session.
    pub slots: u32,
    /// Rounds this process saw through [`Msg::RoundEnd`].
    pub rounds: u64,
    /// Batches computed and reported.
    pub batches: u64,
    /// Hosted-client downloads acknowledged.
    pub downloads: u64,
    /// Mirror resyncs requested (stale process mirror at round start).
    pub mirror_resyncs: u64,
    /// Hosted-device resyncs requested (stale per-client cache).
    pub hosted_resyncs: u64,
    /// The run ended through a [`FaultPlan`] exit, not a clean
    /// [`Msg::Shutdown`]/[`Msg::Bye`].
    pub crashed: bool,
}

/// Dial `addr`, retrying until `timeout` elapses — the coordinator may
/// still be binding when a client process launches.
pub fn connect_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("connecting to {addr}: {e} (gave up after {timeout:?})");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// The device side of the transport lane: dataset + fleet rebuilt from
/// config, a compute runtime, and the mirror/hosted decode state.
pub struct ClientEngine {
    cfg: RunConfig,
    fleet: Fleet,
    m: usize,
    k: usize,
    rt: FcfRuntime,
    #[cfg(feature = "parallel")]
    workers: Vec<FcfRuntime>,
    codec: Box<dyn PayloadCodec>,
    sparse: SparsePolicy,
    simnet: SimNetConfig,
    threads: usize,
    mirror: VqClientState,
    hosted: BTreeMap<u64, VqClientState>,
    sel_pos: Vec<i32>,
}

impl ClientEngine {
    /// Rebuild the dataset, split, and fleet exactly as the
    /// coordinator's trainer does (same seed, same calls, same RNG
    /// stream), and stand up a compute runtime.
    pub fn new(cfg: &RunConfig) -> Result<ClientEngine> {
        cfg.validate()?;
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let data = load_dataset(cfg, &mut rng)?;
        let split = data.split(cfg.dataset.train_frac, &mut rng);
        let m = split.train.num_items();
        let fleet = Fleet::from_split(&split);
        let rt = BackendFactory::from_config(cfg)
            .build_runtime()
            .context("building the client compute runtime")?;
        Ok(ClientEngine {
            cfg: cfg.clone(),
            fleet,
            m,
            k: cfg.model.k,
            rt,
            #[cfg(feature = "parallel")]
            workers: Vec::new(),
            codec: make_codec_with(cfg.codec.precision, cfg.codec.entropy),
            sparse: SparsePolicy {
                top_k: cfg.codec.sparse_topk,
                threshold: cfg.codec.sparse_threshold as f32,
                auto_topk: cfg.codec.sparse_topk_auto,
            },
            simnet: cfg.simnet.clone(),
            threads: cfg.runtime.threads.max(1),
            mirror: VqClientState::new(),
            hosted: BTreeMap::new(),
            sel_pos: vec![-1; m],
        })
    }

    /// Run the session protocol on `stream` until the coordinator's
    /// [`Msg::Shutdown`] (or a [`FaultPlan`] exit).
    pub fn run(&mut self, mut stream: TcpStream, fault: FaultPlan) -> Result<EngineReport> {
        let _ = stream.set_nodelay(true);
        send(
            &mut stream,
            &Msg::Hello {
                proto: PROTO_VERSION,
                fingerprint: self.cfg.determinism_fingerprint(),
            },
        )?;
        let (slot, slots) = match recv_required(&mut stream)? {
            Msg::HelloAck { slot, slots } => (slot, slots),
            Msg::HelloReject { reason } => bail!("coordinator refused the session: {reason}"),
            other => bail!("expected HelloAck, got {}", other.name()),
        };
        let mut report = EngineReport {
            slot,
            slots,
            ..EngineReport::default()
        };
        // The round staged by the last RoundBegin, owned here so every
        // later phase of the same iteration reuses one decoded task.
        let mut round: Option<(u64, RoundTask)> = None;
        loop {
            let msg = match recv(&mut stream)? {
                Some(m) => m,
                None => bail!("coordinator closed the connection mid-session"),
            };
            match msg {
                Msg::RoundBegin {
                    iter,
                    evaluate,
                    selected,
                    participants,
                    frame,
                    q_full,
                } => {
                    let task = self.stage_round(
                        &mut stream,
                        iter,
                        evaluate,
                        &selected,
                        &participants,
                        &frame,
                        q_full,
                        &mut report,
                    )?;
                    round = Some((iter, task));
                    send(&mut stream, &Msg::MirrorSync { iter })?;
                }
                Msg::Download {
                    iter,
                    client,
                    frame,
                } => {
                    let (cur, task) = round.as_ref().context("Download outside a round")?;
                    ensure!(
                        *cur == iter,
                        "Download for iteration {iter} during round {cur}"
                    );
                    self.handle_download(&mut stream, iter, client, &frame, &task.q_sel, &mut report)?;
                }
                Msg::Assign { iter, batches } => {
                    let (cur, task) = round.as_ref().context("Assign outside a round")?;
                    ensure!(
                        *cur == iter,
                        "Assign for iteration {iter} during round {cur}"
                    );
                    if fault.stall_in_round == Some(iter) {
                        stall_until_closed(&mut stream);
                        report.crashed = true;
                        return Ok(report);
                    }
                    let outs = self.compute(task, &batches)?;
                    for (index, out, up_frame) in outs {
                        let (sum, count) = out.metrics.parts();
                        send(
                            &mut stream,
                            &Msg::BatchDone {
                                iter,
                                index,
                                up_frame,
                                p: out.p,
                                metric_count: count as u64,
                                metric_bits: [
                                    sum.precision.to_bits(),
                                    sum.recall.to_bits(),
                                    sum.f1.to_bits(),
                                    sum.map.to_bits(),
                                ],
                                phase_ns: [
                                    out.phase_ns[0] as u64,
                                    out.phase_ns[1] as u64,
                                    out.phase_ns[2] as u64,
                                    out.phase_ns[3] as u64,
                                ],
                            },
                        )?;
                        report.batches += 1;
                    }
                }
                Msg::RoundEnd { iter } => {
                    round = None;
                    report.rounds += 1;
                    if fault.exit_after_round == Some(iter) {
                        // Simulated crash: drop the socket with no Bye;
                        // the coordinator notices at the next round.
                        report.crashed = true;
                        return Ok(report);
                    }
                }
                Msg::Shutdown => {
                    send(&mut stream, &Msg::Bye { slot })?;
                    return Ok(report);
                }
                other => bail!("unexpected {} message from the coordinator", other.name()),
            }
        }
    }

    /// Decode the broadcast through the process mirror (requesting a
    /// mirror resync if it is stale) and stage the round's compute
    /// task, bit-identical to the trainer's own staging.
    #[allow(clippy::too_many_arguments)]
    fn stage_round(
        &mut self,
        stream: &mut TcpStream,
        iter: u64,
        evaluate: bool,
        selected: &[u32],
        participants: &[u64],
        frame_bytes: &[u8],
        q_full: Vec<f32>,
        report: &mut EngineReport,
    ) -> Result<RoundTask> {
        let hint = frame::total_len_hint(frame_bytes)
            .context("inspecting the broadcast frame header")?;
        ensure!(
            hint == Some(frame_bytes.len()),
            "broadcast frame is {} bytes but its header says {hint:?}",
            frame_bytes.len()
        );
        let dense = if is_session_frame(frame_bytes) {
            match self
                .mirror
                .decode_dense(frame_bytes)
                .context("decoding the broadcast frame against the process mirror")?
            {
                SessionDecode::Data(d) => d,
                SessionDecode::Stale { cached, .. } => {
                    report.mirror_resyncs += 1;
                    send(
                        stream,
                        &Msg::NeedResync {
                            iter,
                            client: MIRROR,
                            cached: cached.map_or(NO_GENERATION, u64::from),
                        },
                    )?;
                    let rf = expect_resync(stream, iter, MIRROR)?;
                    self.mirror
                        .decode_dense(&rf)
                        .context("decoding the mirror resync frame")?
                        .into_data()?
                }
            }
        } else {
            self.codec
                .decode_dense(frame_bytes)
                .context("decoding the stateless broadcast frame")?
        };
        ensure!(
            dense.rows == selected.len() && dense.cols == self.k,
            "broadcast decoded to {}x{}, expected {}x{}",
            dense.rows,
            dense.cols,
            selected.len(),
            self.k
        );
        ensure!(
            q_full.is_empty() || q_full.len() == self.m * self.k,
            "eval snapshot has {} values, expected {}x{}",
            q_full.len(),
            self.m,
            self.k
        );
        for p in self.sel_pos.iter_mut() {
            *p = -1;
        }
        for (pos, &item) in selected.iter().enumerate() {
            ensure!(
                (item as usize) < self.m,
                "selected item {item} out of range (M = {})",
                self.m
            );
            self.sel_pos[item as usize] = pos as i32;
        }
        let rows: Vec<SelRow> = participants
            .iter()
            .map(|&cid| {
                ensure!(
                    (cid as usize) < self.fleet.len(),
                    "participant {cid} out of range (fleet has {} clients)",
                    self.fleet.len()
                );
                Ok(self.fleet.client(cid as usize).selected_row(&self.sel_pos))
            })
            .collect::<Result<_>>()?;
        Ok(RoundTask {
            q_sel: dense.data,
            k: self.k,
            m: self.m,
            q_full,
            evaluate,
            rows,
            client_ids: participants.iter().map(|&c| c as usize).collect(),
            batch: self.rt.b,
            precision: self.codec.precision(),
            entropy: self.codec.entropy(),
            sparse: self.sparse,
            simnet: self.simnet.clone(),
            fleet: self.fleet.view(),
            // the TCP lane rejects upload-delta runs at startup; hosted
            // clients always attribute uploads at batch level
            collect_up_frames: false,
        })
    }

    /// Decode one hosted client's download (requesting a per-device
    /// resync if its cache is stale), bit-verify it against the
    /// broadcast decode, and acknowledge.
    fn handle_download(
        &mut self,
        stream: &mut TcpStream,
        iter: u64,
        client: u64,
        frame_bytes: &[u8],
        q_sel: &[f32],
        report: &mut EngineReport,
    ) -> Result<()> {
        let data = if is_session_frame(frame_bytes) {
            let state = self.hosted.entry(client).or_default();
            match state
                .decode_dense(frame_bytes)
                .with_context(|| format!("decoding client {client}'s download"))?
            {
                SessionDecode::Data(d) => d.data,
                SessionDecode::Stale { cached, .. } => {
                    report.hosted_resyncs += 1;
                    send(
                        stream,
                        &Msg::NeedResync {
                            iter,
                            client,
                            cached: cached.map_or(NO_GENERATION, u64::from),
                        },
                    )?;
                    let rf = expect_resync(stream, iter, client)?;
                    state
                        .decode_dense(&rf)
                        .with_context(|| format!("decoding client {client}'s resync frame"))?
                        .into_data()?
                        .data
                }
            }
        } else {
            self.codec
                .decode_dense(frame_bytes)
                .with_context(|| format!("decoding client {client}'s download"))?
                .data
        };
        ensure!(
            data.len() == q_sel.len()
                && data.iter().zip(q_sel).all(|(a, b)| a.to_bits() == b.to_bits()),
            "client {client}'s download decoded differently from the broadcast"
        );
        send(stream, &Msg::DownloadAck { iter, client })?;
        report.downloads += 1;
        Ok(())
    }

    /// Compute the assigned batches, in assignment order.
    fn compute(
        &mut self,
        task: &RoundTask,
        batches: &[u64],
    ) -> Result<Vec<(u64, BatchOutcome, Vec<u8>)>> {
        #[cfg(feature = "parallel")]
        if self.threads > 1 && batches.len() > 1 {
            return self.compute_parallel(task, batches);
        }
        let mut out = Vec::with_capacity(batches.len());
        for &bi in batches {
            let (o, f) = run_batch_framed(&mut self.rt, self.codec.as_ref(), task, bi as usize)
                .with_context(|| format!("computing batch {bi}"))?;
            out.push((bi, o, f));
        }
        Ok(out)
    }

    /// Scoped-thread batch compute: workers claim indices from a shared
    /// counter, results land in per-index slots, and the caller emits
    /// them in assignment order — outcomes are pure per batch, so the
    /// thread count never reaches the wire.
    #[cfg(feature = "parallel")]
    fn compute_parallel(
        &mut self,
        task: &RoundTask,
        batches: &[u64],
    ) -> Result<Vec<(u64, BatchOutcome, Vec<u8>)>> {
        let n = self.threads.min(batches.len());
        while self.workers.len() < n - 1 {
            self.workers
                .push(BackendFactory::from_config(&self.cfg).build_runtime()?);
        }
        let next = AtomicUsize::new(0);
        type BatchSlot = Mutex<Option<Result<(BatchOutcome, Vec<u8>)>>>;
        let slots: Vec<BatchSlot> = batches.iter().map(|_| Mutex::new(None)).collect();
        let run = |rt: &mut FcfRuntime| {
            // Codecs are stateless; each worker builds its own.
            let codec = make_codec_with(task.precision, task.entropy);
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(&bi) = batches.get(i) else { break };
                let r = run_batch_framed(rt, codec.as_ref(), task, bi as usize);
                *slots[i].lock().expect("batch slot lock") = Some(r);
            }
        };
        std::thread::scope(|s| {
            for rt in self.workers.iter_mut().take(n - 1) {
                s.spawn(|| run(rt));
            }
            run(&mut self.rt);
        });
        let mut out = Vec::with_capacity(batches.len());
        for (i, slot) in slots.into_iter().enumerate() {
            let r = slot
                .into_inner()
                .expect("batch slot lock")
                .unwrap_or_else(|| Err(anyhow::anyhow!("batch {} was never computed", batches[i])));
            let (o, f) = r.with_context(|| format!("computing batch {}", batches[i]))?;
            out.push((batches[i], o, f));
        }
        Ok(out)
    }
}

/// Both frame layouts carry the format version at byte 4; session
/// frames decode through per-client state, v1 frames through the
/// stateless codec.
fn is_session_frame(frame_bytes: &[u8]) -> bool {
    frame_bytes.len() > 4 && frame_bytes[4] == frame::SESSION_VERSION
}

fn send(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    let (ty, payload) = msg.encode();
    framing::write_msg(stream, ty, &payload).with_context(|| format!("sending {}", msg.name()))
}

fn recv(stream: &mut TcpStream) -> Result<Option<Msg>> {
    match framing::read_msg(stream)? {
        None => Ok(None),
        Some((ty, payload)) => Ok(Some(Msg::decode(ty, &payload)?)),
    }
}

fn recv_required(stream: &mut TcpStream) -> Result<Msg> {
    recv(stream)?.context("coordinator closed the connection")
}

/// Block until the resync frame for `client` arrives (the coordinator
/// sends nothing else to this slot between a NeedResync and its
/// Resync).
fn expect_resync(stream: &mut TcpStream, iter: u64, client: u64) -> Result<Vec<u8>> {
    match recv_required(stream)? {
        Msg::Resync {
            iter: ri,
            client: rc,
            frame,
        } => {
            ensure!(
                ri == iter && rc == client,
                "resync addressed to client {rc} (iteration {ri}), expected client {client} \
                 (iteration {iter})"
            );
            Ok(frame)
        }
        other => bail!("expected a Resync frame, got {}", other.name()),
    }
}

/// The mid-round stall fault: consume and discard until the
/// coordinator's deadline cuts the socket.
fn stall_until_closed(stream: &mut TcpStream) {
    let mut sink = [0u8; 1024];
    loop {
        match stream.read(&mut sink) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
    }
}
