//! The coordinator side of the TCP lane.
//!
//! [`TcpLane`] implements [`RoundLane`] over real sockets: it accepts
//! `client` processes into hosting slots, drives the phase-ordered
//! round protocol from `transport::proto`, paces downloads through the
//! [`DownloadScheduler`], detects mid-round dropout (EOF or round
//! deadline), and serves reconnect-triggered session resyncs. All
//! training bookkeeping stays in the trainer; this type only reports
//! what moved as an [`ExchangeOutcome`].
//!
//! ## Threads
//!
//! One accept thread (new connections → events) and one reader thread
//! per live connection (decoded messages → events) feed a single mpsc
//! channel; the lane's own methods run single-threaded on the trainer's
//! thread and do all the writes. Reader threads are tagged with the
//! slot's connection *epoch*: after a dropout + rejoin, events from the
//! replaced connection's reader carry a stale epoch and are ignored —
//! a slow zombie can never corrupt the successor's round.
//!
//! ## Determinism
//!
//! Everything order-sensitive is keyed, never arrival-ordered: download
//! records sit in participant order and are compacted at phase end,
//! batch outcomes land in an index-addressed table and merge through
//! the same fold as the in-process lane ([`merge_partial`]). Arrival
//! order, pacing sleeps and deadlines therefore shift *when* things
//! happen, never what the round computes — the `transport-e2e` CI job
//! holds a fault-free run to byte-identical dumps/digests/journals
//! against the in-process lane.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write as _;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::TransportConfig;
use crate::metrics::{MetricAccumulator, MetricSet};
use crate::runtime::fleet::{decode_upload, BatchOutcome};
use crate::runtime::FcfRuntime;
use crate::server::journal::check_fingerprint;
use crate::simnet::TrafficLedger;
use crate::transport::framing::{read_msg, write_msg, MSG_HEADER_LEN};
use crate::transport::lane::{
    merge_partial, plan_downloads, verified_resync_frame, DownloadRecord, ExchangeOutcome,
    ExchangeRequest, RoundLane, TransportStats,
};
use crate::transport::proto::{Msg, MIRROR, NO_GENERATION, PROTO_VERSION};
use crate::transport::sched::DownloadScheduler;
use crate::wire::PayloadCodec;

/// How long a handshaking connection may dawdle over its `Hello`.
const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Round-start settling window in `wait_rejoin` mode: long enough for a
/// loopback peer's end-of-round disconnect to surface as an EOF event,
/// short enough to be invisible next to a round's compute.
const DROPOUT_GRACE: Duration = Duration::from_millis(50);

/// One hosting slot's connection state.
struct Slot {
    /// Write half (a `try_clone` of the reader thread's stream).
    writer: Option<TcpStream>,
    /// Connection epoch; bumped on every (re)admission so events from a
    /// replaced connection's reader are recognizably stale.
    epoch: u64,
    /// Has any process ever held this slot? (First joins are not
    /// rejoins and must not invalidate anything.)
    ever_joined: bool,
    /// A process rejoined this slot and its hosted clients' cached
    /// download state is gone; consumed at the next round start.
    needs_invalidate: bool,
}

impl Slot {
    fn alive(&self) -> bool {
        self.writer.is_some()
    }
}

/// An event from the accept thread or a reader thread.
enum Event {
    /// A fresh TCP connection awaiting its `Hello`.
    Conn(TcpStream),
    /// A message (or EOF/error as `None`) from slot `slot`'s reader at
    /// connection epoch `epoch`; `wire_bytes` is the framed size.
    From {
        slot: usize,
        epoch: u64,
        msg: Option<Msg>,
        wire_bytes: u64,
    },
}

/// The TCP round lane: coordinator side.
pub struct TcpLane {
    addr: SocketAddr,
    slots: Vec<Slot>,
    events: mpsc::Receiver<Event>,
    tx: mpsc::Sender<Event>,
    fingerprint: String,
    cfg: TransportConfig,
    sched: DownloadScheduler,
    stats: TransportStats,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    readers: Vec<JoinHandle<()>>,
    finished: bool,
}

impl TcpLane {
    /// Bind the listener and start accepting client processes into
    /// `cfg.clients` hosting slots. `fingerprint` is the run's
    /// `determinism_fingerprint()`; processes presenting a different
    /// one are rejected at handshake.
    pub fn bind(cfg: &TransportConfig, fingerprint: String) -> Result<TcpLane> {
        ensure!(cfg.clients >= 1, "transport.clients must be >= 1");
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding transport listener on {}", cfg.listen))?;
        let addr = listener.local_addr()?;
        let (tx, events) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let tx = tx.clone();
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("transport-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        if let Ok(stream) = conn {
                            if tx.send(Event::Conn(stream)).is_err() {
                                break;
                            }
                        }
                    }
                })?
        };
        Ok(TcpLane {
            addr,
            slots: (0..cfg.clients)
                .map(|_| Slot {
                    writer: None,
                    epoch: 0,
                    ever_joined: false,
                    needs_invalidate: false,
                })
                .collect(),
            events,
            tx,
            fingerprint,
            cfg: cfg.clone(),
            sched: DownloadScheduler::new(cfg.bandwidth_cap_bps),
            stats: TransportStats::default(),
            stop,
            accept: Some(accept),
            readers: Vec::new(),
            finished: false,
        })
    }

    /// The address the listener actually bound (port 0 resolved).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until every hosting slot has a live client process, or
    /// `timeout` elapses (error). Run this before training so round 1
    /// starts with a full fleet.
    pub fn wait_for_fleet(&mut self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        while self.slots.iter().any(|s| !s.alive()) {
            match self.recv_until(Some(deadline)) {
                Some(ev) => self.handle_idle_event(ev),
                None => bail!(
                    "only {}/{} client processes connected within {timeout:?}",
                    self.slots.iter().filter(|s| s.alive()).count(),
                    self.slots.len()
                ),
            }
        }
        Ok(())
    }

    /// Live slots right now (for operator output).
    pub fn live_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.alive()).count()
    }

    fn recv_until(&self, deadline: Option<Instant>) -> Option<Event> {
        match deadline {
            None => self.events.recv().ok(),
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    return None;
                }
                self.events.recv_timeout(d - now).ok()
            }
        }
    }

    /// Handle an event while no round phase is in flight: admit
    /// connections, retire dead slots, ignore stale messages.
    fn handle_idle_event(&mut self, ev: Event) {
        match ev {
            Event::Conn(stream) => {
                if let Err(e) = self.admit(stream) {
                    eprintln!("transport: rejected connection: {e:#}");
                }
            }
            Event::From {
                slot,
                epoch,
                msg,
                wire_bytes,
            } => {
                self.stats.msgs_recv += u64::from(msg.is_some());
                self.stats.bytes_recv += wire_bytes;
                if self.slots[slot].epoch == epoch && msg.is_none() {
                    self.kill_slot(slot);
                }
            }
        }
    }

    /// Handshake a fresh connection into a vacant slot.
    fn admit(&mut self, mut stream: TcpStream) -> Result<()> {
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(HELLO_TIMEOUT))?;
        let (ty, payload) = read_msg(&mut stream)?.context("peer closed before Hello")?;
        let hello = Msg::decode(ty, &payload)?;
        let Msg::Hello { proto, fingerprint } = hello else {
            bail!("expected Hello, got {}", hello.name());
        };
        let reject = if proto != PROTO_VERSION {
            Some(format!(
                "protocol version mismatch (coordinator {PROTO_VERSION}, client {proto})"
            ))
        } else if let Err(e) = check_fingerprint(&self.fingerprint, &fingerprint) {
            Some(format!("config fingerprint mismatch: {e}"))
        } else {
            None
        };
        let slot = self.slots.iter().position(|s| !s.alive());
        let reject = reject.or_else(|| {
            slot.is_none()
                .then(|| format!("session is full ({} slots)", self.slots.len()))
        });
        if let Some(reason) = reject {
            let (ty, payload) = Msg::HelloReject {
                reason: reason.clone(),
            }
            .encode();
            let _ = write_msg(&mut stream, ty, &payload);
            let _ = stream.shutdown(Shutdown::Both);
            bail!("{reason}");
        }
        let slot = slot.unwrap();
        stream.set_read_timeout(None)?;
        let (ty, payload) = Msg::HelloAck {
            slot: slot as u32,
            slots: self.slots.len() as u32,
        }
        .encode();
        write_msg(&mut stream, ty, &payload)?;
        self.stats.msgs_sent += 1;
        self.stats.bytes_sent += (MSG_HEADER_LEN + payload.len() + 4) as u64;

        let s = &mut self.slots[slot];
        s.epoch += 1;
        if s.ever_joined {
            s.needs_invalidate = true;
            self.stats.rejoins += 1;
        }
        s.ever_joined = true;
        s.writer = Some(stream.try_clone()?);

        let epoch = self.slots[slot].epoch;
        let tx = self.tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("transport-read-{slot}"))
            .spawn(move || reader_loop(stream, slot, epoch, tx))?;
        self.readers.push(handle);
        Ok(())
    }

    /// Tear a slot's socket down so its reader thread unblocks (no
    /// dropout accounting — used for orderly shutdown too).
    fn close_slot(&mut self, slot: usize) {
        if let Some(w) = self.slots[slot].writer.take() {
            let _ = w.shutdown(Shutdown::Both);
        }
    }

    /// Mark a slot's connection dead mid-session: a dropout.
    fn kill_slot(&mut self, slot: usize) {
        if self.slots[slot].alive() {
            self.stats.dropouts += 1;
        }
        self.close_slot(slot);
    }

    /// Send one message to a slot; a write failure is a dropout.
    fn send(&mut self, slot: usize, msg: &Msg) {
        let (ty, payload) = msg.encode();
        let ok = match self.slots[slot].writer.as_mut() {
            Some(w) => write_msg(w, ty, &payload).is_ok(),
            None => return,
        };
        if ok {
            self.stats.msgs_sent += 1;
            self.stats.bytes_sent += (MSG_HEADER_LEN + payload.len() + 4) as u64;
        } else {
            self.kill_slot(slot);
        }
    }

    /// Build (or reuse) this round's verified resync frame.
    fn resync_frame(
        req: &ExchangeRequest<'_>,
        cache: &mut Option<Vec<u8>>,
    ) -> Result<Vec<u8>> {
        if let Some(f) = cache {
            return Ok(f.clone());
        }
        let (sess, enc) = req
            .session
            .ok_or_else(|| anyhow!("client requested a resync but no session is active"))?;
        let f = verified_resync_frame(sess, req.q_sel, enc.generation)?;
        *cache = Some(f.clone());
        Ok(f)
    }
}

/// Per-connection reader: decoded messages (or one final `None`) into
/// the event channel, tagged with the connection epoch.
fn reader_loop(mut stream: TcpStream, slot: usize, epoch: u64, tx: mpsc::Sender<Event>) {
    loop {
        let (msg, wire_bytes) = match read_msg(&mut stream) {
            Ok(Some((ty, payload))) => {
                let wire = (MSG_HEADER_LEN + payload.len() + 4) as u64;
                match Msg::decode(ty, &payload) {
                    Ok(m) => (Some(m), wire),
                    Err(_) => (None, wire),
                }
            }
            Ok(None) | Err(_) => (None, 0),
        };
        let last = msg.is_none();
        if tx
            .send(Event::From {
                slot,
                epoch,
                msg,
                wire_bytes,
            })
            .is_err()
            || last
        {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    }
}

impl RoundLane for TcpLane {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn exchange(
        &mut self,
        req: ExchangeRequest<'_>,
        _rt: &mut FcfRuntime,
        codec: &dyn PayloadCodec,
    ) -> Result<ExchangeOutcome> {
        let start = Instant::now();
        let n_slots = self.slots.len();
        let m_s = req.selected.len();
        let k = req.task.k;
        let b = req.task.batch;
        let evaluate = req.task.evaluate;
        let deadline = (self.cfg.round_deadline_ms > 0)
            .then(|| start + Duration::from_millis(self.cfg.round_deadline_ms));
        let mut resync_cache: Option<Vec<u8>> = None;

        // ---- round start: drain pending events, optionally wait out
        // vacant slots (deterministic reconnect), consume rejoins ----
        while let Ok(ev) = self.events.try_recv() {
            self.handle_idle_event(ev);
        }
        if self.cfg.wait_rejoin {
            // A peer that died at the previous round's edge races this
            // round's start: its reader thread may not have posted the
            // EOF yet, and a dropout we fail to observe here would make
            // the round run partial instead of waiting for the rejoin.
            // Deterministic-reconnect mode buys reliable detection with
            // a short, timing-only grace window (quarantined to the
            // trace `"t"` field like all transport timing).
            let grace = Instant::now() + DROPOUT_GRACE;
            while let Some(ev) = self.recv_until(Some(grace)) {
                self.handle_idle_event(ev);
            }
        }
        if self.cfg.wait_rejoin && self.slots.iter().any(|s| !s.alive()) {
            let until = Instant::now() + Duration::from_millis(self.cfg.rejoin_wait_ms);
            while self.slots.iter().any(|s| !s.alive()) {
                match self.recv_until(Some(until)) {
                    Some(ev) => self.handle_idle_event(ev),
                    None => break,
                }
            }
        }
        let mut fresh = BTreeSet::new();
        for slot in 0..n_slots {
            if self.slots[slot].alive() && self.slots[slot].needs_invalidate {
                self.slots[slot].needs_invalidate = false;
                fresh.extend((0..req.fleet.len()).filter(|cid| cid % n_slots == slot));
            }
        }
        let invalidated: Vec<usize> = fresh.iter().copied().collect();
        for &cid in &fresh {
            // a rejoined process's hosted devices start with a free link
            self.sched.forget(cid as u64);
        }

        // ---- participants with a live hosting slot actually run ----
        let active: Vec<usize> = req
            .participants
            .iter()
            .copied()
            .filter(|cid| self.slots[cid % n_slots].alive())
            .collect();
        let mut dropped: BTreeSet<usize> = req
            .participants
            .iter()
            .copied()
            .filter(|cid| !self.slots[cid % n_slots].alive())
            .collect();
        let n_batches = if active.is_empty() {
            0
        } else {
            active.len().div_ceil(b)
        };

        // ---- phase 1: RoundBegin to every live slot ----
        let begin = Msg::RoundBegin {
            iter: req.iter,
            evaluate,
            selected: req.selected.to_vec(),
            participants: active.iter().map(|&c| c as u64).collect(),
            frame: req.frame.to_vec(),
            q_full: if evaluate {
                req.task.q_full.clone()
            } else {
                Vec::new()
            },
        };
        for slot in 0..n_slots {
            if self.slots[slot].alive() {
                self.send(slot, &begin);
            }
        }

        // ---- phase 2: mirror sync (serves the network-driven
        // SessionDecode::Stale path for rejoined processes) ----
        let mut pending: BTreeSet<usize> =
            (0..n_slots).filter(|&s| self.slots[s].alive()).collect();
        while !pending.is_empty() {
            let Some(ev) = self.recv_until(deadline) else {
                self.stats.deadline_expiries += 1;
                for slot in std::mem::take(&mut pending) {
                    self.kill_slot(slot);
                }
                break;
            };
            match ev {
                Event::Conn(stream) => {
                    // joins mid-round; participates from the next round
                    if let Err(e) = self.admit(stream) {
                        eprintln!("transport: rejected connection: {e:#}");
                    }
                }
                Event::From {
                    slot,
                    epoch,
                    msg,
                    wire_bytes,
                } => {
                    self.stats.bytes_recv += wire_bytes;
                    if self.slots[slot].epoch != epoch {
                        continue;
                    }
                    let Some(msg) = msg else {
                        self.kill_slot(slot);
                        pending.remove(&slot);
                        continue;
                    };
                    self.stats.msgs_recv += 1;
                    match msg {
                        Msg::MirrorSync { iter } if iter == req.iter => {
                            pending.remove(&slot);
                        }
                        Msg::NeedResync {
                            iter,
                            client: MIRROR,
                            ..
                        } if iter == req.iter => {
                            self.stats.need_resync_reqs += 1;
                            let frame = Self::resync_frame(&req, &mut resync_cache)?;
                            self.stats.resyncs_served += 1;
                            self.send(
                                slot,
                                &Msg::Resync {
                                    iter: req.iter,
                                    client: MIRROR,
                                    frame,
                                },
                            );
                            // mirror resyncs keep the process decoder
                            // current; they are not a device download and
                            // are not ledger-recorded (the in-process
                            // mirror costs nothing either)
                        }
                        other => eprintln!(
                            "transport: slot {slot} sent {} during mirror sync",
                            other.name()
                        ),
                    }
                }
            }
        }

        // ---- phase 3: paced downloads, recorded on ack ----
        let (template, planned_resync) = plan_downloads(&req, &active, |cid| {
            if fresh.contains(&cid) {
                None
            } else {
                req.fleet.download_gen(cid)
            }
        })?;
        if resync_cache.is_none() {
            resync_cache = planned_resync;
        }
        let pos_of: BTreeMap<usize, usize> =
            active.iter().enumerate().map(|(i, &c)| (c, i)).collect();
        let mut acked = vec![false; active.len()];
        let mut extras: Vec<(usize, DownloadRecord)> = Vec::new();
        let mut await_ack: BTreeSet<usize> = BTreeSet::new();
        for (i, rec) in template.iter().enumerate() {
            let cid = rec.client;
            let slot = cid % n_slots;
            if !self.slots[slot].alive() {
                continue;
            }
            let now_ns = start.elapsed().as_nanos() as u64;
            let wait = self.sched.schedule(cid as u64, rec.bytes, now_ns);
            if wait > 0 {
                self.stats.paced_wait_ns += wait;
                std::thread::sleep(Duration::from_nanos(wait));
            }
            let frame = if rec.resync {
                self.stats.resyncs_served += 1;
                Self::resync_frame(&req, &mut resync_cache)?
            } else {
                req.frame.to_vec()
            };
            self.send(
                slot,
                &Msg::Download {
                    iter: req.iter,
                    client: cid as u64,
                    frame,
                },
            );
            if self.slots[slot].alive() {
                await_ack.insert(i);
            }
        }
        while !await_ack.is_empty() {
            let Some(ev) = self.recv_until(deadline) else {
                self.stats.deadline_expiries += 1;
                let stalled: BTreeSet<usize> =
                    await_ack.iter().map(|&i| active[i] % n_slots).collect();
                for slot in stalled {
                    self.kill_slot(slot);
                }
                await_ack.clear();
                break;
            };
            match ev {
                Event::Conn(stream) => {
                    if let Err(e) = self.admit(stream) {
                        eprintln!("transport: rejected connection: {e:#}");
                    }
                }
                Event::From {
                    slot,
                    epoch,
                    msg,
                    wire_bytes,
                } => {
                    self.stats.bytes_recv += wire_bytes;
                    if self.slots[slot].epoch != epoch {
                        continue;
                    }
                    let Some(msg) = msg else {
                        self.kill_slot(slot);
                        await_ack.retain(|&i| active[i] % n_slots != slot);
                        continue;
                    };
                    self.stats.msgs_recv += 1;
                    match msg {
                        Msg::DownloadAck { iter, client } if iter == req.iter => {
                            if let Some(&i) = pos_of.get(&(client as usize)) {
                                acked[i] = true;
                                await_ack.remove(&i);
                            }
                        }
                        Msg::NeedResync {
                            iter,
                            client,
                            cached,
                        } if iter == req.iter && client != MIRROR => {
                            // safety net: the device cache disagreed with
                            // the coordinator's generation table
                            self.stats.need_resync_reqs += 1;
                            let frame = Self::resync_frame(&req, &mut resync_cache)?;
                            self.stats.resyncs_served += 1;
                            if let Some(&i) = pos_of.get(&(client as usize)) {
                                extras.push((
                                    i,
                                    DownloadRecord {
                                        client: client as usize,
                                        bytes: frame.len() as u64,
                                        resync: true,
                                        cached: (cached != NO_GENERATION)
                                            .then_some(cached as u32),
                                    },
                                ));
                            }
                            self.send(
                                slot,
                                &Msg::Resync {
                                    iter: req.iter,
                                    client,
                                    frame,
                                },
                            );
                        }
                        other => eprintln!(
                            "transport: slot {slot} sent {} during downloads",
                            other.name()
                        ),
                    }
                }
            }
        }

        // ---- phase 4: assign batches round-robin over live slots ----
        let live: Vec<usize> = (0..n_slots).filter(|&s| self.slots[s].alive()).collect();
        let mut owner: Vec<Option<usize>> = vec![None; n_batches];
        if !live.is_empty() {
            let mut per_slot: BTreeMap<usize, Vec<u64>> =
                live.iter().map(|&s| (s, Vec::new())).collect();
            for i in 0..n_batches {
                let slot = live[i % live.len()];
                owner[i] = Some(slot);
                per_slot.get_mut(&slot).unwrap().push(i as u64);
            }
            for (&slot, batches) in &per_slot {
                self.send(
                    slot,
                    &Msg::Assign {
                        iter: req.iter,
                        batches: batches.clone(),
                    },
                );
            }
        }

        // ---- phase 5: collect batch outcomes (partial on deadline) ----
        let mut outcomes: Vec<Option<BatchOutcome>> = (0..n_batches).map(|_| None).collect();
        let mut missing: BTreeSet<usize> = (0..n_batches)
            .filter(|&i| owner[i].is_some_and(|s| self.slots[s].alive()))
            .collect();
        while !missing.is_empty() {
            let Some(ev) = self.recv_until(deadline) else {
                self.stats.deadline_expiries += 1;
                let stalled: BTreeSet<usize> =
                    missing.iter().filter_map(|&i| owner[i]).collect();
                for slot in stalled {
                    self.kill_slot(slot);
                }
                missing.clear();
                break;
            };
            match ev {
                Event::Conn(stream) => {
                    if let Err(e) = self.admit(stream) {
                        eprintln!("transport: rejected connection: {e:#}");
                    }
                }
                Event::From {
                    slot,
                    epoch,
                    msg,
                    wire_bytes,
                } => {
                    self.stats.bytes_recv += wire_bytes;
                    if self.slots[slot].epoch != epoch {
                        continue;
                    }
                    let Some(msg) = msg else {
                        self.kill_slot(slot);
                        missing.retain(|&i| owner[i] != Some(slot));
                        continue;
                    };
                    self.stats.msgs_recv += 1;
                    match msg {
                        Msg::BatchDone {
                            iter,
                            index,
                            up_frame,
                            p,
                            metric_count,
                            metric_bits,
                            phase_ns,
                        } if iter == req.iter && (index as usize) < n_batches => {
                            let index = index as usize;
                            let grad = decode_upload(codec, &up_frame, m_s, k)?;
                            let lo = index * b;
                            let hi = (lo + b).min(active.len());
                            let mut ledger = TrafficLedger::new();
                            for _ in lo..hi {
                                ledger.record_up(&req.task.simnet, up_frame.len() as u64);
                            }
                            let metrics = MetricAccumulator::from_parts(
                                MetricSet {
                                    precision: f64::from_bits(metric_bits[0]),
                                    recall: f64::from_bits(metric_bits[1]),
                                    f1: f64::from_bits(metric_bits[2]),
                                    map: f64::from_bits(metric_bits[3]),
                                },
                                metric_count as usize,
                            );
                            outcomes[index] = Some(BatchOutcome {
                                grad,
                                p,
                                ledger,
                                metrics,
                                phase_ns: phase_ns.map(u128::from),
                                lane: slot + 1,
                                up_frame: None,
                            });
                            missing.remove(&index);
                        }
                        other => eprintln!(
                            "transport: slot {slot} sent {} during compute",
                            other.name()
                        ),
                    }
                }
            }
        }

        // ---- phase 6: round end + deterministic fold ----
        let end = Msg::RoundEnd { iter: req.iter };
        for slot in 0..n_slots {
            if self.slots[slot].alive() {
                self.send(slot, &end);
            }
        }
        let (agg, batch_dropped) = merge_partial(m_s, k, &active, b, outcomes)?;
        let contributed = active.len() - batch_dropped.len();
        dropped.extend(batch_dropped);

        // compact download records: participant order, acked only, with
        // any safety-net resyncs spliced in after their broadcast slot
        let mut downloads = Vec::with_capacity(active.len());
        for (i, rec) in template.into_iter().enumerate() {
            if acked[i] {
                downloads.push(rec);
            }
            for (_, extra) in extras.iter().filter(|(pos, _)| *pos == i) {
                downloads.push(*extra);
            }
        }

        self.stats.rounds += 1;
        Ok(ExchangeOutcome {
            downloads,
            agg,
            contributed,
            dropped: dropped.into_iter().collect(),
            invalidated,
            transport_ns: start.elapsed().as_nanos() as u64,
        })
    }

    fn finish(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        for slot in 0..self.slots.len() {
            self.send(slot, &Msg::Shutdown);
        }
        // give clients a moment to say goodbye, then tear down
        let grace = Instant::now() + Duration::from_millis(2000);
        while self.slots.iter().any(|s| s.alive()) {
            match self.recv_until(Some(grace)) {
                Some(Event::From {
                    slot,
                    epoch,
                    msg,
                    wire_bytes,
                }) => {
                    self.stats.bytes_recv += wire_bytes;
                    if self.slots[slot].epoch != epoch {
                        continue;
                    }
                    match msg {
                        Some(Msg::Bye { .. }) | None => self.close_slot(slot),
                        Some(_) => {}
                    }
                }
                Some(Event::Conn(stream)) => {
                    let _ = stream.shutdown(Shutdown::Both);
                }
                None => break,
            }
        }
        for slot in 0..self.slots.len() {
            self.close_slot(slot);
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept thread out of its blocking accept()
        if let Ok(mut s) = TcpStream::connect(self.addr) {
            let _ = s.write_all(&[0]);
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        Ok(())
    }

    fn stats(&self) -> Option<TransportStats> {
        Some(self.stats)
    }
}

impl Drop for TcpLane {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}
