//! Real network transport lane: the coordinator/client process pair.
//!
//! Everything below `server` so far ran in one process. This module
//! moves the same versioned, checksummed `wire` frames over TCP as
//! length-prefixed messages between a `coordinator` bin and one or
//! more `client` bins (each hosting many fleet clients), without
//! touching a single training decision:
//!
//! * [`framing`] — the byte layer: `FPTL` magic, type byte, `u32`
//!   length prefix, trailing FNV checksum; torn reads surface as
//!   typed [`framing::FrameError`]s, never as garbage frames.
//! * [`proto`] — the message layer: the phase-ordered round protocol
//!   (`Hello` → `RoundBegin` → downloads → `Assign`/`BatchDone` →
//!   `RoundEnd`) with hand-rolled little-endian encoding.
//! * [`sched`] — the download scheduler: per-client bandwidth caps as
//!   pure logical-nanosecond arithmetic, so pacing shifts *when*
//!   frames leave, never *what* they contain.
//! * [`lane`] — the seam: [`lane::RoundLane`] abstracts one round's
//!   exchange (downloads out, aggregated batches back) and
//!   [`lane::InProcessLane`] keeps the deterministic single-process
//!   reference; the trainer applies the returned records identically
//!   whichever lane produced them.
//! * [`coordinator`] — the server side: [`coordinator::TcpLane`]
//!   accepts client processes into hosting slots, paces downloads,
//!   enforces round deadlines with partial aggregation, detects
//!   mid-round dropouts, and resyncs rejoining processes.
//! * [`client_proc`] — the device side: [`client_proc::ClientEngine`]
//!   rebuilds the dataset from config, mirrors broadcast decodes,
//!   hosts per-client session caches, computes assigned batches with
//!   the same `run_batch_framed` the in-process executor uses, and
//!   injects faults for the dropout e2e tests.
//!
//! ## Determinism contract
//!
//! Under a fault-free schedule a transport run must produce
//! **byte-identical** round dumps, trace digests, and journal records
//! to the in-process lane at any thread count — transport timing is
//! quarantined to `"t":{...}` trace fields, which the digest strips.
//! `ci/transport_e2e.sh` diffs the two lanes end to end.

pub mod client_proc;
pub mod coordinator;
pub mod framing;
pub mod lane;
pub mod proto;
pub mod sched;

pub use client_proc::{connect_with_retry, ClientEngine, EngineReport, FaultPlan};
pub use coordinator::TcpLane;
pub use lane::{InProcessLane, RoundLane, TransportStats};
