//! The round exchange abstraction: one trait, two lanes.
//!
//! A [`RoundLane`] performs the network half of one FL round — deliver
//! the broadcast/resync download to every participant, run the client
//! compute, collect the encoded uploads — and reports *what moved* as
//! plain data ([`ExchangeOutcome`]). The [`Trainer`] keeps every piece
//! of bookkeeping (ledger, session stats, resync trace events,
//! download-generation table, journal fields) on its own side, applied
//! from the outcome records in deterministic participant/batch order.
//! Single-sourcing the bookkeeping is the whole determinism story: two
//! lanes cannot drift in accounting they do not own.
//!
//! * [`InProcessLane`] — the deterministic reference. Downloads are
//!   table-lookups, compute runs on the sharded [`FleetExecutor`].
//!   This is bit-for-bit the pre-transport behavior.
//! * [`TcpLane`](super::coordinator::TcpLane) — the same exchange over
//!   real sockets against `client` processes. Fault-free it must
//!   produce an [`ExchangeOutcome`] that leads to byte-identical round
//!   dumps, trace digests, and journal records (the `transport-e2e` CI
//!   job diffs all three); under faults it reports partial aggregation
//!   honestly via `dropped`/`contributed`.
//!
//! [`Trainer`]: crate::server::Trainer

use anyhow::{ensure, Context, Result};

use crate::client::Fleet;
use crate::runtime::fleet::{
    merge_outcomes, BatchOutcome, BatchStat, FleetExecutor, RoundAggregate, RoundTask,
};
use crate::runtime::FcfRuntime;
use crate::wire::{EncodedDownload, PayloadCodec, VqClientState, VqSession};

/// Everything the trainer hands a lane for one round's exchange.
pub struct ExchangeRequest<'a> {
    /// 1-based FL iteration.
    pub iter: u64,
    /// Participating client ids in round order.
    pub participants: &'a [usize],
    /// Sorted selected item ids (M_s of M) — client processes rebuild
    /// their interaction rows from these.
    pub selected: &'a [u32],
    /// The broadcast download frame bytes (stateless v1 or session v2).
    pub frame: &'a [u8],
    /// `frame.len()`, pre-cast for ledger math.
    pub down_bytes: u64,
    /// Active codebook session + this round's encoded download, when
    /// sessions are on (the lane decides per-participant broadcast vs
    /// resync from `EncodedDownload::in_sync` against the fleet table).
    pub session: Option<(&'a VqSession, &'a EncodedDownload)>,
    /// Decoded broadcast factors (what a synced client decodes) — the
    /// bit-reference every resync frame is verified against.
    pub q_sel: &'a [f32],
    /// The coordinator-side fleet: download-generation table reads.
    pub fleet: &'a Fleet,
    /// The round's compute task (already staged by the trainer).
    pub task: RoundTask,
}

/// One served download, in participant order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DownloadRecord {
    /// Client id served.
    pub client: usize,
    /// Encoded frame bytes that moved to it.
    pub bytes: u64,
    /// Was this a full-codebook resync frame instead of the broadcast?
    pub resync: bool,
    /// The cached generation the decision was made against.
    pub cached: Option<u32>,
}

/// What one round's exchange moved and computed.
pub struct ExchangeOutcome {
    /// Served downloads in participant order. Fault-free this covers
    /// every participant; under faults only the downloads that were
    /// actually delivered (and acknowledged) appear — exact ledger
    /// attribution, nothing phantom.
    pub downloads: Vec<DownloadRecord>,
    /// The round's deterministic aggregate (partial under faults).
    pub agg: RoundAggregate,
    /// Clients whose uploads made it into `agg` — the divisor for mean
    /// aggregation and reward scaling. Equals `participants.len()`
    /// fault-free.
    pub contributed: usize,
    /// Client ids dropped this round (undelivered download, dead
    /// hosting process, or missing batch at the deadline), sorted.
    pub dropped: Vec<usize>,
    /// Hosted client ids whose cached download state was lost to a
    /// process restart — the trainer invalidates their generation-table
    /// entries, which is what turns a reconnect into real resync
    /// frames next round.
    pub invalidated: Vec<usize>,
    /// Wall-clock nanoseconds the exchange spent (timing fact: rides in
    /// `"t":{...}` trace fields only, 0 for the in-process lane).
    pub transport_ns: u64,
}

/// Cumulative transport-side counters (zero for the in-process lane).
/// Wall-clock/network facts for operator output — never journaled,
/// never traced outside `"t":{...}` fields.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Rounds exchanged.
    pub rounds: u64,
    /// Messages sent / received by the coordinator.
    pub msgs_sent: u64,
    /// Messages received by the coordinator.
    pub msgs_recv: u64,
    /// Bytes sent (framed messages, headers included).
    pub bytes_sent: u64,
    /// Bytes received (framed messages, headers included).
    pub bytes_recv: u64,
    /// Resync frames served (per-client downloads + mirror resyncs).
    pub resyncs_served: u64,
    /// `NeedResync` requests received from clients — each one is the
    /// `SessionDecode::Stale` path fired by a real network peer.
    pub need_resync_reqs: u64,
    /// Client processes detected dead (EOF or deadline).
    pub dropouts: u64,
    /// Processes (re)joined after the session started.
    pub rejoins: u64,
    /// Round phases cut short by the deadline.
    pub deadline_expiries: u64,
    /// Nanoseconds spent sleeping for the bandwidth scheduler.
    pub paced_wait_ns: u64,
}

/// The network half of one FL round, behind a trait so the trainer is
/// lane-agnostic. Implementations must construct `downloads` in
/// participant order and `agg` by batch-index-ordered merge — the two
/// invariants that make the outcome independent of delivery/completion
/// order.
pub trait RoundLane {
    /// Lane name for logs and reports.
    fn name(&self) -> &'static str;

    /// Execute one round's exchange.
    fn exchange(
        &mut self,
        req: ExchangeRequest<'_>,
        rt: &mut FcfRuntime,
        codec: &dyn PayloadCodec,
    ) -> Result<ExchangeOutcome>;

    /// Orderly teardown (close sockets, say goodbye). No-op by default.
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }

    /// Transport counters, when the lane has any.
    fn stats(&self) -> Option<TransportStats> {
        None
    }
}

/// Build this round's full-codebook resync frame and verify it decodes
/// — statelessly, as a fresh client would — to bit-identical factors as
/// the broadcast. Shared by both lanes so a resync is *proven*
/// trajectory-neutral no matter which wire it rides.
pub fn verified_resync_frame(sess: &VqSession, q_sel: &[f32], generation: u32) -> Result<Vec<u8>> {
    let rf = sess.resync_frame()?;
    let dec = VqClientState::new()
        .decode_dense(&rf)?
        .into_data()
        .context("resync frame must decode statelessly")?;
    ensure!(
        dec.data.len() == q_sel.len()
            && dec
                .data
                .iter()
                .zip(q_sel)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
        "resync frame decoded differently from the broadcast frame (generation {generation})"
    );
    Ok(rf)
}

/// Fold whatever batches completed into a round aggregate, in
/// batch-index order, and report the clients of missing batches. With
/// full coverage this delegates to [`merge_outcomes`] — the transport
/// lane's fault-free path runs the *same code* as the in-process lane,
/// so bit-identity is shared, not re-implemented. With gaps it performs
/// the identical fold over the present batches only (deadline-based
/// partial aggregation).
pub fn merge_partial(
    m_s: usize,
    k: usize,
    client_ids: &[usize],
    batch: usize,
    outcomes: Vec<Option<BatchOutcome>>,
) -> Result<(RoundAggregate, Vec<usize>)> {
    ensure!(batch > 0, "batch width must be > 0");
    let expected = client_ids.len().div_ceil(batch);
    ensure!(
        outcomes.len() == expected,
        "merge_partial: {} outcome slots for {expected} batches",
        outcomes.len()
    );
    if outcomes.iter().all(|o| o.is_some()) {
        let full: Vec<BatchOutcome> = outcomes.into_iter().map(|o| o.unwrap()).collect();
        return Ok((merge_outcomes(m_s, k, client_ids, batch, &full)?, Vec::new()));
    }
    let mut agg = RoundAggregate {
        grad: vec![0.0f32; m_s * k],
        ..RoundAggregate::default()
    };
    let mut dropped = Vec::new();
    for (i, slot) in outcomes.iter().enumerate() {
        let lo = i * batch;
        let hi = (lo + batch).min(client_ids.len());
        let Some(o) = slot else {
            dropped.extend_from_slice(&client_ids[lo..hi]);
            continue;
        };
        ensure!(
            o.grad.len() == m_s * k,
            "merge_partial: batch {i} gradient has {} values, expected {}",
            o.grad.len(),
            m_s * k
        );
        for (acc, v) in agg.grad.iter_mut().zip(&o.grad) {
            *acc += v;
        }
        agg.metrics.merge(&o.metrics);
        agg.ledger.merge(&o.ledger);
        ensure!(
            o.p.len() == (hi - lo) * k,
            "merge_partial: batch {i} has {} factor values, expected {}",
            o.p.len(),
            (hi - lo) * k
        );
        agg.factor_ids.extend_from_slice(&client_ids[lo..hi]);
        agg.factors.extend_from_slice(&o.p[..(hi - lo) * k]);
        for (total, ns) in agg.phase_ns.iter_mut().zip(&o.phase_ns) {
            *total += ns;
        }
        agg.batches.push(BatchStat {
            batch: i,
            clients: hi - lo,
            lane: o.lane,
            phase_ns: o.phase_ns,
        });
    }
    dropped.sort_unstable();
    Ok((agg, dropped))
}

/// Serve one round's downloads as records, in participant order, using
/// the shared stale-or-broadcast decision. `cached_of` abstracts the
/// generation lookup so the TCP lane can overlay "this process just
/// rejoined, treat its clients as fresh" on top of the fleet table.
pub fn plan_downloads(
    req: &ExchangeRequest<'_>,
    participants: &[usize],
    mut cached_of: impl FnMut(usize) -> Option<u32>,
) -> Result<(Vec<DownloadRecord>, Option<Vec<u8>>)> {
    let mut records = Vec::with_capacity(participants.len());
    let mut resync: Option<Vec<u8>> = None;
    match req.session {
        Some((sess, enc)) => {
            for &cid in participants {
                let cached = cached_of(cid);
                if enc.in_sync(cached) {
                    records.push(DownloadRecord {
                        client: cid,
                        bytes: req.down_bytes,
                        resync: false,
                        cached,
                    });
                } else {
                    // built + verified at most once per round
                    if resync.is_none() {
                        resync = Some(verified_resync_frame(sess, req.q_sel, enc.generation)?);
                    }
                    let len = resync.as_ref().map(|f| f.len() as u64).unwrap();
                    records.push(DownloadRecord {
                        client: cid,
                        bytes: len,
                        resync: true,
                        cached,
                    });
                }
            }
        }
        None => {
            for &cid in participants {
                records.push(DownloadRecord {
                    client: cid,
                    bytes: req.down_bytes,
                    resync: false,
                    cached: None,
                });
            }
        }
    }
    Ok((records, resync))
}

/// The deterministic reference lane: downloads are generation-table
/// lookups, compute runs on the in-process sharded executor. Behavior
/// is bit-for-bit the pre-transport round loop.
pub struct InProcessLane {
    executor: FleetExecutor,
}

impl InProcessLane {
    /// Wrap the sharded executor as a lane.
    pub fn new(executor: FleetExecutor) -> InProcessLane {
        InProcessLane { executor }
    }
}

impl RoundLane for InProcessLane {
    fn name(&self) -> &'static str {
        "in-process"
    }

    fn exchange(
        &mut self,
        req: ExchangeRequest<'_>,
        rt: &mut FcfRuntime,
        codec: &dyn PayloadCodec,
    ) -> Result<ExchangeOutcome> {
        let fleet = req.fleet;
        let (downloads, _resync) =
            plan_downloads(&req, req.participants, |cid| fleet.download_gen(cid))?;
        let contributed = req.task.client_ids.len();
        let agg = self.executor.run_round(req.task, rt, codec)?;
        Ok(ExchangeOutcome {
            downloads,
            agg,
            contributed,
            dropped: Vec::new(),
            invalidated: Vec::new(),
            transport_ns: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{MetricAccumulator, MetricSet};
    use crate::simnet::TrafficLedger;

    fn outcome(m_s: usize, k: usize, n: usize, seed: f32) -> BatchOutcome {
        let mut ledger = TrafficLedger::new();
        let sim = crate::config::RunConfig::paper_defaults().simnet;
        for _ in 0..n {
            ledger.record_up(&sim, 100 + seed as u64);
        }
        let mut metrics = MetricAccumulator::new();
        metrics.push(&MetricSet {
            precision: seed as f64,
            recall: 0.5,
            f1: 0.25,
            map: seed as f64 * 0.1,
        });
        BatchOutcome {
            grad: (0..m_s * k).map(|i| seed + i as f32 * 0.25).collect(),
            p: (0..n * k).map(|i| seed - i as f32).collect(),
            ledger,
            metrics,
            phase_ns: [10, 20, 30, 40],
            lane: 1,
            up_frame: None,
        }
    }

    #[test]
    fn full_coverage_matches_merge_outcomes_bitwise() {
        let (m_s, k, batch) = (3, 2, 2);
        let client_ids = vec![10, 11, 12, 13, 14];
        let outcomes = vec![
            outcome(m_s, k, 2, 1.0),
            outcome(m_s, k, 2, 2.0),
            outcome(m_s, k, 1, 3.0),
        ];
        let reference = merge_outcomes(m_s, k, &client_ids, batch, &outcomes).unwrap();
        let (partial, dropped) = merge_partial(
            m_s,
            k,
            &client_ids,
            batch,
            outcomes.into_iter().map(Some).collect(),
        )
        .unwrap();
        assert!(dropped.is_empty());
        assert_eq!(
            partial.grad.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            reference.grad.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(partial.factor_ids, reference.factor_ids);
        assert_eq!(partial.ledger.up_bytes, reference.ledger.up_bytes);
        assert_eq!(partial.metrics.count(), reference.metrics.count());
        assert_eq!(partial.batches, reference.batches);
    }

    #[test]
    fn missing_batches_drop_their_clients_and_fold_in_index_order() {
        let (m_s, k, batch) = (2, 2, 2);
        let client_ids = vec![0, 1, 2, 3, 4, 5];
        let o0 = outcome(m_s, k, 2, 1.0);
        let o2 = outcome(m_s, k, 2, 5.0);
        let (agg, dropped) = merge_partial(
            m_s,
            k,
            &client_ids,
            batch,
            vec![Some(o0.clone()), None, Some(o2.clone())],
        )
        .unwrap();
        // batch 1's clients are the dropped ones
        assert_eq!(dropped, vec![2, 3]);
        // grad = o0 + o2 summed in index order
        let expected: Vec<u32> = o0
            .grad
            .iter()
            .zip(&o2.grad)
            .map(|(a, b)| (a + b).to_bits())
            .collect();
        assert_eq!(
            agg.grad.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            expected
        );
        // factors cover batches 0 and 2 only, in order
        assert_eq!(agg.factor_ids, vec![0, 1, 4, 5]);
        assert_eq!(agg.batches.len(), 2);
        assert_eq!((agg.batches[0].batch, agg.batches[1].batch), (0, 2));
        // uploads of the missing batch never entered the ledger
        assert_eq!(agg.ledger.up_msgs, 4);
    }

    #[test]
    fn all_batches_missing_yields_zero_grad_and_all_dropped() {
        let (agg, dropped) = merge_partial(2, 2, &[7, 8], 2, vec![None]).unwrap();
        assert_eq!(dropped, vec![7, 8]);
        assert_eq!(agg.grad, vec![0.0; 4]);
        assert!(agg.factor_ids.is_empty());
        assert_eq!(agg.metrics.count(), 0);
    }
}
