//! The coordinator ⇄ client message set and its byte codec.
//!
//! Hand-rolled little-endian serialization (the crate's only dependency
//! is `anyhow`, so no serde): each message encodes to a `(type tag,
//! payload)` pair that `transport::framing` envelopes with magic,
//! length prefix and checksum. Decoding is bounds-checked through a
//! cursor — a truncated payload is a typed error naming the field that
//! fell off the end, never a panic.
//!
//! ## Round protocol
//!
//! A session is strictly phase-ordered per round, which is what lets
//! both endpoints run synchronous single-reader loops:
//!
//! ```text
//! client → Hello{proto, fingerprint}      coordinator → HelloAck{slot, slots}
//!                                                     | HelloReject{reason}
//! per round:
//!   coord → RoundBegin{...broadcast frame...}  (all live slots)
//!   client → MirrorSync | NeedResync(MIRROR)   (stale process mirror —
//!   coord → Resync(MIRROR, frame)               the real-network
//!   client → MirrorSync                         SessionDecode::Stale path)
//!   coord → Download{client, frame}            (per hosted participant,
//!   client → DownloadAck{client}                paced by the scheduler)
//!          | NeedResync{client, cached}        (device cache disagrees)
//!   coord → Resync{client, frame} → DownloadAck
//!   coord → Assign{batch indices}
//!   client → BatchDone{index, up_frame, p, metrics, phase_ns}  (per batch)
//!   coord → RoundEnd
//! shutdown:
//!   coord → Shutdown                           client → Bye
//! ```
//!
//! `NeedResync`/`Resync` address a *hosted client id*, or the
//! [`MIRROR`] sentinel for the process-level mirror decoder that every
//! client process keeps for the compute plane.

use anyhow::{bail, ensure, Result};

/// Protocol version; bumped on any wire-visible change. Checked in the
/// Hello handshake before anything else moves.
pub const PROTO_VERSION: u32 = 1;

/// Client-id sentinel addressing the process-level mirror decoder
/// instead of a hosted client.
pub const MIRROR: u64 = u64::MAX;

/// A `cached` generation sentinel meaning "no cached codebook".
pub const NO_GENERATION: u64 = u64::MAX;

/// One coordinator ⇄ client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Client → coordinator: join the session.
    Hello {
        /// [`PROTO_VERSION`] of the sender.
        proto: u32,
        /// `RunConfig::determinism_fingerprint()` of the client's
        /// config — both processes must run the identical
        /// training-relevant configuration.
        fingerprint: String,
    },
    /// Coordinator → client: admitted.
    HelloAck {
        /// Slot this process occupies (hosting clients `cid` with
        /// `cid % slots == slot`).
        slot: u32,
        /// Total process slots in the session.
        slots: u32,
    },
    /// Coordinator → client: refused (version/fingerprint mismatch).
    HelloReject {
        /// Human-readable refusal, naming the first differing config
        /// key on a fingerprint mismatch.
        reason: String,
    },
    /// Coordinator → all live slots: a round starts. Carries everything
    /// a client process needs to rebuild the round's compute task
    /// bit-identically: the sorted selected item ids, the participant
    /// list (batch `i` covers `participants[i*B..(i+1)*B]`), the
    /// broadcast download frame, and — on eval rounds — the full model
    /// snapshot for recommendation scoring.
    RoundBegin {
        /// 1-based FL iteration.
        iter: u64,
        /// Compute contributing clients' test metrics this round?
        evaluate: bool,
        /// Sorted selected item ids (M_s of M).
        selected: Vec<u32>,
        /// Participating client ids in round order.
        participants: Vec<u64>,
        /// The broadcast download frame (complete `wire` frame bytes).
        frame: Vec<u8>,
        /// Full model snapshot, row-major m × k (empty when
        /// `!evaluate`).
        q_full: Vec<f32>,
    },
    /// Client → coordinator: the process mirror decoded the broadcast
    /// (possibly after a mirror resync); the compute plane is staged.
    MirrorSync {
        /// Iteration being acknowledged.
        iter: u64,
    },
    /// Client → coordinator: a decoder is stale and needs a resync
    /// frame — the `SessionDecode::Stale` path driven by a real
    /// network event.
    NeedResync {
        /// Iteration this happened in.
        iter: u64,
        /// Hosted client id, or [`MIRROR`] for the process mirror.
        client: u64,
        /// Cached codebook generation, [`NO_GENERATION`] if none.
        cached: u64,
    },
    /// Coordinator → client: full-codebook resync frame for one stale
    /// decoder.
    Resync {
        /// Iteration.
        iter: u64,
        /// Hosted client id, or [`MIRROR`].
        client: u64,
        /// Complete statelessly-decodable resync frame bytes.
        frame: Vec<u8>,
    },
    /// Coordinator → hosting slot: one participant's download (the
    /// broadcast frame, or a resync frame for a stale/rejoined client).
    Download {
        /// Iteration.
        iter: u64,
        /// Hosted client id this download is addressed to.
        client: u64,
        /// Complete `wire` frame bytes.
        frame: Vec<u8>,
    },
    /// Client → coordinator: the hosted client decoded its download.
    DownloadAck {
        /// Iteration.
        iter: u64,
        /// Hosted client id acknowledging.
        client: u64,
    },
    /// Coordinator → client: compute these batch indices of the round's
    /// participant list.
    Assign {
        /// Iteration.
        iter: u64,
        /// Batch indices assigned to this slot.
        batches: Vec<u64>,
    },
    /// Client → coordinator: one batch finished. The gradient travels
    /// *encoded* — the coordinator decodes `up_frame` exactly as the
    /// in-process lane decodes its local round-trip, so quantization
    /// stays part of the training dynamics on both lanes.
    BatchDone {
        /// Iteration.
        iter: u64,
        /// Batch index within the round.
        index: u64,
        /// Sparse ∇Q* upload frame (complete `wire` frame bytes).
        up_frame: Vec<u8>,
        /// Solved user factors, n × k in batch order (f32 bits).
        p: Vec<f32>,
        /// Eval metric sets pushed (0 on non-eval rounds).
        metric_count: u64,
        /// Metric sums as f64 bits: precision, recall, f1, map.
        metric_bits: [u64; 4],
        /// Busy nanoseconds per phase: solve, grad, codec, eval
        /// (wall-clock facts; never feed the deterministic merge).
        phase_ns: [u64; 4],
    },
    /// Coordinator → all live slots: the round is fully aggregated.
    RoundEnd {
        /// Iteration that ended.
        iter: u64,
    },
    /// Coordinator → client: the run is over, disconnect cleanly.
    Shutdown,
    /// Client → coordinator: goodbye (sent before a clean disconnect).
    Bye {
        /// Slot saying goodbye.
        slot: u32,
    },
}

// type tags (framing header byte 4)
const T_HELLO: u8 = 1;
const T_HELLO_ACK: u8 = 2;
const T_HELLO_REJECT: u8 = 3;
const T_ROUND_BEGIN: u8 = 4;
const T_MIRROR_SYNC: u8 = 5;
const T_NEED_RESYNC: u8 = 6;
const T_RESYNC: u8 = 7;
const T_DOWNLOAD: u8 = 8;
const T_DOWNLOAD_ACK: u8 = 9;
const T_ASSIGN: u8 = 10;
const T_BATCH_DONE: u8 = 11;
const T_ROUND_END: u8 = 12;
const T_SHUTDOWN: u8 = 13;
const T_BYE: u8 = 14;

struct Writer(Vec<u8>);

impl Writer {
    fn new() -> Writer {
        Writer(Vec::new())
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.0.extend_from_slice(v);
    }
    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
    }
    fn u64s(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u64(x);
        }
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x.to_bits());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated message: `{what}` needs {n} bytes at offset {}, payload is {} bytes",
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }
    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }
    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
    fn len(&mut self, what: &str) -> Result<usize> {
        let n = self.u32(what)? as usize;
        // a corrupt count cannot promise more elements than the payload
        // has bytes left — rejects absurd counts before any `take`
        ensure!(
            n <= self.buf.len().saturating_sub(self.pos),
            "truncated message: `{what}` count {n} exceeds the {} remaining payload bytes",
            self.buf.len().saturating_sub(self.pos)
        );
        Ok(n)
    }
    fn bytes(&mut self, what: &str) -> Result<Vec<u8>> {
        let n = self.len(what)?;
        Ok(self.take(n, what)?.to_vec())
    }
    fn str(&mut self, what: &str) -> Result<String> {
        let b = self.bytes(what)?;
        String::from_utf8(b).map_err(|_| anyhow::anyhow!("`{what}` is not valid UTF-8"))
    }
    fn u32s(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.len(what)?;
        let raw = self.take(n * 4, what)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn u64s(&mut self, what: &str) -> Result<Vec<u64>> {
        let n = self.len(what)?;
        let raw = self.take(n * 8, what)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.len(what)?;
        let raw = self.take(n * 4, what)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
    fn done(&self, what: &str) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "{what}: {} trailing bytes after the last field",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

impl Msg {
    /// Serialize to a `(framing type tag, payload)` pair.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut w = Writer::new();
        let ty = match self {
            Msg::Hello { proto, fingerprint } => {
                w.u32(*proto);
                w.str(fingerprint);
                T_HELLO
            }
            Msg::HelloAck { slot, slots } => {
                w.u32(*slot);
                w.u32(*slots);
                T_HELLO_ACK
            }
            Msg::HelloReject { reason } => {
                w.str(reason);
                T_HELLO_REJECT
            }
            Msg::RoundBegin {
                iter,
                evaluate,
                selected,
                participants,
                frame,
                q_full,
            } => {
                w.u64(*iter);
                w.u8(u8::from(*evaluate));
                w.u32s(selected);
                w.u64s(participants);
                w.bytes(frame);
                w.f32s(q_full);
                T_ROUND_BEGIN
            }
            Msg::MirrorSync { iter } => {
                w.u64(*iter);
                T_MIRROR_SYNC
            }
            Msg::NeedResync {
                iter,
                client,
                cached,
            } => {
                w.u64(*iter);
                w.u64(*client);
                w.u64(*cached);
                T_NEED_RESYNC
            }
            Msg::Resync {
                iter,
                client,
                frame,
            } => {
                w.u64(*iter);
                w.u64(*client);
                w.bytes(frame);
                T_RESYNC
            }
            Msg::Download {
                iter,
                client,
                frame,
            } => {
                w.u64(*iter);
                w.u64(*client);
                w.bytes(frame);
                T_DOWNLOAD
            }
            Msg::DownloadAck { iter, client } => {
                w.u64(*iter);
                w.u64(*client);
                T_DOWNLOAD_ACK
            }
            Msg::Assign { iter, batches } => {
                w.u64(*iter);
                w.u64s(batches);
                T_ASSIGN
            }
            Msg::BatchDone {
                iter,
                index,
                up_frame,
                p,
                metric_count,
                metric_bits,
                phase_ns,
            } => {
                w.u64(*iter);
                w.u64(*index);
                w.bytes(up_frame);
                w.f32s(p);
                w.u64(*metric_count);
                for &b in metric_bits {
                    w.u64(b);
                }
                for &ns in phase_ns {
                    w.u64(ns);
                }
                T_BATCH_DONE
            }
            Msg::RoundEnd { iter } => {
                w.u64(*iter);
                T_ROUND_END
            }
            Msg::Shutdown => T_SHUTDOWN,
            Msg::Bye { slot } => {
                w.u32(*slot);
                T_BYE
            }
        };
        (ty, w.0)
    }

    /// Deserialize from a `(framing type tag, payload)` pair. Unknown
    /// tags and truncated payloads are typed errors.
    pub fn decode(ty: u8, payload: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(payload);
        let msg = match ty {
            T_HELLO => Msg::Hello {
                proto: r.u32("proto")?,
                fingerprint: r.str("fingerprint")?,
            },
            T_HELLO_ACK => Msg::HelloAck {
                slot: r.u32("slot")?,
                slots: r.u32("slots")?,
            },
            T_HELLO_REJECT => Msg::HelloReject {
                reason: r.str("reason")?,
            },
            T_ROUND_BEGIN => Msg::RoundBegin {
                iter: r.u64("iter")?,
                evaluate: r.u8("evaluate")? != 0,
                selected: r.u32s("selected")?,
                participants: r.u64s("participants")?,
                frame: r.bytes("frame")?,
                q_full: r.f32s("q_full")?,
            },
            T_MIRROR_SYNC => Msg::MirrorSync {
                iter: r.u64("iter")?,
            },
            T_NEED_RESYNC => Msg::NeedResync {
                iter: r.u64("iter")?,
                client: r.u64("client")?,
                cached: r.u64("cached")?,
            },
            T_RESYNC => Msg::Resync {
                iter: r.u64("iter")?,
                client: r.u64("client")?,
                frame: r.bytes("frame")?,
            },
            T_DOWNLOAD => Msg::Download {
                iter: r.u64("iter")?,
                client: r.u64("client")?,
                frame: r.bytes("frame")?,
            },
            T_DOWNLOAD_ACK => Msg::DownloadAck {
                iter: r.u64("iter")?,
                client: r.u64("client")?,
            },
            T_ASSIGN => Msg::Assign {
                iter: r.u64("iter")?,
                batches: r.u64s("batches")?,
            },
            T_BATCH_DONE => {
                let iter = r.u64("iter")?;
                let index = r.u64("index")?;
                let up_frame = r.bytes("up_frame")?;
                let p = r.f32s("p")?;
                let metric_count = r.u64("metric_count")?;
                let mut metric_bits = [0u64; 4];
                for b in metric_bits.iter_mut() {
                    *b = r.u64("metric_bits")?;
                }
                let mut phase_ns = [0u64; 4];
                for ns in phase_ns.iter_mut() {
                    *ns = r.u64("phase_ns")?;
                }
                Msg::BatchDone {
                    iter,
                    index,
                    up_frame,
                    p,
                    metric_count,
                    metric_bits,
                    phase_ns,
                }
            }
            T_ROUND_END => Msg::RoundEnd {
                iter: r.u64("iter")?,
            },
            T_SHUTDOWN => Msg::Shutdown,
            T_BYE => Msg::Bye { slot: r.u32("slot")? },
            other => bail!("unknown transport message type {other}"),
        };
        r.done("transport message")?;
        Ok(msg)
    }

    /// Short name for logs and errors.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::HelloAck { .. } => "HelloAck",
            Msg::HelloReject { .. } => "HelloReject",
            Msg::RoundBegin { .. } => "RoundBegin",
            Msg::MirrorSync { .. } => "MirrorSync",
            Msg::NeedResync { .. } => "NeedResync",
            Msg::Resync { .. } => "Resync",
            Msg::Download { .. } => "Download",
            Msg::DownloadAck { .. } => "DownloadAck",
            Msg::Assign { .. } => "Assign",
            Msg::BatchDone { .. } => "BatchDone",
            Msg::RoundEnd { .. } => "RoundEnd",
            Msg::Shutdown => "Shutdown",
            Msg::Bye { .. } => "Bye",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let (ty, payload) = msg.encode();
        let back = Msg::decode(ty, &payload).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn every_variant_roundtrips() {
        roundtrip(Msg::Hello {
            proto: PROTO_VERSION,
            fingerprint: "seed=1;dataset.users=42".into(),
        });
        roundtrip(Msg::HelloAck { slot: 1, slots: 2 });
        roundtrip(Msg::HelloReject {
            reason: "fingerprint differs at `seed`".into(),
        });
        roundtrip(Msg::RoundBegin {
            iter: 3,
            evaluate: true,
            selected: vec![1, 5, 9],
            participants: vec![0, 1, 2, 3],
            frame: vec![0xAB; 40],
            q_full: vec![1.5, -2.25, f32::MIN_POSITIVE],
        });
        roundtrip(Msg::RoundBegin {
            iter: 4,
            evaluate: false,
            selected: vec![],
            participants: vec![],
            frame: vec![],
            q_full: vec![],
        });
        roundtrip(Msg::MirrorSync { iter: 3 });
        roundtrip(Msg::NeedResync {
            iter: 3,
            client: MIRROR,
            cached: NO_GENERATION,
        });
        roundtrip(Msg::Resync {
            iter: 3,
            client: 17,
            frame: vec![1, 2, 3],
        });
        roundtrip(Msg::Download {
            iter: 3,
            client: 8,
            frame: vec![9; 64],
        });
        roundtrip(Msg::DownloadAck { iter: 3, client: 8 });
        roundtrip(Msg::Assign {
            iter: 3,
            batches: vec![0, 2],
        });
        roundtrip(Msg::BatchDone {
            iter: 3,
            index: 2,
            up_frame: vec![4; 33],
            p: vec![0.5; 8],
            metric_count: 5,
            metric_bits: [1, 2, 3, u64::MAX],
            phase_ns: [10, 20, 30, 0],
        });
        roundtrip(Msg::RoundEnd { iter: 3 });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Bye { slot: 1 });
    }

    #[test]
    fn float_bits_are_exact() {
        // f32 payloads travel as raw bits — NaN payloads and signed
        // zeros survive exactly
        let vals = vec![-0.0f32, f32::NAN, f32::INFINITY, 1.0e-40];
        let (ty, payload) = Msg::RoundBegin {
            iter: 1,
            evaluate: true,
            selected: vec![],
            participants: vec![],
            frame: vec![],
            q_full: vals.clone(),
        }
        .encode();
        match Msg::decode(ty, &payload).unwrap() {
            Msg::RoundBegin { q_full, .. } => {
                assert_eq!(
                    q_full.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    vals.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn truncation_is_a_typed_field_error() {
        let (ty, payload) = Msg::Download {
            iter: 9,
            client: 3,
            frame: vec![7; 32],
        }
        .encode();
        for cut in 0..payload.len() {
            let e = Msg::decode(ty, &payload[..cut]).unwrap_err().to_string();
            assert!(
                e.contains("truncated") || e.contains("count"),
                "cut at {cut}: unexpected error `{e}`"
            );
        }
        // trailing garbage is rejected too
        let mut long = payload.clone();
        long.push(0);
        assert!(Msg::decode(ty, &long)
            .unwrap_err()
            .to_string()
            .contains("trailing"));
    }

    #[test]
    fn unknown_tag_is_rejected() {
        assert!(Msg::decode(200, &[]).unwrap_err().to_string().contains("unknown"));
    }
}
