//! Download scheduler: per-client bandwidth pacing for the TCP lane.
//!
//! The coordinator serves every participant's download through this
//! scheduler. Each client has an independent simulated downlink of
//! `cap_bps` bytes/second (0 = uncapped): a frame of `n` bytes occupies
//! the client's link for `n / cap_bps` seconds, so back-to-back frames
//! to the *same* client are spaced while different clients proceed
//! independently — heterogeneous delivery times without any effect on
//! *what* is delivered.
//!
//! The math is pure (logical nanosecond clock in, delay out), so the
//! determinism contract is visible by construction: pacing shifts
//! *when* bytes move, never which bytes move, which is why a capped
//! fault-free transport run still produces byte-identical round dumps.
//! The unit tests below and the Python prototype exercise exactly this
//! arithmetic; the coordinator maps it onto `Instant`/`sleep`.

use std::collections::BTreeMap;

/// Per-client pacing state over a logical nanosecond clock.
#[derive(Debug, Clone)]
pub struct DownloadScheduler {
    cap_bps: u64,
    /// Earliest ns at which each client's link is free again.
    next_free_ns: BTreeMap<u64, u64>,
}

impl DownloadScheduler {
    /// A scheduler enforcing `cap_bps` bytes/second per client
    /// (0 = uncapped: every delay is zero).
    pub fn new(cap_bps: u64) -> DownloadScheduler {
        DownloadScheduler {
            cap_bps,
            next_free_ns: BTreeMap::new(),
        }
    }

    /// Is pacing active at all?
    pub fn capped(&self) -> bool {
        self.cap_bps > 0
    }

    /// Schedule `bytes` to `client` at logical time `now_ns`: returns
    /// the nanoseconds the send must wait for the client's link, and
    /// books the transfer onto it.
    pub fn schedule(&mut self, client: u64, bytes: u64, now_ns: u64) -> u64 {
        if self.cap_bps == 0 {
            return 0;
        }
        let free = self.next_free_ns.get(&client).copied().unwrap_or(0);
        let start = free.max(now_ns);
        let busy_ns = bytes.saturating_mul(1_000_000_000) / self.cap_bps;
        self.next_free_ns.insert(client, start.saturating_add(busy_ns));
        start - now_ns
    }

    /// Forget a client's link state (its process dropped; a rejoined
    /// process starts with a free link).
    pub fn forget(&mut self, client: u64) {
        self.next_free_ns.remove(&client);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncapped_never_waits() {
        let mut s = DownloadScheduler::new(0);
        assert!(!s.capped());
        for i in 0..10 {
            assert_eq!(s.schedule(i, 1 << 30, 0), 0);
        }
    }

    #[test]
    fn back_to_back_frames_are_spaced_by_bytes_over_cap() {
        // 1000 B/s → a 500-byte frame busies the link for 0.5e9 ns
        let mut s = DownloadScheduler::new(1000);
        assert_eq!(s.schedule(7, 500, 0), 0);
        // second frame at t=0 must wait out the first transfer
        assert_eq!(s.schedule(7, 500, 0), 500_000_000);
        // third waits for both
        assert_eq!(s.schedule(7, 100, 0), 1_000_000_000);
    }

    #[test]
    fn clients_pace_independently() {
        let mut s = DownloadScheduler::new(1000);
        assert_eq!(s.schedule(1, 1000, 0), 0);
        // a different client's link is untouched
        assert_eq!(s.schedule(2, 1000, 0), 0);
        // ...but each is busy for itself
        assert_eq!(s.schedule(1, 10, 0), 1_000_000_000);
        assert_eq!(s.schedule(2, 10, 0), 1_000_000_000);
    }

    #[test]
    fn elapsed_time_drains_the_backlog() {
        let mut s = DownloadScheduler::new(1000);
        s.schedule(3, 1000, 0); // busy until 1e9
        // arriving at 0.4e9 waits the remaining 0.6e9
        assert_eq!(s.schedule(3, 0, 400_000_000), 600_000_000);
        // arriving after the link freed waits nothing
        let mut s = DownloadScheduler::new(1000);
        s.schedule(3, 1000, 0);
        assert_eq!(s.schedule(3, 10, 2_000_000_000), 0);
    }

    #[test]
    fn forget_resets_a_client_link() {
        let mut s = DownloadScheduler::new(1000);
        s.schedule(5, 10_000, 0);
        s.forget(5);
        assert_eq!(s.schedule(5, 10, 0), 0);
    }
}
