//! Length-prefixed message framing for the TCP lane.
//!
//! Every transport message travels as one frame:
//!
//! ```text
//! offset  size  field
//! 0       4     magic "FPTL"
//! 4       1     message type (transport::proto tag)
//! 5       4     payload length in bytes (u32, little-endian)
//! 9       ...   payload
//! 9+len   4     FNV-1a checksum of bytes 4..9+len (type, length, payload)
//! ```
//!
//! The checksum reuses `wire::frame`'s FNV-1a (chained, so no payload
//! copy is needed on either side). The payload itself is usually a
//! `transport::proto` message, which may in turn *contain* a complete
//! `wire::frame` download frame — the wire frame keeps its own header
//! checksum, so payload corruption is detected twice, once per envelope.
//!
//! ## Torn reads are typed, not mysterious
//!
//! [`read_msg`] distinguishes every way a stream can end:
//!
//! * clean EOF **at a frame boundary** → `Ok(None)` (the peer closed
//!   between messages — an orderly goodbye),
//! * EOF **inside the 9-byte prefix** → [`FrameError::TornPrefix`],
//! * EOF **inside payload or checksum** → [`FrameError::TornPayload`],
//! * wrong magic → [`FrameError::BadMagic`] (desynchronized stream),
//! * a length field beyond [`MAX_PAYLOAD`] → [`FrameError::Oversize`]
//!   (a desynced or hostile peer must not make us allocate gigabytes),
//! * checksum mismatch → [`FrameError::Checksum`].
//!
//! The coordinator maps `TornPrefix`/`TornPayload` on a live connection
//! to mid-round dropout; the fault-injection e2e pins each variant.

use std::io::{Read, Write};

use anyhow::Result;

use crate::wire::frame::{checksum_chained, CHECKSUM_SEED};

/// Transport frame magic: "FPTL" (FedPayload Transport Lane).
pub const MSG_MAGIC: [u8; 4] = *b"FPTL";

/// Fixed prefix size: magic + type byte + u32 payload length.
pub const MSG_HEADER_LEN: usize = 9;

/// Hard cap on a single message payload (256 MiB). Far above any real
/// frame (a 10^6 × 32 f32 download is 128 MiB) but small enough that a
/// desynchronized length field cannot trigger an absurd allocation.
pub const MAX_PAYLOAD: u32 = 256 * 1024 * 1024;

/// Typed framing failures — every way a transport stream can be torn,
/// truncated, or corrupted. Carried inside `anyhow::Error`; callers
/// downcast with `err.downcast_ref::<FrameError>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// The stream ended inside the 9-byte message prefix (after at
    /// least one byte): a torn length-prefix.
    TornPrefix {
        /// Prefix bytes actually received before EOF.
        got: usize,
    },
    /// The stream ended inside the payload or trailing checksum.
    TornPayload {
        /// Payload + checksum bytes the prefix promised.
        expected: usize,
        /// Bytes actually received before EOF.
        got: usize,
    },
    /// The prefix did not start with [`MSG_MAGIC`] — the stream is
    /// desynchronized or the peer is not a transport endpoint.
    BadMagic(
        /// The four bytes read where the magic should be.
        [u8; 4],
    ),
    /// The declared payload length exceeds [`MAX_PAYLOAD`].
    Oversize(
        /// The declared payload length.
        u32,
    ),
    /// The trailing FNV-1a checksum did not match.
    Checksum {
        /// Checksum stored on the wire.
        stored: u32,
        /// Checksum recomputed from the received bytes.
        computed: u32,
    },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TornPrefix { got } => write!(
                f,
                "torn message prefix: stream ended after {got} of {MSG_HEADER_LEN} header bytes"
            ),
            FrameError::TornPayload { expected, got } => write!(
                f,
                "torn message payload: stream ended after {got} of {expected} body bytes"
            ),
            FrameError::BadMagic(m) => {
                write!(f, "bad transport magic {m:02x?} (stream desynchronized?)")
            }
            FrameError::Oversize(len) => write!(
                f,
                "message declares {len} payload bytes, above the {MAX_PAYLOAD}-byte cap"
            ),
            FrameError::Checksum { stored, computed } => write!(
                f,
                "message checksum mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
        }
    }
}

impl std::error::Error for FrameError {}

/// Write one framed message. The whole frame is assembled and written
/// with a single `write_all`, so a crash mid-call leaves at worst one
/// torn frame on the wire — which the peer's [`read_msg`] reports as a
/// typed [`FrameError`] instead of garbage.
pub fn write_msg(w: &mut impl Write, msg_type: u8, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_PAYLOAD as usize);
    let mut frame = Vec::with_capacity(MSG_HEADER_LEN + payload.len() + 4);
    frame.extend_from_slice(&MSG_MAGIC);
    frame.push(msg_type);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(payload);
    let sum = checksum_chained(CHECKSUM_SEED, &frame[4..]);
    frame.extend_from_slice(&sum.to_le_bytes());
    w.write_all(&frame)?;
    w.flush()
}

/// Fill `buf` from the reader. Returns the number of bytes read before
/// EOF (== `buf.len()` unless the stream ended early).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(filled)
}

/// Read one framed message. `Ok(None)` is a clean EOF at a frame
/// boundary; every torn/corrupt variant is a typed [`FrameError`]
/// inside the `anyhow::Error`.
pub fn read_msg(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; MSG_HEADER_LEN];
    let got = read_exact_or_eof(r, &mut header)?;
    if got == 0 {
        return Ok(None);
    }
    if got < MSG_HEADER_LEN {
        return Err(FrameError::TornPrefix { got }.into());
    }
    if header[0..4] != MSG_MAGIC {
        return Err(FrameError::BadMagic([header[0], header[1], header[2], header[3]]).into());
    }
    let msg_type = header[4];
    let len = u32::from_le_bytes(header[5..9].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversize(len).into());
    }
    // payload + 4 trailing checksum bytes
    let body_len = len as usize + 4;
    let mut body = vec![0u8; body_len];
    let got = read_exact_or_eof(r, &mut body)?;
    if got < body_len {
        return Err(FrameError::TornPayload {
            expected: body_len,
            got,
        }
        .into());
    }
    let stored = u32::from_le_bytes(body[len as usize..].try_into().unwrap());
    let computed = checksum_chained(checksum_chained(CHECKSUM_SEED, &header[4..]), &body[..len as usize]);
    if stored != computed {
        return Err(FrameError::Checksum { stored, computed }.into());
    }
    body.truncate(len as usize);
    Ok(Some((msg_type, body)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(ty: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_msg(&mut out, ty, payload).unwrap();
        out
    }

    fn err_of(bytes: &[u8]) -> FrameError {
        let e = read_msg(&mut &bytes[..]).unwrap_err();
        *e.downcast_ref::<FrameError>().expect("typed FrameError")
    }

    #[test]
    fn roundtrip_and_clean_eof() {
        let payload = b"hello transport".to_vec();
        let mut wire = frame_bytes(7, &payload);
        wire.extend_from_slice(&frame_bytes(9, &[]));
        let mut r = &wire[..];
        assert_eq!(read_msg(&mut r).unwrap(), Some((7, payload)));
        assert_eq!(read_msg(&mut r).unwrap(), Some((9, Vec::new())));
        // boundary EOF is a clean goodbye, not an error
        assert_eq!(read_msg(&mut r).unwrap(), None);
    }

    #[test]
    fn torn_prefix_is_typed() {
        let wire = frame_bytes(1, b"abc");
        for cut in 1..MSG_HEADER_LEN {
            assert_eq!(err_of(&wire[..cut]), FrameError::TornPrefix { got: cut });
        }
    }

    #[test]
    fn torn_payload_is_typed() {
        let wire = frame_bytes(1, b"abcdef");
        // cut anywhere in payload or trailing checksum
        for cut in MSG_HEADER_LEN..wire.len() {
            assert_eq!(
                err_of(&wire[..cut]),
                FrameError::TornPayload {
                    expected: 6 + 4,
                    got: cut - MSG_HEADER_LEN
                }
            );
        }
    }

    #[test]
    fn bad_magic_oversize_and_checksum_are_typed() {
        let mut wire = frame_bytes(1, b"abc");
        wire[0] = b'X';
        assert!(matches!(err_of(&wire), FrameError::BadMagic(_)));

        let mut wire = frame_bytes(1, b"abc");
        wire[5..9].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(err_of(&wire), FrameError::Oversize(MAX_PAYLOAD + 1));

        let mut wire = frame_bytes(1, b"abc");
        let n = wire.len();
        wire[n - 6] ^= 0x20; // payload byte under the checksum
        assert!(matches!(err_of(&wire), FrameError::Checksum { .. }));
        // type byte and length are covered too
        let mut wire = frame_bytes(1, b"abc");
        wire[4] ^= 0x01;
        assert!(matches!(err_of(&wire), FrameError::Checksum { .. }));
    }

    #[test]
    fn empty_payload_roundtrips() {
        let wire = frame_bytes(42, &[]);
        assert_eq!(wire.len(), MSG_HEADER_LEN + 4);
        assert_eq!(read_msg(&mut &wire[..]).unwrap(), Some((42, Vec::new())));
    }
}
