//! Recommendation metrics (paper §6.2): Precision, Recall, F1 and MAP for
//! the top-10 of a 100-item recommendation list, normalized by the
//! theoretically best achievable value per user, plus the Impr%/Diff%
//! summary statistics (Eq. 15–16) and the TopList baseline evaluator.

use crate::data::Interactions;

/// Top-k cut the paper reports (top 10 predicted recommendations).
pub const TOP_K: usize = 10;
/// Recommendation list length (candidates considered).
pub const LIST_LEN: usize = 100;

/// One metric quadruple.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MetricSet {
    /// Precision@10, normalized by the per-user theoretical best.
    pub precision: f64,
    /// Recall@10, normalized.
    pub recall: f64,
    /// F1@10, normalized.
    pub f1: f64,
    /// Mean average precision@10, normalized.
    pub map: f64,
}

impl MetricSet {
    /// The all-zero metric set.
    pub fn zeros() -> MetricSet {
        MetricSet::default()
    }

    fn add(&mut self, other: &MetricSet) {
        self.precision += other.precision;
        self.recall += other.recall;
        self.f1 += other.f1;
        self.map += other.map;
    }

    fn scale(&mut self, s: f64) {
        self.precision *= s;
        self.recall *= s;
        self.f1 *= s;
        self.map *= s;
    }

    /// Element-wise ratio (used for theoretical-best normalization).
    fn normalized_by(&self, best: &MetricSet) -> MetricSet {
        let safe = |x: f64, b: f64| if b > 0.0 { (x / b).min(1.0) } else { 0.0 };
        MetricSet {
            precision: safe(self.precision, best.precision),
            recall: safe(self.recall, best.recall),
            f1: safe(self.f1, best.f1),
            map: safe(self.map, best.map),
        }
    }
}

impl std::fmt::Display for MetricSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "P={:.4} R={:.4} F1={:.4} MAP={:.4}",
            self.precision, self.recall, self.f1, self.map
        )
    }
}

/// Raw (un-normalized) metrics @ TOP_K for one ranked recommendation list.
///
/// `ranked` must already exclude the user's train items. Relevance =
/// membership in `test_items` (sorted).
pub fn raw_metrics(ranked: &[u32], test_items: &[u32]) -> MetricSet {
    if test_items.is_empty() {
        return MetricSet::zeros();
    }
    let k = TOP_K.min(ranked.len());
    let mut hits = 0usize;
    let mut ap = 0.0f64;
    for (i, &item) in ranked.iter().take(k).enumerate() {
        if test_items.binary_search(&item).is_ok() {
            hits += 1;
            ap += hits as f64 / (i + 1) as f64; // precision@i+1 at each hit
        }
    }
    let denom_ap = TOP_K.min(test_items.len()) as f64;
    let precision = hits as f64 / TOP_K as f64;
    let recall = hits as f64 / test_items.len() as f64;
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    MetricSet {
        precision,
        recall,
        f1,
        map: ap / denom_ap,
    }
}

/// Theoretical best achievable metrics for a user with `n_test` test items
/// (paper §6.2: recommend the test set itself, padding with random
/// non-interacted items when the test set is smaller than the list).
pub fn best_metrics(n_test: usize) -> MetricSet {
    if n_test == 0 {
        return MetricSet::zeros();
    }
    let hits = TOP_K.min(n_test);
    let precision = hits as f64 / TOP_K as f64;
    let recall = hits as f64 / n_test as f64;
    let f1 = 2.0 * precision * recall / (precision + recall);
    // perfect ranking: AP = 1 by construction
    MetricSet {
        precision,
        recall,
        f1,
        map: 1.0,
    }
}

/// Normalized metrics for one user given their ranked list.
pub fn user_metrics(ranked: &[u32], test_items: &[u32]) -> Option<MetricSet> {
    if test_items.is_empty() {
        return None; // paper evaluates only users with test interactions
    }
    let raw = raw_metrics(ranked, test_items);
    Some(raw.normalized_by(&best_metrics(test_items.len())))
}

/// Build the top-LIST_LEN ranked recommendation list for a user from dense
/// scores, excluding their train items.
pub fn rank_candidates(scores: &[f32], train_items: &[u32]) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..scores.len() as u32)
        .filter(|i| train_items.binary_search(i).is_err())
        .collect();
    let cut = LIST_LEN.min(idx.len());
    if cut == 0 {
        return idx;
    }
    // partial select of the top LIST_LEN, then sort just that prefix
    if idx.len() > cut {
        idx.select_nth_unstable_by(cut - 1, |&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(cut);
    }
    idx.sort_unstable_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    idx
}

/// Mean of per-user metric sets (users yielding `None` are skipped).
#[derive(Debug, Clone, Default)]
pub struct MetricAccumulator {
    sum: MetricSet,
    count: usize,
}

impl MetricAccumulator {
    /// A fresh, empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one user's metric set.
    pub fn push(&mut self, m: &MetricSet) {
        self.sum.add(m);
        self.count += 1;
    }

    /// Fold another accumulator in (per-batch accumulators merged at the
    /// parallel round barrier). Merging MUST happen in a fixed order —
    /// float addition is not associative — which the fleet executor
    /// guarantees by always folding in batch-index order.
    pub fn merge(&mut self, other: &MetricAccumulator) {
        self.sum.add(&other.sum);
        self.count += other.count;
    }

    /// Number of metric sets pushed.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Mean of everything pushed (zeros when empty).
    pub fn mean(&self) -> MetricSet {
        let mut m = self.sum;
        if self.count > 0 {
            m.scale(1.0 / self.count as f64);
        }
        m
    }

    /// The accumulator's exact state: (sum of pushed sets, push count).
    /// The TCP lane ships these as raw f64 bits so a batch's metrics
    /// survive the socket bit-exactly.
    pub fn parts(&self) -> (MetricSet, usize) {
        (self.sum, self.count)
    }

    /// Rebuild an accumulator from [`MetricAccumulator::parts`] output.
    /// `merge`/`mean` over the result behave exactly as on the original.
    pub fn from_parts(sum: MetricSet, count: usize) -> MetricAccumulator {
        MetricAccumulator { sum, count }
    }
}

/// Mean ± standard deviation across model rebuilds (Table 4 rows).
#[derive(Debug, Clone, Default)]
pub struct RebuildStats {
    samples: Vec<MetricSet>,
}

impl RebuildStats {
    /// Record one rebuild's final metric set.
    pub fn push(&mut self, m: MetricSet) {
        self.samples.push(m);
    }

    /// Number of rebuilds recorded.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Is the sample set empty?
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Mean across rebuilds.
    pub fn mean(&self) -> MetricSet {
        let mut acc = MetricAccumulator::new();
        for s in &self.samples {
            acc.push(s);
        }
        acc.mean()
    }

    /// Population standard deviation across rebuilds (zeros when n < 2).
    pub fn std(&self) -> MetricSet {
        let n = self.samples.len();
        if n < 2 {
            return MetricSet::zeros();
        }
        let mean = self.mean();
        let mut var = MetricSet::zeros();
        for s in &self.samples {
            var.precision += (s.precision - mean.precision).powi(2);
            var.recall += (s.recall - mean.recall).powi(2);
            var.f1 += (s.f1 - mean.f1).powi(2);
            var.map += (s.map - mean.map).powi(2);
        }
        MetricSet {
            precision: (var.precision / (n - 1) as f64).sqrt(),
            recall: (var.recall / (n - 1) as f64).sqrt(),
            f1: (var.f1 / (n - 1) as f64).sqrt(),
            map: (var.map / (n - 1) as f64).sqrt(),
        }
    }
}

/// Relative improvement of `ours` over `baseline`, |Δ|/baseline × 100
/// (paper Eq. 15, "Impr %").
pub fn impr_pct(ours: f64, baseline: f64) -> f64 {
    if baseline == 0.0 {
        return 0.0;
    }
    ((ours - baseline) / baseline).abs() * 100.0
}

/// Relative difference of `ours` from `upper`, |Δ|/upper × 100
/// (paper Eq. 16, "Diff %").
pub fn diff_pct(ours: f64, upper: f64) -> f64 {
    impr_pct(ours, upper)
}

/// TopList baseline (§6): recommend the globally most popular train items
/// to every user, evaluated with the same normalized metrics.
pub fn toplist_eval(train: &Interactions, test: &Interactions) -> MetricSet {
    let ranking = train.popularity_ranking();
    let mut acc = MetricAccumulator::new();
    for u in 0..train.num_users() {
        let test_items = test.user_items(u);
        if test_items.is_empty() {
            continue;
        }
        let train_items = train.user_items(u);
        let list: Vec<u32> = ranking
            .iter()
            .copied()
            .filter(|i| train_items.binary_search(i).is_err())
            .take(LIST_LEN)
            .collect();
        if let Some(m) = user_metrics(&list, test_items) {
            acc.push(&m);
        }
    }
    acc.mean()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking_is_normalized_to_one() {
        // 5 test items, perfect list
        let test = [3u32, 5, 7, 9, 11];
        let ranked: Vec<u32> = test.iter().copied().chain([100, 101, 102, 103, 104]).collect();
        let m = user_metrics(&ranked, &test).unwrap();
        assert!((m.precision - 1.0).abs() < 1e-9);
        assert!((m.recall - 1.0).abs() < 1e-9);
        assert!((m.f1 - 1.0).abs() < 1e-9);
        assert!((m.map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_test_set_skipped() {
        assert!(user_metrics(&[1, 2, 3], &[]).is_none());
    }

    #[test]
    fn raw_metrics_partial_hits() {
        // test items {1, 2}; ranked hits at positions 1 and 4 (0-based 0,3)
        let m = raw_metrics(&[1, 9, 8, 2, 7, 6, 5, 4, 3, 0], &[1, 2]);
        assert!((m.precision - 0.2).abs() < 1e-9);
        assert!((m.recall - 1.0).abs() < 1e-9);
        // AP = (1/1 + 2/4) / min(10, 2) = 0.75
        assert!((m.map - 0.75).abs() < 1e-9);
    }

    #[test]
    fn best_metrics_small_test_set() {
        let b = best_metrics(3);
        assert!((b.precision - 0.3).abs() < 1e-9);
        assert!((b.recall - 1.0).abs() < 1e-9);
        assert!((b.map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_candidates_excludes_train_and_orders() {
        let scores = [0.1f32, 0.9, 0.5, 0.7, 0.3];
        let ranked = rank_candidates(&scores, &[1]); // item 1 is train
        assert_eq!(ranked[0], 3);
        assert_eq!(ranked[1], 2);
        assert!(!ranked.contains(&1));
    }

    #[test]
    fn rank_candidates_truncates_to_list_len() {
        let scores: Vec<f32> = (0..500).map(|i| (i % 97) as f32).collect();
        let ranked = rank_candidates(&scores, &[]);
        assert_eq!(ranked.len(), LIST_LEN);
        // descending scores
        for w in ranked.windows(2) {
            assert!(scores[w[0] as usize] >= scores[w[1] as usize]);
        }
    }

    #[test]
    fn impr_and_diff_match_paper_formulas() {
        assert!((impr_pct(0.3041, 0.2370) - 28.3122).abs() < 0.01);
        assert!((diff_pct(0.3041, 0.3744) - 18.776).abs() < 0.01);
        assert_eq!(impr_pct(0.5, 0.0), 0.0);
    }

    #[test]
    fn rebuild_stats_mean_std() {
        let mut rs = RebuildStats::default();
        for p in [0.1, 0.2, 0.3] {
            rs.push(MetricSet {
                precision: p,
                recall: p,
                f1: p,
                map: p,
            });
        }
        assert!((rs.mean().precision - 0.2).abs() < 1e-12);
        assert!((rs.std().precision - 0.1).abs() < 1e-9);
    }

    #[test]
    fn accumulator_merge_matches_sequential_pushes() {
        let sets: Vec<MetricSet> = (0..7)
            .map(|i| {
                let v = 0.1 * (i + 1) as f64;
                MetricSet {
                    precision: v,
                    recall: v / 2.0,
                    f1: v / 3.0,
                    map: v / 4.0,
                }
            })
            .collect();
        // per-batch accumulators merged in batch order == pushing each
        // batch's members then the next batch's (same fold shape)
        let mut merged = MetricAccumulator::new();
        for chunk in sets.chunks(3) {
            let mut part = MetricAccumulator::new();
            for s in chunk {
                part.push(s);
            }
            merged.merge(&part);
        }
        assert_eq!(merged.count(), 7);
        let mean = merged.mean();
        assert!((mean.precision - 0.4).abs() < 1e-12);
        assert!((mean.map - 0.1).abs() < 1e-12);
    }

    #[test]
    fn toplist_recommends_popular() {
        use crate::data::Interactions;
        // item 0 is most popular in train; user 2's test set contains it
        let train = Interactions::from_pairs(
            3,
            4,
            vec![(0, 0), (0, 1), (1, 0), (1, 2), (2, 3)],
        )
        .unwrap();
        let test = Interactions::from_pairs(3, 4, vec![(2, 0)]).unwrap();
        let m = toplist_eval(&train, &test);
        assert!(m.precision > 0.0);
        assert!(m.recall > 0.0);
    }
}
