//! `fedpayload` launcher — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`       — run one FCF training build and print the report.
//! * `experiments` — regenerate the paper's tables/figures into `--out-dir`
//!                   (`all` | `table1` | `table2` | `fig2` | `fig3` | `table4`
//!                   | `codecs` — the wire-codec payload sweep | `threads` —
//!                   the parallel-fleet scaling sweep).
//! * `trace-digest` — strip the timing objects from a `--trace-out` file,
//!                   leaving the thread-count-invariant decision trace.
//! * `journal-dump` — render a `--journal` file as the exact
//!                   `--dump-rounds` text, no retraining (the CI
//!                   determinism job re-derives the golden digest from
//!                   the journal alone with this).
//! * `info`        — print artifact manifest + config resolution.
//!
//! Common options: `--config <file.toml>`, repeated `--set path=value`
//! overrides, `--dataset <preset>`, `--strategy <bts|random|full|...>`,
//! `--backend <pjrt|reference>`, `--scale <paper|reduced|smoke>`,
//! `--log-level <debug|info|warn|error>`.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use fedpayload::cli::{resolve_config, write_round_dump, Args};
use fedpayload::experiments::{self, Scale};
use fedpayload::server::Trainer;
use fedpayload::simnet::human_bytes;
use fedpayload::telemetry;

const USAGE: &str = "\
fedpayload — payload-optimized federated recommender (FCF-BTS, RecSys'21)

USAGE:
  fedpayload train [--dataset <preset>] [--strategy <s>] [--iterations N]
                   [--payload-fraction F] [--theta N] [--theta-sample N]
                   [--seed N]
                   [--codec f64|f32|f16|int8|vq8|vq4|vq8r]
                   [--sparse-topk N|auto]
                   [--entropy none|varint|range|full]
                   [--codebook-reuse off|delta|auto]
                   [--policy uniform|budget|bandit] [--upload-delta]
                   [--threads N] [--backend pjrt|reference]
                   [--config file.toml] [--set path=value ...]
                   [--dump-rounds file.csv]
                   [--trace-out trace.jsonl] [--metrics-out metrics.prom]
                   [--trace-level off|decision|full]
                   [--journal run.jsonl] [--resume run.jsonl]
  fedpayload experiments <all|table1|table2|fig2|fig3|table4|codecs|threads>
                   [--out-dir results] [--scale paper|reduced|smoke]
                   [--backend pjrt|reference]
  fedpayload trace-digest <trace.jsonl>
  fedpayload journal-dump <run.jsonl>
  fedpayload info  [--config file.toml]
  fedpayload help

  The TCP transport lane ships as two sibling bins that accept the same
  training options: `coordinator train --listen 127.0.0.1:0 --port-file
  addr.txt --transport-clients N ...` runs the trainer with downloads,
  uploads, and batch compute moving over real sockets, and `client
  --port-file addr.txt ...` hosts one process slot's share of the fleet
  (see docs/ARCHITECTURE.md, "Transport lane"). Fault-free, the pair's
  round dumps / trace digests / journals are byte-identical to this
  bin's — ci/transport_e2e.sh enforces it.

  (--precision is an alias for --codec; `--set codec.sparse_threshold=X`
   tunes the upload sparsifier. The vq8|vq4|vq8r codecs product-quantize
   dense Q* downloads against a per-round codebook learned on the
   coordinator — uploads fall back to int8 rows. --sparse-topk auto
   picks the upload top-k per frame from the measured encoded-bytes +
   retained-energy curves instead of a fixed count. --entropy layers
   lossless entropy coding under the frame checksum: varint-coded sparse
   indices and/or range-coded payload bytes — decoded payloads are
   bit-identical to --entropy none, only the measured frame bytes shrink
   (codebook indices are low-entropy, so vq is where range coding bites
   on downloads). --codebook-reuse turns the vq codebook into a
   cross-round session resource: `delta` ships int8 centroid deltas
   against the previous generation (bit-transparent to training),
   `auto` additionally reuses the cached codebook verbatim while its
   measured reconstruction error stays in budget — clients that missed
   rounds hit a typed stale-generation signal and receive a
   full-codebook resync frame, charged to them in the ledger.
   --threads N runs each round's client batches on N parallel lanes —
   bit-identical results for any N; ci/determinism.sh diffs
   --dump-rounds records to enforce it, including int8+full, vq8+full,
   and codebook-session legs. --trace-out records the flight recorder's
   structured round events — bandit arm posteriors, codec/session
   choices with their measured-bytes rationale, per-client resyncs,
   ledger deltas — as one JSON object per line; wall-clock timings ride
   in each event's trailing `\"t\"` object, which `fedpayload
   trace-digest` strips so decision traces diff byte-identical across
   --threads values. --metrics-out rewrites a Prometheus-text snapshot
   of the decision-side counters/gauges/histograms after every round.
   --trace-level full adds per-batch fleet lane spans. --journal appends
   one checksummed JSONL record per completed round — the round's RNG
   stream position, participants, bandit selection, codec/session
   decision and state digests; --resume replays a journal from the same
   seed, verifying every recorded field, then continues training
   bit-identically to an uninterrupted run. `--resume X` alone appends
   new rounds to X in place; `--resume X --journal Y` rewrites a
   complete fresh journal at Y. The config must match the journaled
   run's determinism fingerprint. --theta-sample K draws K distinct
   participants per round from a dedicated reproducible PCG stream
   keyed by (seed, round) — the fleet-scale mode: sampling cost is
   O(K) regardless of fleet size, the draw is independent of
   --threads and of every other random stream, and the sampled ids
   are journaled so --resume replay-verifies sampled runs unchanged.
   Requires 1 <= K <= theta; unset = every round trains the classic
   theta cohort drawn from the main stream. --policy budget|bandit turns
   on per-client payload policies: every round the coordinator measures
   all four download arms (int8|vq8r|vq8|vq4), draws each participant's
   simulated bandwidth/battery budget from a dedicated reproducible
   stream, and serves each client the arm + upload top-k its budget
   affords (`budget`) or the arm a per-class Thompson bandit scored on
   measured bytes picks (`bandit`); clients whose budget fits nothing
   sit the round out. --upload-delta turns ∇Q* uploads into a SecEmb-
   style session: each client's sparse int8 rows ship as byte deltas
   against its previous upload when that measures smaller, with
   generation tags and forced full-frame resyncs on state mismatch —
   bit-transparent to training, only measured upload bytes change.)
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    if let Some(level) = args.opt("log-level") {
        match telemetry::parse_level(level) {
            Some(l) => telemetry::set_log_level(l),
            None => bail!(
                "bad --log-level `{level}` (expected one of: {})",
                telemetry::LEVEL_NAMES
            ),
        }
    }
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("experiments") => cmd_experiments(&args),
        Some("trace-digest") => cmd_trace_digest(&args),
        Some("journal-dump") => cmd_journal_dump(&args),
        Some("info") => cmd_info(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}`\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = resolve_config(args)?;
    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;
    if report.replayed_rounds > 0 {
        println!(
            "resumed: {} round(s) reconstructed by verified journal replay",
            report.replayed_rounds
        );
    }
    println!(
        "run complete: strategy={} codec={} entropy={} codebook_reuse={} iterations={} \
         M={} M_s={} ({:.0}% payload reduction)",
        report.strategy,
        report.codec,
        report.entropy,
        report.codebook_reuse,
        report.iterations,
        report.m,
        report.m_s,
        report.payload_reduction_pct()
    );
    if let Some(s) = &report.session {
        println!(
            "codebook session: {} reuse / {} delta / {} full frames, {} resyncs \
             ({:+} extra bytes)",
            s.reuse_frames, s.delta_frames, s.full_frames, s.resync_msgs, s.resync_extra_bytes
        );
    }
    if report.policy != "uniform" {
        println!(
            "payload policy: mode={} skipped_participants={}",
            report.policy, report.policy_skips
        );
    }
    if let Some(u) = &report.upload {
        println!(
            "upload session: {} full / {} delta frames, {} resyncs, {} saved",
            u.full_frames,
            u.delta_frames,
            u.resyncs,
            human_bytes(u.delta_saved_bytes)
        );
    }
    println!("final metrics (window mean): {}", report.final_metrics);
    println!(
        "traffic: down={} ({} msgs), up={} ({} msgs), simulated transfer {:.1}s",
        human_bytes(report.ledger.down_bytes),
        report.ledger.down_msgs,
        human_bytes(report.ledger.up_bytes),
        report.ledger.up_msgs,
        report.ledger.sim_secs
    );
    println!("wall time: {:.2}s; phase breakdown:", report.wall_secs);
    for (name, secs, count) in &report.phase_times {
        println!("  {name:<8} {secs:>8.3}s over {count} calls");
    }
    if let Some(path) = args.opt("dump-rounds") {
        write_round_dump(path, &report)?;
        println!("round records dumped to {path}");
    }
    if let Some(path) = &cfg.trace.out {
        println!("flight recorder: {} events traced to {path}", report.trace_events);
    }
    if let Some(path) = &cfg.trace.metrics_out {
        println!("metrics snapshot written to {path}");
    }
    if let Some(path) = cfg.journal.path.as_ref().or(cfg.journal.resume.as_ref()) {
        println!("round journal: {path}");
    }
    Ok(())
}

/// Print the decision digest of a trace file: every event line with its
/// trailing timing object stripped. The digest of a `--threads 1` run is
/// byte-identical to a `--threads N` run of the same config — the
/// determinism CI leg diffs exactly this output.
fn cmd_trace_digest(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("trace-digest expects a trace.jsonl path\n{USAGE}"))?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    print!("{}", fedpayload::telemetry::trace::trace_digest(&text));
    Ok(())
}

/// Render a round journal as the exact `--dump-rounds` text — the
/// journal-driven replay mode: no dataset, no model, no retraining.
/// `ci/determinism.sh` §7 re-derives the golden round-dump digest from
/// the journal alone through this subcommand.
fn cmd_journal_dump(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("journal-dump expects a journal.jsonl path\n{USAGE}"))?;
    let jf = fedpayload::server::journal::read(std::path::Path::new(path))?;
    if jf.torn {
        eprintln!("warning: journal `{path}` had a torn final record (dropped)");
    }
    print!("{}", fedpayload::server::journal::render_round_dump(&jf.rounds));
    Ok(())
}

fn parse_scale(args: &Args) -> Result<Scale> {
    Ok(match args.opt("scale").unwrap_or("reduced") {
        "paper" => Scale::paper(),
        "reduced" => Scale::reduced(),
        "smoke" => Scale::smoke(),
        other => bail!("bad --scale `{other}` (paper|reduced|smoke)"),
    })
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out_dir = PathBuf::from(args.opt("out-dir").unwrap_or("results"));
    let scale = parse_scale(args)?;
    let backend = args.opt("backend").unwrap_or("pjrt");
    std::fs::create_dir_all(&out_dir)?;
    match what {
        "all" => experiments::run_all(&out_dir, &scale, backend)?,
        "table1" => experiments::table1(&out_dir)?,
        "table2" => experiments::table2(&out_dir, &scale)?,
        "fig2" => {
            for ds in experiments::DATASETS {
                experiments::fig2(&out_dir, ds, &scale, backend)?;
            }
        }
        "fig3" => {
            for ds in experiments::DATASETS {
                experiments::fig3(&out_dir, ds, &scale, backend)?;
            }
        }
        "table4" => experiments::table4(&out_dir, &scale, backend)?,
        "threads" => experiments::threads_sweep(&out_dir, &scale, backend)?,
        "codecs" => {
            for ds in experiments::DATASETS {
                experiments::codec_sweep(&out_dir, ds, &scale, backend)?;
            }
        }
        other => bail!("unknown experiment `{other}`"),
    }
    println!("experiment outputs written to {}", out_dir.display());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = resolve_config(args)?;
    println!("resolved config:");
    println!("  seed               = {}", cfg.seed);
    println!(
        "  dataset            = {} ({} users x {} items, {} interactions)",
        cfg.dataset.name, cfg.dataset.users, cfg.dataset.items, cfg.dataset.interactions
    );
    println!(
        "  model              = K={} lam={} alpha={} eta={}",
        cfg.model.k, cfg.model.lam, cfg.model.alpha, cfg.model.eta
    );
    println!(
        "  bandit             = {} (mu0={}, tau0={}, gamma={})",
        cfg.bandit.strategy.name(),
        cfg.bandit.mu0,
        cfg.bandit.tau0,
        cfg.bandit.gamma
    );
    let theta_sample = match cfg.fleet.theta_sample {
        Some(k) => format!(", theta_sample={k}"),
        None => String::new(),
    };
    println!(
        "  train              = {} iters, theta={}{theta_sample}, payload_fraction={}",
        cfg.train.iterations, cfg.train.theta, cfg.train.payload_fraction
    );
    let topk = if cfg.codec.sparse_topk_auto {
        "auto".to_string()
    } else {
        cfg.codec.sparse_topk.to_string()
    };
    println!(
        "  codec              = {} (entropy={}, codebook_reuse={}, sparse_topk={topk}, \
         sparse_threshold={})",
        cfg.codec.precision.name(),
        cfg.codec.entropy.name(),
        cfg.codec.codebook_reuse.name(),
        cfg.codec.sparse_threshold
    );
    println!("  backend            = {}", cfg.runtime.backend);
    match fedpayload::runtime::Manifest::load(std::path::Path::new(&cfg.runtime.artifacts_dir)) {
        Ok(m) => {
            println!(
                "artifacts: B={} K={} tiles={:?} ({} artifacts)",
                m.b,
                m.k,
                m.tiles,
                m.artifacts.len()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}
