//! The FL server / coordinator — the paper's Algorithm 1.
//!
//! Per FL iteration t the [`Trainer`]:
//!
//! 1. asks the bandit for M_s items (Alg. 1 line 8) and assembles Q*,
//! 2. encodes Q* through the configured `wire` codec — element
//!    quantization plus the optional lossless entropy layer — and
//!    "transmits" the frame to the Θ participating clients; the clients
//!    train against the *decoded* factors and the `TrafficLedger` records
//!    the encoded frame lengths (measured payload, not the analytic
//!    formula),
//! 3. runs the client math through the AOT artifacts — Eq. 3 solve and
//!    Eq. 5–6 gradients, batched B clients per execution and dispatched
//!    across `runtime.threads` parallel lanes by the sharded fleet
//!    executor (`runtime::fleet`, one backend per worker thread); ∇Q*
//!    uploads round-trip through the sparse wire encoder per batch while
//!    the ledger records **per-client** frame lengths, and the per-batch
//!    outcomes merge in batch order so any thread count trains
//!    bit-identically,
//! 4. aggregates the Θ decoded gradients and applies server-side Adam
//!    (Eq. 4),
//! 5. updates the squared-gradient trace (Eq. 14), computes the composite
//!    reward (Eq. 13) and feeds the bandit posterior (Eq. 10–12),
//! 6. aggregates the contributing clients' test metrics into the global
//!    metric window (paper §6.2).

pub mod journal;
pub mod policy;

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::bandit::{make_selector, ItemSelector};
use crate::client::Fleet;
use crate::config::{Aggregate, RunConfig, Strategy};
use crate::data::{synthetic, Interactions, Split};
use crate::linalg::Mat;
use crate::metrics::{MetricAccumulator, MetricSet};
use crate::optim::Adam;
use crate::reward::RewardEngine;
use crate::rng::{ParticipantSampler, Rng};
use crate::runtime::fleet::{BackendFactory, FleetExecutor, RoundTask};
use crate::runtime::{make_backend, FcfRuntime, SelRow};
use crate::simnet::TrafficLedger;
use crate::telemetry::export::write_metrics_snapshot;
use crate::telemetry::registry::{BYTE_BUCKETS, REWARD_BUCKETS};
use crate::telemetry::trace::f64_bits;
use crate::telemetry::{Registry, Stopwatch, TraceEvent, TraceLevel, Tracer};
use crate::transport::lane::{ExchangeRequest, InProcessLane, RoundLane};
use crate::wire::{
    make_codec_with, EncodedDownload, PayloadCodec, SessionMode, SparsePolicy, UploadStats,
    UploadStore, VqClientState, VqSession,
};
use crate::{debug_log, info, warn_log};

use self::policy::{ArmCost, PolicyEngine, PolicyMode, ARMS};

/// Per-round record for convergence analysis (paper Figure 3).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// 1-based FL iteration.
    pub iter: usize,
    /// Items transmitted this round (M_s).
    pub m_s: usize,
    /// Mean metrics of this round's contributing clients (un-smoothed).
    pub raw: MetricSet,
    /// Mean of the last `metric_window` global metric values (§6.2).
    pub smoothed: MetricSet,
    /// Bytes moved this round (both directions, encoded frame lengths).
    pub round_bytes: u64,
}

/// Per-run counters of the cross-round codebook session
/// (`wire::vq::session`): which frame modes the coordinator shipped and
/// what client churn cost on top.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Rounds whose broadcast frame reused the cached codebook verbatim.
    pub reuse_frames: u64,
    /// Rounds whose broadcast frame shipped centroid deltas.
    pub delta_frames: u64,
    /// Rounds whose broadcast frame shipped a full codebook.
    pub full_frames: u64,
    /// Full-codebook resync messages served to stale clients.
    pub resync_msgs: u64,
    /// Σ (resync frame length − broadcast frame length) over those
    /// messages — exactly the download bytes the ledger shows above an
    /// all-clients-in-sync run (the churn e2e pins this equality).
    pub resync_extra_bytes: i64,
}

/// Everything a finished training run reports.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Item-selection strategy name (`bandit` registry name).
    pub strategy: &'static str,
    /// Wire codec the payloads moved through (`wire::Precision` name).
    pub codec: &'static str,
    /// Entropy coding mode layered on the codec (`wire::EntropyMode`
    /// name) — lossless, so it changes ledger bytes but never metrics.
    pub entropy: &'static str,
    /// Cross-round codebook session policy actually in effect
    /// (`wire::vq::session::ReuseMode` name; `off` for scalar codecs
    /// even when configured, since sessions apply to vq downloads).
    pub codebook_reuse: &'static str,
    /// Session frame/resync counters (`None` when sessions are off).
    pub session: Option<SessionStats>,
    /// Per-client payload policy in effect (`server::policy` mode name;
    /// `uniform` = the legacy single-codec path).
    pub policy: &'static str,
    /// Participants the policy sat out across the run (0 when uniform).
    pub policy_skips: u64,
    /// Upload-session counters (`None` when `codec.upload_delta` is
    /// off).
    pub upload: Option<UploadStats>,
    /// Smoothed metrics at the final iteration (the paper's headline
    /// number for a run).
    pub final_metrics: MetricSet,
    /// Per-round records in iteration order.
    pub history: Vec<RoundRecord>,
    /// Cumulative measured traffic of the run.
    pub ledger: TrafficLedger,
    /// Wall-clock seconds of the whole run.
    pub wall_secs: f64,
    /// (phase name, seconds, invocations) for the perf log.
    pub phase_times: Vec<(String, f64, u64)>,
    /// FL iterations executed.
    pub iterations: usize,
    /// Catalog size M.
    pub m: usize,
    /// Items transmitted per round M_s.
    pub m_s: usize,
    /// Structured events the flight recorder emitted (0 with tracing
    /// off).
    pub trace_events: u64,
    /// Rounds reconstructed by verified journal replay (`--resume`)
    /// rather than fresh execution — 0 for an uninterrupted run.
    pub replayed_rounds: u64,
}

impl TrainReport {
    /// Payload reduction percentage vs. the full model.
    pub fn payload_reduction_pct(&self) -> f64 {
        100.0 * (1.0 - self.m_s as f64 / self.m as f64)
    }
}

/// The coordinator for one model build.
pub struct Trainer {
    cfg: RunConfig,
    split: Split,
    fleet: Fleet,
    q: Mat,
    adam: Adam,
    selector: Box<dyn ItemSelector>,
    reward: RewardEngine,
    /// Wire codec for Q* downloads and ∇Q* uploads; the ledger records
    /// the encoded frame lengths this codec produces.
    codec: Box<dyn PayloadCodec>,
    /// Cross-round codebook session for vq downloads (`Some` when
    /// `codec.codebook_reuse` is active on a vq precision): owns the
    /// generation-tagged coordinator codebook state. Dense downloads
    /// then ship version-2 session frames; uploads are untouched.
    vq_session: Option<VqSession>,
    /// The coordinator's own mirror of an always-in-sync client
    /// decoder: every broadcast frame round-trips through it, so the
    /// clients train on exactly what a synced device would decode and
    /// encoder/decoder agreement is re-proven every round.
    vq_mirror: VqClientState,
    /// Session frame/resync counters for the report.
    session_stats: SessionStats,
    /// Per-client payload policy engine (`[policy] mode != uniform`):
    /// decides each participant's download arm, upload top-k and
    /// participation from simulated per-client budgets, scored by the
    /// measured per-arm frame bytes. `None` keeps the uniform path
    /// byte-identical to previous releases.
    policy: Option<PolicyEngine>,
    /// Coordinator half of the upload session (`codec.upload_delta`):
    /// per-client ∇Q* reference planes this round's uploads are
    /// delta-encoded against, generation-tagged against the device-side
    /// table in `client::Fleet`. `None` = stateless uploads.
    upload_store: Option<UploadStore>,
    sparse: SparsePolicy,
    /// Shared across trainers: PJRT executable compilation is expensive
    /// and xla_extension 0.5.1 does not fully release compiled programs,
    /// so experiment sweeps MUST reuse one runtime (EXPERIMENTS.md §Perf).
    /// This is the caller-lane runtime; worker lanes build their own
    /// backends through the executor's `BackendFactory`.
    runtime: Rc<RefCell<FcfRuntime>>,
    /// The round exchange lane: downloads + client compute behind the
    /// [`RoundLane`] trait. Defaults to the in-process deterministic
    /// reference (the sharded fleet executor); the `coordinator` bin
    /// installs a TCP lane that moves the same frames over sockets.
    lane: Box<dyn RoundLane>,
    rng: Rng,
    /// Dedicated per-round participant stream for `fleet.theta_sample`
    /// runs. Keyed purely by `(cfg.seed, round index)` — never consulted
    /// on the legacy (unset) path, so legacy rounds stay byte-identical
    /// and sampled draws are independent of the main stream's position.
    participant_sampler: ParticipantSampler,
    t: u64,
    metric_history: VecDeque<MetricSet>,
    ledger: TrafficLedger,
    history: Vec<RoundRecord>,
    /// Flight recorder (`telemetry::trace`): `None` keeps every
    /// emission site down to a single `Option` check per round phase.
    tracer: Option<Tracer>,
    /// Decision-side metrics registry feeding `--metrics-out`
    /// snapshots; never holds wall-clock values, so snapshots are
    /// thread-count invariant like the trace digest.
    registry: Registry,
    /// Prometheus snapshot destination, rewritten after every round.
    metrics_out: Option<std::path::PathBuf>,
    /// Round journal appender (`--journal`): one checksummed record per
    /// completed round. `None` with journaling off; appends are
    /// suppressed while replaying an existing journal in place (the
    /// records are already on disk) unless `journal_rewrite` is set.
    journal: Option<journal::JournalWriter>,
    /// Journaled rounds awaiting verified replay (`--resume`), front =
    /// next. Popped at round entry; the round then re-executes and every
    /// recorded field is checked against the fresh result.
    replay: VecDeque<journal::RoundEntry>,
    /// Rounds replayed-and-verified so far.
    replayed: u64,
    /// `--resume X --journal Y` with different paths: a complete fresh
    /// journal is being written at Y, so replayed rounds append too.
    journal_rewrite: bool,
    // reused per-round scratch
    sel_pos: Vec<i32>,
    // phase stopwatches; solve/grad/eval/codec absorb the worker lanes'
    // per-shard busy time (can exceed wall), `fleet` is the wall-clock of
    // the parallel section itself
    sw_select: Stopwatch,
    sw_stage: Stopwatch,
    sw_solve: Stopwatch,
    sw_grad: Stopwatch,
    sw_eval: Stopwatch,
    sw_update: Stopwatch,
    sw_reward: Stopwatch,
    sw_codec: Stopwatch,
    sw_fleet: Stopwatch,
}

/// What a round's mid-section (codec → exchange → barrier bookkeeping)
/// hands the common tail (Adam → rewards → metric window → journal),
/// produced by exactly one of [`Trainer::uniform_mid`] /
/// [`Trainer::policy_mid`].
struct RoundMid {
    /// This round's participant ids, in draw order.
    participants: Vec<usize>,
    /// Broadcast-bytes evidence: the single frame length on the uniform
    /// path, the summed served download bytes on the policy path.
    down_bytes: u64,
    /// The session frame shipped, when a codebook session is active
    /// (always `None` on the policy path — sessions and policies are
    /// mutually exclusive by config validation).
    session_frame: Option<EncodedDownload>,
    /// Σ decoded batch gradients over every cohort, m_s × k.
    g_total: Vec<f32>,
    /// Contributing clients' local test metrics.
    round_acc: MetricAccumulator,
    /// Clients whose uploads reached the aggregate.
    contributed: usize,
    /// Busy nanoseconds per phase summed over batches and cohorts.
    phase_ns: [u128; 4],
    /// Exchange wall-clock (0 in-process).
    transport_ns: u64,
}

impl Trainer {
    /// Build a trainer from a config: generates/loads the dataset, splits
    /// it per user, initializes the model and the backend.
    pub fn from_config(cfg: &RunConfig) -> Result<Trainer> {
        cfg.validate()?;
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let data = load_dataset(cfg, &mut rng)?;
        let split = data.split(cfg.dataset.train_frac, &mut rng);
        Trainer::with_split(cfg, split)
    }

    /// Build a trainer over a pre-made split (used by the experiment
    /// harness to compare strategies on identical data).
    pub fn with_split(cfg: &RunConfig, split: Split) -> Result<Trainer> {
        let backend = make_backend(cfg).context("building compute backend")?;
        Trainer::with_split_and_runtime(
            cfg,
            split,
            Rc::new(RefCell::new(FcfRuntime::new(backend))),
        )
    }

    /// Build a trainer over a pre-made split and a shared runtime. Use
    /// this for sweeps: one compiled runtime serves every run.
    pub fn with_split_and_runtime(
        cfg: &RunConfig,
        split: Split,
        runtime: Rc<RefCell<FcfRuntime>>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        let m = split.train.num_items();
        {
            let rt = runtime.borrow();
            anyhow::ensure!(
                rt.k == cfg.model.k,
                "artifacts compiled for K={} but config wants K={}",
                rt.k,
                cfg.model.k
            );
        }
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x5eed_f00d);
        let q = Mat::randn(m, cfg.model.k, cfg.model.init_scale, &mut rng);
        let fleet = Fleet::from_split(&split);
        info!(
            "trainer: {} users, {} items, strategy={}, backend={}, M_s={}, codec={}, \
             entropy={}, codebook_reuse={}, threads={}",
            fleet.len(),
            m,
            cfg.bandit.strategy.name(),
            runtime.borrow().backend_name(),
            cfg.selected_items(m),
            cfg.codec.precision.name(),
            cfg.codec.entropy.name(),
            cfg.codec.codebook_reuse.name(),
            cfg.runtime.threads
        );
        // lanes beyond the number of B-sized batches per round can never
        // claim work (threads > theta is the degenerate case of this)
        let round_batches = cfg.train.theta.div_ceil(runtime.borrow().b).max(1);
        if cfg.runtime.threads > round_batches {
            warn_log!(
                "runtime.threads = {} exceeds the {round_batches} client batches per round \
                 (theta = {}, B = {}); extra lanes will idle",
                cfg.runtime.threads,
                cfg.train.theta,
                runtime.borrow().b
            );
        }
        if let Some(k) = cfg.fleet.theta_sample {
            info!(
                "fleet: per-round participant sampling active, theta_sample = {k} \
                 of theta = {} (dedicated stream)",
                cfg.train.theta
            );
            if cfg.codec.codebook_reuse.is_active() && cfg.codec.precision.is_vq() {
                // With only K of Θ clients hearing each broadcast, most of
                // the fleet misses codebook installs and churns back in as
                // resync traffic — sessions still converge bit-identically,
                // but the reuse savings the mode exists for are starved.
                warn_log!(
                    "fleet.theta_sample = {k} with codec.codebook_reuse = {}: sampled \
                     rounds reach only {k}/{} clients per broadcast, so cached-codebook \
                     reuse is starved and stale participants resync often; expect extra \
                     download bytes",
                    cfg.codec.codebook_reuse.name(),
                    cfg.train.theta
                );
            }
        }
        let vq_session = if cfg.codec.codebook_reuse.is_active() {
            if cfg.codec.precision.is_vq() {
                Some(VqSession::new(
                    cfg.codec.precision,
                    cfg.codec.entropy,
                    cfg.codec.codebook_reuse,
                )?)
            } else {
                warn_log!(
                    "codec.codebook_reuse = {} has no effect on the scalar {} codec \
                     (codebook sessions apply to vq downloads); running stateless",
                    cfg.codec.codebook_reuse.name(),
                    cfg.codec.precision.name()
                );
                None
            }
        } else {
            None
        };
        let cw = match cfg.bandit.cosine_weight {
            "literal" => crate::reward::CosineWeight::Literal,
            _ => crate::reward::CosineWeight::Power,
        };
        let tb = match cfg.bandit.time_base {
            "global" => crate::reward::TimeBase::Global,
            _ => crate::reward::TimeBase::PerItem,
        };
        let tracer = match (&cfg.trace.out, cfg.trace.level) {
            (Some(path), level) if level != TraceLevel::Off => {
                Some(Tracer::to_file(std::path::Path::new(path), level)?)
            }
            _ => None,
        };
        // --journal / --resume: open the round journal and, when
        // resuming, queue the journaled rounds for verified replay.
        // Recovery is re-execution — determinism re-derives the model,
        // bandit and session state; the journal verifies every step
        // (see `server::journal` module docs).
        let fingerprint = cfg.determinism_fingerprint();
        let mut replay: VecDeque<journal::RoundEntry> = VecDeque::new();
        let mut journal_rewrite = false;
        let journal_writer = match (&cfg.journal.resume, &cfg.journal.path) {
            (Some(resume), maybe_out) => {
                let resume_path = std::path::Path::new(resume);
                let jf = journal::read(resume_path)?;
                journal::check_fingerprint(&jf.header.fingerprint, &fingerprint)?;
                let mut rounds = jf.rounds;
                if rounds.len() > cfg.train.iterations {
                    warn_log!(
                        "journal `{resume}` holds {} rounds but the run is configured \
                         for {} iterations; replaying only the first {}",
                        rounds.len(),
                        cfg.train.iterations,
                        cfg.train.iterations
                    );
                    rounds.truncate(cfg.train.iterations);
                }
                info!(
                    "resume: replaying {} journaled round(s) from `{resume}`",
                    rounds.len()
                );
                replay = rounds.into();
                match maybe_out {
                    Some(out) if out != resume => {
                        // fresh journal at a new path: replayed rounds
                        // re-append, producing a complete rewrite
                        journal_rewrite = true;
                        Some(journal::JournalWriter::create(
                            std::path::Path::new(out),
                            &fingerprint,
                        )?)
                    }
                    // same path (or no --journal): append in place past
                    // the valid prefix, dropping any torn tail
                    _ => Some(journal::JournalWriter::append_to(resume_path, jf.valid_len)?),
                }
            }
            (None, Some(out)) => Some(journal::JournalWriter::create(
                std::path::Path::new(out),
                &fingerprint,
            )?),
            (None, None) => None,
        };
        Ok(Trainer {
            selector: make_selector(cfg.bandit.strategy, m, &cfg.bandit),
            reward: RewardEngine::new(m, cfg.model.k, cfg.bandit.gamma, cfg.model.beta2 as f64)
                .with_cosine_weight(cw)
                .with_time_base(tb),
            codec: make_codec_with(cfg.codec.precision, cfg.codec.entropy),
            vq_session,
            vq_mirror: VqClientState::new(),
            session_stats: SessionStats::default(),
            policy: (cfg.policy.mode != PolicyMode::Uniform)
                .then(|| PolicyEngine::new(&cfg.policy, &cfg.simnet, cfg.seed)),
            upload_store: cfg.codec.upload_delta.then(UploadStore::new),
            sparse: SparsePolicy {
                top_k: cfg.codec.sparse_topk,
                threshold: cfg.codec.sparse_threshold as f32,
                auto_topk: cfg.codec.sparse_topk_auto,
            },
            adam: Adam::new(m, &cfg.model),
            sel_pos: vec![-1; m],
            lane: Box::new(InProcessLane::new(FleetExecutor::new(
                BackendFactory::from_config(cfg),
                cfg.runtime.threads,
            ))),
            cfg: cfg.clone(),
            split,
            fleet,
            q,
            runtime,
            rng,
            participant_sampler: ParticipantSampler::new(cfg.seed),
            t: 0,
            metric_history: VecDeque::new(),
            ledger: TrafficLedger::new(),
            history: Vec::new(),
            tracer,
            registry: Registry::new(),
            metrics_out: cfg.trace.metrics_out.as_ref().map(std::path::PathBuf::from),
            journal: journal_writer,
            replay,
            replayed: 0,
            journal_rewrite,
            sw_select: Stopwatch::new("select"),
            sw_stage: Stopwatch::new("stage"),
            sw_solve: Stopwatch::new("solve"),
            sw_grad: Stopwatch::new("grad"),
            sw_eval: Stopwatch::new("eval"),
            sw_update: Stopwatch::new("update"),
            sw_reward: Stopwatch::new("reward"),
            sw_codec: Stopwatch::new("codec"),
            sw_fleet: Stopwatch::new("fleet"),
        })
    }

    /// Global model access (diagnostics / tests).
    pub fn q(&self) -> &Mat {
        &self.q
    }

    /// The simulated client fleet (diagnostics / tests).
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// The train/test split this trainer runs on.
    pub fn split(&self) -> &Split {
        &self.split
    }

    /// Cumulative measured traffic so far (tests that step rounds
    /// manually read it between rounds; [`Trainer::run`] snapshots it
    /// into the report).
    pub fn ledger(&self) -> &TrafficLedger {
        &self.ledger
    }

    /// Per-round records completed so far (manual-stepping tests read
    /// the trajectory between rounds).
    pub fn history(&self) -> &[RoundRecord] {
        &self.history
    }

    /// Codebook-session frame/resync counters so far (all zero while
    /// sessions are off).
    pub fn session_stats(&self) -> SessionStats {
        self.session_stats
    }

    /// The coordinator's current codebook generation (`None` when
    /// sessions are off, 0 before the first download frame).
    pub fn session_generation(&self) -> Option<u32> {
        self.vq_session.as_ref().map(|s| s.generation())
    }

    /// Upload-session counters so far (`None` when `codec.upload_delta`
    /// is off).
    pub fn upload_stats(&self) -> Option<UploadStats> {
        self.upload_store.as_ref().map(|s| s.stats)
    }

    /// The coordinator's upload-reference generation for one client
    /// (`None` when upload deltas are off or the client never uploaded).
    pub fn upload_generation(&self, client: usize) -> Option<u32> {
        self.upload_store.as_ref().and_then(|s| s.generation(client))
    }

    /// Participants the payload policy sat out so far (0 when the
    /// policy layer is inert).
    pub fn policy_skips(&self) -> u64 {
        self.policy.as_ref().map_or(0, |p| p.skips())
    }

    /// Churn hook: drop one client's cached download codebook, as if
    /// the device evicted it or missed the rounds that shipped it. Its
    /// next session download arrives as a full-codebook resync frame —
    /// bit-identical decoded factors, extra ledger bytes (the churn e2e
    /// test drives this).
    pub fn invalidate_client_codebook(&mut self, client: usize) {
        self.fleet.invalidate_download_cache(client);
    }

    /// Churn hook, upload side: drop one client's device-held upload
    /// reference, as if the device evicted it. The coordinator notices
    /// the generation mismatch on the client's next upload and forces a
    /// full-frame resync — bit-identical training, extra ledger bytes
    /// (the upload-churn e2e test drives this).
    pub fn invalidate_client_upload(&mut self, client: usize) {
        self.fleet.invalidate_upload_cache(client);
    }

    /// Replace the round lane. The default is the deterministic
    /// in-process reference ([`InProcessLane`]); the `coordinator` bin
    /// installs a `transport::TcpLane` here, after which every round's
    /// downloads and client compute move over real sockets.
    pub fn install_lane(&mut self, lane: Box<dyn RoundLane>) {
        self.lane = lane;
    }

    /// The installed round lane (bins read transport stats and drive
    /// shutdown through this).
    pub fn lane_mut(&mut self) -> &mut dyn RoundLane {
        &mut *self.lane
    }

    /// Install (or replace) the flight recorder — tests and sweeps hook
    /// an in-memory tracer here; `--trace-out` installs a file-backed
    /// one at construction.
    pub fn install_tracer(&mut self, tracer: Tracer) {
        self.tracer = Some(tracer);
    }

    /// The flight recorder, if one is installed (in-memory tracers
    /// expose their captured lines through this).
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// The decision-side metrics registry (populated while a tracer or
    /// `--metrics-out` destination is active).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Is recording at `level` active right now?
    fn trace_on(&self, level: TraceLevel) -> bool {
        self.tracer.as_ref().is_some_and(|t| t.enabled(level))
    }

    /// Emit one structured event (no-op without a tracer at `level`).
    fn emit(&mut self, level: TraceLevel, event: TraceEvent) {
        if let Some(t) = self.tracer.as_mut() {
            t.emit(level, event);
        }
    }

    /// Is the metrics registry being maintained this run? True whenever
    /// either observability output is on — the registry costs a few
    /// BTreeMap updates per round, so it rides along with tracing too.
    fn registry_on(&self) -> bool {
        self.metrics_out.is_some() || self.tracer.is_some()
    }

    /// Run the configured number of FL iterations and report.
    pub fn run(&mut self) -> Result<TrainReport> {
        let t0 = std::time::Instant::now();
        let iterations = self.cfg.train.iterations;
        if self.trace_on(TraceLevel::Decision) {
            let ev = TraceEvent::new("run_start")
                .str("strategy", self.selector.name())
                .str("codec", self.codec.name())
                .str("entropy", self.codec.entropy().name())
                .str(
                    "codebook_reuse",
                    self.vq_session.as_ref().map_or("off", |s| s.mode().name()),
                )
                .u64("iterations", iterations as u64)
                .u64("theta", self.cfg.train.theta as u64)
                .u64("m", self.split.train.num_items() as u64)
                .u64("seed", self.cfg.seed)
                // thread count shapes nothing the decision trace records;
                // it lives with the wall-clock facts so t1/tN digests match
                .t_u64("threads", self.cfg.runtime.threads as u64);
            self.emit(TraceLevel::Decision, ev);
        }
        for _ in 0..iterations {
            self.round()?;
        }
        self.lane.finish().context("closing the round lane")?;
        let wall = t0.elapsed().as_secs_f64();
        if self.trace_on(TraceLevel::Decision) {
            let ev = TraceEvent::new("run_end")
                .u64("iterations", self.t)
                .u64("down_bytes", self.ledger.down_bytes)
                .u64("up_bytes", self.ledger.up_bytes)
                .u64("down_msgs", self.ledger.down_msgs)
                .u64("up_msgs", self.ledger.up_msgs)
                .bits("map_bits", self.smoothed_metrics().map)
                .t_f64("wall_secs", wall);
            self.emit(TraceLevel::Decision, ev);
        }
        if let Some(t) = self.tracer.as_mut() {
            t.flush().context("flushing trace output")?;
        }
        let m = self.split.train.num_items();
        Ok(TrainReport {
            strategy: self.selector.name(),
            codec: self.codec.name(),
            entropy: self.codec.entropy().name(),
            codebook_reuse: self.vq_session.as_ref().map_or("off", |s| s.mode().name()),
            session: self.vq_session.as_ref().map(|_| self.session_stats),
            policy: self.policy.as_ref().map_or("uniform", |p| p.mode().name()),
            policy_skips: self.policy_skips(),
            upload: self.upload_stats(),
            final_metrics: self.smoothed_metrics(),
            history: self.history.clone(),
            ledger: self.ledger.clone(),
            wall_secs: wall,
            phase_times: [
                &self.sw_select,
                &self.sw_stage,
                &self.sw_solve,
                &self.sw_grad,
                &self.sw_eval,
                &self.sw_update,
                &self.sw_reward,
                &self.sw_codec,
                &self.sw_fleet,
            ]
            .iter()
            .map(|sw| (sw.name.to_string(), sw.total_secs(), sw.count()))
            .collect(),
            iterations,
            m,
            m_s: self.cfg.selected_items(m),
            trace_events: self.tracer.as_ref().map_or(0, |t| t.events()),
            replayed_rounds: self.replayed,
        })
    }

    /// Mean of the last `metric_window` global metric values (§6.2).
    pub fn smoothed_metrics(&self) -> MetricSet {
        let mut acc = MetricAccumulator::new();
        for m in self.metric_history.iter() {
            acc.push(m);
        }
        acc.mean()
    }

    /// One FL iteration (Alg. 1 body). Public so integration tests can
    /// step the trainer manually.
    pub fn round(&mut self) -> Result<RoundRecord> {
        // journal: fingerprint the RNG stream *before* any draw — the
        // round's entry state, and the first thing replay verifies: if
        // the stream position already diverged, every downstream check
        // would fail anyway, so fail here with the sharpest signal.
        let journal_active = self.journal.is_some() || !self.replay.is_empty();
        let rng_fp = if journal_active {
            self.rng.state_fingerprint()
        } else {
            0
        };
        let expected = self.replay.pop_front();
        self.t += 1;
        if let Some(e) = &expected {
            anyhow::ensure!(
                e.iter == self.t,
                "journal replay diverged entering round {}: the journal holds round {} \
                 at this position",
                self.t,
                e.iter
            );
            anyhow::ensure!(
                e.rng_fp == rng_fp,
                "journal replay diverged entering round {}: RNG stream fingerprint \
                 {:016x} in the journal vs {rng_fp:016x} recomputed",
                self.t,
                e.rng_fp
            );
        }
        let m = self.split.train.num_items();
        let k = self.cfg.model.k;
        let m_s = match self.cfg.bandit.strategy {
            Strategy::Full => m,
            _ => self.cfg.selected_items(m),
        };

        // (1) bandit selection (Alg. 1 line 8) — sorted for staging.
        self.sw_select.start();
        let mut selected = self.selector.select(m_s, &mut self.rng);
        selected.sort_unstable();
        self.sw_select.stop();
        if self.trace_on(TraceLevel::Decision) {
            let mut ev = TraceEvent::new("bandit_select")
                .u64("iter", self.t)
                .str("strategy", self.selector.name())
                .u64("m_s", selected.len() as u64);
            // posterior summary over the arms actually selected — the
            // decision evidence the system computed but never recorded
            let mut n = 0u64;
            let (mut mu_min, mut mu_max) = (f64::INFINITY, f64::NEG_INFINITY);
            let (mut mu_sum, mut sigma_sum, mut pulls) = (0.0f64, 0.0f64, 0u64);
            for &item in &selected {
                if let Some(st) = self.selector.arm_stats(item) {
                    n += 1;
                    mu_min = mu_min.min(st.mu);
                    mu_max = mu_max.max(st.mu);
                    mu_sum += st.mu;
                    sigma_sum += st.sigma;
                    pulls += st.pulls;
                }
            }
            if n > 0 {
                ev = ev
                    .f64("mu_min", mu_min)
                    .f64("mu_mean", mu_sum / n as f64)
                    .f64("mu_max", mu_max)
                    .f64("sigma_mean", sigma_sum / n as f64)
                    .u64("pulls_total", pulls);
            }
            self.emit(TraceLevel::Decision, ev);
        }

        // (2) assemble Q* (item-major m_s × k) + position lookup.
        self.sw_stage.start();
        for p in self.sel_pos.iter_mut() {
            *p = -1;
        }
        let mut q_sel = vec![0.0f32; selected.len() * k];
        for (pos, &item) in selected.iter().enumerate() {
            self.sel_pos[item as usize] = pos as i32;
            q_sel[pos * k..(pos + 1) * k].copy_from_slice(self.q.row(item as usize));
        }
        self.sw_stage.stop();

        // Pre-exchange snapshots: everything the common tail reports as
        // per-round deltas, captured before either mid-section moves a
        // byte.
        let ledger_bytes_before = self.ledger.total_bytes();
        let down_before = self.ledger.down_bytes;
        let up_before = self.ledger.up_bytes;
        let stats_before = self.session_stats;
        let upload_before = self.upload_stats();
        let evaluate = self.t as usize % self.cfg.train.eval_every.max(1) == 0;

        // (2b–4) the mid-section forks: with a per-client policy active
        // every arm is measured once, the engine decides per participant
        // and cohorts exchange separately (`policy_mid`); otherwise the
        // uniform path runs exactly as previous releases did — policy
        // off stays byte-identical.
        let RoundMid {
            participants,
            down_bytes,
            session_frame,
            mut g_total,
            round_acc,
            contributed,
            phase_ns,
            transport_ns,
        } = if self.policy.is_some() {
            self.policy_mid(m, k, evaluate, &selected, &q_sel)?
        } else {
            self.uniform_mid(m, k, evaluate, &selected, q_sel)?
        };

        // (5) aggregate + server-side Adam (Eq. 4).
        self.sw_update.start();
        // The divisor is the clients whose uploads actually reached the
        // aggregate — identical to the participant count fault-free, and
        // the honest mean under deadline-based partial aggregation.
        if self.cfg.train.aggregate == Aggregate::Mean && contributed > 0 {
            let inv = 1.0 / contributed as f32;
            for v in g_total.iter_mut() {
                *v *= inv;
            }
        }
        self.adam.step_selected(&mut self.q, &selected, &g_total);
        self.sw_update.stop();

        // Eq. 13–14 rewards + bandit posterior update. The gradient fed
        // to the reward engine is optionally 1/Θ-scaled so reward
        // magnitudes stay commensurate with the N(0, 1/τ_θ) prior (see
        // BanditConfig::mean_scaled_rewards).
        self.sw_reward.start();
        let reward_scale = if self.cfg.bandit.mean_scaled_rewards
            && self.cfg.train.aggregate == Aggregate::Sum
            && contributed > 0
        {
            1.0 / contributed as f32
        } else {
            1.0
        };
        let mut rewards = Vec::with_capacity(selected.len());
        let mut g_row = vec![0.0f32; k];
        for (pos, &item) in selected.iter().enumerate() {
            for (dst, src) in g_row.iter_mut().zip(&g_total[pos * k..(pos + 1) * k]) {
                *dst = src * reward_scale;
            }
            let r = self.reward.observe(item, self.t, &g_row);
            rewards.push((item, r));
        }
        if self.cfg.bandit.normalize_rewards {
            standardize_rewards(&mut rewards, self.cfg.bandit.reward_std_scale);
        }
        self.selector.update(&rewards);
        self.sw_reward.stop();
        if self.trace_on(TraceLevel::Decision) {
            let n = rewards.len();
            let mut ev = TraceEvent::new("reward_update")
                .u64("iter", self.t)
                .u64("n", n as u64)
                .bool("standardized", self.cfg.bandit.normalize_rewards);
            if n > 0 {
                let (mut lo, mut hi, mut sum) = (f64::INFINITY, f64::NEG_INFINITY, 0.0f64);
                for &(_, r) in &rewards {
                    lo = lo.min(r);
                    hi = hi.max(r);
                    sum += r;
                }
                ev = ev
                    .f64("r_min", lo)
                    .f64("r_mean", sum / n as f64)
                    .f64("r_max", hi);
            }
            self.emit(TraceLevel::Decision, ev);
        }
        if self.registry_on() {
            for &(_, r) in &rewards {
                self.registry
                    .observe("fedpayload_reward_abs", REWARD_BUCKETS, r.abs());
            }
        }

        // global metric window (§6.2)
        let raw = round_acc.mean();
        if evaluate && round_acc.count() > 0 {
            if self.metric_history.len() == self.cfg.train.metric_window {
                self.metric_history.pop_front();
            }
            self.metric_history.push_back(raw);
        }
        let record = RoundRecord {
            iter: self.t as usize,
            m_s: selected.len(),
            raw,
            smoothed: self.smoothed_metrics(),
            round_bytes: self.ledger.total_bytes() - ledger_bytes_before,
        };
        debug_log!(
            "iter {} m_s={} raw={} smoothed={}",
            record.iter,
            record.m_s,
            record.raw,
            record.smoothed
        );
        // Upload-session evidence, delta runs only (uniform non-delta
        // rounds must emit the exact legacy event set — the trace-count
        // tests pin it).
        if let (Some(before), Some(stats)) = (upload_before, self.upload_stats()) {
            if self.trace_on(TraceLevel::Decision) {
                let ev = TraceEvent::new("upload_plan")
                    .u64("iter", self.t)
                    .u64("full_frames", stats.full_frames - before.full_frames)
                    .u64("delta_frames", stats.delta_frames - before.delta_frames)
                    .u64("resyncs", stats.resyncs - before.resyncs)
                    .u64(
                        "saved_bytes",
                        stats.delta_saved_bytes - before.delta_saved_bytes,
                    );
                self.emit(TraceLevel::Decision, ev);
            }
            if self.registry_on() {
                self.registry.inc(
                    "fedpayload_upload_delta_frames_total",
                    stats.delta_frames - before.delta_frames,
                );
                self.registry.inc(
                    "fedpayload_upload_resyncs_total",
                    stats.resyncs - before.resyncs,
                );
                self.registry.set_gauge(
                    "fedpayload_upload_delta_saved_bytes",
                    stats.delta_saved_bytes as f64,
                );
            }
        }
        if self.trace_on(TraceLevel::Decision) {
            let ev = TraceEvent::new("round_end")
                .u64("iter", self.t)
                .u64("m_s", record.m_s as u64)
                .u64("round_bytes", record.round_bytes)
                .u64("down_bytes", self.ledger.down_bytes - down_before)
                .u64("up_bytes", self.ledger.up_bytes - up_before)
                .bool("evaluated", evaluate)
                .u64("eval_clients", round_acc.count() as u64)
                .bits("raw_map_bits", record.raw.map)
                .bits("smoothed_map_bits", record.smoothed.map)
                .t_u128("solve_ns", phase_ns[0])
                .t_u128("grad_ns", phase_ns[1])
                .t_u128("codec_ns", phase_ns[2])
                .t_u128("eval_ns", phase_ns[3])
                // exchange wall-clock: 0 in-process, socket time over TCP
                // — a timing fact, quarantined with the other `"t"` fields
                .t_u64("exchange_ns", transport_ns);
            self.emit(TraceLevel::Decision, ev);
        }
        if self.registry_on() {
            self.registry.inc("fedpayload_rounds_total", 1);
            self.registry
                .inc("fedpayload_down_bytes_total", self.ledger.down_bytes - down_before);
            self.registry
                .inc("fedpayload_up_bytes_total", self.ledger.up_bytes - up_before);
            self.registry
                .observe("fedpayload_down_frame_bytes", BYTE_BUCKETS, down_bytes as f64);
            self.registry.set_gauge("fedpayload_smoothed_map", record.smoothed.map);
            if let Some(enc) = &session_frame {
                let key = format!(
                    "fedpayload_session_frames_total{{mode=\"{}\"}}",
                    enc.mode.name()
                );
                self.registry.inc(&key, 1);
                self.registry
                    .inc(
                        "fedpayload_session_resyncs_total",
                        self.session_stats.resync_msgs - stats_before.resync_msgs,
                    );
                self.registry.set_gauge(
                    "fedpayload_session_resync_extra_bytes",
                    self.session_stats.resync_extra_bytes as f64,
                );
                self.registry
                    .set_gauge("fedpayload_session_generation", f64::from(enc.generation));
                self.registry.set_gauge(
                    "fedpayload_session_synced_clients",
                    self.fleet.synced_clients() as f64,
                );
            }
            if let Some(path) = self.metrics_out.clone() {
                write_metrics_snapshot(&path, &self.registry, self.t as usize)
                    .context("writing metrics snapshot")?;
            }
        }
        if journal_active {
            let entry = journal::RoundEntry {
                iter: self.t,
                rng_fp,
                participants: participants.iter().map(|&c| c as u64).collect(),
                selected: selected.iter().map(|&i| u64::from(i)).collect(),
                frame_bytes: down_bytes,
                session_mode: session_frame.as_ref().map(|e| e.mode.name().to_string()),
                generation: session_frame.as_ref().map(|e| u64::from(e.generation)),
                installs: session_frame.as_ref().map(|e| e.installs_generation),
                resync_msgs: self.session_stats.resync_msgs,
                resync_extra: self.session_stats.resync_extra_bytes,
                evaluated: evaluate,
                eval_clients: round_acc.count() as u64,
                m_s: record.m_s as u64,
                raw_bits: [
                    record.raw.precision.to_bits(),
                    record.raw.recall.to_bits(),
                    record.raw.f1.to_bits(),
                    record.raw.map.to_bits(),
                ],
                smoothed_bits: [
                    record.smoothed.precision.to_bits(),
                    record.smoothed.recall.to_bits(),
                    record.smoothed.f1.to_bits(),
                    record.smoothed.map.to_bits(),
                ],
                round_bytes: record.round_bytes,
                down_bytes: self.ledger.down_bytes,
                up_bytes: self.ledger.up_bytes,
                down_msgs: self.ledger.down_msgs,
                up_msgs: self.ledger.up_msgs,
                sim_secs_bits: self.ledger.sim_secs.to_bits(),
                bandit_digest: self.selector.state_digest(),
                session_digest: self.vq_session.as_ref().map(|s| s.state_digest()),
                policy_mode: self.policy.as_ref().map(|p| p.mode().name().to_string()),
                policy_skips: self.policy.as_ref().map(|p| p.skips()),
                policy_digest: self.policy.as_ref().map(|p| p.state_digest()),
                up_full: self.upload_store.as_ref().map(|s| s.stats.full_frames),
                up_delta: self.upload_store.as_ref().map(|s| s.stats.delta_frames),
                up_resyncs: self.upload_store.as_ref().map(|s| s.stats.resyncs),
                upload_digest: self.upload_store.as_ref().map(|s| s.state_digest()),
            };
            match expected {
                // replayed round: verify every recorded field against
                // the fresh re-execution; append only when rewriting the
                // journal to a new path (in-place resume already holds
                // these records)
                Some(journaled) => {
                    journal::verify_round(&journaled, &entry)?;
                    self.replayed += 1;
                    if self.journal_rewrite {
                        if let Some(j) = self.journal.as_mut() {
                            j.append(&entry).context("appending journal record")?;
                        }
                    }
                }
                None => {
                    if let Some(j) = self.journal.as_mut() {
                        j.append(&entry).context("appending journal record")?;
                    }
                }
            }
        }
        self.history.push(record.clone());
        Ok(record)
    }

    /// The uniform arm of the round mid-section — the legacy single-codec
    /// path, moved verbatim out of [`Trainer::round`] when the policy
    /// layer landed. Policy-off runs MUST stay byte-identical to previous
    /// releases, so nothing here may reorder RNG draws or ledger records.
    fn uniform_mid(
        &mut self,
        m: usize,
        k: usize,
        evaluate: bool,
        selected: &[u32],
        q_sel: Vec<f32>,
    ) -> Result<RoundMid> {
        // (2b) put Q* on the wire: encode the download frame, then train
        // the clients against the *decoded* factors, so a lossy codec's
        // quantization error flows into the round exactly as it would on
        // real devices. The ledger records the encoded frame length.
        // With a codebook session active, the dense download goes
        // through the stateful session encoder (version-2 frames) and
        // the coordinator's mirror decoder — an always-in-sync client —
        // supplies the decoded factors.
        self.sw_codec.start();
        let (q_sel, down_bytes, session_frame, stateless_frame) = match self.vq_session.as_mut() {
            Some(sess) => {
                let enc = sess.encode_dense(&q_sel, selected.len(), k)?;
                let down = self
                    .vq_mirror
                    .decode_dense(&enc.frame)?
                    .into_data()
                    .context("coordinator mirror decoder fell out of sync (bug)")?;
                anyhow::ensure!(
                    down.rows == selected.len() && down.cols == k,
                    "session frame decoded to {}x{}, expected {}x{k}",
                    down.rows,
                    down.cols,
                    selected.len()
                );
                let len = enc.frame.len() as u64;
                (down.data, len, Some(enc), None)
            }
            None => {
                let down_frame = self.codec.encode_dense(&q_sel, selected.len(), k)?;
                let down = self.codec.decode_dense(&down_frame)?;
                anyhow::ensure!(
                    down.rows == selected.len() && down.cols == k,
                    "download frame decoded to {}x{}, expected {}x{k}",
                    down.rows,
                    down.cols,
                    selected.len()
                );
                (down.data, down_frame.len() as u64, None, Some(down_frame))
            }
        };
        self.sw_codec.stop();
        if self.trace_on(TraceLevel::Decision) {
            let mut ev = TraceEvent::new("codec_choice")
                .u64("iter", self.t)
                .str("codec", self.codec.name())
                .str("entropy", self.codec.entropy().name())
                .u64("frame_bytes", down_bytes);
            match &session_frame {
                Some(enc) => {
                    // the mode actually shipped plus the measured-bytes /
                    // SSE-budget evidence the session weighed to pick it
                    ev = ev
                        .str("kind", "session")
                        .str("mode", enc.mode.name())
                        .u64("generation", enc.generation as u64)
                        .bool("installs", enc.installs_generation)
                        .opt_u64("full_bytes", enc.rationale.full_bytes)
                        .opt_u64("delta_bytes", enc.rationale.delta_bytes)
                        .opt_u64("reuse_bytes", enc.rationale.reuse_bytes)
                        .f64("sse_fresh", enc.rationale.sse_fresh)
                        .opt_f64("sse_reuse", enc.rationale.sse_reuse)
                        .opt_bool("reuse_within_budget", enc.rationale.reuse_within_budget);
                }
                None => ev = ev.str("kind", "stateless"),
            }
            self.emit(TraceLevel::Decision, ev);
        }

        // (3) participants + download accounting. Under a codebook
        // session, a participant whose cached generation cannot decode
        // the broadcast frame is served a full-codebook **resync**
        // frame instead — decoding to bit-identical factors (verified
        // below), so churn shows up only in the ledger, never in the
        // training trajectory.
        // `theta_sample` draws from the dedicated per-round stream and
        // must never touch `self.rng`; the legacy path must never touch
        // the sampler — either way the other stream's position is
        // unaffected, which is what keeps old journals and goldens valid.
        let participants = match self.cfg.fleet.theta_sample {
            Some(k) => self
                .participant_sampler
                .sample_round(self.t, self.fleet.len(), k),
            None => self
                .fleet
                .sample_participants(self.cfg.train.theta, &mut self.rng),
        };
        if let Some(enc) = &session_frame {
            match enc.mode {
                SessionMode::Reuse => self.session_stats.reuse_frames += 1,
                SessionMode::Delta => self.session_stats.delta_frames += 1,
                SessionMode::Full => self.session_stats.full_frames += 1,
            }
        }

        // (4) client compute: B-sized batches dispatched across the
        // sharded fleet executor's lanes. Each worker owns its own
        // backend; per-batch outcomes (decoded batch ∇Q* after the
        // sparse wire round-trip, solved factors, per-client upload
        // frames, eval metrics) merge in batch-index order, so any
        // `runtime.threads` value produces bit-identical rounds. Also
        // (6): contributing clients' local test metrics (§6.2) are
        // computed in the lanes — the recommendation x* = p_i^T Q uses
        // the full current global model (inference-time download; see
        // DESIGN.md §1).
        let b = self.runtime.borrow().b;
        self.sw_stage.start();
        let rows: Vec<SelRow> = participants
            .iter()
            .map(|&cid| self.fleet.client(cid).selected_row(&self.sel_pos))
            .collect();
        self.sw_stage.stop();
        let task = RoundTask {
            q_sel: q_sel.clone(),
            k,
            m,
            q_full: if evaluate {
                self.q.data().to_vec()
            } else {
                Vec::new()
            },
            evaluate,
            rows,
            client_ids: participants.clone(),
            batch: b,
            precision: self.codec.precision(),
            entropy: self.codec.entropy(),
            sparse: self.sparse,
            simnet: self.cfg.simnet.clone(),
            fleet: self.fleet.view(),
            collect_up_frames: self.upload_store.is_some(),
        };
        // The exchange moves the round through the installed lane:
        // in-process, downloads are generation-table lookups and compute
        // runs on the sharded executor; over TCP, the same frames travel
        // as real messages to client processes. Either way the lane only
        // reports *what moved* — every piece of bookkeeping is applied
        // below, from the records, in participant/batch order, so the
        // two lanes cannot drift in accounting.
        let req = ExchangeRequest {
            iter: self.t,
            participants: &participants,
            selected,
            frame: match (&session_frame, &stateless_frame) {
                (Some(enc), _) => &enc.frame,
                (None, Some(f)) => f,
                (None, None) => unreachable!("one of the frame arms always binds"),
            },
            down_bytes,
            session: match (&self.vq_session, &session_frame) {
                (Some(s), Some(e)) => Some((s, e)),
                _ => None,
            },
            q_sel: &q_sel,
            fleet: &self.fleet,
            task,
        };
        self.sw_fleet.start();
        let ex = self
            .lane
            .exchange(req, &mut self.runtime.borrow_mut(), self.codec.as_ref())?;
        self.sw_fleet.stop();

        // Session bookkeeping from the outcome records. Rejoin-driven
        // invalidations first (the lane already treated those clients as
        // cache-less this round), then per-download accounting exactly as
        // the pre-transport loop did: resync stats + trace, ledger,
        // generation installs — in participant order.
        for &cid in &ex.invalidated {
            self.fleet.invalidate_download_cache(cid);
        }
        match &session_frame {
            Some(enc) => {
                for rec in &ex.downloads {
                    if rec.resync {
                        self.session_stats.resync_msgs += 1;
                        self.session_stats.resync_extra_bytes +=
                            rec.bytes as i64 - down_bytes as i64;
                        if self.trace_on(TraceLevel::Decision) {
                            let ev = TraceEvent::new("resync")
                                .u64("iter", self.t)
                                .u64("client", rec.client as u64)
                                .opt_u64("cached", rec.cached.map(u64::from))
                                .u64("generation", enc.generation as u64)
                                .u64("frame_bytes", rec.bytes)
                                .i64("extra_bytes", rec.bytes as i64 - down_bytes as i64);
                            self.emit(TraceLevel::Decision, ev);
                        }
                    }
                    self.ledger.record_down(&self.cfg.simnet, rec.bytes);
                    // empty frames install no codebook on the device, so
                    // they must not be recorded as a held generation
                    if enc.installs_generation {
                        self.fleet.set_download_gen(rec.client, enc.generation);
                    }
                }
            }
            None => {
                for rec in &ex.downloads {
                    self.ledger.record_down(&self.cfg.simnet, rec.bytes);
                }
            }
        }
        // Dropout is a transport fact: the event and counter exist only
        // when clients actually dropped, so fault-free trace digests stay
        // byte-identical across lanes.
        if !ex.dropped.is_empty() {
            if self.trace_on(TraceLevel::Decision) {
                let ids = ex
                    .dropped
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                let ev = TraceEvent::new("transport_dropout")
                    .u64("iter", self.t)
                    .u64("n", ex.dropped.len() as u64)
                    .u64("contributed", ex.contributed as u64)
                    .str("clients", &ids);
                self.emit(TraceLevel::Decision, ev);
            }
            if self.registry_on() {
                self.registry.inc(
                    "fedpayload_transport_dropped_clients_total",
                    ex.dropped.len() as u64,
                );
            }
        }
        let agg = ex.agg;
        let n_batches = agg.batches.len() as u64;
        // absorb the lanes' per-shard busy time into the phase stopwatches
        self.sw_solve.absorb_ns(agg.phase_ns[0], n_batches);
        self.sw_grad.absorb_ns(agg.phase_ns[1], n_batches);
        self.sw_codec.absorb_ns(agg.phase_ns[2], n_batches);
        self.sw_eval.absorb_ns(agg.phase_ns[3], if evaluate { n_batches } else { 0 });
        // per-lane spans, absorbed at the batch-order barrier: batch
        // index and client count are decisions (identical at any thread
        // count); the lane that ran the batch and its busy nanoseconds
        // are wall-clock facts and ride in the timing-only object
        if self.trace_on(TraceLevel::Full) {
            for bs in &agg.batches {
                let ev = TraceEvent::new("lane_span")
                    .u64("iter", self.t)
                    .u64("batch", bs.batch as u64)
                    .u64("clients", bs.clients as u64)
                    .t_u64("lane", bs.lane as u64)
                    .t_u128("solve_ns", bs.phase_ns[0])
                    .t_u128("grad_ns", bs.phase_ns[1])
                    .t_u128("codec_ns", bs.phase_ns[2])
                    .t_u128("eval_ns", bs.phase_ns[3]);
                self.emit(TraceLevel::Full, ev);
            }
        }
        // barrier merge: upload ledger (per-client frames), local factors
        // (flat slot buffer — no per-participant allocation crosses here)
        self.ledger.merge(&agg.ledger);
        // upload-delta runs carried the batch frames through the barrier
        // instead of batch-level ledger records: attribute the exact
        // per-client session-frame bytes now, in participant order
        if self.upload_store.is_some() {
            self.attribute_uploads(selected, &participants, b, &agg.up_frames)?;
        }
        for (i, &cid) in agg.factor_ids.iter().enumerate() {
            self.fleet.set_factors(cid, &agg.factors[i * k..(i + 1) * k]);
        }
        Ok(RoundMid {
            participants,
            down_bytes,
            session_frame,
            g_total: agg.grad,
            round_acc: agg.metrics,
            contributed: ex.contributed,
            phase_ns: agg.phase_ns,
            transport_ns: ex.transport_ns,
        })
    }

    /// The policy arm of the round mid-section (`[policy] mode !=
    /// uniform`): encode and measure every precision arm once, let the
    /// engine decide each participant's arm / top-k / participation,
    /// then run one exchange per (arm, top-k) cohort and fold the
    /// outcomes in fixed cohort order — participant order inside a
    /// cohort and cohort order across the round are both deterministic,
    /// so policy rounds stay thread- and lane-invariant. Skipped
    /// participants move no bytes and contribute no gradient: the round
    /// simply trains on fewer clients.
    fn policy_mid(
        &mut self,
        m: usize,
        k: usize,
        evaluate: bool,
        selected: &[u32],
        q_raw: &[f32],
    ) -> Result<RoundMid> {
        let m_s = selected.len();
        // participants come off the exact same streams as the uniform
        // path (see the stream-discipline note there)
        let participants = match self.cfg.fleet.theta_sample {
            Some(n) => self
                .participant_sampler
                .sample_round(self.t, self.fleet.len(), n),
            None => self
                .fleet
                .sample_participants(self.cfg.train.theta, &mut self.rng),
        };

        // measure every arm once — encoded dense frame length + decode
        // SSE against the staged f32 Q*: the evidence both policy modes
        // (and the trace) decide from, and the decoded factors each
        // cohort trains against
        self.sw_codec.start();
        let mut arm_frames: Vec<Vec<u8>> = Vec::with_capacity(ARMS.len());
        let mut arm_decoded: Vec<Vec<f32>> = Vec::with_capacity(ARMS.len());
        let mut costs = [ArmCost::default(); ARMS.len()];
        for (a, &prec) in ARMS.iter().enumerate() {
            let codec = make_codec_with(prec, self.cfg.codec.entropy);
            let frame = codec.encode_dense(q_raw, m_s, k)?;
            let dec = codec.decode_dense(&frame)?;
            anyhow::ensure!(
                dec.rows == m_s && dec.cols == k,
                "arm {} frame decoded to {}x{}, expected {m_s}x{k}",
                prec.name(),
                dec.rows,
                dec.cols
            );
            let sse = q_raw
                .iter()
                .zip(&dec.data)
                .map(|(&x, &y)| (f64::from(x) - f64::from(y)).powi(2))
                .sum::<f64>();
            costs[a] = ArmCost {
                frame_bytes: frame.len() as u64,
                sse,
            };
            arm_frames.push(frame);
            arm_decoded.push(dec.data);
        }
        self.sw_codec.stop();

        let engine = self
            .policy
            .as_mut()
            .expect("policy_mid requires an engine");
        let decisions = engine.decide(self.t, &participants, &costs, m_s, k);
        let policy_mode = engine.mode();
        // cohorts keyed (arm, top-k) in BTreeMap order: the fold below
        // must not depend on participant order across cohorts
        let mut cohorts: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        let mut skipped = 0u64;
        for d in &decisions {
            match d.arm {
                Some(a) => cohorts.entry((a, d.top_k)).or_default().push(d.client),
                None => skipped += 1,
            }
        }
        if self.trace_on(TraceLevel::Decision) {
            let mut ev = TraceEvent::new("policy_decide")
                .u64("iter", self.t)
                .str("mode", policy_mode.name())
                .u64("participants", participants.len() as u64)
                .u64("skipped", skipped)
                .u64("cohorts", cohorts.len() as u64);
            // per-arm bytes rationale: who ships what, and what each arm
            // measured this round
            for (a, c) in costs.iter().enumerate() {
                let n: u64 = cohorts
                    .iter()
                    .filter(|((arm, _), _)| *arm == a)
                    .map(|(_, v)| v.len() as u64)
                    .sum();
                ev = ev
                    .u64(&format!("n_{}", ARMS[a].name()), n)
                    .u64(&format!("bytes_{}", ARMS[a].name()), c.frame_bytes)
                    .bits(&format!("sse_{}_bits", ARMS[a].name()), c.sse);
            }
            self.emit(TraceLevel::Decision, ev);
        }
        if self.registry_on() {
            self.registry.inc("fedpayload_policy_skipped_total", skipped);
        }

        // one exchange per cohort, folded in cohort order
        let b = self.runtime.borrow().b;
        let mut g_total = vec![0.0f32; m_s * k];
        let mut round_acc = MetricAccumulator::new();
        let mut contributed = 0usize;
        let mut phase_ns = [0u128; 4];
        let mut transport_ns = 0u64;
        let mut down_bytes = 0u64;
        for (&(arm, top_k), clients) in &cohorts {
            self.sw_stage.start();
            let rows: Vec<SelRow> = clients
                .iter()
                .map(|&cid| self.fleet.client(cid).selected_row(&self.sel_pos))
                .collect();
            self.sw_stage.stop();
            let task = RoundTask {
                q_sel: arm_decoded[arm].clone(),
                k,
                m,
                q_full: if evaluate {
                    self.q.data().to_vec()
                } else {
                    Vec::new()
                },
                evaluate,
                rows,
                client_ids: clients.clone(),
                batch: b,
                precision: ARMS[arm],
                entropy: self.cfg.codec.entropy,
                sparse: SparsePolicy {
                    top_k,
                    threshold: self.cfg.codec.sparse_threshold as f32,
                    auto_topk: false,
                },
                simnet: self.cfg.simnet.clone(),
                fleet: self.fleet.view(),
                collect_up_frames: self.upload_store.is_some(),
            };
            let req = ExchangeRequest {
                iter: self.t,
                participants: clients,
                selected,
                frame: &arm_frames[arm],
                down_bytes: costs[arm].frame_bytes,
                session: None,
                q_sel: &arm_decoded[arm],
                fleet: &self.fleet,
                task,
            };
            let cohort_codec = make_codec_with(ARMS[arm], self.cfg.codec.entropy);
            self.sw_fleet.start();
            let ex = self
                .lane
                .exchange(req, &mut self.runtime.borrow_mut(), cohort_codec.as_ref())?;
            self.sw_fleet.stop();
            for &cid in &ex.invalidated {
                self.fleet.invalidate_download_cache(cid);
            }
            for rec in &ex.downloads {
                self.ledger.record_down(&self.cfg.simnet, rec.bytes);
                down_bytes += rec.bytes;
            }
            let agg = ex.agg;
            let n_batches = agg.batches.len() as u64;
            self.sw_solve.absorb_ns(agg.phase_ns[0], n_batches);
            self.sw_grad.absorb_ns(agg.phase_ns[1], n_batches);
            self.sw_codec.absorb_ns(agg.phase_ns[2], n_batches);
            self.sw_eval.absorb_ns(agg.phase_ns[3], if evaluate { n_batches } else { 0 });
            for (dst, &ns) in phase_ns.iter_mut().zip(&agg.phase_ns) {
                *dst += ns;
            }
            self.ledger.merge(&agg.ledger);
            if self.upload_store.is_some() {
                self.attribute_uploads(selected, clients, b, &agg.up_frames)?;
            }
            for (i, &cid) in agg.factor_ids.iter().enumerate() {
                self.fleet.set_factors(cid, &agg.factors[i * k..(i + 1) * k]);
            }
            round_acc.merge(&agg.metrics);
            for (dst, &src) in g_total.iter_mut().zip(&agg.grad) {
                *dst += src;
            }
            contributed += ex.contributed;
            transport_ns += ex.transport_ns;
        }
        Ok(RoundMid {
            participants,
            down_bytes,
            session_frame: None,
            g_total,
            round_acc,
            contributed,
            phase_ns,
            transport_ns,
        })
    }

    /// Upload-delta attribution for one exchange's carried batch frames:
    /// parse each batch's raw ∇Q* value plane once (byte-lossless — no
    /// re-quantization), then re-frame it per client against that
    /// client's reference — forced full on device/server generation
    /// mismatch (a **resync**), otherwise whichever of full/delta
    /// measures smaller — and record the exact session-frame length. The
    /// mirror decode re-proves byte-exact reconstruction every time, so
    /// delta mode can never change training, only ledger bytes.
    fn attribute_uploads(
        &mut self,
        selected: &[u32],
        clients: &[usize],
        batch: usize,
        up_frames: &[Vec<u8>],
    ) -> Result<()> {
        let entropy = self.cfg.codec.entropy;
        anyhow::ensure!(batch > 0, "attribute_uploads: batch width must be > 0");
        anyhow::ensure!(
            up_frames.len() == clients.len().div_ceil(batch),
            "upload-delta: {} batch frames carried for {} clients at batch width {batch}",
            up_frames.len(),
            clients.len()
        );
        let store = self
            .upload_store
            .as_mut()
            .expect("attribute_uploads requires the store");
        for (i, frame) in up_frames.iter().enumerate() {
            let plane = crate::wire::upload::plane_of_batch_frame(frame, selected)?;
            let lo = i * batch;
            let hi = ((i + 1) * batch).min(clients.len());
            for &cid in &clients[lo..hi] {
                let device = self.fleet.upload_gen(cid);
                let server = store.generation(cid);
                let resync = device != server;
                let reference = if resync { None } else { store.reference(cid) };
                let enc = crate::wire::upload::encode_upload(&plane, entropy, reference)?;
                match crate::wire::upload::decode_upload(&enc.frame, reference)? {
                    crate::wire::upload::UploadDecode::Data(ref p) if *p == plane => {}
                    other => anyhow::bail!(
                        "upload session frame for client {cid} failed to reconstruct \
                         its plane (bug): {other:?}"
                    ),
                }
                self.ledger.record_up(&self.cfg.simnet, enc.frame.len() as u64);
                if resync {
                    store.stats.resyncs += 1;
                }
                match enc.mode {
                    SessionMode::Delta => {
                        store.stats.delta_frames += 1;
                        store.stats.delta_saved_bytes += enc.full_bytes - enc.frame.len() as u64;
                    }
                    _ => store.stats.full_frames += 1,
                }
                store.install(cid, &plane, enc.generation);
                self.fleet.set_upload_gen(cid, enc.generation);
            }
        }
        Ok(())
    }
}

/// Render a report's per-round records and ledger totals with full bit
/// precision (f64 metric values as hex bit patterns), one CSV row per
/// round plus a totals line. This string is the unit of bit-exact
/// trajectory comparison: `--dump-rounds` writes it, the CI determinism
/// job diffs it across `--threads` values, and the golden-trajectory
/// fixtures under `rust/tests/golden/` pin it across commits — sharing
/// one renderer is what keeps those three nets equivalent.
pub fn round_dump_string(report: &TrainReport) -> String {
    let mut text = String::from(
        "iter,m_s,raw_precision,raw_recall,raw_f1,raw_map,\
         smoothed_precision,smoothed_recall,smoothed_f1,smoothed_map,round_bytes\n",
    );
    for r in &report.history {
        text.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{}\n",
            r.iter,
            r.m_s,
            f64_bits(r.raw.precision),
            f64_bits(r.raw.recall),
            f64_bits(r.raw.f1),
            f64_bits(r.raw.map),
            f64_bits(r.smoothed.precision),
            f64_bits(r.smoothed.recall),
            f64_bits(r.smoothed.f1),
            f64_bits(r.smoothed.map),
            r.round_bytes,
        ));
    }
    text.push_str(&format!(
        "totals,down_bytes={},up_bytes={},down_msgs={},up_msgs={},sim_secs_bits={}\n",
        report.ledger.down_bytes,
        report.ledger.up_bytes,
        report.ledger.down_msgs,
        report.ledger.up_msgs,
        f64_bits(report.ledger.sim_secs),
    ));
    text
}

/// Standardize one round's rewards to zero mean / `scale` standard
/// deviation (keeps the within-round ordering; calibrates the magnitude
/// to the BTS prior — see `BanditConfig::reward_std_scale`).
pub fn standardize_rewards(rewards: &mut [(u32, f64)], scale: f64) {
    let n = rewards.len();
    if n < 2 {
        return;
    }
    let mean = rewards.iter().map(|(_, r)| r).sum::<f64>() / n as f64;
    let var = rewards
        .iter()
        .map(|(_, r)| (r - mean).powi(2))
        .sum::<f64>()
        / n as f64;
    let sd = var.sqrt().max(1e-12);
    for (_, r) in rewards.iter_mut() {
        *r = (*r - mean) / sd * scale;
    }
}

/// Load or synthesize the configured dataset.
pub fn load_dataset(cfg: &RunConfig, rng: &mut Rng) -> Result<Interactions> {
    let data = match cfg.dataset.name.as_str() {
        "file" => {
            let path = cfg
                .dataset
                .path
                .as_ref()
                .context("dataset.name = \"file\" requires dataset.path")?;
            let format = cfg
                .dataset
                .format
                .as_ref()
                .context("dataset.name = \"file\" requires dataset.format")?;
            crate::data::loaders::load(format, path)?
        }
        _ => synthetic::generate(&cfg.dataset, rng),
    };
    let data = if cfg.dataset.min_user_interactions > 0 {
        data.filter_min_user_interactions(cfg.dataset.min_user_interactions)
    } else {
        data
    };
    info!("dataset `{}`: {}", cfg.dataset.name, data.stats());
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> RunConfig {
        let mut cfg = RunConfig::paper_defaults();
        cfg.apply_dataset_preset("synthetic-small").unwrap();
        cfg.dataset.users = 48;
        cfg.dataset.items = 96;
        cfg.dataset.interactions = 900;
        cfg.train.theta = 16;
        cfg.train.iterations = 4;
        cfg.train.payload_fraction = 0.25;
        cfg.runtime.backend = "reference".into();
        cfg
    }

    #[test]
    fn trainer_runs_rounds_and_reports() {
        let cfg = tiny_cfg();
        let mut tr = Trainer::from_config(&cfg).unwrap();
        let report = tr.run().unwrap();
        assert_eq!(report.history.len(), 4);
        assert_eq!(report.strategy, "bts");
        assert_eq!(report.codec, "f32");
        assert_eq!(report.entropy, "none");
        assert_eq!(report.m, 96);
        assert_eq!(report.m_s, 24);
        assert!((report.payload_reduction_pct() - 75.0).abs() < 1e-9);
        // payload accounting: 4 rounds × 16 participants × 2 directions,
        // byte counts are the encoded frame lengths the codec produced
        assert_eq!(report.ledger.down_msgs, 64);
        assert_eq!(report.ledger.up_msgs, 64);
        let down_frame = crate::wire::encoded_dense_len(24, 25, crate::wire::Precision::F32);
        assert_eq!(report.ledger.down_bytes, 64 * down_frame as u64);
        // uploads: one message per client at the batch frame's length
        // (exact per-client attribution — the dense implicit-feedback
        // ∇Q* makes every client's frame the batch frame; see
        // runtime::fleet docs); at most m_s rows survive per frame
        let up_max = crate::wire::encoded_sparse_len(24, 25, crate::wire::Precision::F32);
        assert!(report.ledger.up_bytes > 0);
        assert!(report.ledger.up_bytes <= 64 * up_max as u64);
        // per-round byte records sum to the ledger totals
        let recorded: u64 = report.history.iter().map(|r| r.round_bytes).sum();
        assert_eq!(recorded, report.ledger.total_bytes());
    }

    #[test]
    fn full_strategy_moves_whole_model() {
        let mut cfg = tiny_cfg();
        cfg.bandit.strategy = Strategy::Full;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        let rec = tr.round().unwrap();
        assert_eq!(rec.m_s, 96);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = tiny_cfg();
        let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(r1.final_metrics.map, r2.final_metrics.map);
        assert_eq!(r1.ledger.down_bytes, r2.ledger.down_bytes);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let mut c1 = tiny_cfg();
        c1.runtime.threads = 1;
        let mut c4 = tiny_cfg();
        c4.runtime.threads = 4;
        let r1 = Trainer::from_config(&c1).unwrap().run().unwrap();
        let r4 = Trainer::from_config(&c4).unwrap().run().unwrap();
        assert_eq!(r1.final_metrics.map.to_bits(), r4.final_metrics.map.to_bits());
        assert_eq!(r1.ledger.up_bytes, r4.ledger.up_bytes);
        assert_eq!(r1.ledger.sim_secs.to_bits(), r4.ledger.sim_secs.to_bits());
    }

    #[test]
    fn clients_receive_factors() {
        let cfg = tiny_cfg();
        let mut tr = Trainer::from_config(&cfg).unwrap();
        tr.round().unwrap();
        let with_p = (0..tr.fleet().len())
            .filter(|&c| !tr.fleet().factors(c).is_empty())
            .count();
        assert_eq!(with_p, 16); // exactly Θ participants got fresh factors
    }

    #[test]
    fn theta_sample_draws_exactly_k_per_round_reproducibly() {
        let mut cfg = tiny_cfg();
        cfg.fleet.theta_sample = Some(5);
        let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        // one download message per participant per round: exactly K of them
        assert_eq!(r1.ledger.down_msgs, 4 * 5);
        assert_eq!(round_dump_string(&r1), round_dump_string(&r2));
        // the sampled trajectory is a different run, not a relabeled one
        let legacy = Trainer::from_config(&tiny_cfg()).unwrap().run().unwrap();
        assert_ne!(round_dump_string(&r1), round_dump_string(&legacy));
    }

    #[test]
    fn theta_sample_is_thread_count_invariant() {
        let mut c1 = tiny_cfg();
        c1.fleet.theta_sample = Some(7);
        c1.runtime.threads = 1;
        let mut c4 = c1.clone();
        c4.runtime.threads = 4;
        let r1 = Trainer::from_config(&c1).unwrap().run().unwrap();
        let r4 = Trainer::from_config(&c4).unwrap().run().unwrap();
        assert_eq!(round_dump_string(&r1), round_dump_string(&r4));
    }

    #[test]
    fn theta_sample_runs_journal_and_replay_verify() {
        // the journal's participants field records the sampled ids, so a
        // --resume replay re-draws them from the dedicated stream and
        // verifies the match round by round
        let dir = std::env::temp_dir().join("fedpayload_theta_sample_resume");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let jpath = dir.join("run.jsonl");
        let mut cfg = tiny_cfg();
        cfg.fleet.theta_sample = Some(6);
        cfg.journal.path = Some(jpath.to_string_lossy().into_owned());
        let full = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let mut rcfg = cfg.clone();
        rcfg.journal.resume = cfg.journal.path.clone();
        rcfg.journal.path = None;
        let mut tr = Trainer::from_config(&rcfg).unwrap();
        let resumed = tr.run().unwrap();
        assert_eq!(resumed.replayed_rounds, 4);
        assert_eq!(round_dump_string(&full), round_dump_string(&resumed));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scalar_precision_ignores_codebook_reuse() {
        let mut cfg = tiny_cfg();
        cfg.codec.codebook_reuse = crate::wire::ReuseMode::Auto; // f32 precision
        let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(report.codebook_reuse, "off");
        assert!(report.session.is_none());
    }

    #[test]
    fn session_reuse_cuts_download_bytes_on_stable_q() {
        // Strategy::Full selects the same rows every round and Q drifts
        // only by Adam steps, so `auto` must reuse the codebook at
        // least once and move strictly fewer download bytes than the
        // stateless vq path at otherwise identical settings.
        let mut base = tiny_cfg();
        base.dataset.users = 64;
        base.dataset.items = 128;
        base.dataset.interactions = 2500;
        base.train.iterations = 6;
        base.train.theta = 64; // everyone participates: nobody goes stale
        base.train.payload_fraction = 1.0;
        base.bandit.strategy = Strategy::Full;
        base.codec.precision = crate::wire::Precision::Vq8;
        base.codec.entropy = crate::wire::EntropyMode::Full;
        let mut auto_cfg = base.clone();
        auto_cfg.codec.codebook_reuse = crate::wire::ReuseMode::Auto;
        let off = Trainer::from_config(&base).unwrap().run().unwrap();
        let auto_r = Trainer::from_config(&auto_cfg).unwrap().run().unwrap();
        assert_eq!(off.codebook_reuse, "off");
        assert!(off.session.is_none());
        assert_eq!(auto_r.codebook_reuse, "auto");
        let stats = auto_r.session.unwrap();
        assert_eq!(
            stats.reuse_frames + stats.delta_frames + stats.full_frames,
            6,
            "one session frame per round: {stats:?}"
        );
        assert!(stats.reuse_frames >= 1, "stable Q never reused: {stats:?}");
        assert_eq!(stats.resync_msgs, 0, "theta == users: {stats:?}");
        assert_eq!(stats.resync_extra_bytes, 0);
        assert_eq!(off.ledger.down_msgs, auto_r.ledger.down_msgs);
        assert!(
            auto_r.ledger.down_bytes < off.ledger.down_bytes,
            "auto {} !< off {} download bytes",
            auto_r.ledger.down_bytes,
            off.ledger.down_bytes
        );
        // uploads ride the same int8 path; message counts match
        assert_eq!(off.ledger.up_msgs, auto_r.ledger.up_msgs);
    }

    #[test]
    fn session_delta_mode_trains_bit_identically_to_stateless() {
        // delta frames reconstruct the freshly trained codebook exactly
        // (post-requant), so `delta` must train bit-identically to
        // `off` — only the ledger bytes may differ.
        let mut base = tiny_cfg();
        base.codec.precision = crate::wire::Precision::Vq8;
        base.codec.entropy = crate::wire::EntropyMode::Full;
        let mut delta_cfg = base.clone();
        delta_cfg.codec.codebook_reuse = crate::wire::ReuseMode::Delta;
        let off = Trainer::from_config(&base).unwrap().run().unwrap();
        let delta = Trainer::from_config(&delta_cfg).unwrap().run().unwrap();
        assert_eq!(delta.codebook_reuse, "delta");
        let stats = delta.session.unwrap();
        assert_eq!(stats.reuse_frames, 0, "delta mode never reuses verbatim");
        assert!(stats.delta_frames >= 1, "no delta frames shipped: {stats:?}");
        assert_eq!(
            off.final_metrics.map.to_bits(),
            delta.final_metrics.map.to_bits(),
            "delta frames changed training"
        );
        for (a, b) in off.history.iter().zip(&delta.history) {
            assert_eq!(a.raw.map.to_bits(), b.raw.map.to_bits(), "iter {}", a.iter);
            assert_eq!(a.m_s, b.m_s);
        }
        assert_eq!(off.ledger.up_bytes, delta.ledger.up_bytes);
    }

    #[test]
    fn policy_modes_train_reproducibly_and_thread_invariant() {
        for mode in ["budget", "bandit"] {
            let mut c1 = tiny_cfg();
            c1.policy.mode = crate::server::policy::PolicyMode::parse(mode).unwrap();
            c1.runtime.threads = 1;
            let mut c4 = c1.clone();
            c4.runtime.threads = 4;
            let r1 = Trainer::from_config(&c1).unwrap().run().unwrap();
            let r4 = Trainer::from_config(&c4).unwrap().run().unwrap();
            assert_eq!(r1.policy, mode);
            assert_eq!(
                round_dump_string(&r1),
                round_dump_string(&r4),
                "{mode} rounds depend on the thread count"
            );
            let again = Trainer::from_config(&c1).unwrap().run().unwrap();
            assert_eq!(round_dump_string(&r1), round_dump_string(&again));
        }
    }

    #[test]
    fn policy_budget_skips_low_battery_clients_and_accounts_them() {
        let mut cfg = tiny_cfg();
        cfg.policy.mode = crate::server::policy::PolicyMode::Budget;
        cfg.policy.battery_floor = 0.9; // battery ~ U[0,1): most clients sit out
        let report = Trainer::from_config(&cfg).unwrap().run().unwrap();
        assert!(
            report.policy_skips > 0,
            "a 0.9 battery floor skipped nobody across 4 rounds x 16 participants"
        );
        // skipped clients move no bytes: fewer download messages than
        // the uniform 4 x 16
        assert!(
            report.ledger.down_msgs < 64,
            "{} download msgs despite {} skips",
            report.ledger.down_msgs,
            report.policy_skips
        );
        assert_eq!(
            report.ledger.down_msgs + report.policy_skips,
            64,
            "every participant either downloaded or was skipped"
        );
    }

    #[test]
    fn upload_delta_trains_bit_identically_to_stateless_uploads() {
        // The delta encoder re-frames the exact raw value plane the
        // batch frame carried, so turning it on must not change one bit
        // of the training trajectory — only the upload ledger moves.
        // Stable workload (same rows every round, everyone participates)
        // so consecutive uploads resemble each other and the range coder
        // actually ships deltas.
        let mut base = tiny_cfg();
        base.dataset.users = 32;
        base.dataset.items = 64;
        base.dataset.interactions = 1200;
        base.train.iterations = 5;
        base.train.theta = 32;
        base.train.payload_fraction = 1.0;
        base.bandit.strategy = Strategy::Full;
        base.codec.precision = crate::wire::Precision::Int8;
        base.codec.entropy = crate::wire::EntropyMode::Full;
        let mut delta_cfg = base.clone();
        delta_cfg.codec.upload_delta = true;
        let off = Trainer::from_config(&base).unwrap().run().unwrap();
        let on = Trainer::from_config(&delta_cfg).unwrap().run().unwrap();
        assert!(off.upload.is_none());
        let stats = on.upload.unwrap();
        for (a, b) in off.history.iter().zip(&on.history) {
            assert_eq!(a.raw.map.to_bits(), b.raw.map.to_bits(), "iter {}", a.iter);
            assert_eq!(a.m_s, b.m_s);
        }
        assert_eq!(off.ledger.up_msgs, on.ledger.up_msgs);
        assert_eq!(off.ledger.down_bytes, on.ledger.down_bytes);
        // one session frame per participant per round, no churn => no
        // resyncs; the stable plane must win at least one delta
        assert_eq!(
            stats.full_frames + stats.delta_frames,
            on.ledger.up_msgs,
            "{stats:?}"
        );
        assert_eq!(stats.resyncs, 0, "{stats:?}");
        assert!(stats.delta_frames >= 1, "no deltas on a stable plane: {stats:?}");
        assert!(
            on.ledger.up_bytes < off.ledger.up_bytes + stats.delta_saved_bytes,
            "delta savings not reflected in the ledger"
        );
    }

    #[test]
    fn upload_delta_forced_resync_is_counted_and_attribution_is_exact() {
        // Invalidate one device's upload-session cache mid-run: the next
        // round must serve a counted full-frame resync for that client,
        // training must not notice, and the per-client up_bytes
        // attribution must stay bit-identical across thread counts.
        let run = |threads: usize, churn: bool| {
            let mut cfg = tiny_cfg();
            cfg.train.theta = 48; // everyone uploads every round
            cfg.codec.precision = crate::wire::Precision::Int8;
            cfg.codec.entropy = crate::wire::EntropyMode::Full;
            cfg.codec.upload_delta = true;
            cfg.runtime.threads = threads;
            let mut tr = Trainer::from_config(&cfg).unwrap();
            tr.round().unwrap();
            tr.round().unwrap();
            let before = tr.upload_stats().unwrap();
            assert_eq!(before.resyncs, 0);
            if churn {
                tr.invalidate_client_upload(0);
            }
            tr.round().unwrap();
            let after = tr.upload_stats().unwrap();
            let up_bytes = tr.ledger().up_bytes;
            let maps: Vec<u64> =
                tr.history().iter().map(|r| r.raw.map.to_bits()).collect();
            (before, after, up_bytes, maps, tr.upload_generation(0))
        };
        let (_, clean_after, clean_bytes, clean_maps, clean_gen) = run(1, false);
        assert_eq!(clean_after.resyncs, 0);
        let (_, churn_after, churn_bytes, churn_maps, churn_gen) = run(1, true);
        assert_eq!(churn_after.resyncs, 1, "{churn_after:?}");
        assert_eq!(clean_maps, churn_maps, "a resync changed training");
        // generations realign after the forced full frame
        assert_eq!(clean_gen, churn_gen);
        // exact attribution is thread-invariant, churn or not
        let (_, t4_after, t4_bytes, t4_maps, _) = run(4, true);
        assert_eq!(t4_after, churn_after);
        assert_eq!(t4_bytes, churn_bytes);
        assert_eq!(t4_maps, churn_maps);
        // the resync round re-shipped client 0's rows as a full frame:
        // its bytes can only match or exceed the clean run's
        assert!(
            churn_bytes >= clean_bytes,
            "churn {churn_bytes} < clean {clean_bytes}"
        );
    }

    #[test]
    fn round_dump_string_is_stable_and_bit_exact() {
        let cfg = tiny_cfg();
        let r1 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let (d1, d2) = (round_dump_string(&r1), round_dump_string(&r2));
        assert_eq!(d1, d2, "repeat runs must dump identical trajectories");
        assert_eq!(d1.lines().count(), 4 + 2); // header + 4 rounds + totals
        assert!(d1.starts_with("iter,m_s,raw_precision"));
        assert!(d1.trim_end().ends_with(&format!(
            "sim_secs_bits={:016x}",
            r1.ledger.sim_secs.to_bits()
        )));
    }

    #[test]
    fn flight_recorder_digest_is_thread_count_invariant() {
        let run_digest = |threads: usize| {
            let mut cfg = tiny_cfg();
            cfg.runtime.threads = threads;
            let mut tr = Trainer::from_config(&cfg).unwrap();
            tr.install_tracer(Tracer::in_memory(TraceLevel::Full));
            tr.run().unwrap();
            let text = tr.tracer().unwrap().lines().join("\n");
            crate::telemetry::trace::trace_digest(&text)
        };
        let d1 = run_digest(1);
        let d4 = run_digest(4);
        assert_eq!(d1, d4, "decision digests must not depend on threads");
        for ev in ["run_start", "bandit_select", "codec_choice", "reward_update", "round_end", "run_end"]
        {
            assert!(d1.contains(&format!("\"ev\":\"{ev}\"")), "missing {ev}");
        }
        assert!(
            !d1.contains(",\"t\":{"),
            "digest must strip every timing object"
        );
    }

    #[test]
    fn flight_recorder_lines_are_structured_and_counted() {
        let cfg = tiny_cfg();
        let mut tr = Trainer::from_config(&cfg).unwrap();
        tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
        let report = tr.run().unwrap();
        let tracer = tr.tracer().unwrap();
        assert_eq!(report.trace_events, tracer.events());
        let lines = tracer.lines();
        assert!(!lines.is_empty());
        // 4 rounds × (bandit_select + codec_choice + reward_update +
        // round_end) + run_start + run_end
        assert_eq!(lines.len(), 4 * 4 + 2);
        for line in lines {
            assert!(line.starts_with("{\"ev\":\""), "{line}");
            assert!(line.ends_with('}'), "{line}");
        }
        // wall-clock facts ride in the raw lines' timing objects...
        assert!(lines.iter().any(|l| l.contains(",\"t\":{")));
        // ...and the round_end events carry the exact-bits metric fields
        assert!(lines.iter().any(|l| l.contains("\"smoothed_map_bits\":\"")));
    }

    #[test]
    fn registry_collects_decision_side_metrics() {
        let cfg = tiny_cfg();
        let mut tr = Trainer::from_config(&cfg).unwrap();
        tr.install_tracer(Tracer::in_memory(TraceLevel::Decision));
        tr.run().unwrap();
        let reg = tr.registry();
        assert_eq!(reg.counter("fedpayload_rounds_total"), 4);
        assert_eq!(
            reg.counter("fedpayload_down_bytes_total"),
            tr.ledger().down_bytes
        );
        assert_eq!(
            reg.counter("fedpayload_up_bytes_total"),
            tr.ledger().up_bytes
        );
        let h = reg.histogram("fedpayload_down_frame_bytes").unwrap();
        assert_eq!(h.count(), 4, "one download frame observed per round");
        assert!(reg.gauge("fedpayload_smoothed_map").is_some());
        // rewards flow into the log-bucket histogram every round
        let r = reg.histogram("fedpayload_reward_abs").unwrap();
        assert_eq!(r.count(), 4 * 24, "m_s rewards per round");
    }

    #[test]
    fn tracing_off_records_nothing() {
        let cfg = tiny_cfg();
        let mut tr = Trainer::from_config(&cfg).unwrap();
        let report = tr.run().unwrap();
        assert_eq!(report.trace_events, 0);
        assert!(tr.tracer().is_none());
        assert!(tr.registry().is_empty());
    }

    #[test]
    fn training_improves_metrics_on_learnable_data() {
        let mut cfg = tiny_cfg();
        cfg.dataset.users = 64;
        cfg.dataset.items = 128;
        cfg.dataset.interactions = 2500;
        cfg.train.iterations = 60;
        cfg.train.theta = 32;
        cfg.train.payload_fraction = 1.0;
        cfg.bandit.strategy = Strategy::Full;
        let mut tr = Trainer::from_config(&cfg).unwrap();
        let report = tr.run().unwrap();
        let early = &report.history[4].raw;
        let late = &report.final_metrics;
        assert!(
            late.map > early.map,
            "MAP did not improve: early={} late={}",
            early.map,
            late.map
        );
        assert!(late.map > 0.05, "final MAP too low: {}", late.map);
    }
}
