//! Per-client payload policies: each round, for every participant, a
//! policy decides *how* the round ships — which download precision arm
//! (int8 / vq8r / vq8 / vq4), how many upload rows survive (top-k), and
//! whether the client participates at all — under a simulated per-client
//! bandwidth/battery budget, scored by the **measured** encoded-bytes
//! ledger (never the analytic formula).
//!
//! ## Modes (`[policy] mode = uniform|budget|bandit`)
//!
//! * `uniform` — the legacy path: every client gets the configured codec
//!   (`Trainer::round` does not consult this module at all; uniform runs
//!   stay byte-identical to previous releases).
//! * `budget` — deterministic greedy: the most expensive (highest
//!   fidelity) arm whose measured frame fits the client's drawn downlink
//!   budget; the largest top-k class whose analytic upload length fits
//!   the uplink budget; skip when nothing fits or battery is below the
//!   floor.
//! * `bandit` — per-budget-class Gaussian Thompson sampling over the
//!   arms, mirroring the paper's item bandit one level up: the reward is
//!   a pure function of the arms' measured frame bytes and decode SSE
//!   this round, so posteriors learn the cheapest arm that still tracks
//!   Q* per class (the bytes-per-MAP frontier the ROADMAP targets).
//!
//! ## Determinism contract
//!
//! All randomness comes from a dedicated tagged PCG stream (same
//! pattern as [`crate::rng::ParticipantSampler`]): every draw is a pure
//! function of `(master seed, round, client)` or `(master seed, round,
//! class, arm)` — never of a shared mutable stream position, thread
//! count, or iteration order. Replaying a journaled policy run re-derives
//! identical decisions, and `state_digest` journals the posterior
//! evolution as evidence.

use crate::config::{PolicyConfig, SimNetConfig};
use crate::rng::SplitMix64;
use crate::wire::{encoded_sparse_len, Precision};

/// Domain-separation tag for the policy stream (cf.
/// `PARTICIPANT_STREAM_TAG` — different constant, same construction).
const POLICY_STREAM_TAG: u64 = 0x5047_504f_4c49_0001; // "PG\x50OLI" + 1

/// The download precision arms a policy chooses between, ordered by
/// decreasing fidelity (and, for dense frames at matched entropy, by
/// decreasing measured bytes — the budget policy exploits that order).
pub const ARMS: [Precision; 4] = [
    Precision::Int8,
    Precision::Vq8r,
    Precision::Vq8,
    Precision::Vq4,
];

/// Budget classes the bandit maintains separate posteriors for (drawn
/// bandwidth quartiles).
pub const N_CLASSES: usize = 4;

/// Top-k classes as fractions of m_s (denominators): full, half,
/// quarter. Quantized so clients group into a bounded number of cohorts.
const TOPK_DENOMS: [usize; 3] = [1, 2, 4];

/// Policy mode (`[policy] mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyMode {
    /// Legacy single-codec path; the policy layer is inert.
    #[default]
    Uniform,
    /// Deterministic budget-greedy arm/top-k/participation choice.
    Budget,
    /// Per-class Thompson sampling over the arms.
    Bandit,
}

impl PolicyMode {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> anyhow::Result<PolicyMode> {
        match s {
            "uniform" => Ok(PolicyMode::Uniform),
            "budget" => Ok(PolicyMode::Budget),
            "bandit" => Ok(PolicyMode::Bandit),
            other => anyhow::bail!("unknown policy.mode `{other}` (uniform|budget|bandit)"),
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyMode::Uniform => "uniform",
            PolicyMode::Budget => "budget",
            PolicyMode::Bandit => "bandit",
        }
    }
}

/// Measured per-arm evidence for one round: the encoded dense frame
/// length and the decode SSE against the staged f32 Q*.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArmCost {
    /// Measured encoded dense-frame length for this arm.
    pub frame_bytes: u64,
    /// Σ (decoded − staged)² over the frame.
    pub sse: f64,
}

/// One participant's decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyDecision {
    /// Client id.
    pub client: usize,
    /// Index into [`ARMS`] (`None` = the client sits this round out).
    pub arm: Option<usize>,
    /// Upload top-k rows this client's cohort keeps (0 when skipped;
    /// `m_s` = unconstrained).
    pub top_k: usize,
}

/// Per-client draws for one round: the budget the decision was made
/// under (traced as the decision's rationale).
#[derive(Debug, Clone, Copy)]
pub struct ClientBudget {
    /// Drawn effective bandwidth fraction in `[min_bandwidth_frac, 1)`.
    pub bandwidth_frac: f64,
    /// Drawn battery level in `[0, 1)`.
    pub battery: f64,
    /// Downlink/uplink byte budget for the window.
    pub budget_bytes: u64,
}

/// The per-client payload policy engine. Owns the dedicated stream seed
/// and (for `bandit`) the per-class arm posteriors.
#[derive(Debug, Clone)]
pub struct PolicyEngine {
    mode: PolicyMode,
    cfg: PolicyConfig,
    bandwidth_mbps: f64,
    stream_seed: u64,
    /// Reward observations per (class, arm): count and running sum.
    obs_n: [[u64; ARMS.len()]; N_CLASSES],
    obs_sum: [[f64; ARMS.len()]; N_CLASSES],
    /// Cumulative participants the policy sat out.
    skips: u64,
}

impl PolicyEngine {
    /// Build the engine for a run. `seed` is the run's master seed; the
    /// policy stream is derived through its own tag so it never collides
    /// with the trainer's main stream or the participant sampler.
    pub fn new(cfg: &PolicyConfig, simnet: &SimNetConfig, seed: u64) -> PolicyEngine {
        let mut sm = SplitMix64::new(seed ^ POLICY_STREAM_TAG);
        PolicyEngine {
            mode: cfg.mode,
            cfg: cfg.clone(),
            bandwidth_mbps: simnet.bandwidth_mbps,
            stream_seed: sm.next_u64(),
            obs_n: [[0; ARMS.len()]; N_CLASSES],
            obs_sum: [[0.0; ARMS.len()]; N_CLASSES],
            skips: 0,
        }
    }

    /// Active mode.
    pub fn mode(&self) -> PolicyMode {
        self.mode
    }

    /// Cumulative skipped participants.
    pub fn skips(&self) -> u64 {
        self.skips
    }

    /// A unit-interval draw, pure in `(stream, round, salt)`.
    fn unit(&self, round_child: u64, salt: u64) -> f64 {
        let mut sm = SplitMix64::new(round_child ^ salt);
        (sm.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal draw, pure in `(stream, round, salt)` (Box–Muller).
    fn gauss(&self, round_child: u64, salt: u64) -> f64 {
        let mut sm = SplitMix64::new(round_child ^ salt);
        let u1 = ((sm.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64; // (0, 1]
        let u2 = (sm.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// This round's drawn budget for one client — a pure function of
    /// `(master seed, round, client)`, independent of participant order.
    pub fn client_budget(&self, round: u64, client: usize) -> ClientBudget {
        let child = SplitMix64::new(self.stream_seed.wrapping_add(round)).next_u64();
        let u = self.unit(child, 0x0100_0000_0000_0000 | client as u64);
        let battery = self.unit(child, 0x0200_0000_0000_0000 | client as u64);
        let frac = self.cfg.min_bandwidth_frac + (1.0 - self.cfg.min_bandwidth_frac) * u;
        let bytes_per_sec = self.bandwidth_mbps * frac * 1e6 / 8.0;
        ClientBudget {
            bandwidth_frac: frac,
            battery,
            budget_bytes: (bytes_per_sec * self.cfg.budget_window_ms / 1000.0) as u64,
        }
    }

    /// Budget class (bandwidth quartile) of a drawn budget.
    fn class_of(&self, b: &ClientBudget) -> usize {
        let span = (1.0 - self.cfg.min_bandwidth_frac).max(f64::MIN_POSITIVE);
        let u = ((b.bandwidth_frac - self.cfg.min_bandwidth_frac) / span).clamp(0.0, 1.0);
        ((u * N_CLASSES as f64) as usize).min(N_CLASSES - 1)
    }

    /// Normalized arm rewards for this round's measured costs: cheaper
    /// and more faithful is better, both terms scaled to `[−1, 0]` so
    /// `sse_weight` trades them off directly.
    fn arm_rewards(&self, costs: &[ArmCost; ARMS.len()]) -> [f64; ARMS.len()] {
        let max_b = costs.iter().map(|c| c.frame_bytes).max().unwrap_or(1).max(1) as f64;
        let max_s = costs.iter().map(|c| c.sse).fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
        let mut r = [0.0; ARMS.len()];
        for (i, c) in costs.iter().enumerate() {
            r[i] = -(c.frame_bytes as f64 / max_b) - self.cfg.sse_weight * (c.sse / max_s);
        }
        r
    }

    /// Largest quantized top-k whose analytic int8 upload frame fits
    /// `budget` (`None` = not even the quarter frame fits).
    fn top_k_for(&self, m_s: usize, cols: usize, budget: u64) -> Option<usize> {
        for &d in &TOPK_DENOMS {
            let tk = (m_s / d).max(1);
            if encoded_sparse_len(tk, cols, Precision::Int8) as u64 <= budget {
                return Some(tk);
            }
        }
        None
    }

    /// Decide the round: one [`PolicyDecision`] per participant, in
    /// participant order. For `bandit`, the per-class posteriors are
    /// updated with the measured rewards of every arm that was actually
    /// chosen this round (the observation step — rewards here are known
    /// at decision time because they are functions of the round's
    /// measured arm costs).
    pub fn decide(
        &mut self,
        round: u64,
        participants: &[usize],
        costs: &[ArmCost; ARMS.len()],
        m_s: usize,
        cols: usize,
    ) -> Vec<PolicyDecision> {
        let child = SplitMix64::new(self.stream_seed.wrapping_add(round)).next_u64();
        // Thompson samples per (class, arm), shared by every client of
        // the class this round — pure in (seed, round, class, arm).
        let mut theta = [[0.0f64; ARMS.len()]; N_CLASSES];
        if self.mode == PolicyMode::Bandit {
            for (c, row) in theta.iter_mut().enumerate() {
                for (a, t) in row.iter_mut().enumerate() {
                    let n = self.obs_n[c][a] as f64;
                    let mean = self.obs_sum[c][a] / (1.0 + n); // mu0 = 0, tau0 = 1
                    let z = self.gauss(child, 0x0300_0000_0000_0000 | (c * ARMS.len() + a) as u64);
                    *t = mean + z / (1.0 + n).sqrt();
                }
            }
        }
        let rewards = self.arm_rewards(costs);
        let mut chosen = [[false; ARMS.len()]; N_CLASSES];
        let mut out = Vec::with_capacity(participants.len());
        for &client in participants {
            let budget = self.client_budget(round, client);
            if budget.battery < self.cfg.battery_floor {
                self.skips += 1;
                out.push(PolicyDecision {
                    client,
                    arm: None,
                    top_k: 0,
                });
                continue;
            }
            let top_k = self.top_k_for(m_s, cols, budget.budget_bytes);
            let fitting: Vec<usize> = (0..ARMS.len())
                .filter(|&a| costs[a].frame_bytes <= budget.budget_bytes)
                .collect();
            let arm = match (top_k, fitting.is_empty()) {
                (None, _) | (_, true) => None,
                (Some(_), false) => match self.mode {
                    // greedy: the highest-fidelity (most expensive) arm
                    // that fits — ARMS is fidelity-ordered and frame
                    // bytes are measured, so pick by measured bytes
                    PolicyMode::Budget | PolicyMode::Uniform => fitting
                        .iter()
                        .copied()
                        .max_by_key(|&a| (costs[a].frame_bytes, usize::MAX - a)),
                    PolicyMode::Bandit => {
                        let class = self.class_of(&budget);
                        fitting.iter().copied().max_by(|&a, &b| {
                            theta[class][a]
                                .partial_cmp(&theta[class][b])
                                .unwrap_or(std::cmp::Ordering::Equal)
                        })
                    }
                },
            };
            match arm {
                Some(a) => {
                    if self.mode == PolicyMode::Bandit {
                        chosen[self.class_of(&budget)][a] = true;
                    }
                    out.push(PolicyDecision {
                        client,
                        arm: Some(a),
                        top_k: top_k.unwrap_or(m_s),
                    });
                }
                None => {
                    self.skips += 1;
                    out.push(PolicyDecision {
                        client,
                        arm: None,
                        top_k: 0,
                    });
                }
            }
        }
        // observation step: fold this round's measured reward into every
        // (class, arm) pair that shipped, in fixed (class, arm) order
        if self.mode == PolicyMode::Bandit {
            for c in 0..N_CLASSES {
                for a in 0..ARMS.len() {
                    if chosen[c][a] {
                        self.obs_n[c][a] += 1;
                        self.obs_sum[c][a] += rewards[a];
                    }
                }
            }
        }
        out
    }

    /// Order-stable digest of the policy state (journal evidence: a
    /// replayed policy round must re-derive the identical posteriors).
    pub fn state_digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_u8(match self.mode {
            PolicyMode::Uniform => 0,
            PolicyMode::Budget => 1,
            PolicyMode::Bandit => 2,
        });
        h.write_u64(self.stream_seed);
        h.write_u64(self.skips);
        for c in 0..N_CLASSES {
            for a in 0..ARMS.len() {
                h.write_u64(self.obs_n[c][a]);
                h.write_u64(self.obs_sum[c][a].to_bits());
            }
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn engine(mode: PolicyMode) -> PolicyEngine {
        let mut cfg = RunConfig::paper_defaults();
        cfg.policy.mode = mode;
        PolicyEngine::new(&cfg.policy, &cfg.simnet, 2027)
    }

    fn flat_costs() -> [ArmCost; ARMS.len()] {
        [
            ArmCost { frame_bytes: 1000, sse: 0.1 },
            ArmCost { frame_bytes: 700, sse: 0.3 },
            ArmCost { frame_bytes: 400, sse: 0.8 },
            ArmCost { frame_bytes: 200, sse: 2.0 },
        ]
    }

    #[test]
    fn draws_are_pure_and_order_independent() {
        let e = engine(PolicyMode::Budget);
        let a = e.client_budget(3, 17);
        let b = e.client_budget(3, 17);
        assert_eq!(a.budget_bytes, b.budget_bytes);
        assert_eq!(a.bandwidth_frac.to_bits(), b.bandwidth_frac.to_bits());
        // different round or client → different draw
        assert_ne!(
            e.client_budget(4, 17).bandwidth_frac.to_bits(),
            a.bandwidth_frac.to_bits()
        );
        assert_ne!(
            e.client_budget(3, 18).bandwidth_frac.to_bits(),
            a.bandwidth_frac.to_bits()
        );
        // fraction respects the configured floor
        assert!(a.bandwidth_frac >= 0.25 && a.bandwidth_frac < 1.0);
    }

    #[test]
    fn decisions_are_deterministic_and_digest_tracks_posteriors() {
        let participants: Vec<usize> = (0..40).collect();
        let costs = flat_costs();
        let mut e1 = engine(PolicyMode::Bandit);
        let mut e2 = engine(PolicyMode::Bandit);
        for round in 1..=5u64 {
            let d1 = e1.decide(round, &participants, &costs, 24, 25);
            let d2 = e2.decide(round, &participants, &costs, 24, 25);
            assert_eq!(d1, d2, "round {round}");
        }
        assert_eq!(e1.state_digest(), e2.state_digest());
        let before = e1.state_digest();
        e1.decide(6, &participants, &costs, 24, 25);
        assert_ne!(before, e1.state_digest(), "posteriors must evolve");
    }

    #[test]
    fn budget_mode_picks_best_fitting_arm_and_skips_over_budget() {
        let mut cfg = RunConfig::paper_defaults();
        cfg.policy.mode = PolicyMode::Budget;
        // shrink the window so budgets land between the arm costs
        cfg.policy.budget_window_ms = 0.005; // 1 Mbps · frac → 0.625·frac bytes/ms
        let mut e = PolicyEngine::new(&cfg.policy, &cfg.simnet, 7);
        let costs = flat_costs();
        let decisions = e.decide(1, &(0..200).collect::<Vec<_>>(), &costs, 24, 25);
        let mut seen_arms = std::collections::BTreeSet::new();
        for d in &decisions {
            if let Some(a) = d.arm {
                let budget = e.client_budget(1, d.client).budget_bytes;
                assert!(costs[a].frame_bytes <= budget, "chosen arm must fit");
                // greedy: no more expensive arm also fits
                for b in 0..ARMS.len() {
                    if costs[b].frame_bytes > costs[a].frame_bytes {
                        assert!(costs[b].frame_bytes > budget);
                    }
                }
                seen_arms.insert(a);
            }
        }
        assert!(seen_arms.len() > 1, "budget spread must exercise several arms");
        assert!(e.skips() > 0, "tight budgets must skip some clients");
        assert!(decisions.iter().any(|d| d.arm.is_none()));
    }

    #[test]
    fn battery_floor_skips_participation() {
        let mut cfg = RunConfig::paper_defaults();
        cfg.policy.mode = PolicyMode::Budget;
        cfg.policy.battery_floor = 1.0; // nobody qualifies
        let mut e = PolicyEngine::new(&cfg.policy, &cfg.simnet, 9);
        let d = e.decide(1, &[0, 1, 2], &flat_costs(), 24, 25);
        assert!(d.iter().all(|x| x.arm.is_none()));
        assert_eq!(e.skips(), 3);
    }

    #[test]
    fn bandit_learns_toward_higher_reward_arms() {
        // arm 3 is 5× cheaper at equal SSE: rewards should pull the
        // posterior means apart and the bandit should prefer it
        let costs = [
            ArmCost { frame_bytes: 1000, sse: 0.1 },
            ArmCost { frame_bytes: 900, sse: 0.1 },
            ArmCost { frame_bytes: 800, sse: 0.1 },
            ArmCost { frame_bytes: 200, sse: 0.1 },
        ];
        let mut e = engine(PolicyMode::Bandit);
        let participants: Vec<usize> = (0..64).collect();
        let mut last_round_cheap = 0usize;
        for round in 1..=30u64 {
            let d = e.decide(round, &participants, &costs, 24, 25);
            if round == 30 {
                last_round_cheap = d.iter().filter(|x| x.arm == Some(3)).count();
            }
        }
        let participated = 64 - 0; // battery floor 0: nobody skips on battery
        assert!(
            last_round_cheap * 2 > participated,
            "bandit should mostly pick the dominating cheap arm, got {last_round_cheap}/64"
        );
    }
}
