//! The round journal: an append-only, checksummed JSONL event log that
//! makes the coordinator crash-safe (ROADMAP "event-sourced rounds").
//!
//! Every completed FL round appends one [`RoundEntry`] — the round's
//! *inputs* (RNG stream position at round entry, sampled participants,
//! bandit arm selection, codec/session decisions) and its *digests*
//! (bandit posterior state, vq session state, exact-bits metrics and
//! cumulative ledger totals). Because the whole system is
//! bit-deterministic (threads=1/N identity, golden trajectories),
//! **replaying the journal is recovery**: `--resume` re-executes the
//! journaled rounds from the same seed and verifies every recorded
//! field as it goes, reconstructing the bandit posteriors, codebook
//! session caches and ledger byte-for-byte before training continues.
//! There are no model checkpoints to load and none are needed — the
//! journal pins the decisions, determinism re-derives the state, and
//! any divergence is a hard error at the first diverging round rather
//! than a silent drift discovered at the final dump diff.
//!
//! ## Record format
//!
//! One flat JSON object per line, hand-serialized in a canonical field
//! order (the same idiom as `telemetry::trace::TraceEvent`, so the log
//! is greppable and diffable). All f64 values travel as 16-hex-digit
//! bit patterns (the `f64_bits` renderer shared with
//! `round_dump_string`) and all 64-bit digests as 16-hex-digit strings
//! — never as JSON numbers, which lose u64 precision past 2^53. Every
//! line ends with `,"crc":"xxxxxxxx"}` where the value is the FNV-1a 32
//! checksum (`wire::frame::checksum` — the same function that guards
//! wire frames) of the line bytes before the `,"crc"` suffix.
//!
//! ## Torn writes
//!
//! Appends are `write_all` + `flush` of one complete line, so a crash
//! can tear **at most the final line**. [`read`] therefore applies the
//! classic write-ahead-log rule: a final line that fails to parse,
//! fails its CRC, or merely lacks its trailing newline (an incomplete
//! write, even if it happens to parse) is dropped with a warning and
//! the file is treated as ending at the last valid record — that round
//! simply re-runs on resume. A corrupt record *before* the tail can
//! only mean external damage and is a hard error, never skipped.

use std::collections::BTreeMap;
use std::io::{Seek, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::telemetry::trace::f64_bits;
use crate::warn_log;
use crate::wire::frame::checksum;

/// Journal format version; bumped on any breaking record change.
pub const JOURNAL_VERSION: u64 = 1;

/// The journal's first line: format version plus the config
/// determinism fingerprint the run was recorded under. `--resume`
/// refuses to replay a journal whose fingerprint does not match the
/// resuming config (see [`check_fingerprint`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalHeader {
    /// Format version ([`JOURNAL_VERSION`]).
    pub version: u64,
    /// `RunConfig::determinism_fingerprint()` of the recording run.
    pub fingerprint: String,
}

/// One journaled FL round: the inputs that drove it and the state
/// digests that verify its replay. Field semantics mirror the trainer's
/// round variables one-to-one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RoundEntry {
    /// 1-based FL iteration.
    pub iter: u64,
    /// `Rng::state_fingerprint()` at round entry, before any draw.
    pub rng_fp: u64,
    /// Sampled participant client ids, in sampling order.
    pub participants: Vec<u64>,
    /// Bandit-selected item ids (sorted, as staged).
    pub selected: Vec<u64>,
    /// Broadcast download frame length in bytes.
    pub frame_bytes: u64,
    /// Session frame mode name (`full|delta|reuse`); `None` when the
    /// codec ran stateless.
    pub session_mode: Option<String>,
    /// Session frame generation tag (`None` when stateless).
    pub generation: Option<u64>,
    /// Did the session frame install its generation on recipients?
    pub installs: Option<bool>,
    /// Resync messages served to stale clients this round.
    pub resync_msgs: u64,
    /// Σ extra bytes those resyncs cost over the broadcast frame.
    pub resync_extra: i64,
    /// Was this an evaluation round (`train.eval_every`)?
    pub evaluated: bool,
    /// Clients that contributed eval metrics this round.
    pub eval_clients: u64,
    /// Items transmitted (M_s).
    pub m_s: u64,
    /// Raw round metrics as f64 bit patterns:
    /// `[precision, recall, f1, map]`.
    pub raw_bits: [u64; 4],
    /// Smoothed (window-mean) metrics as f64 bit patterns, same order.
    pub smoothed_bits: [u64; 4],
    /// Bytes moved this round (both directions).
    pub round_bytes: u64,
    /// Cumulative ledger download bytes after this round.
    pub down_bytes: u64,
    /// Cumulative ledger upload bytes after this round.
    pub up_bytes: u64,
    /// Cumulative download messages after this round.
    pub down_msgs: u64,
    /// Cumulative upload messages after this round.
    pub up_msgs: u64,
    /// Cumulative simulated transfer seconds, as an f64 bit pattern.
    pub sim_secs_bits: u64,
    /// `ItemSelector::state_digest()` after this round's update.
    pub bandit_digest: u64,
    /// `VqSession::state_digest()` after this round (`None` when
    /// sessions are off).
    pub session_digest: Option<u64>,
    /// Payload policy mode name (`budget|bandit`); `None` when the
    /// policy layer is inert (uniform runs journal the legacy key set).
    pub policy_mode: Option<String>,
    /// Cumulative participants the policy sat out (`None` = no policy).
    pub policy_skips: Option<u64>,
    /// `PolicyEngine::state_digest()` after this round (`None` = no
    /// policy).
    pub policy_digest: Option<u64>,
    /// Cumulative upload-session full frames (`None` when
    /// `codec.upload_delta` is off).
    pub up_full: Option<u64>,
    /// Cumulative upload-session delta frames (`None` = deltas off).
    pub up_delta: Option<u64>,
    /// Cumulative upload-session forced resyncs (`None` = deltas off).
    pub up_resyncs: Option<u64>,
    /// `UploadStore::state_digest()` after this round (`None` = deltas
    /// off).
    pub upload_digest: Option<u64>,
}

/// Everything a journal file held: the header, the valid round prefix,
/// and what (if anything) was torn off the tail.
#[derive(Debug, Clone)]
pub struct JournalFile {
    /// The validated header line.
    pub header: JournalHeader,
    /// All valid round records, in file order.
    pub rounds: Vec<RoundEntry>,
    /// Byte offset of the end of the last valid record — the length to
    /// truncate to before appending (drops the torn tail, if any).
    pub valid_len: u64,
    /// Was a torn/corrupt final line dropped?
    pub torn: bool,
}

// ---------------------------------------------------------------------
// serialization (canonical field order; the roundtrip proptest pins
// parse(serialize(e)) == e and serialize(parse(line)) == line)
// ---------------------------------------------------------------------

fn push_escaped(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

fn push_u64_array(out: &mut String, key: &str, vals: &[u64]) {
    out.push_str(",\"");
    out.push_str(key);
    out.push_str("\":[");
    for (i, v) in vals.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&v.to_string());
    }
    out.push(']');
}

fn push_bits_array(out: &mut String, key: &str, vals: &[u64]) {
    out.push_str(",\"");
    out.push_str(key);
    out.push_str("\":[");
    for (i, v) in vals.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{v:016x}\""));
    }
    out.push(']');
}

/// Seal a serialized-so-far line (an open JSON object missing its final
/// `}`) with the CRC field: `<prefix>,"crc":"xxxxxxxx"}`.
fn seal_line(prefix: String) -> String {
    let crc = checksum(prefix.as_bytes());
    format!("{prefix},\"crc\":\"{crc:08x}\"}}")
}

impl JournalHeader {
    /// Serialize to one sealed JSONL line (without trailing newline).
    pub fn serialize(&self) -> String {
        let mut s = format!("{{\"ev\":\"journal\",\"version\":{}", self.version);
        s.push_str(",\"fingerprint\":\"");
        push_escaped(&mut s, &self.fingerprint);
        s.push('"');
        seal_line(s)
    }
}

impl RoundEntry {
    /// Serialize to one sealed JSONL line (without trailing newline).
    pub fn serialize(&self) -> String {
        let mut s = format!(
            "{{\"ev\":\"round\",\"iter\":{},\"rng\":\"{:016x}\"",
            self.iter, self.rng_fp
        );
        push_u64_array(&mut s, "participants", &self.participants);
        push_u64_array(&mut s, "selected", &self.selected);
        s.push_str(&format!(",\"frame_bytes\":{}", self.frame_bytes));
        if let Some(mode) = &self.session_mode {
            s.push_str(",\"session_mode\":\"");
            push_escaped(&mut s, mode);
            s.push('"');
        }
        if let Some(g) = self.generation {
            s.push_str(&format!(",\"generation\":{g}"));
        }
        if let Some(b) = self.installs {
            s.push_str(&format!(",\"installs\":{b}"));
        }
        s.push_str(&format!(
            ",\"resync_msgs\":{},\"resync_extra\":{},\"evaluated\":{},\"eval_clients\":{},\"m_s\":{}",
            self.resync_msgs, self.resync_extra, self.evaluated, self.eval_clients, self.m_s
        ));
        push_bits_array(&mut s, "raw", &self.raw_bits);
        push_bits_array(&mut s, "smoothed", &self.smoothed_bits);
        s.push_str(&format!(
            ",\"round_bytes\":{},\"down_bytes\":{},\"up_bytes\":{},\"down_msgs\":{},\"up_msgs\":{}",
            self.round_bytes, self.down_bytes, self.up_bytes, self.down_msgs, self.up_msgs
        ));
        s.push_str(&format!(
            ",\"sim_secs\":\"{:016x}\",\"bandit\":\"{:016x}\"",
            self.sim_secs_bits, self.bandit_digest
        ));
        if let Some(d) = self.session_digest {
            s.push_str(&format!(",\"session\":\"{d:016x}\""));
        }
        if let Some(mode) = &self.policy_mode {
            s.push_str(",\"policy_mode\":\"");
            push_escaped(&mut s, mode);
            s.push('"');
        }
        if let Some(v) = self.policy_skips {
            s.push_str(&format!(",\"policy_skips\":{v}"));
        }
        if let Some(d) = self.policy_digest {
            s.push_str(&format!(",\"policy\":\"{d:016x}\""));
        }
        if let Some(v) = self.up_full {
            s.push_str(&format!(",\"up_full\":{v}"));
        }
        if let Some(v) = self.up_delta {
            s.push_str(&format!(",\"up_delta\":{v}"));
        }
        if let Some(v) = self.up_resyncs {
            s.push_str(&format!(",\"up_resyncs\":{v}"));
        }
        if let Some(d) = self.upload_digest {
            s.push_str(&format!(",\"upload\":\"{d:016x}\""));
        }
        seal_line(s)
    }
}

// ---------------------------------------------------------------------
// parsing: a mini flat-JSON reader for exactly the shapes the journal
// emits (integers, strings, bools, flat arrays). No dependency — the
// vendored anyhow shim is the only external crate in the tree.
// ---------------------------------------------------------------------

/// A parsed journal value. Floats never appear: every f64 travels as a
/// 16-hex-digit bit-pattern string.
#[derive(Debug, Clone, PartialEq, Eq)]
enum JsonVal {
    U64(u64),
    I64(i64),
    Str(String),
    Bool(bool),
    ArrU64(Vec<u64>),
    ArrStr(Vec<String>),
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, i: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(
            self.peek() == Some(c),
            "journal record: expected `{}` at byte {}",
            c as char,
            self.i
        );
        self.i += 1;
        Ok(())
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'n') => out.push('\n'),
                        Some(b'u') => {
                            ensure!(self.i + 4 < self.b.len(), "journal record: short \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .context("journal record: non-utf8 \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .context("journal record: bad \\u escape")?;
                            out.push(
                                char::from_u32(cp)
                                    .context("journal record: invalid \\u codepoint")?,
                            );
                            self.i += 4;
                        }
                        other => bail!("journal record: bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    // multi-byte utf8: find the full char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .context("journal record: invalid utf8")?;
                    let ch = rest.chars().next().expect("nonempty");
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
                None => bail!("journal record: unterminated string"),
            }
        }
    }

    fn integer(&mut self) -> Result<JsonVal> {
        let neg = self.peek() == Some(b'-');
        if neg {
            self.i += 1;
        }
        let start = self.i;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        ensure!(self.i > start, "journal record: expected digits at byte {start}");
        let digits = std::str::from_utf8(&self.b[start..self.i]).expect("digits are ascii");
        if neg {
            let v: i64 = format!("-{digits}")
                .parse()
                .with_context(|| format!("journal record: bad integer -{digits}"))?;
            Ok(JsonVal::I64(v))
        } else {
            let v: u64 = digits
                .parse()
                .with_context(|| format!("journal record: bad integer {digits}"))?;
            Ok(JsonVal::U64(v))
        }
    }

    fn value(&mut self) -> Result<JsonVal> {
        match self.peek() {
            Some(b'"') => Ok(JsonVal::Str(self.string()?)),
            Some(b't') => {
                ensure!(
                    self.b[self.i..].starts_with(b"true"),
                    "journal record: bad literal at byte {}",
                    self.i
                );
                self.i += 4;
                Ok(JsonVal::Bool(true))
            }
            Some(b'f') => {
                ensure!(
                    self.b[self.i..].starts_with(b"false"),
                    "journal record: bad literal at byte {}",
                    self.i
                );
                self.i += 5;
                Ok(JsonVal::Bool(false))
            }
            Some(b'[') => {
                self.i += 1;
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(JsonVal::ArrU64(Vec::new()));
                }
                if self.peek() == Some(b'"') {
                    let mut vals = vec![self.string()?];
                    while self.peek() == Some(b',') {
                        self.i += 1;
                        vals.push(self.string()?);
                    }
                    self.eat(b']')?;
                    Ok(JsonVal::ArrStr(vals))
                } else {
                    let mut vals = Vec::new();
                    loop {
                        match self.integer()? {
                            JsonVal::U64(v) => vals.push(v),
                            _ => bail!("journal record: negative value in u64 array"),
                        }
                        match self.peek() {
                            Some(b',') => self.i += 1,
                            Some(b']') => {
                                self.i += 1;
                                break;
                            }
                            other => bail!("journal record: bad array byte {other:?}"),
                        }
                    }
                    Ok(JsonVal::ArrU64(vals))
                }
            }
            Some(b'-') | Some(b'0'..=b'9') => self.integer(),
            other => bail!("journal record: unexpected value byte {other:?}"),
        }
    }

    /// Parse one flat `{"k":v,...}` object.
    fn object(&mut self) -> Result<BTreeMap<String, JsonVal>> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(map);
        }
        loop {
            let key = self.string()?;
            self.eat(b':')?;
            let val = self.value()?;
            ensure!(
                map.insert(key.clone(), val).is_none(),
                "journal record: duplicate key `{key}`"
            );
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    break;
                }
                other => bail!("journal record: bad object byte {other:?}"),
            }
        }
        ensure!(self.i == self.b.len(), "journal record: trailing bytes");
        Ok(map)
    }
}

fn parse_hex16(s: &str, key: &str) -> Result<u64> {
    ensure!(
        s.len() == 16 && s.bytes().all(|b| b.is_ascii_hexdigit()),
        "journal record: `{key}` is not a 16-hex-digit bit pattern: `{s}`"
    );
    Ok(u64::from_str_radix(s, 16).expect("validated hex"))
}

fn get<'m>(map: &'m BTreeMap<String, JsonVal>, key: &str) -> Result<&'m JsonVal> {
    map.get(key)
        .with_context(|| format!("journal record: missing key `{key}`"))
}

fn get_u64(map: &BTreeMap<String, JsonVal>, key: &str) -> Result<u64> {
    match get(map, key)? {
        JsonVal::U64(v) => Ok(*v),
        other => bail!("journal record: `{key}` is not a u64: {other:?}"),
    }
}

fn get_i64(map: &BTreeMap<String, JsonVal>, key: &str) -> Result<i64> {
    match get(map, key)? {
        JsonVal::U64(v) => i64::try_from(*v).with_context(|| format!("`{key}` overflows i64")),
        JsonVal::I64(v) => Ok(*v),
        other => bail!("journal record: `{key}` is not an integer: {other:?}"),
    }
}

fn get_bool(map: &BTreeMap<String, JsonVal>, key: &str) -> Result<bool> {
    match get(map, key)? {
        JsonVal::Bool(v) => Ok(*v),
        other => bail!("journal record: `{key}` is not a bool: {other:?}"),
    }
}

fn get_str<'m>(map: &'m BTreeMap<String, JsonVal>, key: &str) -> Result<&'m str> {
    match get(map, key)? {
        JsonVal::Str(v) => Ok(v),
        other => bail!("journal record: `{key}` is not a string: {other:?}"),
    }
}

fn get_hex16(map: &BTreeMap<String, JsonVal>, key: &str) -> Result<u64> {
    parse_hex16(get_str(map, key)?, key)
}

fn get_arr_u64(map: &BTreeMap<String, JsonVal>, key: &str) -> Result<Vec<u64>> {
    match get(map, key)? {
        JsonVal::ArrU64(v) => Ok(v.clone()),
        other => bail!("journal record: `{key}` is not a u64 array: {other:?}"),
    }
}

fn get_bits4(map: &BTreeMap<String, JsonVal>, key: &str) -> Result<[u64; 4]> {
    match get(map, key)? {
        JsonVal::ArrStr(v) if v.len() == 4 => Ok([
            parse_hex16(&v[0], key)?,
            parse_hex16(&v[1], key)?,
            parse_hex16(&v[2], key)?,
            parse_hex16(&v[3], key)?,
        ]),
        other => bail!("journal record: `{key}` is not a 4-entry bits array: {other:?}"),
    }
}

/// Verify a line's trailing CRC field and return the parsed flat object.
fn parse_checked_line(line: &str) -> Result<BTreeMap<String, JsonVal>> {
    let tail = line
        .rfind(",\"crc\":\"")
        .context("journal line: missing crc field")?;
    let prefix = &line[..tail];
    let crc_part = &line[tail + 8..];
    ensure!(
        crc_part.len() == 10 && crc_part.ends_with("\"}"),
        "journal line: malformed crc suffix"
    );
    let recorded = u32::from_str_radix(&crc_part[..8], 16)
        .context("journal line: crc is not 8 hex digits")?;
    let computed = checksum(prefix.as_bytes());
    ensure!(
        recorded == computed,
        "journal line: crc mismatch (recorded {recorded:08x}, computed {computed:08x})"
    );
    Reader::new(line.as_bytes()).object()
}

/// Parse one sealed header line.
pub fn parse_header(line: &str) -> Result<JournalHeader> {
    let map = parse_checked_line(line)?;
    ensure!(
        get_str(&map, "ev")? == "journal",
        "journal header: first record is not an `ev:journal` header"
    );
    let version = get_u64(&map, "version")?;
    ensure!(
        version == JOURNAL_VERSION,
        "journal header: version {version} is not the supported {JOURNAL_VERSION}"
    );
    Ok(JournalHeader {
        version,
        fingerprint: get_str(&map, "fingerprint")?.to_string(),
    })
}

/// Parse one sealed round line.
pub fn parse_round(line: &str) -> Result<RoundEntry> {
    let map = parse_checked_line(line)?;
    ensure!(
        get_str(&map, "ev")? == "round",
        "journal record: not an `ev:round` record"
    );
    Ok(RoundEntry {
        iter: get_u64(&map, "iter")?,
        rng_fp: get_hex16(&map, "rng")?,
        participants: get_arr_u64(&map, "participants")?,
        selected: get_arr_u64(&map, "selected")?,
        frame_bytes: get_u64(&map, "frame_bytes")?,
        session_mode: match map.get("session_mode") {
            Some(JsonVal::Str(s)) => Some(s.clone()),
            Some(other) => bail!("journal record: `session_mode` is not a string: {other:?}"),
            None => None,
        },
        generation: match map.get("generation") {
            Some(JsonVal::U64(v)) => Some(*v),
            Some(other) => bail!("journal record: `generation` is not a u64: {other:?}"),
            None => None,
        },
        installs: match map.get("installs") {
            Some(JsonVal::Bool(v)) => Some(*v),
            Some(other) => bail!("journal record: `installs` is not a bool: {other:?}"),
            None => None,
        },
        resync_msgs: get_u64(&map, "resync_msgs")?,
        resync_extra: get_i64(&map, "resync_extra")?,
        evaluated: get_bool(&map, "evaluated")?,
        eval_clients: get_u64(&map, "eval_clients")?,
        m_s: get_u64(&map, "m_s")?,
        raw_bits: get_bits4(&map, "raw")?,
        smoothed_bits: get_bits4(&map, "smoothed")?,
        round_bytes: get_u64(&map, "round_bytes")?,
        down_bytes: get_u64(&map, "down_bytes")?,
        up_bytes: get_u64(&map, "up_bytes")?,
        down_msgs: get_u64(&map, "down_msgs")?,
        up_msgs: get_u64(&map, "up_msgs")?,
        sim_secs_bits: get_hex16(&map, "sim_secs")?,
        bandit_digest: get_hex16(&map, "bandit")?,
        session_digest: match map.get("session") {
            Some(JsonVal::Str(s)) => Some(parse_hex16(s, "session")?),
            Some(other) => bail!("journal record: `session` is not a string: {other:?}"),
            None => None,
        },
        policy_mode: match map.get("policy_mode") {
            Some(JsonVal::Str(s)) => Some(s.clone()),
            Some(other) => bail!("journal record: `policy_mode` is not a string: {other:?}"),
            None => None,
        },
        policy_skips: match map.get("policy_skips") {
            Some(JsonVal::U64(v)) => Some(*v),
            Some(other) => bail!("journal record: `policy_skips` is not a u64: {other:?}"),
            None => None,
        },
        policy_digest: match map.get("policy") {
            Some(JsonVal::Str(s)) => Some(parse_hex16(s, "policy")?),
            Some(other) => bail!("journal record: `policy` is not a string: {other:?}"),
            None => None,
        },
        up_full: match map.get("up_full") {
            Some(JsonVal::U64(v)) => Some(*v),
            Some(other) => bail!("journal record: `up_full` is not a u64: {other:?}"),
            None => None,
        },
        up_delta: match map.get("up_delta") {
            Some(JsonVal::U64(v)) => Some(*v),
            Some(other) => bail!("journal record: `up_delta` is not a u64: {other:?}"),
            None => None,
        },
        up_resyncs: match map.get("up_resyncs") {
            Some(JsonVal::U64(v)) => Some(*v),
            Some(other) => bail!("journal record: `up_resyncs` is not a u64: {other:?}"),
            None => None,
        },
        upload_digest: match map.get("upload") {
            Some(JsonVal::Str(s)) => Some(parse_hex16(s, "upload")?),
            Some(other) => bail!("journal record: `upload` is not a string: {other:?}"),
            None => None,
        },
    })
}

// ---------------------------------------------------------------------
// file I/O
// ---------------------------------------------------------------------

/// Read and validate a journal file, applying the torn-tail rule (see
/// the module docs): at most the final line may be dropped, and only
/// when it is provably an incomplete write. Any earlier damage is a
/// hard error.
pub fn read(path: &Path) -> Result<JournalFile> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading journal `{}`", path.display()))?;
    // split into newline-terminated lines, remembering each line's
    // start offset; a trailing chunk without '\n' is by definition an
    // incomplete write (appends always end in '\n' before the flush)
    let mut lines: Vec<(usize, &[u8])> = Vec::new();
    let mut start = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            lines.push((start, &bytes[start..i]));
            start = i + 1;
        }
    }
    let unterminated = (start < bytes.len()).then_some(start);
    ensure!(
        !lines.is_empty(),
        "journal `{}` has no complete header line",
        path.display()
    );
    let header_text = std::str::from_utf8(lines[0].1)
        .with_context(|| format!("journal `{}`: header is not utf8", path.display()))?;
    let header = parse_header(header_text)
        .with_context(|| format!("journal `{}`: invalid header", path.display()))?;

    let mut rounds = Vec::with_capacity(lines.len() - 1);
    let mut valid_len = lines[0].0 as u64 + lines[0].1.len() as u64 + 1;
    let mut torn = false;
    for (idx, (off, raw)) in lines.iter().enumerate().skip(1) {
        let parsed = std::str::from_utf8(raw)
            .map_err(anyhow::Error::from)
            .and_then(|text| parse_round(text));
        match parsed {
            Ok(entry) => {
                rounds.push(entry);
                valid_len = *off as u64 + raw.len() as u64 + 1;
            }
            Err(e) => {
                let is_tail = idx == lines.len() - 1 && unterminated.is_none();
                if is_tail {
                    warn_log!(
                        "journal `{}`: dropping torn final record (line {}): {e:#}; \
                         that round will re-run on resume",
                        path.display(),
                        idx + 1
                    );
                    torn = true;
                } else {
                    return Err(e).with_context(|| {
                        format!(
                            "journal `{}`: corrupt record at line {} (not the tail — \
                             this is file damage, not a torn write)",
                            path.display(),
                            idx + 1
                        )
                    });
                }
            }
        }
    }
    if let Some(off) = unterminated {
        warn_log!(
            "journal `{}`: dropping unterminated final line ({} bytes — an incomplete \
             write); that round will re-run on resume",
            path.display(),
            bytes.len() - off
        );
        torn = true;
    }
    Ok(JournalFile {
        header,
        rounds,
        valid_len,
        torn,
    })
}

/// Append-side handle: owns the open file and flushes one complete
/// line per record, which is what confines crash damage to the tail.
#[derive(Debug)]
pub struct JournalWriter {
    file: std::fs::File,
}

impl JournalWriter {
    /// Create (truncating) a fresh journal and durably write its header.
    pub fn create(path: &Path, fingerprint: &str) -> Result<JournalWriter> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating journal `{}`", path.display()))?;
        let header = JournalHeader {
            version: JOURNAL_VERSION,
            fingerprint: fingerprint.to_string(),
        };
        file.write_all(header.serialize().as_bytes())?;
        file.write_all(b"\n")?;
        file.flush()?;
        Ok(JournalWriter { file })
    }

    /// Reopen an existing journal for appending, first truncating it to
    /// `valid_len` (dropping a torn tail identified by [`read`]).
    pub fn append_to(path: &Path, valid_len: u64) -> Result<JournalWriter> {
        let mut file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("reopening journal `{}`", path.display()))?;
        file.set_len(valid_len)
            .with_context(|| format!("truncating journal `{}` torn tail", path.display()))?;
        file.seek(std::io::SeekFrom::End(0))?;
        Ok(JournalWriter { file })
    }

    /// Append one round record (one complete line + flush).
    pub fn append(&mut self, entry: &RoundEntry) -> Result<()> {
        self.file.write_all(entry.serialize().as_bytes())?;
        self.file.write_all(b"\n")?;
        self.file.flush()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// replay verification + the journal-driven round dump
// ---------------------------------------------------------------------

/// Refuse to replay under a different configuration: compare the
/// journal header's fingerprint against the resuming config's, naming
/// the first differing key (both are canonical `key=value;` lists from
/// `RunConfig::determinism_fingerprint`).
pub fn check_fingerprint(journaled: &str, current: &str) -> Result<()> {
    if journaled == current {
        return Ok(());
    }
    let parse = |s: &str| -> BTreeMap<String, String> {
        s.split(';')
            .filter(|kv| !kv.is_empty())
            .filter_map(|kv| kv.split_once('='))
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    };
    let (j, c) = (parse(journaled), parse(current));
    for (key, jv) in &j {
        match c.get(key) {
            Some(cv) if cv == jv => {}
            Some(cv) => bail!(
                "cannot resume: config differs from the journaled run at `{key}` \
                 (journaled {jv}, current {cv})"
            ),
            None => bail!("cannot resume: journaled config key `{key}` is unknown here"),
        }
    }
    for key in c.keys() {
        if !j.contains_key(key) {
            bail!("cannot resume: config key `{key}` was not journaled");
        }
    }
    bail!("cannot resume: config fingerprint differs from the journaled run");
}

/// Verify one replayed round against its journaled record, field by
/// field — the error names the round and the first diverging field, so
/// a broken resume is diagnosed at the exact state that drifted.
pub fn verify_round(journaled: &RoundEntry, live: &RoundEntry) -> Result<()> {
    macro_rules! check {
        ($field:ident) => {
            ensure!(
                journaled.$field == live.$field,
                "journal replay diverged at round {}: field `{}` — journaled {:?}, \
                 recomputed {:?}",
                journaled.iter,
                stringify!($field),
                journaled.$field,
                live.$field
            );
        };
    }
    check!(iter);
    check!(rng_fp);
    check!(participants);
    check!(selected);
    check!(frame_bytes);
    check!(session_mode);
    check!(generation);
    check!(installs);
    check!(resync_msgs);
    check!(resync_extra);
    check!(evaluated);
    check!(eval_clients);
    check!(m_s);
    check!(raw_bits);
    check!(smoothed_bits);
    check!(round_bytes);
    check!(down_bytes);
    check!(up_bytes);
    check!(down_msgs);
    check!(up_msgs);
    check!(sim_secs_bits);
    check!(bandit_digest);
    check!(session_digest);
    check!(policy_mode);
    check!(policy_skips);
    check!(policy_digest);
    check!(up_full);
    check!(up_delta);
    check!(up_resyncs);
    check!(upload_digest);
    Ok(())
}

/// Render journaled rounds as the exact `round_dump_string` text — the
/// journal-driven replay mode behind `fedpayload journal-dump` and the
/// CI determinism §7 leg: the golden round-dump digest re-derived from
/// the journal alone, no retraining. Byte-identical to the dump the
/// recording run wrote (the totals line reads the last record's
/// cumulative ledger fields).
pub fn render_round_dump(rounds: &[RoundEntry]) -> String {
    let mut text = String::from(
        "iter,m_s,raw_precision,raw_recall,raw_f1,raw_map,\
         smoothed_precision,smoothed_recall,smoothed_f1,smoothed_map,round_bytes\n",
    );
    for r in rounds {
        text.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{}\n",
            r.iter,
            r.m_s,
            f64_bits(f64::from_bits(r.raw_bits[0])),
            f64_bits(f64::from_bits(r.raw_bits[1])),
            f64_bits(f64::from_bits(r.raw_bits[2])),
            f64_bits(f64::from_bits(r.raw_bits[3])),
            f64_bits(f64::from_bits(r.smoothed_bits[0])),
            f64_bits(f64::from_bits(r.smoothed_bits[1])),
            f64_bits(f64::from_bits(r.smoothed_bits[2])),
            f64_bits(f64::from_bits(r.smoothed_bits[3])),
            r.round_bytes,
        ));
    }
    let (down_bytes, up_bytes, down_msgs, up_msgs, sim_secs_bits) = rounds
        .last()
        .map(|r| (r.down_bytes, r.up_bytes, r.down_msgs, r.up_msgs, r.sim_secs_bits))
        .unwrap_or((0, 0, 0, 0, 0f64.to_bits()));
    text.push_str(&format!(
        "totals,down_bytes={down_bytes},up_bytes={up_bytes},down_msgs={down_msgs},\
         up_msgs={up_msgs},sim_secs_bits={sim_secs_bits:016x}\n",
    ));
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entry(iter: u64, with_session: bool) -> RoundEntry {
        RoundEntry {
            iter,
            rng_fp: 0x0123_4567_89ab_cdef ^ iter,
            participants: vec![3, 1, 7, 2],
            selected: vec![0, 4, 9],
            frame_bytes: 1234,
            session_mode: with_session.then(|| "reuse".to_string()),
            generation: with_session.then_some(5),
            installs: with_session.then_some(true),
            resync_msgs: 2,
            resync_extra: -17,
            evaluated: true,
            eval_clients: 16,
            m_s: 3,
            raw_bits: [0.25f64.to_bits(), 0.5f64.to_bits(), 0.125f64.to_bits(), 0.75f64.to_bits()],
            smoothed_bits: [1, 2, 3, u64::MAX],
            round_bytes: 5555,
            down_bytes: 10_000,
            up_bytes: 9_999,
            down_msgs: 64,
            up_msgs: 64,
            sim_secs_bits: 1.5f64.to_bits(),
            bandit_digest: 0xdead_beef_cafe_f00d,
            session_digest: with_session.then_some(0xffff_0000_ffff_0000),
            // exercise the policy/upload keys on the same flag so both
            // the legacy (all-None) and extended key sets roundtrip
            policy_mode: with_session.then(|| "bandit".to_string()),
            policy_skips: with_session.then_some(3),
            policy_digest: with_session.then_some(0x1111_2222_3333_4444),
            up_full: with_session.then_some(12),
            up_delta: with_session.then_some(34),
            up_resyncs: with_session.then_some(1),
            upload_digest: with_session.then_some(0x5555_6666_7777_8888),
        }
    }

    #[test]
    fn header_roundtrips_with_escapes() {
        let h = JournalHeader {
            version: JOURNAL_VERSION,
            fingerprint: "seed=7;dataset.path=C:\\data\\\"x\";".to_string(),
        };
        let line = h.serialize();
        let back = parse_header(&line).unwrap();
        assert_eq!(back, h);
        assert_eq!(back.serialize(), line, "re-serialization identity");
    }

    #[test]
    fn round_entry_roundtrips_bit_exactly() {
        for with_session in [false, true] {
            let e = sample_entry(42, with_session);
            let line = e.serialize();
            let back = parse_round(&line).unwrap();
            assert_eq!(back, e);
            assert_eq!(back.serialize(), line);
        }
    }

    #[test]
    fn crc_rejects_any_flip() {
        let line = sample_entry(1, true).serialize();
        assert!(parse_round(&line).is_ok());
        for pos in [10, line.len() / 2, line.len() - 3] {
            let mut bad = line.clone().into_bytes();
            bad[pos] ^= 0x01;
            let bad = String::from_utf8(bad).unwrap();
            assert!(parse_round(&bad).is_err(), "flip at {pos} accepted");
        }
    }

    #[test]
    fn wrong_event_kind_rejected() {
        let h = JournalHeader {
            version: JOURNAL_VERSION,
            fingerprint: "x=1;".into(),
        };
        assert!(parse_round(&h.serialize()).is_err());
        assert!(parse_header(&sample_entry(1, false).serialize()).is_err());
    }

    fn write_journal(path: &Path, entries: &[RoundEntry]) {
        let mut w = JournalWriter::create(path, "fp=1;").unwrap();
        for e in entries {
            w.append(e).unwrap();
        }
    }

    #[test]
    fn read_reports_valid_prefix_and_torn_tail() {
        let dir = std::env::temp_dir().join("fedpayload_journal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("torn.jsonl");
        let entries: Vec<RoundEntry> = (1..=3).map(|i| sample_entry(i, i % 2 == 0)).collect();
        write_journal(&path, &entries);
        let clean = read(&path).unwrap();
        assert!(!clean.torn);
        assert_eq!(clean.rounds, entries);
        assert_eq!(
            clean.valid_len,
            std::fs::metadata(&path).unwrap().len(),
            "clean file is valid to the end"
        );
        // chop a few bytes off the tail: the final record is torn
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let torn = read(&path).unwrap();
        assert!(torn.torn);
        assert_eq!(torn.rounds, entries[..2]);
        // appending after truncation to valid_len yields a clean journal
        let mut w = JournalWriter::append_to(&path, torn.valid_len).unwrap();
        w.append(&entries[2]).unwrap();
        let healed = read(&path).unwrap();
        assert!(!healed.torn);
        assert_eq!(healed.rounds, entries);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn newline_terminated_but_corrupt_tail_is_torn_too() {
        let dir = std::env::temp_dir().join("fedpayload_journal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("badtail.jsonl");
        let entries: Vec<RoundEntry> = (1..=2).map(|i| sample_entry(i, false)).collect();
        write_journal(&path, &entries);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0x04; // inside the final record, newline intact
        std::fs::write(&path, &bytes).unwrap();
        let jf = read(&path).unwrap();
        assert!(jf.torn);
        assert_eq!(jf.rounds, entries[..1]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_middle_record_is_a_hard_error() {
        let dir = std::env::temp_dir().join("fedpayload_journal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("midcorrupt.jsonl");
        let entries: Vec<RoundEntry> = (1..=3).map(|i| sample_entry(i, false)).collect();
        write_journal(&path, &entries);
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        lines[2] = lines[2].replace("\"iter\":2", "\"iter\":9"); // breaks the crc
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();
        let err = read(&path).unwrap_err().to_string();
        assert!(err.contains("line 3"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_header_is_an_error() {
        let dir = std::env::temp_dir().join("fedpayload_journal_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("noheader.jsonl");
        std::fs::write(&path, "").unwrap();
        assert!(read(&path).is_err());
        std::fs::write(&path, sample_entry(1, false).serialize() + "\n").unwrap();
        assert!(read(&path).is_err(), "round record where the header belongs");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_mismatch_names_the_key() {
        check_fingerprint("seed=1;model.k=25;", "seed=1;model.k=25;").unwrap();
        let err = check_fingerprint("seed=1;model.k=25;", "seed=2;model.k=25;")
            .unwrap_err()
            .to_string();
        assert!(err.contains("`seed`"), "{err}");
        assert!(err.contains("journaled 1") && err.contains("current 2"), "{err}");
    }

    #[test]
    fn verify_round_names_the_diverging_field() {
        let a = sample_entry(7, true);
        verify_round(&a, &a.clone()).unwrap();
        let mut b = a.clone();
        b.bandit_digest ^= 1;
        let err = verify_round(&a, &b).unwrap_err().to_string();
        assert!(err.contains("round 7") && err.contains("`bandit_digest`"), "{err}");
    }

    #[test]
    fn render_round_dump_matches_the_trainer_renderer_shape() {
        let rounds: Vec<RoundEntry> = (1..=2).map(|i| sample_entry(i, false)).collect();
        let text = render_round_dump(&rounds);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4); // header + 2 rounds + totals
        assert!(lines[0].starts_with("iter,m_s,raw_precision"));
        assert!(lines[1].starts_with("1,3,"));
        assert!(lines[3].starts_with("totals,down_bytes=10000,up_bytes=9999,"));
        assert!(lines[3].ends_with(&format!("sim_secs_bits={:016x}", 1.5f64.to_bits())));
        // empty journal: zeroed totals, still well-formed
        let empty = render_round_dump(&[]);
        assert_eq!(empty.lines().count(), 2);
        assert!(empty.contains("totals,down_bytes=0,"));
        assert!(empty.contains("sim_secs_bits=0000000000000000"));
    }
}
