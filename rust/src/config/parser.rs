//! Hand-rolled TOML-subset parser (serde/toml unavailable offline).
//!
//! Supported grammar — everything the launcher's config files need:
//!
//! ```toml
//! # comment
//! [section]            # one level of nesting
//! int_key    = 42
//! float_key  = 0.99
//! bool_key   = true
//! string_key = "bts"
//! list_key   = [25, 50, 75]
//! ```
//!
//! Values are typed [`Value`]s; lookup is by `"section.key"` path.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed scalar or list value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Double-quoted string.
    Str(String),
    /// Bracketed list of values.
    List(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Bool(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "\"{v}\""),
            Value::List(vs) => {
                write!(f, "[")?;
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl Value {
    /// The value as an integer (errors on any other type).
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            _ => bail!("expected integer, got {self}"),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        usize::try_from(v).map_err(|_| anyhow!("expected non-negative integer, got {v}"))
    }

    /// The value as a non-negative 64-bit integer.
    pub fn as_u64(&self) -> Result<u64> {
        let v = self.as_i64()?;
        u64::try_from(v).map_err(|_| anyhow!("expected non-negative integer, got {v}"))
    }

    /// The value as a float (integers widen).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            _ => bail!("expected float, got {self}"),
        }
    }

    /// The value as a single-precision float (integers widen).
    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(v) => Ok(*v),
            _ => bail!("expected bool, got {self}"),
        }
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(v) => Ok(v),
            _ => bail!("expected string, got {self}"),
        }
    }

    /// The value as a list slice.
    pub fn as_list(&self) -> Result<&[Value]> {
        match self {
            Value::List(v) => Ok(v),
            _ => bail!("expected list, got {self}"),
        }
    }
}

/// Parsed document: `"section.key"` (or bare `"key"`) → [`Value`].
#[derive(Debug, Clone, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(val.trim())
                .with_context(|| format!("line {}: bad value for `{key}`", lineno + 1))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(path, value);
        }
        Ok(Doc { entries })
    }

    /// Look up by full path, e.g. `"train.iterations"`.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// Insert or overwrite (used by CLI `--set section.key=value`).
    pub fn set(&mut self, path: &str, value: Value) {
        self.entries.insert(path.to_string(), value);
    }

    /// Parse and apply a `path=value` override string.
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let (path, raw) = spec
            .split_once('=')
            .ok_or_else(|| anyhow!("override `{spec}`: expected path=value"))?;
        let value = parse_value(raw.trim())
            .or_else(|_| Ok::<Value, anyhow::Error>(Value::Str(raw.trim().to_string())))?;
        self.set(path.trim(), value);
        Ok(())
    }

    /// Iterate over all `path → value` entries in sorted path order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.entries.iter()
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the document empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated list"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_list(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::List(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    bail!("cannot parse `{s}`")
}

/// Split a list body on commas (no nested lists needed by our configs).
fn split_list(s: &str) -> Vec<&str> {
    s.split(',').collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            seed = 42            # top-level
            [train]
            iterations = 1000
            gamma = 0.999
            resume = false
            [bandit]
            strategy = "bts"
            levels = [25, 50, 75]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("seed").unwrap().as_i64().unwrap(), 42);
        assert_eq!(doc.get("train.iterations").unwrap().as_usize().unwrap(), 1000);
        assert!((doc.get("train.gamma").unwrap().as_f64().unwrap() - 0.999).abs() < 1e-12);
        assert!(!doc.get("train.resume").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("bandit.strategy").unwrap().as_str().unwrap(), "bts");
        let levels = doc.get("bandit.levels").unwrap().as_list().unwrap();
        assert_eq!(levels.len(), 3);
        assert_eq!(levels[1].as_i64().unwrap(), 50);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = Doc::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc.get("name").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn overrides() {
        let mut doc = Doc::parse("[train]\niterations = 10\n").unwrap();
        doc.apply_override("train.iterations=99").unwrap();
        doc.apply_override("bandit.strategy=bts").unwrap();
        assert_eq!(doc.get("train.iterations").unwrap().as_i64().unwrap(), 99);
        assert_eq!(doc.get("bandit.strategy").unwrap().as_str().unwrap(), "bts");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let err = Doc::parse("[broken\n").unwrap_err();
        assert!(err.to_string().contains("line 1"));
        let err = Doc::parse("justakey\n").unwrap_err();
        assert!(err.to_string().contains("key = value"));
    }

    #[test]
    fn type_mismatches_error() {
        let doc = Doc::parse("x = 5\n").unwrap();
        assert!(doc.get("x").unwrap().as_str().is_err());
        assert!(doc.get("x").unwrap().as_bool().is_err());
        assert_eq!(doc.get("x").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn empty_list() {
        let doc = Doc::parse("xs = []\n").unwrap();
        assert!(doc.get("xs").unwrap().as_list().unwrap().is_empty());
    }
}
