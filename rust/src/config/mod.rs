//! Typed run configuration + the TOML-subset parser behind it.
//!
//! [`RunConfig::paper_defaults`] pins every hyper-parameter from the
//! paper's Table 3 and §6.1; config files and `--set path=value` CLI
//! overrides layer on top. A unit test pins the defaults against the
//! paper so a drive-by edit cannot silently change the reproduction.

mod parser;

pub use parser::{Doc, Value};

use anyhow::{bail, Context, Result};

/// Which item-selection strategy drives the payload optimization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// FCF-BTS: Bayesian Thompson Sampling over items (the paper's method).
    Bts,
    /// FCF-Random: uniform random subset (paper baseline).
    Random,
    /// FCF (Original): full payload every round (paper upper bound).
    Full,
    /// ε-greedy over the same reward signal (ablation, not in the paper).
    EpsGreedy,
    /// UCB1 over the same reward signal (ablation, not in the paper).
    Ucb1,
}

impl Strategy {
    /// Parse a strategy name (`bts|random|full|eps_greedy|ucb1`).
    pub fn parse(s: &str) -> Result<Strategy> {
        Ok(match s {
            "bts" => Strategy::Bts,
            "random" => Strategy::Random,
            "full" => Strategy::Full,
            "eps_greedy" => Strategy::EpsGreedy,
            "ucb1" => Strategy::Ucb1,
            other => bail!("unknown bandit strategy `{other}` (bts|random|full|eps_greedy|ucb1)"),
        })
    }

    /// Strategy name for logs/CSV.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Bts => "bts",
            Strategy::Random => "random",
            Strategy::Full => "full",
            Strategy::EpsGreedy => "eps_greedy",
            Strategy::Ucb1 => "ucb1",
        }
    }
}

/// How the server combines the Θ buffered client gradients (Eq. 4 sums;
/// `Mean` is an ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregate {
    /// Sum the Θ buffered gradients (the paper's Eq. 4).
    Sum,
    /// Average them instead (ablation).
    Mean,
}

/// Dataset selection & synthesis parameters (§5, Table 2).
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    /// One of the calibrated synthetic presets (`movielens`, `lastfm`,
    /// `mind`, `synthetic-small`) or `file` to load `path`.
    pub name: String,
    /// For `name = "file"`: path to the interaction file.
    pub path: Option<String>,
    /// For `name = "file"`: file format (`movielens|lastfm|mind`).
    pub format: Option<String>,
    /// Synthetic generation: number of users (ignored when loading).
    pub users: usize,
    /// Synthetic generation: number of items.
    pub items: usize,
    /// Synthetic generation: number of interactions.
    pub interactions: usize,
    /// Zipf exponent for item popularity.
    pub zipf_s: f64,
    /// Planted latent rank of the ground-truth model.
    pub planted_rank: usize,
    /// Fraction of each user's interactions placed in the train split.
    pub train_frac: f64,
    /// Minimum interactions per user (MIND applies >= 5 clicks).
    pub min_user_interactions: usize,
}

/// FCF model hyper-parameters (Table 3).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Number of latent factors K (paper: 25).
    pub k: usize,
    /// Ridge regularization λ (paper: 1.0).
    pub lam: f32,
    /// Implicit-feedback confidence weight α (paper: 4).
    pub alpha: f32,
    /// Adam learning rate η (paper: 0.01).
    pub eta: f32,
    /// Adam first-moment decay β₁ (paper: 0.1).
    pub beta1: f32,
    /// Adam second-moment decay β₂ (paper: 0.99).
    pub beta2: f32,
    /// Adam denominator ε (paper: 1e-8).
    pub eps: f32,
    /// Std-dev of the Q/P initialization.
    pub init_scale: f32,
}

/// Bandit / payload-selection parameters (§3, §6.1).
#[derive(Debug, Clone)]
pub struct BanditConfig {
    /// Which item-selection strategy drives the payload optimization.
    pub strategy: Strategy,
    /// Prior mean μ_θ (paper: 0).
    pub mu0: f64,
    /// Prior precision τ_θ (paper: 10000).
    pub tau0: f64,
    /// Reward regularization γ (paper: 0.999).
    pub gamma: f64,
    /// ε for the ε-greedy ablation.
    pub eps_greedy: f64,
    /// Scale the gradient fed to Eq. 13 by 1/Θ (`true`, default) or use
    /// the raw Eq. 4 sum (`false`). The paper's reward scale is not
    /// recoverable from the text; 1/Θ keeps rewards commensurate with the
    /// N(0, 1/τ_θ) prior so BTS explores as §7 describes (convergence at
    /// ~400–450 iterations instead of locking onto the round-1 subset).
    pub mean_scaled_rewards: bool,
    /// Standardize each round's rewards to zero mean / unit variance
    /// before the posterior update (default true; ablation switch).
    pub normalize_rewards: bool,
    /// Scale applied after standardization: the exploitation strength of
    /// the posterior relative to the N(0, 1/τ_θ) prior. Calibrated so the
    /// BTS-vs-Random separation matches the paper's Figure 2 shape (see
    /// EXPERIMENTS.md §Calibration).
    pub reward_std_scale: f64,
    /// Eq. 13 cosine weighting: `"literal"` = the printed `(1 − γt)`,
    /// `"power"` = `(1 − γ^t)` matching the paper's textual description.
    /// See the `reward` module docs for the discrepancy.
    pub cosine_weight: &'static str,
    /// What `t` means in Eq. 13: `"per_item"` (this item's observation
    /// count; default) or `"global"` (FL iteration). See `reward` docs.
    pub time_base: &'static str,
}

/// Federated training loop parameters (§6.1–6.2).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// FL iterations per model rebuild (paper: 1000).
    pub iterations: usize,
    /// Θ — client updates buffered before a global update.
    pub theta: usize,
    /// Fraction of items transmitted per round, M_s / M.
    /// 1.0 == FCF (Original); 0.10 == "90% payload reduction".
    pub payload_fraction: f64,
    /// Independent model rebuilds averaged in reports (paper: 3).
    pub rebuilds: usize,
    /// Global-metric smoothing window (paper: last 10 values).
    pub metric_window: usize,
    /// How the Θ buffered gradients combine (paper: sum).
    pub aggregate: Aggregate,
    /// Evaluate contributing clients' test metrics every round (paper
    /// semantics). Setting >1 evaluates every n-th round to save time.
    pub eval_every: usize,
}

/// Wire codec for the round-trip payloads (the second payload-reduction
/// axis; see the `wire` module). Defaults preserve exact f32 round-trips.
#[derive(Debug, Clone)]
pub struct CodecConfig {
    /// Precision on the wire: `f64 | f32 | f16 | int8 | vq8 | vq4 |
    /// vq8r`. The model is f32 in memory, so `f32` is lossless; `f64`
    /// reproduces the paper's Table 1 64-bit accounting; `f16`/`int8`
    /// trade bounded quantization error for 2×/~3.7× smaller frames;
    /// the `vq*` modes product-quantize dense downloads against a
    /// per-round codebook (`wire::vq`) for a further ~3.4× under int8,
    /// with uploads falling back to int8 value planes.
    pub precision: crate::wire::Precision,
    /// Lossless entropy coding on top of the quantizer:
    /// `none | varint | range | full` (varint = delta+LEB128 sparse
    /// indices, range = adaptive range-coded payload bytes, full = both).
    /// Decoded payloads are bit-identical across modes — only the
    /// measured frame lengths change.
    pub entropy: crate::wire::EntropyMode,
    /// Cross-round codebook sessions for the vq download codecs:
    /// `off | delta | auto` (`wire::vq::session`). `off` ships a fresh
    /// in-frame codebook every round (stateless v1 frames); `delta`
    /// ships int8 centroid deltas against the previous generation
    /// (bit-transparent to training); `auto` additionally reuses the
    /// cached codebook verbatim while its measured reconstruction
    /// error stays within budget, choosing per frame by measured
    /// encoded bytes. Ignored (with a warning) for scalar precisions.
    pub codebook_reuse: crate::wire::ReuseMode,
    /// Upload top-k sparsification: keep only the k largest-norm gradient
    /// rows per upload (0 = keep all nonzero rows).
    pub sparse_topk: usize,
    /// `--sparse-topk auto`: tune the upload top-k per frame from the
    /// measured encoded-bytes and retained-energy curves instead of a
    /// fixed count (`wire::sparse::auto_top_k`). Mutually exclusive
    /// with a nonzero `sparse_topk`.
    pub sparse_topk_auto: bool,
    /// Drop upload rows with L2 norm ≤ this threshold (0.0 = drop only
    /// exactly-zero rows, which is lossless).
    pub sparse_threshold: f64,
    /// SecEmb-style upload deltas (`wire::upload`): ship each client's
    /// sparse gradient as int8 symbol-plane deltas against its
    /// previous-round upload under generation-tagged session frames,
    /// with typed stale-reference resync. Bit-transparent to training —
    /// only the measured upload byte ledger changes. Requires an
    /// int8-class upload plane (precision `int8` or any `vq*`).
    pub upload_delta: bool,
}

/// Per-client payload policy knobs (`[policy]`, `server::policy`): how
/// each round's participants get their download precision / top-k /
/// participation decided under simulated per-client budgets.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// `uniform` (legacy single-codec path, the default), `budget`
    /// (deterministic greedy under the drawn budget), or `bandit`
    /// (per-budget-class Thompson sampling over the precision arms).
    pub mode: crate::server::policy::PolicyMode,
    /// Transfer window the per-client byte budget covers, in ms.
    pub budget_window_ms: f64,
    /// Floor of the per-client drawn bandwidth fraction: effective
    /// bandwidth is `simnet.bandwidth_mbps × U[min_frac, 1)`.
    pub min_bandwidth_frac: f64,
    /// Clients whose drawn battery level (U[0,1)) is below this floor
    /// sit the round out (0.0 = battery never skips).
    pub battery_floor: f64,
    /// Weight of the normalized decode-SSE term against the normalized
    /// bytes term in the bandit's arm reward.
    pub sse_weight: f64,
}

impl Default for PolicyConfig {
    fn default() -> PolicyConfig {
        PolicyConfig {
            mode: crate::server::policy::PolicyMode::Uniform,
            budget_window_ms: 250.0,
            min_bandwidth_frac: 0.25,
            battery_floor: 0.0,
            sse_weight: 1.0,
        }
    }
}

/// Payload / network model (Table 1).
#[derive(Debug, Clone)]
pub struct SimNetConfig {
    /// Bits per model parameter (paper's Table 1 uses 64).
    pub bits_per_param: u32,
    /// Simulated link bandwidth in Mbit/s.
    pub bandwidth_mbps: f64,
    /// Simulated per-message latency in ms.
    pub latency_ms: f64,
}

/// Execution backend knobs.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Directory holding the AOT-compiled HLO artifacts.
    pub artifacts_dir: String,
    /// `pjrt` (AOT artifacts through the XLA CPU client) or `reference`
    /// (pure-Rust differential backend, used by tests and available as a
    /// no-artifacts fallback).
    pub backend: String,
    /// Compute lanes for the sharded client-fleet executor
    /// (`runtime::fleet`): the round's B-sized client batches are
    /// distributed over this many lanes (the coordinator thread plus
    /// `threads - 1` workers), each owning its own `ComputeBackend` (the
    /// PJRT client handle is thread-local). Outcomes merge in batch
    /// order, so every value produces bit-identical training to
    /// `threads = 1`. Must be >= 1; values beyond ⌈Θ / B⌉ idle.
    pub threads: usize,
}

/// Flight-recorder knobs (`telemetry::trace`): where structured round
/// events and metrics snapshots go, and how much detail to record.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// JSONL trace destination (`--trace-out`); `None` disables the
    /// recorder entirely — the hot path then pays one relaxed atomic
    /// load per would-be event.
    pub out: Option<String>,
    /// Prometheus-text metrics snapshot destination (`--metrics-out`),
    /// rewritten after every round. Snapshots contain decision-side
    /// values only, so they are thread-count invariant.
    pub metrics_out: Option<String>,
    /// Recording depth: `off | decision | full`. `decision` captures
    /// the per-round decision events; `full` adds per-batch lane spans.
    pub level: crate::telemetry::TraceLevel,
}

/// Round-journal knobs (`server::journal`): the append-only event log
/// that makes the coordinator crash-safe, and the replay entry point.
#[derive(Debug, Clone, Default)]
pub struct JournalConfig {
    /// Append-only JSONL journal destination (`--journal`); `None`
    /// disables journaling. Every completed round appends one
    /// checksummed record before the trainer moves on.
    pub path: Option<String>,
    /// Journal to replay before training continues (`--resume`). The
    /// journaled rounds are re-executed with per-round verification
    /// against the recorded digests; training then continues exactly
    /// where the journaled run stopped. When `path` is unset, new
    /// rounds append to this same file.
    pub resume: Option<String>,
}

/// Fleet-scale simulation knobs (`[fleet]`): participant sampling for
/// runs where the simulated client population is much larger than the
/// per-round cohort.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FleetConfig {
    /// Per-round participant sample size (`--theta-sample`). `None`
    /// (the default) keeps the legacy semantics: every round draws
    /// `train.theta` participants from the trainer's main RNG stream,
    /// byte-for-byte unchanged from previous releases. `Some(k)` draws
    /// `k` distinct participants per round from the **dedicated**
    /// participant PCG stream ([`crate::rng::ParticipantSampler`]) —
    /// keyed purely by `(seed, round)`, so the sequence is independent
    /// of thread count and of every other stream — which is what makes
    /// million-client fleets affordable (O(k) sampling, not O(fleet))
    /// and journal replay exact. Must be `>= 1` and `<= train.theta`.
    pub theta_sample: Option<usize>,
}

/// TCP transport lane knobs (`[transport]`): how the `coordinator` and
/// `client` bins find each other and how the coordinator schedules a
/// round over real sockets (`transport` module). Every field here is
/// bit-transparent to training — lane choice and transport timing
/// never reach a round's decisions — so none of them enter
/// [`RunConfig::determinism_fingerprint`]: a client process with a
/// different `connect` address must still fingerprint-match the
/// coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransportConfig {
    /// Coordinator listen address (`--listen`). Port 0 picks an
    /// ephemeral port (written via `--port-file` for the clients).
    pub listen: String,
    /// Client connect address (`--connect`), unless `--port-file`
    /// supplies one.
    pub connect: String,
    /// Client process slots the coordinator waits for before round 1
    /// (`--transport-clients`). Hosted fleet clients are sharded
    /// `cid % clients == slot`.
    pub clients: usize,
    /// Per-round deadline in milliseconds (`--round-deadline-ms`).
    /// A round that cannot finish by then aggregates what arrived and
    /// drops the stalled clients; `0` disables the deadline.
    pub round_deadline_ms: u64,
    /// Per-client download bandwidth cap in bits/second
    /// (`--bandwidth-cap`). `0` disables pacing. Pacing delays when a
    /// frame is sent, never what it contains.
    pub bandwidth_cap_bps: u64,
    /// Block at each round start until every slot is occupied again
    /// (`--wait-rejoin`): the reconnect-resync e2e's determinism knob —
    /// a rejoining process is resynced rather than dropped.
    pub wait_rejoin: bool,
    /// How long `wait_rejoin` waits, in milliseconds, before giving up
    /// and running the round with the slots it has.
    pub rejoin_wait_ms: u64,
}

impl Default for TransportConfig {
    fn default() -> TransportConfig {
        TransportConfig {
            listen: "127.0.0.1:0".into(),
            connect: "127.0.0.1:7465".into(),
            clients: 1,
            round_deadline_ms: 30_000,
            bandwidth_cap_bps: 0,
            wait_rejoin: false,
            rejoin_wait_ms: 10_000,
        }
    }
}

/// Complete run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Master seed for data synthesis, splits, and all stochastic parts.
    pub seed: u64,
    /// Dataset selection & synthesis parameters.
    pub dataset: DatasetConfig,
    /// FCF model hyper-parameters.
    pub model: ModelConfig,
    /// Bandit / payload-selection parameters.
    pub bandit: BanditConfig,
    /// Federated training loop parameters.
    pub train: TrainConfig,
    /// Wire codec for the round-trip payloads.
    pub codec: CodecConfig,
    /// Per-client payload policy knobs.
    pub policy: PolicyConfig,
    /// Payload / network model parameters.
    pub simnet: SimNetConfig,
    /// Execution backend knobs.
    pub runtime: RuntimeConfig,
    /// Flight-recorder knobs.
    pub trace: TraceConfig,
    /// Round-journal knobs.
    pub journal: JournalConfig,
    /// Fleet-scale simulation knobs.
    pub fleet: FleetConfig,
    /// TCP transport lane knobs (ignored by the in-process bin).
    pub transport: TransportConfig,
}

impl RunConfig {
    /// Defaults exactly as the paper's Table 3 / §6.1 prescribe, with the
    /// Movielens-scale synthetic dataset.
    pub fn paper_defaults() -> RunConfig {
        RunConfig {
            seed: 2021,
            dataset: DatasetConfig {
                name: "movielens".into(),
                path: None,
                format: None,
                users: 6040,
                items: 3064,
                interactions: 914_676,
                zipf_s: 1.05,
                planted_rank: 16,
                train_frac: 0.8,
                min_user_interactions: 5,
            },
            model: ModelConfig {
                k: 25,
                lam: 1.0,
                alpha: 4.0,
                eta: 0.01,
                beta1: 0.1,
                beta2: 0.99,
                eps: 1e-8,
                init_scale: 0.1,
            },
            bandit: BanditConfig {
                strategy: Strategy::Bts,
                mu0: 0.0,
                tau0: 10_000.0,
                gamma: 0.999,
                eps_greedy: 0.1,
                mean_scaled_rewards: true,
                normalize_rewards: true,
                reward_std_scale: 5.0,
                cosine_weight: "power",
                time_base: "per_item",
            },
            train: TrainConfig {
                iterations: 1000,
                theta: 100,
                payload_fraction: 0.10,
                rebuilds: 3,
                metric_window: 10,
                aggregate: Aggregate::Sum,
                eval_every: 1,
            },
            codec: CodecConfig {
                precision: crate::wire::Precision::F32,
                entropy: crate::wire::EntropyMode::None,
                codebook_reuse: crate::wire::ReuseMode::Off,
                sparse_topk: 0,
                sparse_topk_auto: false,
                sparse_threshold: 0.0,
                upload_delta: false,
            },
            policy: PolicyConfig::default(),
            simnet: SimNetConfig {
                bits_per_param: 64,
                bandwidth_mbps: 20.0,
                latency_ms: 50.0,
            },
            runtime: RuntimeConfig {
                artifacts_dir: "artifacts".into(),
                backend: "pjrt".into(),
                threads: 4,
            },
            trace: TraceConfig {
                out: None,
                metrics_out: None,
                level: crate::telemetry::TraceLevel::Decision,
            },
            journal: JournalConfig::default(),
            fleet: FleetConfig::default(),
            transport: TransportConfig::default(),
        }
    }

    /// Apply one of the three paper dataset presets (Table 2 scales + the
    /// per-dataset Θ from §6.1).
    pub fn apply_dataset_preset(&mut self, name: &str) -> Result<()> {
        match name {
            "movielens" => {
                self.dataset.users = 6040;
                self.dataset.items = 3064;
                self.dataset.interactions = 914_676;
                self.dataset.zipf_s = 1.05;
                self.train.theta = 100;
            }
            "lastfm" => {
                self.dataset.users = 1892;
                self.dataset.items = 17_632;
                self.dataset.interactions = 92_834;
                self.dataset.zipf_s = 1.1;
                self.train.theta = 100;
            }
            "mind" => {
                self.dataset.users = 16_026;
                self.dataset.items = 6923;
                self.dataset.interactions = 163_137;
                self.dataset.zipf_s = 1.3;
                self.train.theta = 500;
            }
            "synthetic-small" => {
                self.dataset.users = 256;
                self.dataset.items = 512;
                self.dataset.interactions = 8_192;
                self.dataset.zipf_s = 1.1;
                self.train.theta = 32;
            }
            "file" => {}
            other => bail!("unknown dataset preset `{other}`"),
        }
        self.dataset.name = name.to_string();
        Ok(())
    }

    /// Build from a parsed document layered over the paper defaults.
    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        let mut cfg = RunConfig::paper_defaults();
        if let Some(v) = doc.get("dataset.name") {
            cfg.apply_dataset_preset(v.as_str()?)?;
        }
        macro_rules! take {
            ($path:literal, $target:expr, $conv:ident) => {
                if let Some(v) = doc.get($path) {
                    $target = v.$conv().context(concat!("config key ", $path))?;
                }
            };
        }
        take!("seed", cfg.seed, as_u64);
        if let Some(v) = doc.get("dataset.path") {
            cfg.dataset.path = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("dataset.format") {
            cfg.dataset.format = Some(v.as_str()?.to_string());
        }
        take!("dataset.users", cfg.dataset.users, as_usize);
        take!("dataset.items", cfg.dataset.items, as_usize);
        take!("dataset.interactions", cfg.dataset.interactions, as_usize);
        take!("dataset.zipf_s", cfg.dataset.zipf_s, as_f64);
        take!("dataset.planted_rank", cfg.dataset.planted_rank, as_usize);
        take!("dataset.train_frac", cfg.dataset.train_frac, as_f64);
        take!(
            "dataset.min_user_interactions",
            cfg.dataset.min_user_interactions,
            as_usize
        );
        take!("model.k", cfg.model.k, as_usize);
        take!("model.lam", cfg.model.lam, as_f32);
        take!("model.alpha", cfg.model.alpha, as_f32);
        take!("model.eta", cfg.model.eta, as_f32);
        take!("model.beta1", cfg.model.beta1, as_f32);
        take!("model.beta2", cfg.model.beta2, as_f32);
        take!("model.eps", cfg.model.eps, as_f32);
        take!("model.init_scale", cfg.model.init_scale, as_f32);
        if let Some(v) = doc.get("bandit.strategy") {
            cfg.bandit.strategy = Strategy::parse(v.as_str()?)?;
        }
        take!("bandit.mu0", cfg.bandit.mu0, as_f64);
        take!("bandit.tau0", cfg.bandit.tau0, as_f64);
        take!("bandit.gamma", cfg.bandit.gamma, as_f64);
        take!("bandit.eps_greedy", cfg.bandit.eps_greedy, as_f64);
        take!(
            "bandit.mean_scaled_rewards",
            cfg.bandit.mean_scaled_rewards,
            as_bool
        );
        take!("bandit.normalize_rewards", cfg.bandit.normalize_rewards, as_bool);
        take!("bandit.reward_std_scale", cfg.bandit.reward_std_scale, as_f64);
        if let Some(v) = doc.get("bandit.cosine_weight") {
            cfg.bandit.cosine_weight = match v.as_str()? {
                "power" => "power",
                "literal" => "literal",
                other => bail!("unknown cosine_weight `{other}` (power|literal)"),
            };
        }
        if let Some(v) = doc.get("bandit.time_base") {
            cfg.bandit.time_base = match v.as_str()? {
                "per_item" => "per_item",
                "global" => "global",
                other => bail!("unknown time_base `{other}` (per_item|global)"),
            };
        }
        take!("train.iterations", cfg.train.iterations, as_usize);
        take!("train.theta", cfg.train.theta, as_usize);
        take!("train.payload_fraction", cfg.train.payload_fraction, as_f64);
        take!("train.rebuilds", cfg.train.rebuilds, as_usize);
        take!("train.metric_window", cfg.train.metric_window, as_usize);
        take!("train.eval_every", cfg.train.eval_every, as_usize);
        if let Some(v) = doc.get("train.aggregate") {
            cfg.train.aggregate = match v.as_str()? {
                "sum" => Aggregate::Sum,
                "mean" => Aggregate::Mean,
                other => bail!("unknown aggregate `{other}` (sum|mean)"),
            };
        }
        if let Some(v) = doc.get("codec.precision") {
            cfg.codec.precision = crate::wire::Precision::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.get("codec.entropy") {
            cfg.codec.entropy = crate::wire::EntropyMode::parse(v.as_str()?)?;
        }
        if let Some(v) = doc.get("codec.codebook_reuse") {
            cfg.codec.codebook_reuse = crate::wire::ReuseMode::parse(v.as_str()?)?;
        }
        take!("codec.sparse_topk", cfg.codec.sparse_topk, as_usize);
        take!(
            "codec.sparse_topk_auto",
            cfg.codec.sparse_topk_auto,
            as_bool
        );
        take!(
            "codec.sparse_threshold",
            cfg.codec.sparse_threshold,
            as_f64
        );
        take!("codec.upload_delta", cfg.codec.upload_delta, as_bool);
        if let Some(v) = doc.get("policy.mode") {
            cfg.policy.mode = crate::server::policy::PolicyMode::parse(v.as_str()?)?;
        }
        take!(
            "policy.budget_window_ms",
            cfg.policy.budget_window_ms,
            as_f64
        );
        take!(
            "policy.min_bandwidth_frac",
            cfg.policy.min_bandwidth_frac,
            as_f64
        );
        take!("policy.battery_floor", cfg.policy.battery_floor, as_f64);
        take!("policy.sse_weight", cfg.policy.sse_weight, as_f64);
        take!("simnet.bits_per_param", cfg.simnet.bits_per_param, as_u64_u32);
        take!("simnet.bandwidth_mbps", cfg.simnet.bandwidth_mbps, as_f64);
        take!("simnet.latency_ms", cfg.simnet.latency_ms, as_f64);
        if let Some(v) = doc.get("runtime.artifacts_dir") {
            cfg.runtime.artifacts_dir = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("runtime.backend") {
            cfg.runtime.backend = v.as_str()?.to_string();
        }
        take!("runtime.threads", cfg.runtime.threads, as_usize);
        if let Some(v) = doc.get("trace.out") {
            cfg.trace.out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("trace.metrics_out") {
            cfg.trace.metrics_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("trace.level") {
            let s = v.as_str()?;
            cfg.trace.level = crate::telemetry::parse_trace_level(s)
                .ok_or_else(|| anyhow::anyhow!("unknown trace.level `{s}` (off|decision|full)"))?;
        }
        if let Some(v) = doc.get("journal.path") {
            cfg.journal.path = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("journal.resume") {
            cfg.journal.resume = Some(v.as_str()?.to_string());
        }
        if let Some(v) = doc.get("fleet.theta_sample") {
            cfg.fleet.theta_sample =
                Some(v.as_usize().context("config key fleet.theta_sample")?);
        }
        if let Some(v) = doc.get("transport.listen") {
            cfg.transport.listen = v.as_str()?.to_string();
        }
        if let Some(v) = doc.get("transport.connect") {
            cfg.transport.connect = v.as_str()?.to_string();
        }
        take!("transport.clients", cfg.transport.clients, as_usize);
        take!(
            "transport.round_deadline_ms",
            cfg.transport.round_deadline_ms,
            as_u64
        );
        take!(
            "transport.bandwidth_cap_bps",
            cfg.transport.bandwidth_cap_bps,
            as_u64
        );
        take!("transport.wait_rejoin", cfg.transport.wait_rejoin, as_bool);
        take!(
            "transport.rejoin_wait_ms",
            cfg.transport.rejoin_wait_ms,
            as_u64
        );
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse a config file's text (layered over paper defaults).
    pub fn from_toml_str(text: &str) -> Result<RunConfig> {
        RunConfig::from_doc(&Doc::parse(text)?)
    }

    /// Sanity-check invariants the trainer depends on.
    pub fn validate(&self) -> Result<()> {
        if self.model.k == 0 {
            bail!("model.k must be > 0");
        }
        if !(0.0..=1.0).contains(&self.train.payload_fraction) || self.train.payload_fraction == 0.0
        {
            bail!(
                "train.payload_fraction must be in (0, 1], got {}",
                self.train.payload_fraction
            );
        }
        if self.train.theta == 0 {
            bail!("train.theta must be > 0");
        }
        if let Some(k) = self.fleet.theta_sample {
            if k == 0 {
                bail!(
                    "fleet.theta_sample must be > 0 (it is the per-round participant \
                     draw; unset it to disable sampling)"
                );
            }
            if k > self.train.theta {
                bail!(
                    "fleet.theta_sample ({k}) must not exceed train.theta ({}) — the \
                     sample is drawn from each round's Θ cohort budget",
                    self.train.theta
                );
            }
        }
        if !(0.0 < self.dataset.train_frac && self.dataset.train_frac < 1.0) {
            bail!("dataset.train_frac must be in (0, 1)");
        }
        if self.train.metric_window == 0 {
            bail!("train.metric_window must be > 0");
        }
        if !(self.codec.sparse_threshold.is_finite() && self.codec.sparse_threshold >= 0.0) {
            bail!(
                "codec.sparse_threshold must be a finite value >= 0, got {}",
                self.codec.sparse_threshold
            );
        }
        if self.codec.sparse_topk_auto && self.codec.sparse_topk > 0 {
            bail!(
                "codec.sparse_topk_auto and a fixed codec.sparse_topk ({}) are mutually \
                 exclusive — pick one",
                self.codec.sparse_topk
            );
        }
        // the simulated network model feeds analytic round-time division
        // and the byte ledger — a zero or NaN here poisons every
        // sim-seconds figure rounds later, so reject it by name up front
        if !(self.simnet.bandwidth_mbps.is_finite() && self.simnet.bandwidth_mbps > 0.0) {
            bail!(
                "simnet.bandwidth_mbps must be a finite value > 0, got {}",
                self.simnet.bandwidth_mbps
            );
        }
        if !(self.simnet.latency_ms.is_finite() && self.simnet.latency_ms >= 0.0) {
            bail!(
                "simnet.latency_ms must be a finite value >= 0, got {}",
                self.simnet.latency_ms
            );
        }
        // a non-finite prior or reward weight corrupts every posterior
        // update silently and only surfaces as a baffling journal-replay
        // divergence — fail at startup instead
        if !(self.bandit.gamma.is_finite() && 0.0 < self.bandit.gamma && self.bandit.gamma <= 1.0) {
            bail!(
                "bandit.gamma must be a finite value in (0, 1], got {}",
                self.bandit.gamma
            );
        }
        if !self.bandit.mu0.is_finite() {
            bail!("bandit.mu0 must be finite, got {}", self.bandit.mu0);
        }
        if !self.bandit.tau0.is_finite() {
            bail!("bandit.tau0 must be finite, got {}", self.bandit.tau0);
        }
        if !(self.model.lam.is_finite() && self.model.lam > 0.0) {
            bail!("model.lam must be a finite value > 0, got {}", self.model.lam);
        }
        if !self.model.alpha.is_finite() {
            bail!("model.alpha must be finite, got {}", self.model.alpha);
        }
        if !self.model.eta.is_finite() {
            bail!("model.eta must be finite, got {}", self.model.eta);
        }
        {
            use crate::server::policy::PolicyMode;
            if !(self.policy.budget_window_ms.is_finite() && self.policy.budget_window_ms > 0.0) {
                bail!(
                    "policy.budget_window_ms must be a finite value > 0, got {}",
                    self.policy.budget_window_ms
                );
            }
            if !(self.policy.min_bandwidth_frac.is_finite()
                && 0.0 < self.policy.min_bandwidth_frac
                && self.policy.min_bandwidth_frac <= 1.0)
            {
                bail!(
                    "policy.min_bandwidth_frac must be a finite value in (0, 1], got {}",
                    self.policy.min_bandwidth_frac
                );
            }
            if !(self.policy.battery_floor.is_finite()
                && (0.0..=1.0).contains(&self.policy.battery_floor))
            {
                bail!(
                    "policy.battery_floor must be a finite value in [0, 1], got {}",
                    self.policy.battery_floor
                );
            }
            if !(self.policy.sse_weight.is_finite() && self.policy.sse_weight >= 0.0) {
                bail!(
                    "policy.sse_weight must be a finite value >= 0, got {}",
                    self.policy.sse_weight
                );
            }
            if self.policy.mode != PolicyMode::Uniform {
                if self.codec.codebook_reuse != crate::wire::ReuseMode::Off {
                    bail!(
                        "policy.mode = {} is incompatible with codec.codebook_reuse = {} — \
                         per-client arms re-encode each round, so cross-round codebook \
                         sessions cannot apply (set codec.codebook_reuse = \"off\")",
                        self.policy.mode.name(),
                        self.codec.codebook_reuse.name()
                    );
                }
                if self.codec.sparse_topk_auto {
                    bail!(
                        "policy.mode = {} is incompatible with codec.sparse_topk_auto — \
                         the policy layer owns the per-client top-k decision",
                        self.policy.mode.name()
                    );
                }
            }
        }
        if self.codec.upload_delta
            && self.codec.precision.for_uploads() != crate::wire::Precision::Int8
        {
            bail!(
                "codec.upload_delta requires an int8-class upload plane (codec.precision \
                 int8 or vq8/vq4/vq8r), got codec.precision = {}",
                self.codec.precision.name()
            );
        }
        match self.runtime.backend.as_str() {
            "pjrt" | "reference" => {}
            other => bail!("unknown runtime.backend `{other}` (pjrt|reference)"),
        }
        if self.runtime.threads == 0 {
            bail!("runtime.threads must be >= 1 (the number of parallel fleet compute lanes)");
        }
        if self.transport.clients == 0 {
            bail!("transport.clients must be >= 1 (the number of client process slots)");
        }
        // output files are opened mid-run; a missing parent directory
        // must fail here, at startup, naming the flag — not panic at
        // the first write hundreds of rounds in
        if let Some(p) = &self.trace.out {
            check_parent_dir(p, "--trace-out", "trace.out")?;
        }
        if let Some(p) = &self.trace.metrics_out {
            check_parent_dir(p, "--metrics-out", "trace.metrics_out")?;
        }
        if let Some(p) = &self.journal.path {
            check_parent_dir(p, "--journal", "journal.path")?;
        }
        if let Some(p) = &self.journal.resume {
            if !std::path::Path::new(p).is_file() {
                bail!("--resume (journal.resume): journal file `{p}` does not exist");
            }
        }
        Ok(())
    }

    /// Canonical fingerprint of every determinism-relevant config field:
    /// the `key=value;` list a journal header pins so `--resume` refuses
    /// to replay a run under a different configuration (f64/f32 values
    /// render as exact bit patterns — two configs fingerprint equally
    /// iff they train identically). Deliberately **excluded**: things a
    /// resume may legitimately change — `train.iterations` (a resume may
    /// extend the run) and `train.rebuilds`, `runtime.threads` (threads
    /// are bit-transparent by the fleet contract),
    /// `runtime.artifacts_dir`, the trace/journal paths themselves, and
    /// the whole `[transport]` section (lane choice and transport
    /// timing are bit-transparent — the TCP handshake *relies* on a
    /// client and coordinator with different addresses fingerprinting
    /// equally).
    pub fn determinism_fingerprint(&self) -> String {
        let f64b = |v: f64| format!("{:016x}", v.to_bits());
        let f32b = |v: f32| format!("{:08x}", v.to_bits());
        let mut s = String::with_capacity(1024);
        let mut kv = |k: &str, v: String| {
            s.push_str(k);
            s.push('=');
            s.push_str(&v);
            s.push(';');
        };
        kv("seed", self.seed.to_string());
        kv("dataset.name", self.dataset.name.clone());
        kv("dataset.path", self.dataset.path.clone().unwrap_or_default());
        kv("dataset.format", self.dataset.format.clone().unwrap_or_default());
        kv("dataset.users", self.dataset.users.to_string());
        kv("dataset.items", self.dataset.items.to_string());
        kv("dataset.interactions", self.dataset.interactions.to_string());
        kv("dataset.zipf_s", f64b(self.dataset.zipf_s));
        kv("dataset.planted_rank", self.dataset.planted_rank.to_string());
        kv("dataset.train_frac", f64b(self.dataset.train_frac));
        kv(
            "dataset.min_user_interactions",
            self.dataset.min_user_interactions.to_string(),
        );
        kv("model.k", self.model.k.to_string());
        kv("model.lam", f32b(self.model.lam));
        kv("model.alpha", f32b(self.model.alpha));
        kv("model.eta", f32b(self.model.eta));
        kv("model.beta1", f32b(self.model.beta1));
        kv("model.beta2", f32b(self.model.beta2));
        kv("model.eps", f32b(self.model.eps));
        kv("model.init_scale", f32b(self.model.init_scale));
        kv("bandit.strategy", self.bandit.strategy.name().to_string());
        kv("bandit.mu0", f64b(self.bandit.mu0));
        kv("bandit.tau0", f64b(self.bandit.tau0));
        kv("bandit.gamma", f64b(self.bandit.gamma));
        kv("bandit.eps_greedy", f64b(self.bandit.eps_greedy));
        kv(
            "bandit.mean_scaled_rewards",
            self.bandit.mean_scaled_rewards.to_string(),
        );
        kv(
            "bandit.normalize_rewards",
            self.bandit.normalize_rewards.to_string(),
        );
        kv("bandit.reward_std_scale", f64b(self.bandit.reward_std_scale));
        kv("bandit.cosine_weight", self.bandit.cosine_weight.to_string());
        kv("bandit.time_base", self.bandit.time_base.to_string());
        kv("train.theta", self.train.theta.to_string());
        kv("train.payload_fraction", f64b(self.train.payload_fraction));
        kv("train.metric_window", self.train.metric_window.to_string());
        kv(
            "train.aggregate",
            match self.train.aggregate {
                Aggregate::Sum => "sum".to_string(),
                Aggregate::Mean => "mean".to_string(),
            },
        );
        kv("train.eval_every", self.train.eval_every.to_string());
        kv("codec.precision", self.codec.precision.name().to_string());
        kv("codec.entropy", self.codec.entropy.name().to_string());
        kv(
            "codec.codebook_reuse",
            self.codec.codebook_reuse.name().to_string(),
        );
        kv("codec.sparse_topk", self.codec.sparse_topk.to_string());
        kv("codec.sparse_topk_auto", self.codec.sparse_topk_auto.to_string());
        kv("codec.sparse_threshold", f64b(self.codec.sparse_threshold));
        // emitted only when enabled so legacy journals (whose headers
        // predate these keys) still fingerprint-match and resume
        if self.codec.upload_delta {
            kv("codec.upload_delta", "true".to_string());
        }
        if self.policy.mode != crate::server::policy::PolicyMode::Uniform {
            kv("policy.mode", self.policy.mode.name().to_string());
            kv("policy.budget_window_ms", f64b(self.policy.budget_window_ms));
            kv(
                "policy.min_bandwidth_frac",
                f64b(self.policy.min_bandwidth_frac),
            );
            kv("policy.battery_floor", f64b(self.policy.battery_floor));
            kv("policy.sse_weight", f64b(self.policy.sse_weight));
        }
        kv("simnet.bits_per_param", self.simnet.bits_per_param.to_string());
        kv("simnet.bandwidth_mbps", f64b(self.simnet.bandwidth_mbps));
        kv("simnet.latency_ms", f64b(self.simnet.latency_ms));
        kv("runtime.backend", self.runtime.backend.clone());
        kv(
            "fleet.theta_sample",
            self.fleet
                .theta_sample
                .map(|k| k.to_string())
                .unwrap_or_default(),
        );
        s
    }

    /// Number of items transmitted per round for a catalog of `m` items
    /// (M_s in the paper): at least 1, at most m.
    pub fn selected_items(&self, m: usize) -> usize {
        ((m as f64 * self.train.payload_fraction).round() as usize).clamp(1, m)
    }
}

/// Startup check for output destinations: a relative bare filename (no
/// parent component) always passes; an explicit parent must exist.
fn check_parent_dir(path: &str, flag: &str, key: &str) -> Result<()> {
    let parent = std::path::Path::new(path)
        .parent()
        .unwrap_or_else(|| std::path::Path::new(""));
    if !parent.as_os_str().is_empty() && !parent.is_dir() {
        bail!(
            "{flag} ({key}): parent directory `{}` of `{path}` does not exist — \
             create it before starting the run",
            parent.display()
        );
    }
    Ok(())
}

/// Extension trait shim so the `take!` macro can read u32 from i64.
trait ValueExt {
    fn as_u64_u32(&self) -> Result<u32>;
}

impl ValueExt for Value {
    fn as_u64_u32(&self) -> Result<u32> {
        Ok(u32::try_from(self.as_i64()?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_pin_table3() {
        let c = RunConfig::paper_defaults();
        assert_eq!(c.model.k, 25);
        assert_eq!(c.model.lam, 1.0);
        assert_eq!(c.model.alpha, 4.0);
        assert_eq!(c.model.beta1, 0.1);
        assert_eq!(c.model.beta2, 0.99);
        assert_eq!(c.model.eta, 0.01);
        assert_eq!(c.model.eps, 1e-8);
        assert_eq!(c.bandit.mu0, 0.0);
        assert_eq!(c.bandit.tau0, 10_000.0);
        assert_eq!(c.bandit.gamma, 0.999);
        assert_eq!(c.train.iterations, 1000);
        assert_eq!(c.train.rebuilds, 3);
        assert_eq!(c.train.metric_window, 10);
    }

    #[test]
    fn dataset_presets_pin_table2_and_theta() {
        let mut c = RunConfig::paper_defaults();
        c.apply_dataset_preset("lastfm").unwrap();
        assert_eq!((c.dataset.users, c.dataset.items), (1892, 17_632));
        assert_eq!(c.dataset.interactions, 92_834);
        assert_eq!(c.train.theta, 100);
        c.apply_dataset_preset("mind").unwrap();
        assert_eq!((c.dataset.users, c.dataset.items), (16_026, 6923));
        assert_eq!(c.train.theta, 500);
        assert!(c.apply_dataset_preset("bogus").is_err());
    }

    #[test]
    fn from_toml_overrides() {
        let cfg = RunConfig::from_toml_str(
            r#"
            seed = 7
            [dataset]
            name = "lastfm"
            [train]
            iterations = 50
            payload_fraction = 0.05
            [bandit]
            strategy = "random"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.dataset.items, 17_632);
        assert_eq!(cfg.train.iterations, 50);
        assert_eq!(cfg.bandit.strategy, Strategy::Random);
        assert_eq!(cfg.train.payload_fraction, 0.05);
    }

    #[test]
    fn validation_rejects_bad_values() {
        let mut c = RunConfig::paper_defaults();
        c.train.payload_fraction = 0.0;
        assert!(c.validate().is_err());
        c.train.payload_fraction = 0.5;
        c.runtime.backend = "cuda".into();
        assert!(c.validate().is_err());
        c.runtime.backend = "reference".into();
        c.codec.sparse_threshold = -1.0;
        assert!(c.validate().is_err());
        c.codec.sparse_threshold = f64::NAN;
        assert!(c.validate().is_err());
        c.codec.sparse_threshold = 0.0;
        c.runtime.threads = 0;
        assert!(c.validate().is_err());
        c.runtime.threads = 4;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_simnet_values_naming_the_key() {
        for bad in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let mut c = RunConfig::paper_defaults();
            c.simnet.bandwidth_mbps = bad;
            let err = c.validate().unwrap_err().to_string();
            assert!(
                err.contains("simnet.bandwidth_mbps"),
                "must name the key for {bad}: {err}"
            );
        }
        for bad in [-1.0, f64::NAN, f64::NEG_INFINITY] {
            let mut c = RunConfig::paper_defaults();
            c.simnet.latency_ms = bad;
            let err = c.validate().unwrap_err().to_string();
            assert!(
                err.contains("simnet.latency_ms"),
                "must name the key for {bad}: {err}"
            );
        }
        // zero latency is legal; zero bandwidth is not
        let mut c = RunConfig::paper_defaults();
        c.simnet.latency_ms = 0.0;
        c.validate().unwrap();
    }

    #[test]
    fn validation_rejects_nonfinite_bandit_and_model_values() {
        let cases: [(&str, fn(&mut RunConfig)); 8] = [
            ("bandit.gamma", |c| c.bandit.gamma = f64::NAN),
            ("bandit.gamma", |c| c.bandit.gamma = 0.0),
            ("bandit.gamma", |c| c.bandit.gamma = 1.5),
            ("bandit.mu0", |c| c.bandit.mu0 = f64::INFINITY),
            ("bandit.tau0", |c| c.bandit.tau0 = f64::NAN),
            ("model.lam", |c| c.model.lam = 0.0),
            ("model.alpha", |c| c.model.alpha = f32::NAN),
            ("model.eta", |c| c.model.eta = f32::INFINITY),
        ];
        for (key, poison) in cases {
            let mut c = RunConfig::paper_defaults();
            poison(&mut c);
            let err = c.validate().unwrap_err().to_string();
            assert!(err.contains(key), "error must name {key}: {err}");
        }
        // the boundary gamma = 1.0 is legal
        let mut c = RunConfig::paper_defaults();
        c.bandit.gamma = 1.0;
        c.validate().unwrap();
    }

    #[test]
    fn policy_section_parses_and_validates() {
        let c = RunConfig::paper_defaults();
        assert_eq!(c.policy.mode, crate::server::policy::PolicyMode::Uniform);
        let cfg = RunConfig::from_toml_str(
            "[policy]\nmode = \"bandit\"\nbudget_window_ms = 100.0\n\
             min_bandwidth_frac = 0.5\nbattery_floor = 0.1\nsse_weight = 2.0\n",
        )
        .unwrap();
        assert_eq!(cfg.policy.mode, crate::server::policy::PolicyMode::Bandit);
        assert_eq!(cfg.policy.budget_window_ms, 100.0);
        assert_eq!(cfg.policy.min_bandwidth_frac, 0.5);
        assert_eq!(cfg.policy.battery_floor, 0.1);
        assert_eq!(cfg.policy.sse_weight, 2.0);
        assert!(RunConfig::from_toml_str("[policy]\nmode = \"greedy\"\n").is_err());
        for (key, toml) in [
            ("policy.budget_window_ms", "[policy]\nbudget_window_ms = 0.0\n"),
            ("policy.min_bandwidth_frac", "[policy]\nmin_bandwidth_frac = 0.0\n"),
            ("policy.battery_floor", "[policy]\nbattery_floor = 1.5\n"),
            ("policy.sse_weight", "[policy]\nsse_weight = -1.0\n"),
        ] {
            let err = RunConfig::from_toml_str(toml).unwrap_err().to_string();
            assert!(err.contains(key), "error must name {key}: {err}");
        }
        // policy modes exclude cross-round codebook sessions and auto top-k
        let err = RunConfig::from_toml_str(
            "[policy]\nmode = \"budget\"\n[codec]\nprecision = \"vq8\"\ncodebook_reuse = \"auto\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("codec.codebook_reuse"), "{err}");
        let err =
            RunConfig::from_toml_str("[policy]\nmode = \"budget\"\n[codec]\nsparse_topk_auto = true\n")
                .unwrap_err()
                .to_string();
        assert!(err.contains("codec.sparse_topk_auto"), "{err}");
    }

    #[test]
    fn upload_delta_parses_and_requires_int8_class_uploads() {
        let cfg =
            RunConfig::from_toml_str("[codec]\nprecision = \"int8\"\nupload_delta = true\n")
                .unwrap();
        assert!(cfg.codec.upload_delta);
        for ok in ["vq8", "vq4", "vq8r"] {
            RunConfig::from_toml_str(&format!(
                "[codec]\nprecision = \"{ok}\"\nupload_delta = true\n"
            ))
            .unwrap();
        }
        for bad in ["f64", "f32", "f16"] {
            let err = RunConfig::from_toml_str(&format!(
                "[codec]\nprecision = \"{bad}\"\nupload_delta = true\n"
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains("codec.upload_delta"), "{err}");
        }
    }

    #[test]
    fn policy_and_upload_delta_fingerprint_keys_are_conditional() {
        // legacy configs must fingerprint identically to pre-policy
        // releases so old journals still resume
        let base = RunConfig::paper_defaults();
        assert!(!base.determinism_fingerprint().contains("policy."));
        assert!(!base.determinism_fingerprint().contains("upload_delta"));
        let mut p = RunConfig::paper_defaults();
        p.policy.mode = crate::server::policy::PolicyMode::Bandit;
        let fp = p.determinism_fingerprint();
        assert!(fp.contains("policy.mode=bandit;"), "{fp}");
        assert_ne!(base.determinism_fingerprint(), fp);
        let mut u = RunConfig::paper_defaults();
        u.codec.precision = crate::wire::Precision::Int8;
        u.codec.upload_delta = true;
        assert!(u.determinism_fingerprint().contains("codec.upload_delta=true;"));
    }

    #[test]
    fn threads_parse_and_validate() {
        let cfg = RunConfig::from_toml_str("[runtime]\nthreads = 8\n").unwrap();
        assert_eq!(cfg.runtime.threads, 8);
        assert!(RunConfig::from_toml_str("[runtime]\nthreads = 0\n").is_err());
    }

    #[test]
    fn codec_defaults_are_lossless() {
        let c = RunConfig::paper_defaults();
        assert_eq!(c.codec.precision, crate::wire::Precision::F32);
        assert_eq!(c.codec.entropy, crate::wire::EntropyMode::None);
        assert_eq!(c.codec.codebook_reuse, crate::wire::ReuseMode::Off);
        assert_eq!(c.codec.sparse_topk, 0);
        assert!(!c.codec.sparse_topk_auto);
        assert_eq!(c.codec.sparse_threshold, 0.0);
    }

    #[test]
    fn codebook_reuse_parses_via_config() {
        for (name, m) in [
            ("off", crate::wire::ReuseMode::Off),
            ("delta", crate::wire::ReuseMode::Delta),
            ("auto", crate::wire::ReuseMode::Auto),
        ] {
            let cfg = RunConfig::from_toml_str(&format!(
                "[codec]\nprecision = \"vq8\"\ncodebook_reuse = \"{name}\"\n"
            ))
            .unwrap();
            assert_eq!(cfg.codec.codebook_reuse, m);
        }
        assert!(RunConfig::from_toml_str("[codec]\ncodebook_reuse = \"always\"\n").is_err());
    }

    #[test]
    fn vq_precisions_parse_via_config() {
        for (name, p) in [
            ("vq8", crate::wire::Precision::Vq8),
            ("vq4", crate::wire::Precision::Vq4),
            ("vq8r", crate::wire::Precision::Vq8r),
        ] {
            let cfg =
                RunConfig::from_toml_str(&format!("[codec]\nprecision = \"{name}\"\n")).unwrap();
            assert_eq!(cfg.codec.precision, p);
        }
        assert!(RunConfig::from_toml_str("[codec]\nprecision = \"vq9\"\n").is_err());
    }

    #[test]
    fn sparse_topk_auto_parses_and_excludes_fixed_topk() {
        let cfg = RunConfig::from_toml_str("[codec]\nsparse_topk_auto = true\n").unwrap();
        assert!(cfg.codec.sparse_topk_auto);
        let both = "[codec]\nsparse_topk_auto = true\nsparse_topk = 8\n";
        assert!(RunConfig::from_toml_str(both).is_err());
    }

    #[test]
    fn codec_section_parses() {
        let cfg = RunConfig::from_toml_str(
            r#"
            [codec]
            precision = "int8"
            entropy = "full"
            sparse_topk = 50
            sparse_threshold = 0.001
            "#,
        )
        .unwrap();
        assert_eq!(cfg.codec.precision, crate::wire::Precision::Int8);
        assert_eq!(cfg.codec.entropy, crate::wire::EntropyMode::Full);
        assert_eq!(cfg.codec.sparse_topk, 50);
        assert!((cfg.codec.sparse_threshold - 0.001).abs() < 1e-12);
        assert!(RunConfig::from_toml_str("[codec]\nprecision = \"f8\"\n").is_err());
        assert!(RunConfig::from_toml_str("[codec]\nentropy = \"huffman\"\n").is_err());
    }

    #[test]
    fn entropy_modes_all_parse_via_config() {
        for mode in ["none", "varint", "range", "full"] {
            let cfg =
                RunConfig::from_toml_str(&format!("[codec]\nentropy = \"{mode}\"\n")).unwrap();
            assert_eq!(cfg.codec.entropy.name(), mode);
        }
    }

    #[test]
    fn trace_section_parses() {
        let c = RunConfig::paper_defaults();
        assert!(c.trace.out.is_none() && c.trace.metrics_out.is_none());
        assert_eq!(c.trace.level, crate::telemetry::TraceLevel::Decision);
        let cfg = RunConfig::from_toml_str(
            "[trace]\nout = \"t.jsonl\"\nmetrics_out = \"m.prom\"\nlevel = \"full\"\n",
        )
        .unwrap();
        assert_eq!(cfg.trace.out.as_deref(), Some("t.jsonl"));
        assert_eq!(cfg.trace.metrics_out.as_deref(), Some("m.prom"));
        assert_eq!(cfg.trace.level, crate::telemetry::TraceLevel::Full);
        assert!(RunConfig::from_toml_str("[trace]\nlevel = \"verbose\"\n").is_err());
    }

    #[test]
    fn journal_section_parses() {
        let c = RunConfig::paper_defaults();
        assert!(c.journal.path.is_none() && c.journal.resume.is_none());
        let cfg = RunConfig::from_toml_str("[journal]\npath = \"run.jsonl\"\n").unwrap();
        assert_eq!(cfg.journal.path.as_deref(), Some("run.jsonl"));
        // resume must point at an existing file, checked at parse time
        assert!(RunConfig::from_toml_str("[journal]\nresume = \"no_such.jsonl\"\n").is_err());
    }

    #[test]
    fn missing_parent_dirs_fail_at_startup_naming_the_flag() {
        let cases: [(&str, fn(&mut RunConfig, String)); 3] = [
            ("--trace-out", |c, p| c.trace.out = Some(p)),
            ("--metrics-out", |c, p| c.trace.metrics_out = Some(p)),
            ("--journal", |c, p| c.journal.path = Some(p)),
        ];
        for (flag, set) in cases {
            let mut c = RunConfig::paper_defaults();
            set(&mut c, "/nonexistent_fedpayload_dir/out.file".into());
            let err = c.validate().unwrap_err().to_string();
            assert!(err.contains(flag), "error must name {flag}: {err}");
            assert!(err.contains("/nonexistent_fedpayload_dir"), "{err}");
            // bare filenames (empty parent) always pass
            let mut c = RunConfig::paper_defaults();
            set(&mut c, "out.file".into());
            c.validate().unwrap();
            // existing parents pass
            let mut c = RunConfig::paper_defaults();
            set(&mut c, std::env::temp_dir().join("out.file").to_string_lossy().into_owned());
            c.validate().unwrap();
        }
    }

    #[test]
    fn determinism_fingerprint_tracks_training_relevant_fields() {
        let a = RunConfig::paper_defaults();
        let mut b = RunConfig::paper_defaults();
        assert_eq!(a.determinism_fingerprint(), b.determinism_fingerprint());
        // resume-tolerant fields must not move the fingerprint
        b.train.iterations += 100;
        b.runtime.threads = 1;
        b.runtime.artifacts_dir = "elsewhere".into();
        b.trace.out = Some("t.jsonl".into());
        b.journal.path = Some("j.jsonl".into());
        assert_eq!(a.determinism_fingerprint(), b.determinism_fingerprint());
        // training-relevant fields must
        b.seed ^= 1;
        assert_ne!(a.determinism_fingerprint(), b.determinism_fingerprint());
        assert!(a.determinism_fingerprint().contains("seed=2021;"));
        let mut c = RunConfig::paper_defaults();
        c.model.eta = 0.02;
        assert_ne!(a.determinism_fingerprint(), c.determinism_fingerprint());
        // participant sampling changes which clients train — it must
        // move the fingerprint so a sampled journal never replays under
        // the all-Θ path (or a different sample size)
        let mut d = RunConfig::paper_defaults();
        d.fleet.theta_sample = Some(50);
        assert_ne!(a.determinism_fingerprint(), d.determinism_fingerprint());
        assert!(d.determinism_fingerprint().contains("fleet.theta_sample=50;"));
    }

    #[test]
    fn theta_sample_validation_rejects_zero_and_oversize() {
        let mut c = RunConfig::paper_defaults();
        c.fleet.theta_sample = Some(0);
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fleet.theta_sample"), "must name the key: {err}");
        c.fleet.theta_sample = Some(c.train.theta + 1);
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fleet.theta_sample"), "must name the key: {err}");
        assert!(err.contains("train.theta"), "must name the bound: {err}");
        // the full legal range passes
        c.fleet.theta_sample = Some(1);
        c.validate().unwrap();
        c.fleet.theta_sample = Some(c.train.theta);
        c.validate().unwrap();
        c.fleet.theta_sample = None;
        c.validate().unwrap();
    }

    #[test]
    fn theta_sample_parses_from_doc() {
        let cfg = RunConfig::from_toml_str("[fleet]\ntheta_sample = 10\n").unwrap();
        assert_eq!(cfg.fleet.theta_sample, Some(10));
        // rejected values fail at parse time through validate()
        assert!(RunConfig::from_toml_str("[fleet]\ntheta_sample = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[fleet]\ntheta_sample = 101\n").is_err());
    }

    #[test]
    fn selected_items_rounds_and_clamps() {
        let mut c = RunConfig::paper_defaults();
        c.train.payload_fraction = 0.10;
        assert_eq!(c.selected_items(17_632), 1763);
        c.train.payload_fraction = 1.0;
        assert_eq!(c.selected_items(100), 100);
        c.train.payload_fraction = 0.0001;
        assert_eq!(c.selected_items(100), 1); // clamped to >= 1
    }

    #[test]
    fn strategy_roundtrip() {
        for s in ["bts", "random", "full", "eps_greedy", "ucb1"] {
            assert_eq!(Strategy::parse(s).unwrap().name(), s);
        }
        assert!(Strategy::parse("nope").is_err());
    }
}
