//! Hand-rolled CLI argument parser (clap unavailable offline).
//!
//! Grammar: `fedpayload <subcommand> [positional...] [--flag] [--key value]
//! [--key=value]`. The launchers (`rust/src/main.rs` and the transport
//! bins `rust/src/bin/{coordinator,client}.rs`) declare subcommands;
//! this module does the token wrangling, typed lookups, and the shared
//! flags→[`RunConfig`] resolution so all three bins accept the same
//! training options.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Doc, RunConfig, Strategy};
use crate::telemetry;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (if any).
    pub subcommand: Option<String>,
    /// Remaining non-flag tokens after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; repeated keys accumulate.
    options: BTreeMap<String, Vec<String>>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
}

/// Option keys that consume a value even in `--key value` form. Everything
/// not listed here and not containing `=` is treated as a boolean flag.
const VALUE_KEYS: &[&str] = &[
    "config",
    "out-dir",
    "dataset",
    "strategy",
    "iterations",
    "theta",
    "theta-sample",
    "payload-fraction",
    "rebuilds",
    "seed",
    "set",
    "backend",
    "log-level",
    "levels",
    "scale",
    "threads",
    "format",
    "path",
    "output",
    "codec",
    "precision",
    "entropy",
    "codebook-reuse",
    "sparse-topk",
    "policy",
    "dump-rounds",
    "trace-out",
    "metrics-out",
    "trace-level",
    "journal",
    "resume",
    // transport-lane bins (coordinator / client)
    "listen",
    "connect",
    "transport-clients",
    "round-deadline-ms",
    "bandwidth-cap",
    "rejoin-wait-ms",
    "port-file",
    "connect-timeout-secs",
    "exit-after-round",
    "stall-in-round",
];

impl Args {
    /// Parse from raw argv (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("stray `--`");
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if VALUE_KEYS.contains(&key) {
                    let v = iter
                        .next()
                        .ok_or_else(|| anyhow!("option --{key} expects a value"))?;
                    args.options.entry(key.to_string()).or_default().push(v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Last occurrence of `--key` (CLI conventions: later wins).
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of `--key` (e.g. repeated `--set`).
    pub fn opt_all(&self, key: &str) -> &[String] {
        self.options.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Was the bare `--name` switch given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse the last `--key` occurrence into `T` (None when absent).
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} `{s}`: {e}")),
        }
    }

    /// `opt_parse` with a default.
    pub fn opt_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }
}

/// Resolve the effective config: file -> --set overrides -> typed flags.
/// Shared by the `fedpayload`, `coordinator`, and `client` bins so a
/// transport pair resolves the identical [`RunConfig`] (and therefore
/// the identical determinism fingerprint) from the identical flags.
pub fn resolve_config(args: &Args) -> Result<RunConfig> {
    let mut doc = match args.opt("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            Doc::parse(&text)?
        }
        None => Doc::default(),
    };
    // `--dataset` is a preset: apply it BEFORE --set overrides so that
    // e.g. `--dataset movielens --set dataset.items=766` keeps the 766.
    if let Some(ds) = args.opt("dataset") {
        doc.set("dataset.name", crate::config::Value::Str(ds.to_string()));
    }
    for spec in args.opt_all("set") {
        doc.apply_override(spec)?;
    }
    let mut cfg = RunConfig::from_doc(&doc)?;
    if let Some(s) = args.opt("strategy") {
        cfg.bandit.strategy = Strategy::parse(s)?;
    }
    if let Some(n) = args.opt_parse::<usize>("iterations")? {
        cfg.train.iterations = n;
    }
    if let Some(f) = args.opt_parse::<f64>("payload-fraction")? {
        cfg.train.payload_fraction = f;
    }
    if let Some(n) = args.opt_parse::<usize>("theta")? {
        cfg.train.theta = n;
    }
    if let Some(n) = args.opt_parse::<usize>("theta-sample")? {
        cfg.fleet.theta_sample = Some(n);
    }
    if let Some(n) = args.opt_parse::<u64>("seed")? {
        cfg.seed = n;
    }
    if let Some(b) = args.opt("backend") {
        cfg.runtime.backend = b.to_string();
    }
    if let Some(n) = args.opt_parse::<usize>("threads")? {
        cfg.runtime.threads = n;
    }
    if let Some(p) = args.opt("codec").or_else(|| args.opt("precision")) {
        cfg.codec.precision = crate::wire::Precision::parse(p)?;
    }
    if let Some(e) = args.opt("entropy") {
        cfg.codec.entropy = crate::wire::EntropyMode::parse(e)?;
    }
    if let Some(r) = args.opt("codebook-reuse") {
        cfg.codec.codebook_reuse = crate::wire::ReuseMode::parse(r)?;
    }
    if let Some(p) = args.opt("policy") {
        cfg.policy.mode = crate::server::policy::PolicyMode::parse(p)?;
    }
    if args.flag("upload-delta") {
        cfg.codec.upload_delta = true;
    }
    match args.opt("sparse-topk") {
        Some("auto") => {
            cfg.codec.sparse_topk_auto = true;
            cfg.codec.sparse_topk = 0;
        }
        Some(k) => {
            cfg.codec.sparse_topk = k
                .parse::<usize>()
                .map_err(|e| anyhow!("--sparse-topk `{k}`: {e} (or `auto`)"))?;
            cfg.codec.sparse_topk_auto = false;
        }
        None => {}
    }
    if let Some(p) = args.opt("trace-out") {
        cfg.trace.out = Some(p.to_string());
    }
    if let Some(p) = args.opt("metrics-out") {
        cfg.trace.metrics_out = Some(p.to_string());
    }
    if let Some(l) = args.opt("trace-level") {
        cfg.trace.level = telemetry::parse_trace_level(l)
            .ok_or_else(|| anyhow!("bad --trace-level `{l}` (off|decision|full)"))?;
    }
    if let Some(p) = args.opt("journal") {
        cfg.journal.path = Some(p.to_string());
    }
    if let Some(p) = args.opt("resume") {
        cfg.journal.resume = Some(p.to_string());
    }
    if let Some(a) = args.opt("listen") {
        cfg.transport.listen = a.to_string();
    }
    if let Some(a) = args.opt("connect") {
        cfg.transport.connect = a.to_string();
    }
    if let Some(n) = args.opt_parse::<usize>("transport-clients")? {
        cfg.transport.clients = n;
    }
    if let Some(n) = args.opt_parse::<u64>("round-deadline-ms")? {
        cfg.transport.round_deadline_ms = n;
    }
    if let Some(n) = args.opt_parse::<u64>("bandwidth-cap")? {
        cfg.transport.bandwidth_cap_bps = n;
    }
    if args.flag("wait-rejoin") {
        cfg.transport.wait_rejoin = true;
    }
    if let Some(n) = args.opt_parse::<u64>("rejoin-wait-ms")? {
        cfg.transport.rejoin_wait_ms = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Dump every round record with full bit precision (f64 payloads as hex
/// bit patterns) so two runs can be compared byte-for-byte — the
/// determinism CI job diffs these files across `--threads` values and
/// across the in-process/TCP lanes, and the golden-trajectory fixtures
/// pin the same digest in-repo (the digest itself is
/// `server::round_dump_string`, shared with the tests so the two can
/// never drift apart).
pub fn write_round_dump(path: &str, report: &crate::server::TrainReport) -> Result<()> {
    let text = crate::server::round_dump_string(report);
    std::fs::write(path, text).with_context(|| format!("writing round dump {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["train", "extra1", "extra2"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn options_both_forms() {
        let a = parse(&["train", "--dataset", "lastfm", "--iterations=55"]);
        assert_eq!(a.opt("dataset"), Some("lastfm"));
        assert_eq!(a.opt_or::<usize>("iterations", 0).unwrap(), 55);
    }

    #[test]
    fn codec_options_take_values() {
        let a = parse(&["train", "--codec", "int8", "--sparse-topk", "32"]);
        assert_eq!(a.opt("codec"), Some("int8"));
        assert_eq!(a.opt_or::<usize>("sparse-topk", 0).unwrap(), 32);
        let a = parse(&["train", "--precision=f16"]);
        assert_eq!(a.opt("precision"), Some("f16"));
        let a = parse(&["train", "--entropy", "full"]);
        assert_eq!(a.opt("entropy"), Some("full"));
        let a = parse(&["train", "--codebook-reuse", "auto"]);
        assert_eq!(a.opt("codebook-reuse"), Some("auto"));
    }

    #[test]
    fn policy_takes_a_value_and_upload_delta_is_a_flag() {
        let a = parse(&["train", "--policy", "bandit", "--upload-delta"]);
        assert_eq!(a.opt("policy"), Some("bandit"));
        assert!(a.flag("upload-delta"));
        let a = parse(&["train", "--policy=budget"]);
        assert_eq!(a.opt("policy"), Some("budget"));
        assert!(!a.flag("upload-delta"));
    }

    #[test]
    fn trace_options_take_values() {
        let a = parse(&["train", "--trace-out", "t.jsonl", "--metrics-out=m.prom"]);
        assert_eq!(a.opt("trace-out"), Some("t.jsonl"));
        assert_eq!(a.opt("metrics-out"), Some("m.prom"));
        let a = parse(&["train", "--trace-level", "full"]);
        assert_eq!(a.opt("trace-level"), Some("full"));
    }

    #[test]
    fn journal_options_take_values() {
        let a = parse(&["train", "--journal", "run.jsonl", "--resume=old.jsonl"]);
        assert_eq!(a.opt("journal"), Some("run.jsonl"));
        assert_eq!(a.opt("resume"), Some("old.jsonl"));
    }

    #[test]
    fn repeated_set_accumulates() {
        let a = parse(&["train", "--set", "a=1", "--set", "b=2"]);
        assert_eq!(a.opt_all("set"), &["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn flags() {
        let a = parse(&["bench", "--verbose", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn later_option_wins() {
        let a = parse(&["x", "--seed", "1", "--seed", "2"]);
        assert_eq!(a.opt_or::<u64>("seed", 0).unwrap(), 2);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(vec!["x".to_string(), "--seed".to_string()]).is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["x", "--seed", "abc"]);
        assert!(a.opt_parse::<u64>("seed").is_err());
    }
}
