//! Hand-rolled CLI argument parser (clap unavailable offline).
//!
//! Grammar: `fedpayload <subcommand> [positional...] [--flag] [--key value]
//! [--key=value]`. The launcher (`rust/src/main.rs`) declares subcommands;
//! this module only does the token wrangling and typed lookups.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (if any).
    pub subcommand: Option<String>,
    /// Remaining non-flag tokens after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options; repeated keys accumulate.
    options: BTreeMap<String, Vec<String>>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
}

/// Option keys that consume a value even in `--key value` form. Everything
/// not listed here and not containing `=` is treated as a boolean flag.
const VALUE_KEYS: &[&str] = &[
    "config",
    "out-dir",
    "dataset",
    "strategy",
    "iterations",
    "theta",
    "theta-sample",
    "payload-fraction",
    "rebuilds",
    "seed",
    "set",
    "backend",
    "log-level",
    "levels",
    "scale",
    "threads",
    "format",
    "path",
    "output",
    "codec",
    "precision",
    "entropy",
    "codebook-reuse",
    "sparse-topk",
    "dump-rounds",
    "trace-out",
    "metrics-out",
    "trace-level",
    "journal",
    "resume",
];

impl Args {
    /// Parse from raw argv (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("stray `--`");
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if VALUE_KEYS.contains(&key) {
                    let v = iter
                        .next()
                        .ok_or_else(|| anyhow!("option --{key} expects a value"))?;
                    args.options.entry(key.to_string()).or_default().push(v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Last occurrence of `--key` (CLI conventions: later wins).
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences of `--key` (e.g. repeated `--set`).
    pub fn opt_all(&self, key: &str) -> &[String] {
        self.options.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Was the bare `--name` switch given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse the last `--key` occurrence into `T` (None when absent).
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} `{s}`: {e}")),
        }
    }

    /// `opt_parse` with a default.
    pub fn opt_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["train", "extra1", "extra2"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn options_both_forms() {
        let a = parse(&["train", "--dataset", "lastfm", "--iterations=55"]);
        assert_eq!(a.opt("dataset"), Some("lastfm"));
        assert_eq!(a.opt_or::<usize>("iterations", 0).unwrap(), 55);
    }

    #[test]
    fn codec_options_take_values() {
        let a = parse(&["train", "--codec", "int8", "--sparse-topk", "32"]);
        assert_eq!(a.opt("codec"), Some("int8"));
        assert_eq!(a.opt_or::<usize>("sparse-topk", 0).unwrap(), 32);
        let a = parse(&["train", "--precision=f16"]);
        assert_eq!(a.opt("precision"), Some("f16"));
        let a = parse(&["train", "--entropy", "full"]);
        assert_eq!(a.opt("entropy"), Some("full"));
        let a = parse(&["train", "--codebook-reuse", "auto"]);
        assert_eq!(a.opt("codebook-reuse"), Some("auto"));
    }

    #[test]
    fn trace_options_take_values() {
        let a = parse(&["train", "--trace-out", "t.jsonl", "--metrics-out=m.prom"]);
        assert_eq!(a.opt("trace-out"), Some("t.jsonl"));
        assert_eq!(a.opt("metrics-out"), Some("m.prom"));
        let a = parse(&["train", "--trace-level", "full"]);
        assert_eq!(a.opt("trace-level"), Some("full"));
    }

    #[test]
    fn journal_options_take_values() {
        let a = parse(&["train", "--journal", "run.jsonl", "--resume=old.jsonl"]);
        assert_eq!(a.opt("journal"), Some("run.jsonl"));
        assert_eq!(a.opt("resume"), Some("old.jsonl"));
    }

    #[test]
    fn repeated_set_accumulates() {
        let a = parse(&["train", "--set", "a=1", "--set", "b=2"]);
        assert_eq!(a.opt_all("set"), &["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn flags() {
        let a = parse(&["bench", "--verbose", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn later_option_wins() {
        let a = parse(&["x", "--seed", "1", "--seed", "2"]);
        assert_eq!(a.opt_or::<u64>("seed", 0).unwrap(), 2);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(vec!["x".to_string(), "--seed".to_string()]).is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["x", "--seed", "abc"]);
        assert!(a.opt_parse::<u64>("seed").is_err());
    }
}
