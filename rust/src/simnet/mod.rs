//! Traffic accounting and network model (paper Table 1 + §1).
//!
//! The [`TrafficLedger`] is the system of record for communication: the
//! trainer and the fleet executor feed it the **measured encoded frame
//! lengths** that the `wire` codecs (quantization, sparsification,
//! entropy coding) actually produce, one message per client per
//! direction, and a simple bandwidth/latency model turns those bytes
//! into the *simulated* transfer time the paper's motivation is about.
//!
//! [`payload_bytes`] is the one deliberate exception: it reproduces the
//! paper's analytic Table 1 arithmetic — `(#parameters × bits) / 8` with
//! #parameters = #items × K — and is used only for that reproduction and
//! back-of-envelope comparisons, never for the ledger.

use crate::config::SimNetConfig;

/// Payload size in bytes for a factor-matrix slice of `items × k`
/// parameters at `bits` per parameter (Table 1 formula).
pub fn payload_bytes(items: usize, k: usize, bits: u32) -> u64 {
    (items as u64) * (k as u64) * (bits as u64) / 8
}

/// Human-readable decimal size, matching the paper's Table 1 units
/// (625KB, 1.6 MB, ..., 1.6 GB).
pub fn human_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    // Unit thresholds sit at the *rounding* boundary of the smaller
    // unit's format, so e.g. 999,950 B renders as "1.0 MB" — not as
    // "1000 KB", which the plain `b >= 1e6` check produced (the `{:.0}`
    // formatting rounds up past the unit before the check can see it).
    if b >= 999.95e6 {
        format!("{:.1} GB", b / 1e9)
    } else if b >= 999.5e3 {
        format!("{:.1} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.0} KB", b / 1e3)
    } else {
        format!("{bytes} B")
    }
}

/// Seconds to move `bytes` over the configured link (one direction).
pub fn transfer_secs(cfg: &SimNetConfig, bytes: u64) -> f64 {
    let bits = bytes as f64 * 8.0;
    cfg.latency_ms / 1e3 + bits / (cfg.bandwidth_mbps * 1e6)
}

/// Cumulative communication accounting for one training run.
#[derive(Debug, Clone, Default)]
pub struct TrafficLedger {
    /// Bytes server -> clients (Q* downloads).
    pub down_bytes: u64,
    /// Bytes clients -> server (∇Q* uploads).
    pub up_bytes: u64,
    /// Count of server -> client messages.
    pub down_msgs: u64,
    /// Count of client -> server messages.
    pub up_msgs: u64,
    /// Simulated transfer seconds (sum over messages).
    pub sim_secs: f64,
}

impl TrafficLedger {
    /// A fresh, empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one server->client model transmission.
    pub fn record_down(&mut self, cfg: &SimNetConfig, bytes: u64) {
        self.down_bytes += bytes;
        self.down_msgs += 1;
        self.sim_secs += transfer_secs(cfg, bytes);
    }

    /// Record one client->server gradient upload.
    pub fn record_up(&mut self, cfg: &SimNetConfig, bytes: u64) {
        self.up_bytes += bytes;
        self.up_msgs += 1;
        self.sim_secs += transfer_secs(cfg, bytes);
    }

    /// Fold a sub-ledger in (per-batch ledgers merged at the parallel
    /// round barrier). Counts and bytes are exact; `sim_secs` is a float
    /// sum, so merges MUST happen in a fixed order — the fleet executor
    /// always folds in batch-index order to keep runs bit-reproducible.
    pub fn merge(&mut self, other: &TrafficLedger) {
        self.down_bytes += other.down_bytes;
        self.up_bytes += other.up_bytes;
        self.down_msgs += other.down_msgs;
        self.up_msgs += other.up_msgs;
        self.sim_secs += other.sim_secs;
    }

    /// Total bytes moved in both directions.
    pub fn total_bytes(&self) -> u64 {
        self.down_bytes + self.up_bytes
    }
}

/// The paper's Table 1 row set: payloads for K = 20, f64 parameters.
pub fn table1_rows() -> Vec<(usize, u64)> {
    const ITEMS: &[usize] = &[3912, 10_000, 100_000, 500_000, 1_000_000, 10_000_000];
    ITEMS
        .iter()
        .map(|&m| (m, payload_bytes(m, 20, 64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn payload_formula_matches_table1() {
        // Paper: 3912 items, K=20, 64-bit -> ~625KB
        assert_eq!(payload_bytes(3912, 20, 64), 625_920);
        assert_eq!(payload_bytes(10_000, 20, 64), 1_600_000);
        assert_eq!(payload_bytes(100_000, 20, 64), 16_000_000);
        assert_eq!(payload_bytes(1_000_000, 20, 64), 160_000_000);
        assert_eq!(payload_bytes(10_000_000, 20, 64), 1_600_000_000);
    }

    #[test]
    fn human_units_match_paper() {
        assert_eq!(human_bytes(payload_bytes(3912, 20, 64)), "626 KB");
        assert_eq!(human_bytes(payload_bytes(10_000, 20, 64)), "1.6 MB");
        assert_eq!(human_bytes(payload_bytes(10_000_000, 20, 64)), "1.6 GB");
        assert_eq!(human_bytes(12), "12 B");
    }

    #[test]
    fn human_units_never_round_past_their_unit() {
        // regression: 999,950 used to render as "1000 KB"
        assert_eq!(human_bytes(999_950), "1.0 MB");
        assert_eq!(human_bytes(999_499), "999 KB");
        assert_eq!(human_bytes(999_500), "1.0 MB");
        assert_eq!(human_bytes(999_949_999), "999.9 MB");
        assert_eq!(human_bytes(999_950_000), "1.0 GB");
        assert_eq!(human_bytes(999), "999 B");
        assert_eq!(human_bytes(1000), "1 KB");
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let cfg = RunConfig::paper_defaults().simnet;
        let t1 = transfer_secs(&cfg, 1_000_000);
        let t2 = transfer_secs(&cfg, 2_000_000);
        assert!(t2 > t1);
        // latency floor
        assert!(transfer_secs(&cfg, 0) >= cfg.latency_ms / 1e3);
    }

    #[test]
    fn ledger_accumulates() {
        let cfg = RunConfig::paper_defaults().simnet;
        let mut l = TrafficLedger::new();
        l.record_down(&cfg, 1000);
        l.record_up(&cfg, 500);
        l.record_up(&cfg, 500);
        assert_eq!(l.down_bytes, 1000);
        assert_eq!(l.up_bytes, 1000);
        assert_eq!(l.down_msgs, 1);
        assert_eq!(l.up_msgs, 2);
        assert_eq!(l.total_bytes(), 2000);
        assert!(l.sim_secs > 0.0);
    }

    #[test]
    fn ledger_merge_sums_all_fields() {
        let cfg = RunConfig::paper_defaults().simnet;
        let mut a = TrafficLedger::new();
        a.record_down(&cfg, 1000);
        let mut b = TrafficLedger::new();
        b.record_up(&cfg, 300);
        b.record_up(&cfg, 200);
        a.merge(&b);
        // integer fields are exact sums under any grouping
        assert_eq!(a.down_bytes, 1000);
        assert_eq!(a.up_bytes, 500);
        assert_eq!(a.down_msgs, 1);
        assert_eq!(a.up_msgs, 2);
        // sim_secs reproduces the merge's exact fold shape,
        // t(1000) + (t(300) + t(200)), bit for bit
        let expected =
            transfer_secs(&cfg, 1000) + (transfer_secs(&cfg, 300) + transfer_secs(&cfg, 200));
        assert_eq!(a.sim_secs.to_bits(), expected.to_bits());
    }

    #[test]
    fn table1_has_all_rows() {
        let rows = table1_rows();
        assert_eq!(rows.len(), 6);
        assert_eq!(rows[0], (3912, 625_920));
    }
}
