//! # fedpayload — payload-optimized federated recommender systems
//!
//! Production-shaped reproduction of *"A Payload Optimization Method for
//! Federated Recommender Systems"* (Khan, Flanagan, Tan, Alamgir,
//! Ammad-ud-din — RecSys 2021, DOI 10.1145/3460231.3474257).
//!
//! The paper's system, **FCF-BTS**, reduces the per-round communication
//! payload of Federated Collaborative Filtering by letting a server-side
//! Bayesian Thompson Sampling bandit choose which *subset* of the global
//! item-factor matrix `Q` to transmit each round, guided by a composite
//! reward computed from the gradients the clients return (paper Eq. 13–14).
//!
//! ## Architecture (three layers, python never on the hot path)
//!
//! * **L3 (this crate)** — the coordinator: FL server loop, bandit item
//!   selection, reward engine, server-side Adam, Θ-threshold aggregation,
//!   simulated client fleet, wire codecs + payload accounting, metrics
//!   ([`server`], [`bandit`], [`reward`], [`optim`], [`client`],
//!   [`wire`], [`simnet`]).
//! * **L2 (python/compile/model.py)** — the FCF client compute graph in
//!   JAX (user solve Eq. 3, item gradients Eq. 5–6, scores), AOT-lowered
//!   once to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots, lowered inside the L2 graphs.
//!
//! [`runtime`] loads the HLO-text artifacts, compiles them once on the
//! PJRT CPU client (`xla` crate) and executes them from the round loop.
//!
//! ## The three payload axes
//!
//! Per-round traffic is `Θ × frame_len(M_s, K, precision, entropy)` per
//! direction, reduced along three orthogonal, multiplying axes:
//!
//! 1. **Item selection** (the paper): the bandit picks M_s ≪ M rows.
//! 2. **Quantizer** ([`wire::quant`] + [`wire::vq`]): scalar
//!    f64/f32/f16/int8 per element, or product quantization against a
//!    per-round in-frame codebook (`vq8`/`vq4`/`vq8r`) for downloads.
//! 3. **Entropy coding** ([`wire::entropy`]): lossless varint + range
//!    coding under the frame checksum.
//!
//! Every transmission is a real framed byte buffer; clients train on the
//! decoded factors and the [`simnet::TrafficLedger`] records measured
//! frame lengths.
//!
//! ## Quick start
//!
//! ```no_run
//! use fedpayload::config::RunConfig;
//! use fedpayload::server::Trainer;
//!
//! let mut cfg = RunConfig::paper_defaults();
//! cfg.dataset.name = "synthetic-small".into();
//! cfg.train.iterations = 50;
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final MAP = {:.4}", report.final_metrics.map);
//! ```
//!
//! See `examples/` for runnable scenarios and `docs/ARCHITECTURE.md` for
//! the module map, the paper-equation → code index, and the byte-level
//! wire format specification.

#![deny(missing_docs)]

pub mod bandit;
pub mod cli;
pub mod client;
pub mod config;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod reward;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod simnet;
pub mod telemetry;
pub mod transport;
pub mod wire;

/// Crate-wide result alias (anyhow is the only error substrate available
/// offline; module-level error enums wrap into it).
pub type Result<T> = anyhow::Result<T>;
