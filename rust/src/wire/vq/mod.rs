//! Product (codebook) quantization for the downlink Q* payload — the
//! fourth payload axis, and the one the entropy layer was waiting for.
//!
//! PR 3 measured that int8-quantized factor rows are information-
//! theoretically close to incompressible: the symbols are near-uniform,
//! so the range coder recovers only ~2–12% on downloads. Cutting deeper
//! means changing the *quantizer*, not the entropy layer. `wire::vq`
//! does that: each selected row is normalized by its f16 row scale,
//! split into `S = ⌈K / 5⌉` subvectors, and every subvector is replaced
//! by an index into a small per-subspace codebook learned **per frame**
//! with seeded k-means on the coordinator. A K = 25 row costs
//! `2 + S` bytes (`vq8`) instead of int8's `K + 2` — 7 vs 27 — plus a
//! per-frame codebook block that amortizes across the selected rows.
//! The codebook is trained on exactly the rows it encodes, so small
//! frames get a near-overfit (high-quality) codebook for free.
//!
//! Three modes, selected by [`Precision`](super::Precision):
//!
//! * `vq8`  — up to 64 centroids per subspace, one index byte per
//!   subvector.
//! * `vq4`  — up to 16 centroids, two indices packed per byte (the
//!   aggressive end of the knob).
//! * `vq8r` — `vq8` plus a per-row **int8 residual plane**: the decoder
//!   adds back the int8-quantized `x − recon`, recovering int8-class
//!   accuracy at int8-class size plus the index plane (the quality
//!   knob; its residuals are small and skewed, so the range coder bites
//!   much harder than on raw int8 rows).
//!
//! Codebook indices are low-entropy (≤ 6 bits of information per index
//! byte even on unstructured factors, less once training concentrates
//! Q), which finally gives `entropy = range|full` real purchase on the
//! download direction: the bench workload measures ~24% off vq8 frames
//! vs ~5% off int8 frames.
//!
//! ## Determinism
//!
//! Encoding is a pure function of the payload: k-means uses a fixed
//! PCG seed per subspace (`0x7651_0000 + s`), a fixed iteration count
//! ([`KMEANS_ITERS`]), and batch-order-stable updates (points are
//! scanned in row order, accumulators are f64, ties break toward the
//! lower centroid index), so the threads = 1/N bit-identity contract
//! survives untouched — the determinism CI job runs vq legs at both
//! thread counts to prove it. The decoder reconstructs from the shipped
//! (int8-requantized) codebook, and the encoder assigns indices against
//! that same requantized codebook, so `decode(encode(x))` equals the
//! encoder's own reconstruction bit for bit.
//!
//! ## Uploads
//!
//! VQ applies to the **downlink dense** payload only: a codebook
//! amortizes over a broadcast frame that Θ clients receive, while the
//! uplink ∇Q* is a one-shot sample per client. Sparse frames under the
//! vq modes therefore carry plain int8 value planes (see
//! [`Precision::for_uploads`](super::Precision::for_uploads)); the
//! frame header records the precision that actually shaped the bytes,
//! so decode stays self-describing.
//!
//! Reconstruction error is data-dependent (there is no per-element
//! bound like int8's half-step grid — `max_roundtrip_error` reports
//! infinity for the vq modes); the vq property tests pin the empirical
//! error ordering instead: error shrinks as the codebook grows, and
//! `vq8r` sits within int8-residual distance of the input.
//!
//! ## Cross-round sessions
//!
//! [`encode_plane`] / [`decode_plane`] are the **stateless** per-frame
//! codec: every frame carries its own codebook. The [`session`]
//! submodule layers generation-tagged cross-round codebook state on
//! top — reusing the previous round's codebook verbatim or shipping
//! int8 centroid deltas once Q stabilizes — built from the same
//! internals (`prepare_rows` / `train_plane` / `assign_plane` / the
//! emit and parse halves below), so the stateless path's bytes are
//! untouched.

pub mod session;

use anyhow::{ensure, Result};

use super::quant::{f16_to_f32, f32_to_f16, Precision};
use crate::rng::Rng;

/// Factor dimensions per subvector: K = 25 splits into five 5-wide
/// subspaces (the last subspace of a non-multiple K is narrower).
pub const SUB_WIDTH: usize = 5;

/// Fixed Lloyd iteration count of the per-frame k-means (determinism:
/// no convergence-dependent early exit).
pub const KMEANS_ITERS: usize = 6;

/// PCG seed base of the per-subspace k-means streams (subspace `s`
/// seeds with `SEED_BASE + s`).
const SEED_BASE: u64 = 0x7651_0000;

/// Number of subvectors a `cols`-wide row splits into.
pub fn subspaces(cols: usize) -> usize {
    cols.div_ceil(SUB_WIDTH)
}

/// Width of subspace `s` (the last subspace absorbs the remainder).
fn sub_width(cols: usize, s: usize) -> usize {
    SUB_WIDTH.min(cols - s * SUB_WIDTH)
}

/// Largest codebook a mode may ship (vq4 indices must fit a nibble).
pub fn centroid_cap(precision: Precision) -> usize {
    match precision {
        Precision::Vq4 => 16,
        _ => 64,
    }
}

/// Centroids per subspace for a frame of `rows` rows: half the row
/// count (so the codebook never dominates the frame), clamped to
/// `[2, cap]`; zero for an empty frame.
pub fn centroids(precision: Precision, rows: usize) -> usize {
    if rows == 0 {
        return 0;
    }
    centroid_cap(precision).min((rows / 2).max(2))
}

/// Index-plane bytes per row: one byte per subspace (`vq8`/`vq8r`), or
/// two nibble-packed indices per byte (`vq4`).
pub fn index_bytes(precision: Precision, cols: usize) -> usize {
    let s = subspaces(cols);
    match precision {
        Precision::Vq4 => s.div_ceil(2),
        _ => s,
    }
}

/// Per-row payload bytes (f16 row scale + indices, plus the int8
/// residual row for `vq8r`); excludes the per-frame codebook block —
/// see [`encoded_len`] for the full payload size.
pub fn row_bytes(precision: Precision, cols: usize) -> usize {
    let base = 2 + index_bytes(precision, cols);
    match precision {
        Precision::Vq8r => base + cols + 2,
        _ => base,
    }
}

/// Codebook block size: one f16 scale per subspace plus
/// `centroids × cols` int8 entries. Zero for an empty frame.
pub fn prefix_len(precision: Precision, rows: usize, cols: usize) -> usize {
    if rows == 0 {
        return 0;
    }
    2 * subspaces(cols) + centroids(precision, rows) * cols
}

/// Exact payload length of a vq-encoded `rows × cols` plane.
pub fn encoded_len(precision: Precision, rows: usize, cols: usize) -> usize {
    if rows == 0 {
        return 0;
    }
    prefix_len(precision, rows, cols) + rows * row_bytes(precision, cols)
}

/// One subspace's trained, int8-requantized codebook. `pub(crate)` so
/// the [`session`] encoder/decoder can cache and delta-patch codebooks
/// across rounds; the byte layout on the wire is owned by
/// [`emit_books`] / [`parse_books`].
#[derive(Debug, Clone)]
pub(crate) struct SubCodebook {
    /// f16 bits of the per-subspace quantization scale.
    pub(crate) scale_bits: u16,
    /// Quantized entries, centroid-major (`centroids × width`).
    pub(crate) entries: Vec<i8>,
    /// Dequantized entries — what the decoder will reconstruct from,
    /// and what the final assignment pass matches against.
    pub(crate) deq: Vec<f32>,
    pub(crate) width: usize,
}

impl SubCodebook {
    /// Recompute the dequantized entries from `entries` + `scale_bits`
    /// (after a session delta patch), with the exact expression the
    /// trainer and the stateless decoder use, so all three paths
    /// reconstruct bit-identical floats.
    pub(crate) fn redequantize(&mut self) {
        let scale = f16_to_f32(self.scale_bits);
        for (d, &q) in self.deq.iter_mut().zip(&self.entries) {
            *d = q as f32 / 127.0 * scale;
        }
    }
}

/// Nearest centroid by f64 squared distance; ties break toward the
/// lower index (strict `<` scan in centroid order). This single helper
/// carries the assignment rule for both the Lloyd loop (f64 working
/// centroids) and the final pass (the int8-requantized codebook,
/// widened to f64 — exact, since f32 → f64 is lossless), so the
/// determinism-critical tie-break lives in exactly one place. Returns
/// the winning index and its squared distance (the session encoder
/// aggregates the distances into the reuse-vs-retrain error budget).
fn nearest(point: &[f32], centroids: &[f64], width: usize, count: usize) -> (usize, f64) {
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for c in 0..count {
        let mut d = 0.0f64;
        for (a, b) in point.iter().zip(&centroids[c * width..(c + 1) * width]) {
            let t = *a as f64 - b;
            d += t * t;
        }
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    (best, best_d)
}

/// Train one subspace's codebook on the normalized live rows with
/// seeded k-means, then requantize it to int8 + f16 scale.
fn train_subspace(
    points: &[f32],
    n: usize,
    width: usize,
    c_count: usize,
    seed: u64,
) -> SubCodebook {
    // f64 working centroids (batch-order-stable Lloyd updates)
    let mut cent = vec![0.0f64; c_count * width];
    if n > 0 {
        let mut rng = Rng::seed_from_u64(seed);
        let init: Vec<usize> = if n >= c_count {
            rng.sample_indices(n, c_count)
        } else {
            (0..c_count).map(|c| c % n).collect()
        };
        for (c, &p) in init.iter().enumerate() {
            for j in 0..width {
                cent[c * width + j] = points[p * width + j] as f64;
            }
        }
        for _ in 0..KMEANS_ITERS {
            let mut sums = vec![0.0f64; c_count * width];
            let mut counts = vec![0u32; c_count];
            for p in 0..n {
                let point = &points[p * width..(p + 1) * width];
                let (best, _) = nearest(point, &cent, width, c_count);
                counts[best] += 1;
                for (acc, v) in sums[best * width..(best + 1) * width].iter_mut().zip(point) {
                    *acc += *v as f64;
                }
            }
            for c in 0..c_count {
                if counts[c] > 0 {
                    for j in 0..width {
                        cent[c * width + j] = sums[c * width + j] / counts[c] as f64;
                    }
                }
                // empty clusters keep their previous centroid
            }
        }
    }
    // requantize: one f16 scale over the subspace, int8 entries
    let max = cent.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    let scale_bits = f32_to_f16(max as f32);
    let scale = f16_to_f32(scale_bits);
    let mut entries = Vec::with_capacity(c_count * width);
    let mut deq = Vec::with_capacity(c_count * width);
    for &v in &cent {
        let q: i8 = if scale > 0.0 && scale.is_finite() {
            ((v as f32) / scale * 127.0).round().clamp(-127.0, 127.0) as i8
        } else {
            0
        };
        entries.push(q);
        deq.push(q as f32 / 127.0 * scale);
    }
    SubCodebook {
        scale_bits,
        entries,
        deq,
        width,
    }
}

/// Per-frame row normalization state shared by the stateless and the
/// session encoders: f16 row scales, the live (nonzero, finite) row
/// set, and the scale-normalized matrix the codebooks train on.
pub(crate) struct PlanePrep {
    /// f16 bits of each row's scale.
    pub(crate) scale_bits: Vec<u16>,
    /// Dequantized row scales (what the decoder will multiply by).
    pub(crate) scales: Vec<f32>,
    /// Rows with a positive finite scale; all others decode to zeros.
    pub(crate) live: Vec<usize>,
    /// Row-major normalized matrix (dead rows stay zero).
    pub(crate) norm: Vec<f32>,
    pub(crate) rows: usize,
    pub(crate) cols: usize,
}

/// Compute the per-row f16 scales and the normalized matrix;
/// zero/non-finite-scale rows sit out of training and decode to exact
/// zeros (times the residual, for vq8r).
pub(crate) fn prepare_rows(data: &[f32], rows: usize, cols: usize) -> PlanePrep {
    let mut scale_bits = Vec::with_capacity(rows);
    let mut scales = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let bits = f32_to_f16(max);
        scale_bits.push(bits);
        scales.push(f16_to_f32(bits));
    }
    let live: Vec<usize> = (0..rows)
        .filter(|&r| scales[r] > 0.0 && scales[r].is_finite())
        .collect();
    let mut norm = vec![0.0f32; rows * cols];
    for &r in &live {
        let s = scales[r];
        for c in 0..cols {
            norm[r * cols + c] = data[r * cols + c] / s;
        }
    }
    PlanePrep {
        scale_bits,
        scales,
        live,
        norm,
        rows,
        cols,
    }
}

/// Train + int8-requantize one codebook per subspace on the live rows
/// (`centroids(p, rows)` centroids each; the same PCG seed schedule as
/// ever, so this is a pure function of the prepared plane).
pub(crate) fn train_plane(prep: &PlanePrep, p: Precision) -> Vec<SubCodebook> {
    let s_count = subspaces(prep.cols);
    let c_count = centroids(p, prep.rows);
    let mut books = Vec::with_capacity(s_count);
    for s_i in 0..s_count {
        let off = s_i * SUB_WIDTH;
        let w = sub_width(prep.cols, s_i);
        let mut points = Vec::with_capacity(prep.live.len() * w);
        for &r in &prep.live {
            points.extend_from_slice(&prep.norm[r * prep.cols + off..r * prep.cols + off + w]);
        }
        books.push(train_subspace(
            &points,
            prep.live.len(),
            w,
            c_count,
            SEED_BASE + s_i as u64,
        ));
    }
    books
}

/// Assign every live row's subvectors to the nearest requantized
/// centroid of `books`. Returns the `rows × subspaces` index table
/// (dead rows keep index 0) and the summed squared assignment distance
/// over the normalized live rows — the session encoder's
/// reconstruction-error measure for the reuse-vs-retrain decision.
pub(crate) fn assign_plane(prep: &PlanePrep, books: &[SubCodebook]) -> (Vec<u8>, f64) {
    let s_count = subspaces(prep.cols);
    let c_count = if s_count > 0 && books[0].width > 0 {
        books[0].entries.len() / books[0].width
    } else {
        0
    };
    let mut assign = vec![0u8; prep.rows * s_count];
    let mut sse = 0.0f64;
    for (s_i, book) in books.iter().enumerate() {
        let off = s_i * SUB_WIDTH;
        let w = sub_width(prep.cols, s_i);
        let deq64: Vec<f64> = book.deq.iter().map(|&v| v as f64).collect();
        for &r in &prep.live {
            let point = &prep.norm[r * prep.cols + off..r * prep.cols + off + w];
            let (best, d) = nearest(point, &deq64, w, c_count);
            assign[r * s_count + s_i] = best as u8;
            sse += d;
        }
    }
    (assign, sse)
}

/// Emit the in-frame codebook block: per-subspace f16 scales, then the
/// int8 entries, subspace-major.
pub(crate) fn emit_books(out: &mut Vec<u8>, books: &[SubCodebook]) {
    for book in books {
        out.extend_from_slice(&book.scale_bits.to_le_bytes());
    }
    for book in books {
        for &q in &book.entries {
            out.push(q as u8);
        }
    }
}

/// Emit the per-row records (f16 scale + index plane, plus the int8
/// residual row for vq8r) against the codebooks that will decode them
/// — the reconstruction the vq8r residual is computed against is
/// exactly the decoder's.
pub(crate) fn emit_rows(
    out: &mut Vec<u8>,
    data: &[f32],
    prep: &PlanePrep,
    books: &[SubCodebook],
    assign: &[u8],
    p: Precision,
) {
    let (rows, cols) = (prep.rows, prep.cols);
    let s_count = subspaces(cols);
    let mut residual = vec![0.0f32; cols];
    for r in 0..rows {
        out.extend_from_slice(&prep.scale_bits[r].to_le_bytes());
        let idx = &assign[r * s_count..(r + 1) * s_count];
        match p {
            Precision::Vq4 => {
                let mut byte = 0u8;
                for (s_i, &i) in idx.iter().enumerate() {
                    if s_i % 2 == 0 {
                        byte = i & 0x0f;
                        if s_i == s_count - 1 {
                            out.push(byte);
                        }
                    } else {
                        byte |= (i & 0x0f) << 4;
                        out.push(byte);
                    }
                }
            }
            _ => out.extend_from_slice(idx),
        }
        if p == Precision::Vq8r {
            // int8 residual row against the decoder's reconstruction
            let s = prep.scales[r];
            for c in 0..cols {
                let recon = if s > 0.0 && s.is_finite() {
                    let s_i = c / SUB_WIDTH;
                    let book = &books[s_i];
                    let j = c - s_i * SUB_WIDTH;
                    book.deq[idx[s_i] as usize * book.width + j] * s
                } else {
                    0.0
                };
                residual[c] = data[r * cols + c] - recon;
            }
            super::quant::encode_rows(out, &residual, 1, cols, Precision::Int8);
        }
    }
}

/// Encode a row-major `rows × cols` plane into `out` (payload layout:
/// codebook block, then per-row records). Pure and deterministic: the
/// same data always yields the same bytes on any thread.
pub fn encode_plane(out: &mut Vec<u8>, data: &[f32], rows: usize, cols: usize, p: Precision) {
    debug_assert!(p.is_vq(), "encode_plane on {}", p.name());
    debug_assert_eq!(data.len(), rows * cols);
    let start = out.len();
    if rows == 0 {
        return;
    }
    let prep = prepare_rows(data, rows, cols);
    let books = train_plane(&prep, p);
    let (assign, _sse) = assign_plane(&prep, &books);
    emit_books(out, &books);
    emit_rows(out, data, &prep, &books, &assign, p);
    debug_assert_eq!(out.len() - start, encoded_len(p, rows, cols));
}

/// Parse an in-frame codebook block ([`emit_books`] layout) into
/// per-subspace codebooks, advancing `pos`. The caller has validated
/// the payload length, so indexing is in bounds by construction.
pub(crate) fn parse_books(
    payload: &[u8],
    pos: &mut usize,
    c_count: usize,
    cols: usize,
) -> Vec<SubCodebook> {
    let s_count = subspaces(cols);
    let mut scale_bits = Vec::with_capacity(s_count);
    for _ in 0..s_count {
        scale_bits.push(u16::from_le_bytes([payload[*pos], payload[*pos + 1]]));
        *pos += 2;
    }
    let mut books = Vec::with_capacity(s_count);
    for (s_i, &bits) in scale_bits.iter().enumerate() {
        let scale = f16_to_f32(bits);
        let w = sub_width(cols, s_i);
        let mut entries = Vec::with_capacity(c_count * w);
        let mut deq = Vec::with_capacity(c_count * w);
        for _ in 0..c_count * w {
            let q = payload[*pos] as i8;
            *pos += 1;
            entries.push(q);
            deq.push(q as f32 / 127.0 * scale);
        }
        books.push(SubCodebook {
            scale_bits: bits,
            entries,
            deq,
            width: w,
        });
    }
    books
}

/// Decode `rows` per-row records ([`emit_rows`] layout) against
/// already-parsed codebooks, advancing `pos`. Indices are range-checked
/// so a crafted frame cannot read outside the shipped codebook.
pub(crate) fn decode_rows_from(
    payload: &[u8],
    pos: &mut usize,
    rows: usize,
    cols: usize,
    p: Precision,
    books: &[SubCodebook],
    c_count: usize,
) -> Result<Vec<f32>> {
    let s_count = subspaces(cols);
    let ib = index_bytes(p, cols);
    let mut data = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let s = f16_to_f32(u16::from_le_bytes([payload[*pos], payload[*pos + 1]]));
        *pos += 2;
        let raw = &payload[*pos..*pos + ib];
        *pos += ib;
        for s_i in 0..s_count {
            let idx = match p {
                Precision::Vq4 => ((raw[s_i / 2] >> (4 * (s_i % 2))) & 0x0f) as usize,
                _ => raw[s_i] as usize,
            };
            ensure!(
                idx < c_count,
                "vq index {idx} out of range (codebook holds {c_count})"
            );
            let off = s_i * SUB_WIDTH;
            let w = sub_width(cols, s_i);
            for j in 0..w {
                data[r * cols + off + j] = books[s_i].deq[idx * w + j] * s;
            }
        }
        if p == Precision::Vq8r {
            let block = &payload[*pos..*pos + cols + 2];
            let res = super::quant::decode_rows(block, 1, cols, Precision::Int8)?;
            *pos += cols + 2;
            for (dst, r_v) in data[r * cols..(r + 1) * cols].iter_mut().zip(&res) {
                *dst += r_v;
            }
        }
    }
    Ok(data)
}

/// Decode a [`encode_plane`] payload back to f32s. The caller (the
/// quant dispatcher) has already validated the payload length against
/// [`encoded_len`]; indices are still range-checked so a crafted frame
/// cannot read outside the shipped codebook.
pub fn decode_plane(payload: &[u8], rows: usize, cols: usize, p: Precision) -> Result<Vec<f32>> {
    debug_assert!(p.is_vq(), "decode_plane on {}", p.name());
    if rows == 0 {
        return Ok(Vec::new());
    }
    let c_count = centroids(p, rows);
    let mut pos = 0usize;
    let books = parse_books(payload, &mut pos, c_count, cols);
    let data = decode_rows_from(payload, &mut pos, rows, cols, p, &books, c_count)?;
    ensure!(
        pos == payload.len(),
        "vq payload has {} trailing bytes",
        payload.len() - pos
    );
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    fn roundtrip(data: &[f32], rows: usize, cols: usize, p: Precision) -> Vec<f32> {
        let mut buf = Vec::new();
        encode_plane(&mut buf, data, rows, cols, p);
        assert_eq!(buf.len(), encoded_len(p, rows, cols), "{}", p.name());
        decode_plane(&buf, rows, cols, p).unwrap()
    }

    fn mse(a: &[f32], b: &[f32]) -> f64 {
        if a.is_empty() {
            return 0.0;
        }
        let sse: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        sse / a.len() as f64
    }

    #[test]
    fn geometry_matches_doc_numbers() {
        // K = 25: five 5-wide subspaces
        assert_eq!(subspaces(25), 5);
        assert_eq!(subspaces(8), 2);
        assert_eq!(subspaces(0), 0);
        assert_eq!(row_bytes(Precision::Vq8, 25), 7);
        assert_eq!(row_bytes(Precision::Vq4, 25), 5);
        assert_eq!(row_bytes(Precision::Vq8r, 25), 34);
        // the prototype-pinned structural lengths
        assert_eq!(encoded_len(Precision::Vq8, 64, 25), 1258);
        assert_eq!(encoded_len(Precision::Vq4, 64, 25), 730);
        assert_eq!(encoded_len(Precision::Vq8, 1763, 25), 13951);
        assert_eq!(encoded_len(Precision::Vq4, 1763, 25), 9225);
        assert_eq!(encoded_len(Precision::Vq8, 0, 25), 0);
        // codebook scales with the frame until the cap
        assert_eq!(centroids(Precision::Vq8, 8), 4);
        assert_eq!(centroids(Precision::Vq8, 38), 19);
        assert_eq!(centroids(Precision::Vq8, 1763), 64);
        assert_eq!(centroids(Precision::Vq4, 1763), 16);
        assert_eq!(centroids(Precision::Vq8, 1), 2);
    }

    #[test]
    fn vq_beats_int8_structurally_above_tiny_frames() {
        for rows in [4usize, 8, 38, 64, 128, 512, 1763] {
            let int8 = super::super::quant::encoded_len(rows, 25, Precision::Int8);
            let vq8 = encoded_len(Precision::Vq8, rows, 25);
            let vq4 = encoded_len(Precision::Vq4, rows, 25);
            assert!(int8 > vq8, "rows={rows}: int8 {int8} !> vq8 {vq8}");
            assert!(vq8 > vq4, "rows={rows}: vq8 {vq8} !> vq4 {vq4}");
        }
    }

    #[test]
    fn roundtrip_is_deterministic_and_self_consistent() {
        let (rows, cols) = (64, 25);
        let data = gaussian(rows, cols, 2021);
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            let mut a = Vec::new();
            encode_plane(&mut a, &data, rows, cols, p);
            let mut b = Vec::new();
            encode_plane(&mut b, &data, rows, cols, p);
            assert_eq!(a, b, "{} encode not deterministic", p.name());
            let dec1 = decode_plane(&a, rows, cols, p).unwrap();
            let dec2 = decode_plane(&a, rows, cols, p).unwrap();
            for (x, y) in dec1.iter().zip(&dec2) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn error_shrinks_with_codebook_size_and_residual() {
        // iid Gaussian is vq's worst case (no structure to exploit);
        // the ordering vq4 ≥ vq8 ≥ vq8r must hold even there
        let (rows, cols) = (64, 25);
        let data = gaussian(rows, cols, 2021);
        let e4 = mse(&data, &roundtrip(&data, rows, cols, Precision::Vq4));
        let e8 = mse(&data, &roundtrip(&data, rows, cols, Precision::Vq8));
        let e8r = mse(&data, &roundtrip(&data, rows, cols, Precision::Vq8r));
        let var = mse(&data, &vec![0.0; data.len()]);
        assert!(e4 > e8 * 0.8, "vq4 {e4} should not beat vq8 {e8}");
        assert!(e8r < e8, "residual must improve: {e8r} !< {e8}");
        // sanity envelopes around the prototype measurements
        assert!(e8 < var * 0.35, "vq8 mse {e8} vs var {var}");
        assert!(e8r < var * 1e-3, "vq8r mse {e8r} vs var {var}");
    }

    #[test]
    fn zero_and_tiny_inputs_roundtrip() {
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            // all-zero matrix decodes to exact zeros
            let zeros = vec![0.0f32; 6 * 25];
            let dec = roundtrip(&zeros, 6, 25, p);
            assert_eq!(dec, zeros, "{}", p.name());
            // empty frame
            let dec = roundtrip(&[], 0, 25, p);
            assert!(dec.is_empty());
            // single row
            let one = gaussian(1, 25, 9);
            let dec = roundtrip(&one, 1, 25, p);
            assert_eq!(dec.len(), 25);
            // narrow matrices (cols not a multiple of SUB_WIDTH)
            for cols in [1usize, 3, 7, 12] {
                let data = gaussian(10, cols, 30 + cols as u64);
                let dec = roundtrip(&data, 10, cols, p);
                assert_eq!(dec.len(), 10 * cols, "{} cols={cols}", p.name());
            }
        }
    }

    #[test]
    fn mixed_zero_rows_keep_exact_zeros() {
        let (rows, cols) = (20, 25);
        let mut data = gaussian(rows, cols, 11);
        for r in [0usize, 7, 19] {
            data[r * cols..(r + 1) * cols].fill(0.0);
        }
        for p in [Precision::Vq8, Precision::Vq4] {
            let dec = roundtrip(&data, rows, cols, p);
            for r in [0usize, 7, 19] {
                assert!(
                    dec[r * cols..(r + 1) * cols].iter().all(|&v| v == 0.0),
                    "{} row {r} not exactly zero",
                    p.name()
                );
            }
        }
    }

    #[test]
    fn out_of_range_index_is_rejected() {
        let (rows, cols) = (8, 25);
        let data = gaussian(rows, cols, 5);
        let mut buf = Vec::new();
        encode_plane(&mut buf, &data, rows, cols, Precision::Vq8);
        // first row's first index byte sits right after the codebook
        // block and the row's f16 scale
        let idx_pos = prefix_len(Precision::Vq8, rows, cols) + 2;
        buf[idx_pos] = 0xff; // far beyond the 4-centroid codebook
        let err = decode_plane(&buf, rows, cols, Precision::Vq8).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn vq8r_error_is_residual_int8_small() {
        let (rows, cols) = (48, 25);
        let data = gaussian(rows, cols, 77);
        let dec = roundtrip(&data, rows, cols, Precision::Vq8r);
        // per element: |x - dec| is the int8 quantization error of the
        // residual, which is ~1% of the residual magnitude — far below
        // the raw vq error
        let e8 = mse(&data, &roundtrip(&data, rows, cols, Precision::Vq8));
        let e8r = mse(&data, &dec);
        assert!(e8r * 100.0 < e8, "vq8r {e8r} not ≪ vq8 {e8}");
    }
}
