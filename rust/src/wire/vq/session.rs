//! Cross-round codebook sessions: the first **stateful** wire feature.
//!
//! PR 4's `wire::vq` ships a freshly learned codebook in every dense
//! frame — correct, stateless, and wasteful once training settles: at
//! M_s = 1763, K = 25 the in-frame codebook block is 1,610 of the
//! 13,951 payload bytes, re-sent every round even when k-means lands on
//! (nearly) the same centroids. This module makes the codebook a
//! **session resource** shared between the coordinator and its clients:
//!
//! * [`VqSession`] (coordinator) keeps the last-shipped per-subspace
//!   codebooks under a monotonically increasing `generation` tag. Each
//!   round it emits one of three version-2 frame modes:
//!   - **reuse** — the frame carries only the generation id and the
//!     per-row records; clients decode against their cached codebook.
//!   - **delta** — the frame carries the new per-subspace f16 scales
//!     plus one wrapping-u8 **centroid delta** per int8 entry
//!     (`new.wrapping_sub(old)`); applying the delta reconstructs the
//!     freshly trained codebook *exactly* (post-int8-requantization),
//!     so a delta frame trains bit-identically to a full frame. The
//!     byte win is entropy-side: once Q stabilizes the deltas
//!     concentrate near zero and the range coder's codebook-prefix
//!     tree eats them.
//!   - **full** — the PR 4 payload under a v2 header: self-contained
//!     codebook + rows; installs/overwrites the client cache.
//! * [`VqClientState`] (per client) holds the cached codebook and
//!   applies reuse/delta frames against it. A frame whose base
//!   generation does not match the cache is **never** decoded into
//!   garbage: it surfaces as [`SessionDecode::Stale`], the typed
//!   "request a resync" signal (the vendored anyhow shim cannot
//!   downcast, so staleness is a first-class result variant rather
//!   than a string to sniff). Corrupt frames — truncation, flips,
//!   crafted indices, geometry mismatches at a matching generation —
//!   remain hard `Err`s, and a failed decode leaves the cache
//!   untouched.
//!
//! ## Mode selection
//!
//! Selection is a pure function of the payload and the session state —
//! the determinism contract survives: repeat encodes are byte-identical
//! and the coordinator-side choice never depends on thread count.
//! [`ReuseMode::Delta`] always ships a delta when the cached geometry
//! matches (bit-transparent to training, so `ci/determinism.sh` can
//! diff its metrics against the stateless path). [`ReuseMode::Auto`]
//! re-runs assignment against the cached codebook and compares the
//! summed squared assignment error against the freshly trained
//! codebook's: reuse is eligible only within [`REUSE_ERR_BUDGET`]
//! (the prototype measured the ratio at ~1.00–1.11 for one Adam step
//! of drift, ≥ ~1.19 once two steps accumulate, and ~2.5 across
//! disjoint bandit subsets — so auto reuses under stable Q and
//! retrains across selection churn). Among eligible candidates auto
//! picks the smallest
//! **measured encoded frame** (entropy coding included); ties fall to
//! the simpler mode (full over delta over reuse). Because the measured
//! bytes depend on the entropy mode, `auto` may pick different modes —
//! and therefore different (equally valid) codebooks — under different
//! entropy settings; within a fixed config it is fully deterministic.
//!
//! ## Resync
//!
//! A client that missed rounds (its cached generation lags) answers a
//! reuse/delta frame with `Stale`; the coordinator then serves
//! [`VqSession::resync_frame`] — a **full** frame for the *current*
//! generation and the *current* round's row records, reconstructing
//! values bit-identical to what in-sync clients decoded, so the
//! training trajectory is independent of who resynced (the churn e2e
//! test pins this). Only the ledger sees the difference: the resync
//! frame's length is attributed to the lagging client.

use anyhow::{ensure, Context, Result};

use crate::wire::entropy::{self, EntropyMode};
use crate::wire::frame::{self, PayloadKind, SessionMode};
use crate::wire::quant::Precision;
use crate::wire::Dense;

use super::{
    assign_plane, centroids, decode_rows_from, emit_books, emit_rows, encoded_len, parse_books,
    prefix_len, prepare_rows, row_bytes, train_plane, SubCodebook,
};

/// Relative reconstruction-error budget of codebook reuse: `auto`
/// reuses the cached codebook only while its summed squared assignment
/// error stays within this fraction above the freshly trained
/// codebook's. Calibrated against the prototype's drift sweep: one
/// Adam step of drift (|Δ| ≈ η = 0.01 on 0.1-scale factors) measures
/// ≤ ~1.11× even on small overfit frames, two accumulated steps
/// ≥ ~1.19×, and disjoint bandit row subsets ~2.5× — so 0.15 reuses
/// across single-round drift, re-ships after drift accumulates, and
/// never reuses across selection churn. 15% of an already-lossy vq
/// assignment error is below the quantizer's own noise floor.
pub const REUSE_ERR_BUDGET: f64 = 0.15;

/// Cross-round codebook policy (`[codec] codebook_reuse`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReuseMode {
    /// Stateless PR 4 behaviour: version-1 frames, a fresh in-frame
    /// codebook every round. The default.
    #[default]
    Off,
    /// Version-2 session frames; ship a centroid **delta** whenever the
    /// cached geometry matches, a full codebook otherwise. Decoded
    /// factors are bit-identical to `off` (the delta reconstructs the
    /// fresh codebook exactly) — only the bytes change.
    Delta,
    /// Version-2 session frames; choose reuse / delta / full per frame
    /// by measured encoded bytes under the [`REUSE_ERR_BUDGET`].
    Auto,
}

impl ReuseMode {
    /// Parse a mode name (`off|delta|auto`).
    pub fn parse(s: &str) -> Result<ReuseMode> {
        Ok(match s {
            "off" => ReuseMode::Off,
            "delta" => ReuseMode::Delta,
            "auto" => ReuseMode::Auto,
            other => anyhow::bail!("unknown codebook_reuse mode `{other}` (off|delta|auto)"),
        })
    }

    /// Mode name for logs/CSV.
    pub fn name(&self) -> &'static str {
        match self {
            ReuseMode::Off => "off",
            ReuseMode::Delta => "delta",
            ReuseMode::Auto => "auto",
        }
    }

    /// Does this mode keep cross-round codebook state (emit v2 frames)?
    pub fn is_active(&self) -> bool {
        !matches!(self, ReuseMode::Off)
    }
}

/// One generation's codebooks plus the geometry they were trained for.
/// Shared representation between the encoder and the client decoder.
#[derive(Debug, Clone)]
struct GenBooks {
    generation: u32,
    c_count: usize,
    cols: usize,
    precision: Precision,
    books: Vec<SubCodebook>,
}

/// Artifacts of the last [`VqSession::encode_dense`] call, kept so a
/// resync frame can be served without re-running k-means: the
/// full-codebook payload that reconstructs exactly the values the
/// chosen broadcast frame decodes to.
#[derive(Debug, Clone)]
struct LastEncode {
    rows: usize,
    cols: usize,
    generation: u32,
    full_payload: Vec<u8>,
}

/// The structural (entropy-off) payload length of a session frame mode.
pub fn session_payload_len(mode: SessionMode, p: Precision, rows: usize, cols: usize) -> usize {
    if rows == 0 {
        return 0;
    }
    match mode {
        // full codebook and delta blocks are the same size: the delta
        // plane replaces each int8 entry with its wrapping difference
        SessionMode::Full | SessionMode::Delta => encoded_len(p, rows, cols),
        SessionMode::Reuse => rows * row_bytes(p, cols),
    }
}

/// The codebook/delta prefix length of a session payload (the segment
/// that trains the entropy coder's dedicated prefix tree).
pub fn session_prefix_len(mode: SessionMode, p: Precision, rows: usize, cols: usize) -> usize {
    match mode {
        SessionMode::Full | SessionMode::Delta => prefix_len(p, rows, cols),
        SessionMode::Reuse => 0,
    }
}

/// Exact frame length of a session-mode dense payload with entropy
/// coding off (entropy-coded lengths are data-dependent — read them
/// off the encoded frame).
pub fn session_frame_len(mode: SessionMode, p: Precision, rows: usize, cols: usize) -> usize {
    frame::SESSION_HEADER_LEN + session_payload_len(mode, p, rows, cols)
}

/// Why [`VqSession::encode_dense`] picked the mode it did: the
/// measured candidate frame lengths and the SSE budget verdict. The
/// session always computed these to make its choice — this struct
/// merely stops discarding them, so the flight recorder can answer
/// "why did round 37 ship a delta?" from the trace alone. Every field
/// is a pure function of (payload, session state), i.e. safe inside
/// the deterministic trace digest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionRationale {
    /// Sealed full-frame candidate length. `None` only in steady-state
    /// `delta` mode, where the full candidate is not built (sealing it
    /// would waste an entropy pass per round).
    pub full_bytes: Option<u64>,
    /// Sealed delta-frame candidate length (`None` when the cached
    /// geometry is incompatible or no state exists).
    pub delta_bytes: Option<u64>,
    /// Sealed reuse-frame candidate length (`None` unless `auto` found
    /// the cached codebook within budget).
    pub reuse_bytes: Option<u64>,
    /// Summed squared assignment error against the freshly trained
    /// codebook.
    pub sse_fresh: f64,
    /// Summed squared assignment error against the cached codebook
    /// (`None` unless `auto` evaluated reuse).
    pub sse_reuse: Option<f64>,
    /// The [`REUSE_ERR_BUDGET`] verdict: was `sse_reuse` within budget
    /// of `sse_fresh`? (`None` when reuse was never evaluated.)
    pub reuse_within_budget: Option<bool>,
}

impl SessionRationale {
    /// Rationale of a frame that had no competing candidates (empty
    /// payloads): just the one sealed length.
    fn sole(frame_len: usize) -> SessionRationale {
        SessionRationale {
            full_bytes: Some(frame_len as u64),
            delta_bytes: None,
            reuse_bytes: None,
            sse_fresh: 0.0,
            sse_reuse: None,
            reuse_within_budget: None,
        }
    }
}

/// One encoded session download: the broadcast frame plus the metadata
/// the coordinator needs for per-client sync accounting.
#[derive(Debug, Clone)]
pub struct EncodedDownload {
    /// The sealed version-2 frame to broadcast.
    pub frame: Vec<u8>,
    /// Which session mode the frame carries.
    pub mode: SessionMode,
    /// The frame's generation tag (the generation a client holds
    /// *after* successfully decoding it — unless `installs_generation`
    /// is false).
    pub generation: u32,
    /// Does decoding this frame leave the client holding `generation`?
    /// False only for empty (rows = 0) frames, which carry no codebook:
    /// any client can decode them, but the decoder installs nothing, so
    /// the coordinator must not record a generation for the recipients
    /// (mirroring `VqClientState::decode_dense`'s early return).
    pub installs_generation: bool,
    /// The measured-bytes/SSE evidence behind the mode choice.
    pub rationale: SessionRationale,
}

impl EncodedDownload {
    /// Can a client whose cached codebook generation is `cached` decode
    /// this frame directly (no resync needed)?
    pub fn in_sync(&self, cached: Option<u32>) -> bool {
        match self.mode {
            SessionMode::Full => true,
            SessionMode::Delta => cached == Some(self.generation.wrapping_sub(1)),
            SessionMode::Reuse => cached == Some(self.generation),
        }
    }
}

/// Coordinator-side session state: the last-shipped codebooks and the
/// reuse policy. One per trainer; never shared across threads (dense
/// downloads are encoded once per round on the coordinator lane, so
/// the fleet executor's determinism contract is untouched).
#[derive(Debug, Clone)]
pub struct VqSession {
    precision: Precision,
    entropy: EntropyMode,
    mode: ReuseMode,
    state: Option<GenBooks>,
    last: Option<LastEncode>,
}

impl VqSession {
    /// New session for a vq precision. `mode` must be an active session
    /// mode (`delta`/`auto`) — `off` means "don't build a session".
    pub fn new(precision: Precision, entropy: EntropyMode, mode: ReuseMode) -> Result<VqSession> {
        ensure!(
            precision.is_vq(),
            "codebook sessions apply to the vq precisions, not {}",
            precision.name()
        );
        ensure!(
            mode.is_active(),
            "codebook_reuse = off does not use a session"
        );
        Ok(VqSession {
            precision,
            entropy,
            mode,
            state: None,
            last: None,
        })
    }

    /// The current codebook generation (0 before the first frame).
    pub fn generation(&self) -> u32 {
        self.state.as_ref().map_or(0, |s| s.generation)
    }

    /// The session's reuse policy.
    pub fn mode(&self) -> ReuseMode {
        self.mode
    }

    /// Seal one session payload into a v2 frame (entropy-coding it
    /// first when the session's entropy mode range-codes values).
    fn seal(
        &self,
        mode: SessionMode,
        generation: u32,
        rows: usize,
        cols: usize,
        payload: &[u8],
    ) -> Result<Vec<u8>> {
        let coded;
        let body: &[u8] = if self.entropy.range_values() {
            coded = entropy::seal_block_prefixed(
                payload,
                self.precision,
                cols,
                session_prefix_len(mode, self.precision, rows, cols),
            )?;
            &coded
        } else {
            payload
        };
        frame::seal_session(
            self.precision.id(),
            self.entropy.id(),
            PayloadKind::Dense,
            rows,
            cols,
            generation,
            mode,
            body,
        )
    }

    /// Encode one dense Q* download through the session. Pure function
    /// of `(data, session state)`: repeat calls on a cloned session are
    /// byte-identical. Advances the generation when a delta or full
    /// frame ships; reuse keeps it.
    pub fn encode_dense(
        &mut self,
        data: &[f32],
        rows: usize,
        cols: usize,
    ) -> Result<EncodedDownload> {
        ensure!(
            data.len() == rows * cols,
            "session dense encode: {} values for {rows}x{cols}",
            data.len()
        );
        let p = self.precision;
        if rows == 0 {
            let generation = self.generation();
            let frame = self.seal(SessionMode::Full, generation, rows, cols, &[])?;
            self.last = Some(LastEncode {
                rows,
                cols,
                generation,
                full_payload: Vec::new(),
            });
            let rationale = SessionRationale::sole(frame.len());
            return Ok(EncodedDownload {
                frame,
                mode: SessionMode::Full,
                generation,
                // no codebook travels, so no client gains a generation
                installs_generation: false,
                rationale,
            });
        }

        let c_count = centroids(p, rows);
        let prep = prepare_rows(data, rows, cols);
        let fresh = train_plane(&prep, p);
        let (assign_fresh, sse_fresh) = assign_plane(&prep, &fresh);

        let mut full_payload = Vec::with_capacity(encoded_len(p, rows, cols));
        emit_books(&mut full_payload, &fresh);
        emit_rows(&mut full_payload, data, &prep, &fresh, &assign_fresh, p);

        let compatible = self
            .state
            .as_ref()
            .is_some_and(|s| s.c_count == c_count && s.cols == cols && s.precision == p);
        let next_gen = self.generation() + 1;
        // the sealed full candidate is only needed when it can actually
        // be chosen: always under Auto (byte comparison), and as the
        // Delta-mode fallback when no compatible state exists — sealing
        // it unconditionally would waste a full entropy-coding pass per
        // round in steady-state Delta mode
        let full_frame = if self.mode == ReuseMode::Auto || !compatible {
            Some(self.seal(SessionMode::Full, next_gen, rows, cols, &full_payload)?)
        } else {
            None
        };

        // delta candidate: new scales + wrapping entry deltas + the
        // full candidate's row records (same fresh codebook, so the
        // records are shared byte-for-byte)
        let mut delta_frame = None;
        if compatible {
            let s = self.state.as_ref().expect("compatible implies state");
            let mut dp = Vec::with_capacity(full_payload.len());
            for book in &fresh {
                dp.extend_from_slice(&book.scale_bits.to_le_bytes());
            }
            for (old, new) in s.books.iter().zip(&fresh) {
                for (&o, &n) in old.entries.iter().zip(&new.entries) {
                    dp.push((n as u8).wrapping_sub(o as u8));
                }
            }
            dp.extend_from_slice(&full_payload[prefix_len(p, rows, cols)..]);
            delta_frame = Some(self.seal(SessionMode::Delta, next_gen, rows, cols, &dp)?);
        }

        // reuse candidate (auto only): assignment against the cached
        // codebook, eligible within the error budget
        let mut reuse_cand = None; // (sealed frame, row records)
        let mut sse_reuse = None;
        let mut reuse_within_budget = None;
        if self.mode == ReuseMode::Auto && compatible {
            let s = self.state.as_ref().expect("compatible implies state");
            let (assign_reuse, sse_r) = assign_plane(&prep, &s.books);
            let within = sse_r <= sse_fresh * (1.0 + REUSE_ERR_BUDGET);
            sse_reuse = Some(sse_r);
            reuse_within_budget = Some(within);
            if within {
                let mut records = Vec::with_capacity(rows * row_bytes(p, cols));
                emit_rows(&mut records, data, &prep, &s.books, &assign_reuse, p);
                let frame = self.seal(SessionMode::Reuse, s.generation, rows, cols, &records)?;
                reuse_cand = Some((frame, records));
            }
        }

        // choose: delta-mode always deltas when it can; auto takes the
        // smallest measured frame, ties falling to the simpler mode
        let chosen = match self.mode {
            ReuseMode::Delta => {
                if delta_frame.is_some() {
                    SessionMode::Delta
                } else {
                    SessionMode::Full
                }
            }
            ReuseMode::Auto => {
                let mut best = SessionMode::Full;
                let mut best_len = full_frame.as_ref().expect("auto seals full").len();
                if let Some(df) = &delta_frame {
                    if df.len() < best_len {
                        best = SessionMode::Delta;
                        best_len = df.len();
                    }
                }
                if let Some((rf, _)) = &reuse_cand {
                    if rf.len() < best_len {
                        best = SessionMode::Reuse;
                    }
                }
                best
            }
            ReuseMode::Off => unreachable!("VqSession::new rejects off"),
        };

        // the evidence the choice was made from, preserved for the trace
        let rationale = SessionRationale {
            full_bytes: full_frame.as_ref().map(|f| f.len() as u64),
            delta_bytes: delta_frame.as_ref().map(|f| f.len() as u64),
            reuse_bytes: reuse_cand.as_ref().map(|(f, _)| f.len() as u64),
            sse_fresh,
            sse_reuse,
            reuse_within_budget,
        };

        match chosen {
            SessionMode::Reuse => {
                let (frame, records) = reuse_cand.expect("reuse chosen implies candidate");
                let s = self.state.as_ref().expect("reuse chosen implies state");
                let generation = s.generation;
                // resync payload: the cached codebook made explicit,
                // followed by the very records the reuse frame carries
                let mut resync = Vec::with_capacity(encoded_len(p, rows, cols));
                emit_books(&mut resync, &s.books);
                resync.extend_from_slice(&records);
                self.last = Some(LastEncode {
                    rows,
                    cols,
                    generation,
                    full_payload: resync,
                });
                Ok(EncodedDownload {
                    frame,
                    mode: SessionMode::Reuse,
                    generation,
                    installs_generation: true,
                    rationale,
                })
            }
            mode => {
                let frame = if mode == SessionMode::Delta {
                    delta_frame.expect("delta chosen implies candidate")
                } else {
                    full_frame.expect("full chosen implies candidate")
                };
                self.state = Some(GenBooks {
                    generation: next_gen,
                    c_count,
                    cols,
                    precision: p,
                    books: fresh,
                });
                self.last = Some(LastEncode {
                    rows,
                    cols,
                    generation: next_gen,
                    full_payload,
                });
                Ok(EncodedDownload {
                    frame,
                    mode,
                    generation: next_gen,
                    installs_generation: true,
                    rationale,
                })
            }
        }
    }

    /// FNV-64 digest of the full session state: configuration ids, the
    /// cached codebook (generation, geometry, scales and entries by
    /// exact bit pattern) and the retained last-encode artifacts. The
    /// round journal records this each round so a `--resume` replay
    /// verifies the reconstructed session — generation counters alone
    /// would miss a centroid mismatch that only bites at the next
    /// delta frame.
    pub fn state_digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_u8(self.precision.id());
        h.write_u8(self.entropy.id());
        match &self.state {
            Some(s) => {
                h.write_u8(1);
                h.write_u64(s.generation as u64);
                h.write_u64(s.c_count as u64);
                h.write_u64(s.cols as u64);
                for book in &s.books {
                    h.write_u64(book.scale_bits as u64);
                    for &q in &book.entries {
                        h.write_u8(q as u8);
                    }
                }
            }
            None => h.write_u8(0),
        }
        match &self.last {
            Some(l) => {
                h.write_u8(1);
                h.write_u64(l.generation as u64);
                h.write_u64(l.rows as u64);
                h.write_u64(l.cols as u64);
                h.write(&l.full_payload);
            }
            None => h.write_u8(0),
        }
        h.finish()
    }

    /// The resync frame for the last encoded download: a **full** v2
    /// frame carrying the current codebook and the current round's row
    /// records. Decodes to values bit-identical to the broadcast frame
    /// (the churn e2e pins this), installs the current generation in
    /// the client's cache, and needs no prior state to decode.
    pub fn resync_frame(&self) -> Result<Vec<u8>> {
        let last = self
            .last
            .as_ref()
            .context("resync_frame before any encode_dense")?;
        self.seal(
            SessionMode::Full,
            last.generation,
            last.rows,
            last.cols,
            &last.full_payload,
        )
    }
}

/// Outcome of a session decode: data, or the typed stale-state signal.
#[derive(Debug, Clone)]
pub enum SessionDecode {
    /// The frame decoded against (and possibly updated) the cache.
    Data(Dense),
    /// The frame references a codebook generation this client does not
    /// hold — it missed rounds (or lost its cache) and must request a
    /// full-codebook resync. Nothing was decoded; the cache is
    /// unchanged.
    Stale {
        /// The generation this client holds (`None` = no cache at all).
        cached: Option<u32>,
        /// The base generation the frame requires.
        required: u32,
    },
}

impl SessionDecode {
    /// Unwrap the decoded data, turning staleness into a hard error
    /// (for callers that know they are in sync, e.g. the coordinator's
    /// own mirror decoder).
    pub fn into_data(self) -> Result<Dense> {
        match self {
            SessionDecode::Data(d) => Ok(d),
            SessionDecode::Stale { cached, required } => anyhow::bail!(
                "stale codebook generation: cached {cached:?}, frame requires {required}"
            ),
        }
    }
}

/// Per-client decode state: the cached codebook generation a device
/// holds between rounds. Applies reuse/delta frames against the cache;
/// corrupt frames never touch it.
#[derive(Debug, Clone, Default)]
pub struct VqClientState {
    state: Option<GenBooks>,
}

impl VqClientState {
    /// Fresh state: no cached codebook (a brand-new or evicted client).
    pub fn new() -> VqClientState {
        VqClientState::default()
    }

    /// The cached codebook generation, if any.
    pub fn generation(&self) -> Option<u32> {
        self.state.as_ref().map(|s| s.generation)
    }

    /// Drop the cached codebook — the churn hook simulating a device
    /// that evicted its cache (app reinstall, storage pressure) or
    /// missed the rounds that shipped it.
    pub fn invalidate(&mut self) {
        self.state = None;
    }

    /// Decode one session (version-2) dense frame against the cache.
    /// Returns [`SessionDecode::Stale`] when the frame's base
    /// generation is not the cached one (decided from the
    /// checksum-validated header before any payload *decode* work — a
    /// churned client still pays the frame checksum scan but skips the
    /// expensive range-decode); hard-errors on corruption — in both
    /// cases the cache is left exactly as it was.
    pub fn decode_dense(&mut self, buf: &[u8]) -> Result<SessionDecode> {
        let (h, payload) = frame::open_session(buf)?;
        ensure!(
            h.kind == PayloadKind::Dense,
            "expected a dense session frame, got {:?}",
            h.kind
        );
        let p = Precision::from_id(h.codec_id)?;
        ensure!(p.is_vq(), "session frame carries non-vq codec {}", p.name());
        let e = EntropyMode::from_id(h.entropy_id)?;
        let (rows, cols) = (h.rows as usize, h.cols as usize);
        let expected = session_payload_len(h.mode, p, rows, cols);
        // staleness is knowable from the (checksum-validated) header
        // alone — answer churned clients before the range-decode of a
        // payload we would then discard (the checksum scan above is
        // unavoidable: corruption must never masquerade as staleness)
        if rows > 0 {
            match h.mode {
                SessionMode::Delta => {
                    ensure!(h.generation > 0, "delta frame with generation 0");
                    let required = h.generation - 1;
                    let cached = self.generation();
                    if cached != Some(required) {
                        return Ok(SessionDecode::Stale { cached, required });
                    }
                }
                SessionMode::Reuse => {
                    let cached = self.generation();
                    if cached != Some(h.generation) {
                        return Ok(SessionDecode::Stale {
                            cached,
                            required: h.generation,
                        });
                    }
                }
                SessionMode::Full => {}
            }
        }
        let raw_store;
        let raw: &[u8] = if e.range_values() {
            raw_store = entropy::open_block_prefixed(
                payload,
                expected,
                p,
                cols,
                session_prefix_len(h.mode, p, rows, cols),
            )?;
            &raw_store
        } else {
            ensure!(
                payload.len() == expected,
                "session payload of {} bytes does not match {rows}x{cols} {} (expected {expected})",
                payload.len(),
                h.mode.name()
            );
            payload
        };
        if rows == 0 {
            return Ok(SessionDecode::Data(Dense {
                data: Vec::new(),
                rows,
                cols,
            }));
        }
        let c_count = centroids(p, rows);
        match h.mode {
            SessionMode::Full => {
                let mut pos = 0usize;
                let books = parse_books(raw, &mut pos, c_count, cols);
                let data = decode_rows_from(raw, &mut pos, rows, cols, p, &books, c_count)?;
                ensure!(
                    pos == raw.len(),
                    "session full payload has {} trailing bytes",
                    raw.len() - pos
                );
                self.state = Some(GenBooks {
                    generation: h.generation,
                    c_count,
                    cols,
                    precision: p,
                    books,
                });
                Ok(SessionDecode::Data(Dense { data, rows, cols }))
            }
            SessionMode::Delta => {
                let required = h.generation - 1; // staleness checked above
                let s = self.state.as_ref().expect("staleness checked above");
                ensure!(
                    s.c_count == c_count && s.cols == cols && s.precision == p,
                    "delta frame geometry ({c_count} centroids × {cols} cols, {}) does not \
                     match the cached generation {required} codebook",
                    p.name()
                );
                // patch a copy; commit only after the rows decode, so a
                // crafted frame cannot leave a half-updated cache
                let mut books = s.books.clone();
                let mut pos = 0usize;
                for book in books.iter_mut() {
                    book.scale_bits = u16::from_le_bytes([raw[pos], raw[pos + 1]]);
                    pos += 2;
                }
                for book in books.iter_mut() {
                    for q in book.entries.iter_mut() {
                        *q = (*q as u8).wrapping_add(raw[pos]) as i8;
                        pos += 1;
                    }
                    book.redequantize();
                }
                let data = decode_rows_from(raw, &mut pos, rows, cols, p, &books, c_count)?;
                ensure!(
                    pos == raw.len(),
                    "session delta payload has {} trailing bytes",
                    raw.len() - pos
                );
                self.state = Some(GenBooks {
                    generation: h.generation,
                    c_count,
                    cols,
                    precision: p,
                    books,
                });
                Ok(SessionDecode::Data(Dense { data, rows, cols }))
            }
            SessionMode::Reuse => {
                let s = self.state.as_ref().expect("staleness checked above");
                ensure!(
                    s.c_count == c_count && s.cols == cols && s.precision == p,
                    "reuse frame geometry ({c_count} centroids × {cols} cols, {}) does not \
                     match the cached generation {} codebook",
                    p.name(),
                    h.generation
                );
                let mut pos = 0usize;
                let data = decode_rows_from(raw, &mut pos, rows, cols, p, &s.books, c_count)?;
                ensure!(
                    pos == raw.len(),
                    "session reuse payload has {} trailing bytes",
                    raw.len() - pos
                );
                Ok(SessionDecode::Data(Dense { data, rows, cols }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::wire::make_codec;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    fn drifted(base: &[f32], step: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        base.iter().map(|&v| v + rng.normal() as f32 * step).collect()
    }

    fn decode(state: &mut VqClientState, frame: &[u8]) -> Dense {
        state.decode_dense(frame).unwrap().into_data().unwrap()
    }

    #[test]
    fn reuse_mode_registry() {
        for m in [ReuseMode::Off, ReuseMode::Delta, ReuseMode::Auto] {
            assert_eq!(ReuseMode::parse(m.name()).unwrap(), m);
        }
        assert!(ReuseMode::parse("always").is_err());
        assert_eq!(ReuseMode::default(), ReuseMode::Off);
        assert!(!ReuseMode::Off.is_active());
        assert!(ReuseMode::Delta.is_active() && ReuseMode::Auto.is_active());
    }

    #[test]
    fn session_rejects_scalar_precisions_and_off() {
        assert!(VqSession::new(Precision::Int8, EntropyMode::None, ReuseMode::Auto).is_err());
        assert!(VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Off).is_err());
        assert!(VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Auto).is_ok());
    }

    #[test]
    fn first_frame_is_full_and_stable_rounds_reuse() {
        let (rows, cols) = (64usize, 25usize);
        let q1 = gaussian(rows, cols, 2021);
        let q2 = drifted(&q1, 0.002, 7);
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Auto).unwrap();
        let f1 = sess.encode_dense(&q1, rows, cols).unwrap();
        assert_eq!(f1.mode, SessionMode::Full);
        assert_eq!(f1.generation, 1);
        assert_eq!(
            f1.frame.len(),
            session_frame_len(SessionMode::Full, Precision::Vq8, rows, cols)
        );
        let f2 = sess.encode_dense(&q2, rows, cols).unwrap();
        assert_eq!(f2.mode, SessionMode::Reuse, "stable Q must reuse");
        assert_eq!(f2.generation, 1);
        assert!(f2.frame.len() < f1.frame.len());
        assert_eq!(
            f2.frame.len(),
            session_frame_len(SessionMode::Reuse, Precision::Vq8, rows, cols)
        );
        // a client that saw both frames decodes both
        let mut client = VqClientState::new();
        let d1 = decode(&mut client, &f1.frame);
        assert_eq!((d1.rows, d1.cols), (rows, cols));
        assert_eq!(client.generation(), Some(1));
        let d2 = decode(&mut client, &f2.frame);
        assert_eq!(d2.data.len(), rows * cols);
        assert_eq!(client.generation(), Some(1));
    }

    #[test]
    fn delta_frames_decode_bit_identically_to_full_reencode() {
        let (rows, cols) = (48usize, 25usize);
        let q1 = gaussian(rows, cols, 5);
        let q2 = gaussian(rows, cols, 6); // unrelated: worst case for deltas
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            let mut sess = VqSession::new(p, EntropyMode::None, ReuseMode::Delta).unwrap();
            let f1 = sess.encode_dense(&q1, rows, cols).unwrap();
            let f2 = sess.encode_dense(&q2, rows, cols).unwrap();
            assert_eq!(f1.mode, SessionMode::Full);
            assert_eq!(f2.mode, SessionMode::Delta, "{}", p.name());
            assert_eq!(f2.generation, 2);
            let mut client = VqClientState::new();
            decode(&mut client, &f1.frame);
            let via_delta = decode(&mut client, &f2.frame);
            assert_eq!(client.generation(), Some(2));
            // the stateless codec on the same data: identical codebook
            // (post-requant) -> identical reconstruction
            let stateless = make_codec(p);
            let plain = stateless
                .decode_dense(&stateless.encode_dense(&q2, rows, cols).unwrap())
                .unwrap();
            for (a, b) in via_delta.data.iter().zip(&plain.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn stale_client_resyncs_and_rejoins_bit_identically() {
        let (rows, cols) = (64usize, 25usize);
        let q1 = gaussian(rows, cols, 11);
        // round 2 moves to unrelated factors, so the generation
        // advances while the lapsed client is away...
        let q2 = gaussian(rows, cols, 12);
        // ... and rounds 3/4 are stable again, so they reuse it
        let q3 = drifted(&q2, 0.002, 13);
        let q4 = drifted(&q3, 0.002, 14);
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::Full, ReuseMode::Auto).unwrap();
        let mut on = VqClientState::new();
        let mut lapsed = VqClientState::new();

        let f1 = sess.encode_dense(&q1, rows, cols).unwrap();
        decode(&mut on, &f1.frame);
        decode(&mut lapsed, &f1.frame);

        // lapsed misses round 2 entirely
        let f2 = sess.encode_dense(&q2, rows, cols).unwrap();
        let d2 = decode(&mut on, &f2.frame);

        let f3 = sess.encode_dense(&q3, rows, cols).unwrap();
        let d3 = decode(&mut on, &f3.frame);
        assert_ne!(f3.mode, SessionMode::Full, "stable Q should not re-ship");
        // ... so the lapsed client must hit the stale signal, untouched
        let before = lapsed.generation();
        match lapsed.decode_dense(&f3.frame).unwrap() {
            SessionDecode::Stale { cached, required } => {
                assert_eq!(cached, before);
                assert_ne!(Some(required), before);
            }
            SessionDecode::Data(_) => panic!("lapsed client decoded a frame it cannot hold"),
        }
        assert_eq!(lapsed.generation(), before, "stale decode mutated the cache");

        // resync: full frame for the current round, bit-identical data
        let resync = sess.resync_frame().unwrap();
        let dr = decode(&mut lapsed, &resync);
        assert_eq!(lapsed.generation(), on.generation());
        for (a, b) in dr.data.iter().zip(&d3.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = d2;

        // ... and from here the lapsed client tracks bit-identically
        let f4 = sess.encode_dense(&q4, rows, cols).unwrap();
        let a = decode(&mut on, &f4.frame);
        let b = decode(&mut lapsed, &f4.frame);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn in_sync_predicate_matches_decoder() {
        let (rows, cols) = (32usize, 25usize);
        let q1 = gaussian(rows, cols, 3);
        let q2 = drifted(&q1, 0.002, 4);
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Auto).unwrap();
        let f1 = sess.encode_dense(&q1, rows, cols).unwrap();
        assert!(f1.in_sync(None) && f1.in_sync(Some(9)), "full syncs anyone");
        let f2 = sess.encode_dense(&q2, rows, cols).unwrap();
        assert_eq!(f2.mode, SessionMode::Reuse);
        assert!(f2.in_sync(Some(f2.generation)));
        assert!(!f2.in_sync(None));
        assert!(!f2.in_sync(Some(f2.generation + 1)));
    }

    #[test]
    fn geometry_change_forces_full() {
        let cols = 25usize;
        let q1 = gaussian(64, cols, 21);
        let q2 = gaussian(32, cols, 22); // different row count -> new c_count
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Auto).unwrap();
        sess.encode_dense(&q1, 64, cols).unwrap();
        let f2 = sess.encode_dense(&q2, 32, cols).unwrap();
        assert_eq!(f2.mode, SessionMode::Full);
        assert_eq!(f2.generation, 2);
    }

    #[test]
    fn empty_frame_roundtrips_without_state() {
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::Full, ReuseMode::Auto).unwrap();
        let f = sess.encode_dense(&[], 0, 25).unwrap();
        // decodable by anyone, but it ships no codebook — the metadata
        // must say so, or the coordinator would mark recipients as
        // holding a generation they never received
        assert!(f.in_sync(None));
        assert!(!f.installs_generation);
        let mut client = VqClientState::new();
        let d = decode(&mut client, &f.frame);
        assert_eq!((d.rows, d.cols), (0, 25));
        assert!(d.data.is_empty());
        assert_eq!(client.generation(), None);
        // non-empty frames do install their generation
        let q = gaussian(8, 25, 40);
        let f2 = sess.encode_dense(&q, 8, 25).unwrap();
        assert!(f2.installs_generation);
    }

    #[test]
    fn corrupt_session_frames_are_rejected_not_applied() {
        let (rows, cols) = (40usize, 25usize);
        let q1 = gaussian(rows, cols, 31);
        let q2 = gaussian(rows, cols, 32);
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Delta).unwrap();
        let f1 = sess.encode_dense(&q1, rows, cols).unwrap();
        let f2 = sess.encode_dense(&q2, rows, cols).unwrap();
        let mut client = VqClientState::new();
        decode(&mut client, &f1.frame);
        // flipped delta-plane byte: checksum rejects, cache untouched
        let mut bad = f2.frame.clone();
        bad[frame::SESSION_HEADER_LEN + 12] ^= 0x20;
        assert!(client.decode_dense(&bad).is_err());
        assert_eq!(client.generation(), Some(1));
        // truncation inside the delta plane
        assert!(client.decode_dense(&f2.frame[..f2.frame.len() - 3]).is_err());
        assert_eq!(client.generation(), Some(1));
        // the intact frame still applies afterwards
        decode(&mut client, &f2.frame);
        assert_eq!(client.generation(), Some(2));
    }

    #[test]
    fn rationale_records_the_evidence_behind_the_choice() {
        let (rows, cols) = (64usize, 25usize);
        let q1 = gaussian(rows, cols, 2021);
        let q2 = drifted(&q1, 0.002, 7);
        let mut sess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Auto).unwrap();
        let f1 = sess.encode_dense(&q1, rows, cols).unwrap();
        // first frame: full candidate only, no cached state to compare
        let r1 = f1.rationale;
        assert_eq!(r1.full_bytes, Some(f1.frame.len() as u64));
        assert_eq!(r1.delta_bytes, None);
        assert_eq!(r1.reuse_bytes, None);
        assert!(r1.sse_fresh >= 0.0);
        assert_eq!(r1.sse_reuse, None);
        assert_eq!(r1.reuse_within_budget, None);
        // stable round: reuse wins, and the rationale shows all three
        // candidates measured with the budget verdict positive
        let f2 = sess.encode_dense(&q2, rows, cols).unwrap();
        assert_eq!(f2.mode, SessionMode::Reuse);
        let r2 = f2.rationale;
        assert_eq!(r2.reuse_bytes, Some(f2.frame.len() as u64));
        assert_eq!(r2.reuse_within_budget, Some(true));
        let sse_reuse = r2.sse_reuse.unwrap();
        assert!(sse_reuse <= r2.sse_fresh * (1.0 + REUSE_ERR_BUDGET));
        assert!(r2.reuse_bytes.unwrap() < r2.full_bytes.unwrap());
        assert!(r2.delta_bytes.is_some());
        // steady-state delta mode: no full candidate is sealed
        let mut dsess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Delta).unwrap();
        let d1 = dsess.encode_dense(&q1, rows, cols).unwrap();
        assert_eq!(d1.rationale.full_bytes, Some(d1.frame.len() as u64));
        let d2 = dsess.encode_dense(&q2, rows, cols).unwrap();
        assert_eq!(d2.mode, SessionMode::Delta);
        assert_eq!(d2.rationale.full_bytes, None, "delta mode skips the full seal");
        assert_eq!(d2.rationale.delta_bytes, Some(d2.frame.len() as u64));
        assert_eq!(d2.rationale.sse_reuse, None, "delta mode never evaluates reuse");
    }

    #[test]
    fn state_digest_tracks_session_evolution() {
        let (rows, cols) = (48usize, 25usize);
        let q1 = gaussian(rows, cols, 71);
        let q2 = drifted(&q1, 0.002, 72);
        let mut a = VqSession::new(Precision::Vq8, EntropyMode::Full, ReuseMode::Auto).unwrap();
        let b = a.clone();
        assert_eq!(a.state_digest(), b.state_digest(), "clones digest equally");
        let fresh = a.state_digest();
        a.encode_dense(&q1, rows, cols).unwrap();
        let after_full = a.state_digest();
        assert_ne!(fresh, after_full, "installing a codebook must move the digest");
        // a reuse round keeps the codebook but refreshes the resync
        // artifacts — the digest must see that too
        let f2 = a.encode_dense(&q2, rows, cols).unwrap();
        assert_eq!(f2.mode, SessionMode::Reuse);
        assert_ne!(after_full, a.state_digest());
        // replaying the same inputs on a fresh session reproduces the
        // digest exactly (what --resume relies on)
        let mut replay = VqSession::new(Precision::Vq8, EntropyMode::Full, ReuseMode::Auto).unwrap();
        replay.encode_dense(&q1, rows, cols).unwrap();
        replay.encode_dense(&q2, rows, cols).unwrap();
        assert_eq!(replay.state_digest(), a.state_digest());
    }

    #[test]
    fn resync_before_encode_errors() {
        let sess = VqSession::new(Precision::Vq8, EntropyMode::None, ReuseMode::Auto).unwrap();
        assert!(sess.resync_frame().is_err());
    }
}
