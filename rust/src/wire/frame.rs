//! Versioned binary frame enveloping every payload on the simulated wire.
//!
//! Layout (little-endian, 24-byte header):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "FPAY"
//! 4       1     format version (1)
//! 5       1     codec id (wire::Precision)
//! 6       1     payload kind (0 = dense, 1 = sparse)
//! 7       1     entropy codec id (wire::EntropyMode; 0 = none)
//! 8       4     rows (u32)
//! 12      4     cols (u32)
//! 16      4     payload length in bytes (u32)
//! 20      4     FNV-1a checksum of header bytes 0..20 + payload (u32)
//! 24      ...   payload
//! ```
//!
//! Byte 7 was reserved-zero until the entropy layer landed, so every
//! pre-entropy frame is still a valid mode-0 (`none`) frame. When the
//! entropy id selects range coding, the payload is one or more
//! **length-prefixed entropy blocks** (`u32 raw_len | coded bytes`, see
//! `wire::entropy`) instead of raw quantized bytes; the checksum covers
//! the coded bytes, so corruption is detected *before* entropy decode
//! runs.
//!
//! [`open`] validates magic, version, length and checksum before handing
//! the payload slice back, so corruption/truncation on the "wire" is a
//! decode error rather than silent garbage (`frame_corruption_detected`
//! property test). The checksum covers the header fields as well as the
//! payload, so a flipped dims/codec byte cannot smuggle a
//! wrong-dimensioned matrix through. A single flipped byte always
//! changes the FNV-1a value — every mixing step is a bijection on the
//! running state — so detection of 1-byte faults is deterministic, not
//! probabilistic.
//!
//! ## Version 2: session frames
//!
//! Cross-round codebook sessions (`wire::vq::session`) need two fields
//! a stateless frame has no room for: the codebook **generation** the
//! frame builds on and the **session mode** (full / delta / reuse).
//! Version 2 widens the header to 32 bytes — bytes 0..20 keep the v1
//! layout, then:
//!
//! ```text
//! offset  size  field
//! 20      4     codebook generation (u32)
//! 24      1     session mode (0 = full, 1 = delta, 2 = reuse)
//! 25      3     reserved (zero)
//! 28      4     FNV-1a checksum of header bytes 0..28 + payload
//! 32      ...   payload
//! ```
//!
//! [`seal_session`] / [`open_session`] handle v2; the v1 [`open`]
//! rejects v2 frames with a pointer at the session decoder instead of
//! misparsing them (the version byte is at the same offset in both
//! layouts, and both checksums cover every header field).

use anyhow::{bail, ensure, Result};

/// Frame magic: "FPAY".
pub const MAGIC: [u8; 4] = *b"FPAY";

/// Current stateless frame format version.
pub const VERSION: u8 = 1;

/// Session (cross-round codebook) frame format version.
pub const SESSION_VERSION: u8 = 2;

/// Fixed header size of a version-1 frame in bytes.
pub const HEADER_LEN: usize = 24;

/// Fixed header size of a version-2 session frame in bytes.
pub const SESSION_HEADER_LEN: usize = 32;

/// What the payload contains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadKind {
    /// Row-major dense matrix (Q* downloads).
    Dense,
    /// Index+value sparse rows (∇Q* uploads).
    Sparse,
}

impl PayloadKind {
    /// Kind id stored in the frame header.
    pub fn id(&self) -> u8 {
        match self {
            PayloadKind::Dense => 0,
            PayloadKind::Sparse => 1,
        }
    }

    /// Inverse of [`PayloadKind::id`].
    pub fn from_id(id: u8) -> Result<PayloadKind> {
        match id {
            0 => Ok(PayloadKind::Dense),
            1 => Ok(PayloadKind::Sparse),
            other => bail!("unknown payload kind id {other}"),
        }
    }

    /// Kind name for logs and trace events.
    pub fn name(&self) -> &'static str {
        match self {
            PayloadKind::Dense => "dense",
            PayloadKind::Sparse => "sparse",
        }
    }
}

/// How a session frame relates to the client's cached codebook.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionMode {
    /// Self-contained codebook + rows; installs/overwrites the cache.
    Full,
    /// Centroid deltas against the previous generation + rows.
    Delta,
    /// Rows only, decoded against the cached generation verbatim.
    Reuse,
}

impl SessionMode {
    /// Mode id stored in session header byte 24.
    pub fn id(&self) -> u8 {
        match self {
            SessionMode::Full => 0,
            SessionMode::Delta => 1,
            SessionMode::Reuse => 2,
        }
    }

    /// Inverse of [`SessionMode::id`].
    pub fn from_id(id: u8) -> Result<SessionMode> {
        match id {
            0 => Ok(SessionMode::Full),
            1 => Ok(SessionMode::Delta),
            2 => Ok(SessionMode::Reuse),
            other => bail!("unknown session mode id {other}"),
        }
    }

    /// Mode name for logs/errors.
    pub fn name(&self) -> &'static str {
        match self {
            SessionMode::Full => "full",
            SessionMode::Delta => "delta",
            SessionMode::Reuse => "reuse",
        }
    }
}

/// Decoded frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Element codec id (`wire::Precision`).
    pub codec_id: u8,
    /// Entropy codec id (`wire::EntropyMode`; 0 = none).
    pub entropy_id: u8,
    /// What the payload contains.
    pub kind: PayloadKind,
    /// Matrix rows this frame describes.
    pub rows: u32,
    /// Matrix columns this frame describes.
    pub cols: u32,
    /// Payload length in bytes (excluding this header).
    pub payload_len: u32,
}

const FNV_OFFSET: u32 = 0x811c_9dc5;

/// The FNV-1a initial state, for callers chaining
/// [`checksum_chained`] over discontiguous byte runs.
pub const CHECKSUM_SEED: u32 = FNV_OFFSET;

fn fnv1a(mut h: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// 32-bit FNV-1a over a byte slice.
pub fn checksum(bytes: &[u8]) -> u32 {
    fnv1a(FNV_OFFSET, bytes)
}

/// Continue an FNV-1a checksum over another byte run. Start from
/// [`CHECKSUM_SEED`]; `checksum_chained(checksum_chained(SEED, a), b)`
/// equals `checksum(a ++ b)` without concatenating — the transport
/// framing layer checksums header + payload this way, copy-free.
pub fn checksum_chained(state: u32, bytes: &[u8]) -> u32 {
    fnv1a(state, bytes)
}

/// The frame checksum: FNV-1a chained over the first 20 header bytes and
/// then the payload.
fn frame_checksum(header: &[u8], payload: &[u8]) -> u32 {
    fnv1a(fnv1a(FNV_OFFSET, header), payload)
}

/// Build the complete frame (header + payload) for a payload.
/// `entropy_id` records which `wire::EntropyMode` shaped the payload so
/// decode is self-describing (0 = raw quantized bytes).
pub fn seal(
    codec_id: u8,
    entropy_id: u8,
    kind: PayloadKind,
    rows: usize,
    cols: usize,
    payload: &[u8],
) -> Result<Vec<u8>> {
    ensure!(rows <= u32::MAX as usize, "frame rows {rows} exceed u32");
    ensure!(cols <= u32::MAX as usize, "frame cols {cols} exceed u32");
    ensure!(
        payload.len() <= u32::MAX as usize,
        "frame payload of {} bytes exceeds u32",
        payload.len()
    );
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(codec_id);
    out.push(kind.id());
    out.push(entropy_id);
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(cols as u32).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let sum = frame_checksum(&out[0..HEADER_LEN - 4], payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

fn read_u32(frame: &[u8], offset: usize) -> u32 {
    u32::from_le_bytes(frame[offset..offset + 4].try_into().unwrap())
}

/// Decoded version-2 session frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionHeader {
    /// Element codec id (`wire::Precision`; always a vq id in practice).
    pub codec_id: u8,
    /// Entropy codec id (`wire::EntropyMode`; 0 = none).
    pub entropy_id: u8,
    /// What the payload contains.
    pub kind: PayloadKind,
    /// Matrix rows this frame describes.
    pub rows: u32,
    /// Matrix columns this frame describes.
    pub cols: u32,
    /// Payload length in bytes (excluding the header).
    pub payload_len: u32,
    /// Codebook generation: what a client holds after decoding this
    /// frame (`delta` builds on `generation - 1`, `reuse` requires
    /// exactly `generation`).
    pub generation: u32,
    /// How the payload relates to the cached codebook.
    pub mode: SessionMode,
}

/// Build a complete version-2 session frame (header + payload).
#[allow(clippy::too_many_arguments)] // mirrors the header fields 1:1
pub fn seal_session(
    codec_id: u8,
    entropy_id: u8,
    kind: PayloadKind,
    rows: usize,
    cols: usize,
    generation: u32,
    mode: SessionMode,
    payload: &[u8],
) -> Result<Vec<u8>> {
    ensure!(rows <= u32::MAX as usize, "frame rows {rows} exceed u32");
    ensure!(cols <= u32::MAX as usize, "frame cols {cols} exceed u32");
    ensure!(
        payload.len() <= u32::MAX as usize,
        "frame payload of {} bytes exceeds u32",
        payload.len()
    );
    let mut out = Vec::with_capacity(SESSION_HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(SESSION_VERSION);
    out.push(codec_id);
    out.push(kind.id());
    out.push(entropy_id);
    out.extend_from_slice(&(rows as u32).to_le_bytes());
    out.extend_from_slice(&(cols as u32).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&generation.to_le_bytes());
    out.push(mode.id());
    out.extend_from_slice(&[0u8; 3]);
    let sum = frame_checksum(&out[0..SESSION_HEADER_LEN - 4], payload);
    out.extend_from_slice(&sum.to_le_bytes());
    out.extend_from_slice(payload);
    Ok(out)
}

/// Validate a version-2 session frame and return its header + payload.
pub fn open_session(frame: &[u8]) -> Result<(SessionHeader, &[u8])> {
    ensure!(
        frame.len() >= SESSION_HEADER_LEN,
        "session frame truncated: {} bytes < {SESSION_HEADER_LEN}-byte header",
        frame.len()
    );
    ensure!(frame[0..4] == MAGIC, "bad frame magic {:02x?}", &frame[0..4]);
    ensure!(
        frame[4] == SESSION_VERSION,
        "unsupported session frame version {} (expected {SESSION_VERSION}; version-1 \
         frames use the stateless wire::frame::open path)",
        frame[4]
    );
    let kind = PayloadKind::from_id(frame[6])?;
    let mode = SessionMode::from_id(frame[24])?;
    let header = SessionHeader {
        codec_id: frame[5],
        entropy_id: frame[7],
        kind,
        rows: read_u32(frame, 8),
        cols: read_u32(frame, 12),
        payload_len: read_u32(frame, 16),
        generation: read_u32(frame, 20),
        mode,
    };
    let expected = frame.len() - SESSION_HEADER_LEN;
    ensure!(
        header.payload_len as usize == expected,
        "session frame length mismatch: header says {} payload bytes, frame has {expected}",
        header.payload_len
    );
    let payload = &frame[SESSION_HEADER_LEN..];
    let sum = read_u32(frame, SESSION_HEADER_LEN - 4);
    let computed = frame_checksum(&frame[0..SESSION_HEADER_LEN - 4], payload);
    ensure!(
        computed == sum,
        "session frame checksum mismatch (stored {sum:#010x}, computed {computed:#010x})"
    );
    Ok((header, payload))
}

/// Streaming length hint: given the first bytes of an incoming frame,
/// return the **total** frame length (header + payload) it declares, or
/// `Ok(None)` when more prefix bytes are needed to tell. Handles both
/// the v1 stateless and v2 session layouts (the version byte and the
/// payload-length field sit at the same offsets in both). Typed errors
/// for bad magic / unknown version, so a receiver can reject a
/// desynchronized stream before buffering a bogus length.
///
/// The transport lane uses this to validate that a download frame
/// enveloped inside a transport message is exactly as long as it
/// declares — a truncated enveloped frame is rejected *before* any
/// decode runs.
pub fn total_len_hint(prefix: &[u8]) -> Result<Option<usize>> {
    if prefix.len() < 5 {
        return Ok(None);
    }
    ensure!(
        prefix[0..4] == MAGIC,
        "bad frame magic {:02x?}",
        &prefix[0..4]
    );
    let header_len = match prefix[4] {
        VERSION => HEADER_LEN,
        SESSION_VERSION => SESSION_HEADER_LEN,
        other => bail!("unsupported frame version {other} (expected {VERSION} or {SESSION_VERSION})"),
    };
    if prefix.len() < 20 {
        return Ok(None);
    }
    Ok(Some(header_len + read_u32(prefix, 16) as usize))
}

/// Validate a frame and return its header + payload slice.
pub fn open(frame: &[u8]) -> Result<(FrameHeader, &[u8])> {
    ensure!(
        frame.len() >= HEADER_LEN,
        "frame truncated: {} bytes < {HEADER_LEN}-byte header",
        frame.len()
    );
    ensure!(frame[0..4] == MAGIC, "bad frame magic {:02x?}", &frame[0..4]);
    ensure!(
        frame[4] == VERSION,
        "unsupported frame version {} (expected {VERSION}; version-{SESSION_VERSION} \
         codebook-session frames need the wire::vq::session decoder)",
        frame[4]
    );
    let kind = PayloadKind::from_id(frame[6])?;
    let header = FrameHeader {
        codec_id: frame[5],
        entropy_id: frame[7],
        kind,
        rows: read_u32(frame, 8),
        cols: read_u32(frame, 12),
        payload_len: read_u32(frame, 16),
    };
    let expected = frame.len() - HEADER_LEN;
    ensure!(
        header.payload_len as usize == expected,
        "frame length mismatch: header says {} payload bytes, frame has {expected}",
        header.payload_len
    );
    let payload = &frame[HEADER_LEN..];
    let sum = read_u32(frame, 20);
    let computed = frame_checksum(&frame[0..HEADER_LEN - 4], payload);
    ensure!(
        computed == sum,
        "frame checksum mismatch (stored {sum:#010x}, computed {computed:#010x})"
    );
    Ok((header, payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seal_open_roundtrip() {
        let payload = [1u8, 2, 3, 4, 5];
        let frame = seal(3, 2, PayloadKind::Dense, 10, 25, &payload).unwrap();
        assert_eq!(frame.len(), HEADER_LEN + 5);
        let (h, p) = open(&frame).unwrap();
        assert_eq!(h.codec_id, 3);
        assert_eq!(h.entropy_id, 2);
        assert_eq!(h.kind, PayloadKind::Dense);
        assert_eq!(h.rows, 10);
        assert_eq!(h.cols, 25);
        assert_eq!(h.payload_len, 5);
        assert_eq!(p, &payload);
    }

    #[test]
    fn empty_payload_is_valid() {
        let frame = seal(1, 0, PayloadKind::Sparse, 0, 0, &[]).unwrap();
        let (h, p) = open(&frame).unwrap();
        assert_eq!(h.kind, PayloadKind::Sparse);
        assert_eq!(h.entropy_id, 0);
        assert!(p.is_empty());
    }

    #[test]
    fn corruption_is_detected() {
        let payload = [9u8; 16];
        let frame = seal(2, 0, PayloadKind::Dense, 4, 4, &payload).unwrap();
        // payload byte flip -> checksum
        let mut bad = frame.clone();
        bad[HEADER_LEN + 3] ^= 0x40;
        assert!(open(&bad).unwrap_err().to_string().contains("checksum"));
        // magic flip
        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(open(&bad).unwrap_err().to_string().contains("magic"));
        // version bump
        let mut bad = frame.clone();
        bad[4] = 9;
        assert!(open(&bad).unwrap_err().to_string().contains("version"));
        // header corruption -> checksum (codec, entropy, dims all covered)
        for offset in [5usize, 7, 8, 9, 12, 13] {
            let mut bad = frame.clone();
            bad[offset] ^= 0x01;
            assert!(open(&bad).is_err(), "header flip at {offset} undetected");
        }
        // truncation
        assert!(open(&frame[..frame.len() - 1]).is_err());
        assert!(open(&frame[..10]).is_err());
    }

    #[test]
    fn session_seal_open_roundtrip() {
        let payload = [7u8, 6, 5, 4];
        let frame = seal_session(5, 3, PayloadKind::Dense, 12, 25, 9, SessionMode::Delta, &payload)
            .unwrap();
        assert_eq!(frame.len(), SESSION_HEADER_LEN + 4);
        let (h, p) = open_session(&frame).unwrap();
        assert_eq!(h.codec_id, 5);
        assert_eq!(h.entropy_id, 3);
        assert_eq!(h.kind, PayloadKind::Dense);
        assert_eq!((h.rows, h.cols), (12, 25));
        assert_eq!(h.generation, 9);
        assert_eq!(h.mode, SessionMode::Delta);
        assert_eq!(p, &payload);
        // reserved bytes are zero, version byte is 2
        assert_eq!(frame[4], SESSION_VERSION);
        assert_eq!(&frame[25..28], &[0, 0, 0]);
    }

    #[test]
    fn session_mode_registry_roundtrips() {
        for m in [SessionMode::Full, SessionMode::Delta, SessionMode::Reuse] {
            assert_eq!(SessionMode::from_id(m.id()).unwrap(), m);
        }
        assert!(SessionMode::from_id(3).is_err());
    }

    #[test]
    fn version_mismatch_points_at_the_other_decoder() {
        let v1 = seal(2, 0, PayloadKind::Dense, 1, 1, &[1, 2, 3, 4]).unwrap();
        let e = open_session(&[v1.as_slice(), &[0u8; 8]].concat()).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
        let v2 = seal_session(5, 0, PayloadKind::Dense, 1, 1, 1, SessionMode::Full, &[9]).unwrap();
        let e = open(&v2).unwrap_err();
        assert!(e.to_string().contains("session"), "{e}");
    }

    #[test]
    fn session_corruption_is_detected() {
        let payload = [3u8; 40];
        let frame = seal_session(5, 0, PayloadKind::Dense, 8, 5, 2, SessionMode::Reuse, &payload)
            .unwrap();
        // every header field is under the checksum — generation and
        // mode included
        for offset in [5usize, 7, 8, 12, 16, 20, 21, 24, 25] {
            let mut bad = frame.clone();
            bad[offset] ^= 0x01;
            assert!(open_session(&bad).is_err(), "header flip at {offset} undetected");
        }
        let mut bad = frame.clone();
        bad[SESSION_HEADER_LEN + 11] ^= 0x80;
        assert!(open_session(&bad).unwrap_err().to_string().contains("checksum"));
        assert!(open_session(&frame[..frame.len() - 1]).is_err());
        assert!(open_session(&frame[..SESSION_HEADER_LEN - 2]).is_err());
    }

    #[test]
    fn chained_checksum_equals_contiguous() {
        let a = b"header bytes";
        let b = b"payload bytes that follow";
        let contiguous = checksum(&[&a[..], &b[..]].concat());
        let chained = checksum_chained(checksum_chained(CHECKSUM_SEED, a), b);
        assert_eq!(contiguous, chained);
    }

    #[test]
    fn total_len_hint_covers_both_versions() {
        let v1 = seal(2, 0, PayloadKind::Dense, 4, 4, &[9u8; 16]).unwrap();
        let v2 =
            seal_session(5, 0, PayloadKind::Dense, 4, 4, 1, SessionMode::Full, &[7u8; 10]).unwrap();
        assert_eq!(total_len_hint(&v1).unwrap(), Some(v1.len()));
        assert_eq!(total_len_hint(&v2).unwrap(), Some(v2.len()));
        // not enough prefix yet: needs magic+version (5) and the length
        // field (bytes 16..20)
        assert_eq!(total_len_hint(&v1[..4]).unwrap(), None);
        assert_eq!(total_len_hint(&v1[..19]).unwrap(), None);
        // a truncated frame still *declares* its full length — the
        // receiver compares the hint against what actually arrived
        assert_eq!(total_len_hint(&v1[..20]).unwrap(), Some(v1.len()));
        // typed rejections
        let mut bad = v1.clone();
        bad[0] = b'X';
        assert!(total_len_hint(&bad).unwrap_err().to_string().contains("magic"));
        let mut bad = v1.clone();
        bad[4] = 9;
        assert!(total_len_hint(&bad).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn checksum_single_byte_sensitivity() {
        let a = checksum(b"hello wire");
        for i in 0..10 {
            let mut m = b"hello wire".to_vec();
            m[i] ^= 1;
            assert_ne!(checksum(&m), a, "flip at {i} undetected");
        }
    }
}
