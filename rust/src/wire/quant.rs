//! Element codecs: f64 / f32 / f16 / int8-affine encodings of row-major
//! f32 matrices.
//!
//! * `f64` — widened little-endian doubles: the paper's Table 1 uses
//!   64-bit parameters, this codec reproduces that accounting on the wire.
//! * `f32` — raw little-endian floats (bit-exact round-trip).
//! * `f16` — IEEE 754 binary16, round-to-nearest-even, saturating at
//!   ±65504 (a bounded error beats an `inf` on the wire); round-trip
//!   error is ≤ `2^-11` relative for normal values.
//! * `int8` — **per-row symmetric affine quantization**: each row stores
//!   its scale `s = max|x|` as an f16 (2 bytes) followed by one signed
//!   byte per element, `q = round(x/s · 127)`. Round-trip error is
//!   bounded by `s · (1/254 + 2^-11)` — see [`max_roundtrip_error`],
//!   which the property tests enforce.
//!
//! A K=25 factor row costs 200 / 100 / 50 / 27 bytes respectively, so
//! int8 is ~3.7× smaller than f32 and ~7.4× smaller than the paper's
//! f64 accounting at identical M_s.
//!
//! The `vq8` / `vq4` / `vq8r` variants dispatch to `wire::vq`: per-row
//! f16 scale + per-subspace codebook indices (7 / 5 / 34 bytes per K=25
//! row) plus a per-frame codebook block — the payload layout that
//! finally pushes *below* the int8 floor on downloads.

use anyhow::{ensure, Result};

/// Wire precision of one matrix element (for the scalar codecs) or of
/// one subvector (for the `wire::vq` product-quantized codecs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Widened 64-bit floats (the paper's Table 1 accounting).
    F64,
    /// Raw little-endian f32 — bit-exact round-trip.
    F32,
    /// IEEE 754 binary16 with saturation at ±65504.
    F16,
    /// Per-row symmetric int8 affine quantization (f16 row scale).
    Int8,
    /// Product quantization, ≤ 64 centroids/subspace, byte indices
    /// (`wire::vq`; dense downloads only — uploads fall back to int8).
    Vq8,
    /// Product quantization, ≤ 16 centroids/subspace, packed nibble
    /// indices (the aggressive end of the vq knob).
    Vq4,
    /// [`Precision::Vq8`] plus a per-row int8 residual plane (the vq
    /// quality knob: int8-class error at index-plane + int8 size).
    Vq8r,
}

impl Precision {
    /// Parse a codec name (`f64|f32|f16|int8|vq8|vq4|vq8r`).
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f64" => Precision::F64,
            "f32" => Precision::F32,
            "f16" => Precision::F16,
            "int8" => Precision::Int8,
            "vq8" => Precision::Vq8,
            "vq4" => Precision::Vq4,
            "vq8r" => Precision::Vq8r,
            other => {
                anyhow::bail!("unknown codec precision `{other}` (f64|f32|f16|int8|vq8|vq4|vq8r)")
            }
        })
    }

    /// Codec name for logs/CSV.
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
            Precision::Vq8 => "vq8",
            Precision::Vq4 => "vq4",
            Precision::Vq8r => "vq8r",
        }
    }

    /// Codec id stored in the frame header.
    pub fn id(&self) -> u8 {
        match self {
            Precision::F64 => 1,
            Precision::F32 => 2,
            Precision::F16 => 3,
            Precision::Int8 => 4,
            Precision::Vq8 => 5,
            Precision::Vq4 => 6,
            Precision::Vq8r => 7,
        }
    }

    /// Inverse of [`Precision::id`].
    pub fn from_id(id: u8) -> Result<Precision> {
        Ok(match id {
            1 => Precision::F64,
            2 => Precision::F32,
            3 => Precision::F16,
            4 => Precision::Int8,
            5 => Precision::Vq8,
            6 => Precision::Vq4,
            7 => Precision::Vq8r,
            other => anyhow::bail!("unknown codec id {other}"),
        })
    }

    /// Is this one of the `wire::vq` product-quantized codecs?
    pub fn is_vq(&self) -> bool {
        matches!(self, Precision::Vq8 | Precision::Vq4 | Precision::Vq8r)
    }

    /// The precision that actually shapes **upload** (sparse ∇Q*) value
    /// planes: the vq codecs amortize a per-frame codebook over a
    /// broadcast download, which a one-shot per-client upload cannot,
    /// so they fall back to int8 rows on the uplink. Scalar codecs map
    /// to themselves.
    pub fn for_uploads(&self) -> Precision {
        if self.is_vq() {
            Precision::Int8
        } else {
            *self
        }
    }

    /// Encoded bytes for one `cols`-wide row. For the vq codecs this is
    /// the per-row marginal (f16 scale + index plane + residual) and
    /// excludes the per-frame codebook block — [`encoded_len`] has the
    /// full payload size.
    pub fn row_bytes(&self, cols: usize) -> usize {
        match self {
            Precision::F64 => 8 * cols,
            Precision::F32 => 4 * cols,
            Precision::F16 => 2 * cols,
            Precision::Int8 => cols + 2, // values + f16 row scale
            Precision::Vq8 | Precision::Vq4 | Precision::Vq8r => super::vq::row_bytes(*self, cols),
        }
    }
}

/// Encoded payload size (no frame header) of a `rows × cols` matrix.
/// Exact for every precision: the vq codecs add their per-frame
/// codebook block (`wire::vq::prefix_len`) on top of the row records.
pub fn encoded_len(rows: usize, cols: usize, precision: Precision) -> usize {
    if precision.is_vq() {
        super::vq::encoded_len(precision, rows, cols)
    } else {
        rows * precision.row_bytes(cols)
    }
}

/// Largest finite f16 value — the lossy codecs saturate here.
pub const F16_MAX: f32 = 65504.0;

/// Worst-case absolute round-trip error for one element of a row whose
/// largest magnitude is `row_max`. Zero for the exact codecs. Beyond
/// [`F16_MAX`] both lossy scalar codecs saturate (f16 elements
/// directly, int8 through its f16 row scale), so the bound grows by the
/// clipped excess. The vq codecs have **no** per-element bound — their
/// error depends on the whole frame's geometry (codebook fit), so this
/// returns infinity for them; the `wire::vq` property tests pin the
/// empirical error ordering instead.
pub fn max_roundtrip_error(precision: Precision, row_max: f32) -> f32 {
    let in_range = row_max.abs().min(F16_MAX);
    let clipped = (row_max.abs() - F16_MAX).max(0.0);
    match precision {
        Precision::F64 | Precision::F32 => 0.0,
        // half-ulp relative for normals, absolute 2^-25 in the subnormal
        // range (and a hair of slack on top).
        Precision::F16 => (in_range * (1.0 / 2048.0)).max(1e-7) * 1.5 + clipped,
        // half-step of the 127-level grid + f16 rounding of the scale.
        Precision::Int8 => in_range * (1.0 / 254.0 + 1.0 / 2048.0) * 1.5 + 1e-7 + clipped,
        Precision::Vq8 | Precision::Vq4 | Precision::Vq8r => f32::INFINITY,
    }
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even. Saturates at
/// ±65504 instead of producing infinities (codec semantics); NaN maps to
/// the canonical quiet NaN.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        if mant != 0 {
            return sign | 0x7e00; // NaN
        }
        return sign | 0x7bff; // ±inf saturates to ±65504
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7bff; // overflow saturates
    }
    if e <= 0 {
        // subnormal f16 range (or underflow to signed zero)
        if e < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit bit
        let shift = (14 - e) as u32; // 14..=24
        let v = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let v = if rem > half || (rem == half && v & 1 == 1) {
            v + 1
        } else {
            v
        };
        return sign | v as u16;
    }
    let mut v = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && v & 1 == 1) {
        v += 1;
    }
    if v >= 0x7c00 {
        return sign | 0x7bff; // rounding carried past the max normal
    }
    sign | v as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // subnormal: renormalize into an f32 normal
            let mut e: u32 = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Append the encoding of a row-major `rows × cols` matrix to `out`.
pub fn encode_rows(out: &mut Vec<u8>, data: &[f32], rows: usize, cols: usize, p: Precision) {
    debug_assert_eq!(data.len(), rows * cols);
    match p {
        Precision::F64 => {
            for &v in data {
                out.extend_from_slice(&(v as f64).to_le_bytes());
            }
        }
        Precision::F32 => {
            for &v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Precision::F16 => {
            for &v in data {
                out.extend_from_slice(&f32_to_f16(v).to_le_bytes());
            }
        }
        Precision::Int8 => {
            for r in 0..rows {
                let row = &data[r * cols..(r + 1) * cols];
                let max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let s_bits = f32_to_f16(max);
                let s = f16_to_f32(s_bits);
                out.extend_from_slice(&s_bits.to_le_bytes());
                if s > 0.0 && s.is_finite() {
                    for &v in row {
                        let q = (v / s * 127.0).round().clamp(-127.0, 127.0) as i8;
                        out.push(q as u8);
                    }
                } else {
                    // all-zero (or denormal-tiny) row: zero bytes decode to 0.0
                    out.resize(out.len() + cols, 0);
                }
            }
        }
        Precision::Vq8 | Precision::Vq4 | Precision::Vq8r => {
            super::vq::encode_plane(out, data, rows, cols, p);
        }
    }
}

/// Decode a payload produced by [`encode_rows`] back into f32s.
pub fn decode_rows(payload: &[u8], rows: usize, cols: usize, p: Precision) -> Result<Vec<f32>> {
    ensure!(
        payload.len() == encoded_len(rows, cols, p),
        "{} payload of {} bytes does not match {rows}x{cols} (expected {})",
        p.name(),
        payload.len(),
        encoded_len(rows, cols, p)
    );
    let mut out = Vec::with_capacity(rows * cols);
    match p {
        Precision::F64 => {
            for ch in payload.chunks_exact(8) {
                out.push(f64::from_le_bytes(ch.try_into().unwrap()) as f32);
            }
        }
        Precision::F32 => {
            for ch in payload.chunks_exact(4) {
                out.push(f32::from_le_bytes(ch.try_into().unwrap()));
            }
        }
        Precision::F16 => {
            for ch in payload.chunks_exact(2) {
                out.push(f16_to_f32(u16::from_le_bytes(ch.try_into().unwrap())));
            }
        }
        Precision::Int8 => {
            for r in 0..rows {
                let row = &payload[r * (cols + 2)..(r + 1) * (cols + 2)];
                let s = f16_to_f32(u16::from_le_bytes([row[0], row[1]]));
                for &b in &row[2..] {
                    out.push(b as i8 as f32 / 127.0 * s);
                }
            }
        }
        Precision::Vq8 | Precision::Vq4 | Precision::Vq8r => {
            return super::vq::decode_plane(payload, rows, cols, p);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn f16_bits_roundtrip_exhaustively() {
        // every finite f16 must survive f16 -> f32 -> f16 bit-exactly
        for sign in [0u16, 0x8000] {
            for h in 0..0x7c00u16 {
                let h = h | sign;
                let back = f32_to_f16(f16_to_f32(h));
                assert_eq!(back, h, "bits {h:#06x} -> {back:#06x}");
            }
        }
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f16_to_f32(0x3c00), 1.0);
        assert_eq!(f16_to_f32(0xbc00), -1.0);
        assert_eq!(f16_to_f32(0x4000), 2.0);
        assert_eq!(f16_to_f32(0x3555), 0.25 * (1.0 + 341.0 / 1024.0));
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24)); // smallest subnormal
        assert_eq!(f16_to_f32(0x7bff), 65504.0); // largest normal
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(0.0), 0x0000);
    }

    #[test]
    fn f16_saturates_instead_of_overflowing() {
        assert_eq!(f32_to_f16(1e9), 0x7bff);
        assert_eq!(f32_to_f16(-1e9), 0xfbff);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7bff);
        assert_eq!(f16_to_f32(f32_to_f16(66000.0)), 65504.0);
    }

    #[test]
    fn f16_error_is_bounded() {
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..20_000 {
            let x = (rng.normal() * 10f64.powi(rng.below(7) as i32 - 3)) as f32;
            let y = f16_to_f32(f32_to_f16(x));
            let tol = (x.abs() * (1.0 / 2048.0)).max(1e-7);
            assert!((x - y).abs() <= tol, "x={x} y={y}");
        }
    }

    #[test]
    fn int8_roundtrip_error_bounded_per_row() {
        let mut rng = Rng::seed_from_u64(12);
        let (rows, cols) = (40, 25);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.3).collect();
        let mut buf = Vec::new();
        encode_rows(&mut buf, &data, rows, cols, Precision::Int8);
        assert_eq!(buf.len(), encoded_len(rows, cols, Precision::Int8));
        let dec = decode_rows(&buf, rows, cols, Precision::Int8).unwrap();
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            let max = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let tol = max_roundtrip_error(Precision::Int8, max);
            for (a, b) in row.iter().zip(&dec[r * cols..(r + 1) * cols]) {
                assert!((a - b).abs() <= tol, "row {r}: {a} vs {b} (tol {tol})");
            }
        }
    }

    #[test]
    fn lossy_codecs_stay_within_bound_even_when_saturating() {
        // rows whose magnitudes exceed F16_MAX: the error bound must
        // absorb the clipping of the element (f16) / row scale (int8)
        let row = vec![1.0e5f32, -2.0e5, 3.0, 65504.0, -0.5];
        let (rows, cols) = (1, row.len());
        let row_max = 2.0e5f32;
        for p in [Precision::F16, Precision::Int8] {
            let mut buf = Vec::new();
            encode_rows(&mut buf, &row, rows, cols, p);
            let dec = decode_rows(&buf, rows, cols, p).unwrap();
            let tol = max_roundtrip_error(p, row_max);
            for (a, b) in row.iter().zip(&dec) {
                assert!(
                    (a - b).abs() <= tol,
                    "{}: {a} vs {b} (tol {tol})",
                    p.name()
                );
                assert!(b.is_finite(), "{}: non-finite decode {b}", p.name());
            }
        }
    }

    #[test]
    fn int8_zero_rows_decode_to_exact_zeros() {
        let data = vec![0.0f32; 3 * 8];
        let mut buf = Vec::new();
        encode_rows(&mut buf, &data, 3, 8, Precision::Int8);
        let dec = decode_rows(&buf, 3, 8, Precision::Int8).unwrap();
        assert_eq!(dec, data);
    }

    #[test]
    fn exact_codecs_are_bit_exact() {
        let mut rng = Rng::seed_from_u64(13);
        let data: Vec<f32> = (0..200).map(|_| rng.normal() as f32 * 1e3).collect();
        for p in [Precision::F32, Precision::F64] {
            let mut buf = Vec::new();
            encode_rows(&mut buf, &data, 8, 25, p);
            let dec = decode_rows(&buf, 8, 25, p).unwrap();
            assert_eq!(dec, data, "{}", p.name());
        }
    }

    #[test]
    fn decode_rejects_wrong_length() {
        let mut buf = Vec::new();
        encode_rows(&mut buf, &[1.0, 2.0], 1, 2, Precision::F32);
        assert!(decode_rows(&buf, 2, 2, Precision::F32).is_err());
        assert!(decode_rows(&buf[..buf.len() - 1], 1, 2, Precision::F32).is_err());
    }

    #[test]
    fn precision_registry_roundtrips() {
        for p in [
            Precision::F64,
            Precision::F32,
            Precision::F16,
            Precision::Int8,
            Precision::Vq8,
            Precision::Vq4,
            Precision::Vq8r,
        ] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
            assert_eq!(Precision::from_id(p.id()).unwrap(), p);
        }
        assert!(Precision::parse("f8").is_err());
        assert!(Precision::parse("vq9").is_err());
        assert!(Precision::from_id(99).is_err());
    }

    #[test]
    fn row_bytes_match_doc_numbers() {
        assert_eq!(Precision::F64.row_bytes(25), 200);
        assert_eq!(Precision::F32.row_bytes(25), 100);
        assert_eq!(Precision::F16.row_bytes(25), 50);
        assert_eq!(Precision::Int8.row_bytes(25), 27);
        assert_eq!(Precision::Vq8.row_bytes(25), 7);
        assert_eq!(Precision::Vq4.row_bytes(25), 5);
        assert_eq!(Precision::Vq8r.row_bytes(25), 34);
    }

    #[test]
    fn upload_precision_maps_vq_to_int8() {
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            assert!(p.is_vq());
            assert_eq!(p.for_uploads(), Precision::Int8);
        }
        for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
            assert!(!p.is_vq());
            assert_eq!(p.for_uploads(), p);
        }
    }

    #[test]
    fn vq_roundtrip_through_quant_dispatch() {
        let mut rng = Rng::seed_from_u64(14);
        let (rows, cols) = (32, 25);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.2).collect();
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            let mut buf = Vec::new();
            encode_rows(&mut buf, &data, rows, cols, p);
            assert_eq!(buf.len(), encoded_len(rows, cols, p), "{}", p.name());
            let dec = decode_rows(&buf, rows, cols, p).unwrap();
            assert_eq!(dec.len(), data.len());
            // lossy but sane: reconstruction correlates with the input
            let dot: f64 = data.iter().zip(&dec).map(|(a, b)| (a * b) as f64).sum();
            assert!(dot > 0.0, "{}: reconstruction uncorrelated", p.name());
        }
    }
}
