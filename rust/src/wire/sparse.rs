//! Index+value sparse encoding for ∇Q* uploads.
//!
//! A gradient upload is a `m_s × k` row-major matrix in which whole item
//! rows may be zero (no participating client touched the item) or
//! negligible. The sparse payload stores only the surviving rows. With
//! entropy coding off the layout is
//!
//! ```text
//! u32 nnz | nnz × u32 row index | nnz rows encoded via wire::quant
//! ```
//!
//! and the `wire::entropy` modes swap in smaller blocks per stream: a
//! varint-coded index block (`u32 idx_len | delta+zigzag+LEB128 bytes`)
//! replaces the raw `u32` indices, and a length-prefixed range-coded
//! block (`u32 raw_len | coded bytes`) replaces the raw quantized rows.
//! Both substitutions are lossless, so every mode decodes to identical
//! matrices; the frame header records which mode shaped the payload.
//!
//! Row selection is governed by [`SparsePolicy`]:
//!
//! * `threshold` — rows with L2 norm ≤ threshold are dropped. The default
//!   `0.0` drops only exactly-zero rows, so with an exact element codec
//!   (`f32`/`f64`) the decode reconstructs the input **bit-exactly** —
//!   the "zero-loss setting" the property tests pin.
//! * `top_k` — optional top-k sparsification: keep at most `k` rows,
//!   largest L2 norm first (0 disables). This is the codec-level analog
//!   of the bandit's M_s selection, applied to the upload direction.
//! * `auto_topk` — entropy-aware tuning (`--sparse-topk auto`): instead
//!   of a fixed count, [`auto_top_k`] picks k per upload from the
//!   retained-energy curve and the **measured** encoded-bytes curve —
//!   when the entropy layer has already eaten the near-zero tail rows
//!   (trimming them saves almost no bytes), it keeps everything; when
//!   the tail still costs real bytes, it trims to the smallest k that
//!   preserves ≥ 99.5% of the gradient energy.
//!
//! The vq precisions never appear in sparse frames: a per-frame codebook
//! amortizes over a broadcast download, not a one-shot upload, so
//! [`encode_with`] maps them to int8 value planes up front
//! ([`Precision::for_uploads`]) and the frame header records the mapped
//! precision — decode stays self-describing.

use anyhow::{ensure, Result};

use super::entropy::{self, EntropyMode};
use super::frame::{self, PayloadKind};
use super::quant::{self, Precision};
use super::Dense;

/// Upload sparsification policy. The default (`top_k = 0`,
/// `threshold = 0.0`, `auto_topk = false`) drops only exactly-zero
/// rows — lossless.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SparsePolicy {
    /// Keep at most this many rows (largest L2 norm); 0 = keep all.
    pub top_k: usize,
    /// Drop rows with L2 norm ≤ this value; 0.0 = drop only zero rows.
    pub threshold: f32,
    /// Tune `top_k` per upload from the measured encoded-bytes and
    /// retained-energy curves (overrides `top_k`; see [`auto_top_k`]).
    pub auto_topk: bool,
}

/// Row indices (ascending) that survive `policy` for a row-major
/// `rows × cols` matrix — the encoder's row survey, factored out so the
/// selection rule (threshold + top-k, deterministic tie-breaks) is
/// testable and reusable on its own.
pub fn kept_rows(data: &[f32], rows: usize, cols: usize, policy: &SparsePolicy) -> Vec<u32> {
    assert_eq!(
        data.len(),
        rows * cols,
        "kept_rows: {} values for {rows}x{cols}",
        data.len()
    );
    // squared-norm row survey
    let thr_sq = (policy.threshold as f64) * (policy.threshold as f64);
    let mut kept: Vec<(u32, f64)> = Vec::new();
    for r in 0..rows {
        let norm_sq: f64 = data[r * cols..(r + 1) * cols]
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum();
        if norm_sq > thr_sq {
            kept.push((r as u32, norm_sq));
        }
    }
    if policy.top_k > 0 && kept.len() > policy.top_k {
        // largest norms win, ties break by row index for determinism
        kept.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        kept.truncate(policy.top_k);
        kept.sort_by_key(|&(r, _)| r);
    }
    kept.into_iter().map(|(r, _)| r).collect()
}

/// Fraction of the total gradient energy (Σ row-norm²) an auto-tuned
/// upload must retain.
pub const AUTO_TOPK_ENERGY: f64 = 0.995;

/// Minimum fraction of the full frame's measured bytes a trim must save
/// before the tuner bothers dropping information.
pub const AUTO_TOPK_MIN_SAVINGS: f64 = 0.05;

/// Entropy-aware `--sparse-topk auto`: resolve a concrete top-k for one
/// upload from the **measured** encoded-bytes-per-kept-row curve rather
/// than a fixed count.
///
/// 1. Survey the surviving rows (after `threshold`) and find `k_e`, the
///    smallest k whose largest-norm rows retain ≥ [`AUTO_TOPK_ENERGY`]
///    of the total gradient energy.
/// 2. Encode the frame at `k_e` and at keep-all and compare real frame
///    lengths — this is where the entropy layer enters: near-zero tail
///    rows range-code to almost nothing, so under `range|full` the
///    measured saving of a trim can collapse even when the row count
///    drops a lot.
/// 3. If trimming to `k_e` saves less than [`AUTO_TOPK_MIN_SAVINGS`] of
///    the full frame's bytes, keep everything (returns 0 = keep-all):
///    dropping gradient energy that the wire had already compressed
///    away is pure loss. Otherwise return `k_e`.
///
/// Deterministic: a pure function of the gradient data, so fleet
/// workers can tune independently without breaking the threads = 1/N
/// bit-identity contract.
pub fn auto_top_k(
    data: &[f32],
    rows: usize,
    cols: usize,
    precision: Precision,
    entropy: EntropyMode,
    policy: &SparsePolicy,
) -> Result<usize> {
    Ok(auto_decision(data, rows, cols, precision, entropy, policy)?.0)
}

/// The shared implementation behind [`auto_top_k`] and the `auto_topk`
/// encode path: returns the chosen top-k (0 = keep all) **and** the
/// winning encoded frame, so the encoder never pays a third encode to
/// re-produce the frame it already measured.
fn auto_decision(
    data: &[f32],
    rows: usize,
    cols: usize,
    precision: Precision,
    entropy: EntropyMode,
    policy: &SparsePolicy,
) -> Result<(usize, Vec<u8>)> {
    let base = SparsePolicy {
        top_k: 0,
        threshold: policy.threshold,
        auto_topk: false,
    };
    let (k_e, n) = energy_top_k(data, rows, cols, &base);
    if k_e == 0 {
        return Ok((0, encode_with(data, rows, cols, precision, entropy, &base)?));
    }
    let trimmed_policy = SparsePolicy {
        top_k: k_e,
        threshold: policy.threshold,
        auto_topk: false,
    };
    if !entropy.range_values() && !entropy.varint_indices() {
        // plain frame lengths are structural — decide arithmetically and
        // pay exactly one encode, for the winner
        let full_len = super::encoded_sparse_len(n, cols, precision);
        let trim_len = super::encoded_sparse_len(k_e, cols, precision);
        let saved = full_len.saturating_sub(trim_len) as f64;
        return if saved < AUTO_TOPK_MIN_SAVINGS * full_len as f64 {
            Ok((0, encode_with(data, rows, cols, precision, entropy, &base)?))
        } else {
            let frame = encode_with(data, rows, cols, precision, entropy, &trimmed_policy)?;
            Ok((k_e, frame))
        };
    }
    // entropy-coded lengths are data-dependent: measure the real frames
    let full = encode_with(data, rows, cols, precision, entropy, &base)?;
    let trimmed = encode_with(data, rows, cols, precision, entropy, &trimmed_policy)?;
    let saved = full.len().saturating_sub(trimmed.len()) as f64;
    if saved < AUTO_TOPK_MIN_SAVINGS * full.len() as f64 {
        Ok((0, full))
    } else {
        Ok((k_e, trimmed))
    }
}

/// The retained-energy survey of the auto tuner: `(k_e, n)` where `n`
/// is the surviving-row count and `k_e` is the smallest k whose
/// largest-norm surviving rows hold ≥ [`AUTO_TOPK_ENERGY`] of the total
/// gradient energy — 0 when no proper prefix does (keep all).
fn energy_top_k(data: &[f32], rows: usize, cols: usize, base: &SparsePolicy) -> (usize, usize) {
    let kept = kept_rows(data, rows, cols, base);
    let n = kept.len();
    if n <= 1 {
        return (0, n);
    }
    let mut norms: Vec<f64> = kept
        .iter()
        .map(|&r| {
            data[r as usize * cols..(r as usize + 1) * cols]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum()
        })
        .collect();
    norms.sort_by(|a, b| b.total_cmp(a));
    let total: f64 = norms.iter().sum();
    if !(total.is_finite() && total > 0.0) {
        return (0, n);
    }
    let mut cum = 0.0f64;
    for (i, &nrm) in norms.iter().enumerate() {
        cum += nrm;
        if cum >= AUTO_TOPK_ENERGY * total {
            return (if i + 1 >= n { 0 } else { i + 1 }, n);
        }
    }
    (0, n)
}

/// Encode the sparse frame for a row-major `rows × cols` matrix without
/// entropy coding (the PR 1 wire format).
pub fn encode(
    data: &[f32],
    rows: usize,
    cols: usize,
    precision: Precision,
    policy: &SparsePolicy,
) -> Result<Vec<u8>> {
    encode_with(data, rows, cols, precision, EntropyMode::None, policy)
}

/// Encode the sparse frame for a row-major `rows × cols` matrix, with the
/// index and value streams shaped by `entropy` (see the module docs for
/// the per-mode layouts). The vq precisions are mapped to their int8
/// upload plane here; `auto_topk` policies are resolved to a concrete
/// top-k through [`auto_top_k`] first.
pub fn encode_with(
    data: &[f32],
    rows: usize,
    cols: usize,
    precision: Precision,
    entropy: EntropyMode,
    policy: &SparsePolicy,
) -> Result<Vec<u8>> {
    ensure!(
        data.len() == rows * cols,
        "sparse encode: {} values for {rows}x{cols}",
        data.len()
    );
    let precision = precision.for_uploads();
    if policy.auto_topk {
        return Ok(auto_decision(data, rows, cols, precision, entropy, policy)?.1);
    }
    let kept = kept_rows(data, rows, cols, policy);

    let mut payload = Vec::with_capacity(4 + kept.len() * (4 + precision.row_bytes(cols)));
    payload.extend_from_slice(&(kept.len() as u32).to_le_bytes());
    if entropy.varint_indices() {
        let idx = entropy::encode_indices(&kept);
        ensure!(
            idx.len() <= u32::MAX as usize,
            "varint index block of {} bytes exceeds u32",
            idx.len()
        );
        payload.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        payload.extend_from_slice(&idx);
    } else {
        for &r in &kept {
            payload.extend_from_slice(&r.to_le_bytes());
        }
    }
    let mut compact = Vec::with_capacity(kept.len() * cols);
    for &r in &kept {
        compact.extend_from_slice(&data[r as usize * cols..(r as usize + 1) * cols]);
    }
    let mut values = Vec::with_capacity(quant::encoded_len(kept.len(), cols, precision));
    quant::encode_rows(&mut values, &compact, kept.len(), cols, precision);
    if entropy.range_values() {
        payload.extend_from_slice(&entropy::seal_block(&values, precision, cols, kept.len())?);
    } else {
        payload.extend_from_slice(&values);
    }
    frame::seal(
        precision.id(),
        entropy.id(),
        PayloadKind::Sparse,
        rows,
        cols,
        &payload,
    )
}

/// Decode a sparse frame back into a dense matrix (dropped rows are 0).
/// The frame header names its precision and entropy mode, so this decodes
/// every layout [`encode_with`] produces.
pub fn decode(buf: &[u8]) -> Result<Dense> {
    let (header, payload) = frame::open(buf)?;
    ensure!(
        header.kind == PayloadKind::Sparse,
        "expected a sparse frame, got {:?}",
        header.kind
    );
    let precision = Precision::from_id(header.codec_id)?;
    let entropy = EntropyMode::from_id(header.entropy_id)?;
    let (rows, cols) = (header.rows as usize, header.cols as usize);
    ensure!(payload.len() >= 4, "sparse payload missing row count");
    let nnz = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    ensure!(nnz <= rows, "sparse frame claims {nnz} rows of {rows}");
    let mut pos = 4usize;
    let indices: Vec<u32> = if entropy.varint_indices() {
        ensure!(
            payload.len() >= pos + 4,
            "sparse payload missing varint index block length"
        );
        let idx_len = u32::from_le_bytes(payload[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        ensure!(
            payload.len() >= pos + idx_len,
            "sparse varint index block truncated"
        );
        let idx = entropy::decode_indices(&payload[pos..pos + idx_len], nnz)?;
        pos += idx_len;
        idx
    } else {
        ensure!(
            payload.len() >= pos + nnz * 4,
            "sparse index block truncated (nnz={nnz})"
        );
        let idx = (0..nnz)
            .map(|i| {
                u32::from_le_bytes(payload[pos + i * 4..pos + (i + 1) * 4].try_into().unwrap())
            })
            .collect();
        pos += nnz * 4;
        idx
    };
    let raw_len = quant::encoded_len(nnz, cols, precision);
    let raw;
    let value_bytes: &[u8] = if entropy.range_values() {
        raw = entropy::open_block(&payload[pos..], raw_len, precision, cols, nnz)?;
        &raw
    } else {
        ensure!(
            payload.len() == pos + raw_len,
            "sparse payload length mismatch (nnz={nnz}, cols={cols}, {})",
            precision.name()
        );
        &payload[pos..]
    };
    let values = quant::decode_rows(value_bytes, nnz, cols, precision)?;
    let mut data = vec![0.0f32; rows * cols];
    for (i, &r) in indices.iter().enumerate() {
        let r = r as usize;
        ensure!(r < rows, "sparse row index {r} out of range ({rows} rows)");
        data[r * cols..(r + 1) * cols].copy_from_slice(&values[i * cols..(i + 1) * cols]);
    }
    Ok(Dense { data, rows, cols })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gradient_like(rows: usize, cols: usize, zero_frac: f64, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            if rng.chance(zero_frac) {
                continue;
            }
            for c in 0..cols {
                data[r * cols + c] = rng.normal() as f32 * 0.1;
            }
        }
        data
    }

    #[test]
    fn zero_loss_roundtrip_is_exact() {
        let data = gradient_like(60, 25, 0.4, 1);
        for p in [Precision::F32, Precision::F64] {
            let buf = encode(&data, 60, 25, p, &SparsePolicy::default()).unwrap();
            let dec = decode(&buf).unwrap();
            assert_eq!(dec.rows, 60);
            assert_eq!(dec.cols, 25);
            assert_eq!(dec.data, data, "{}", p.name());
        }
    }

    #[test]
    fn zero_rows_shrink_the_frame() {
        let dense = gradient_like(60, 25, 0.0, 2);
        let sparse = gradient_like(60, 25, 0.5, 2);
        let a = encode(&dense, 60, 25, Precision::F32, &SparsePolicy::default()).unwrap();
        let b = encode(&sparse, 60, 25, Precision::F32, &SparsePolicy::default()).unwrap();
        assert!(b.len() < a.len(), "{} !< {}", b.len(), a.len());
    }

    #[test]
    fn top_k_keeps_the_largest_rows() {
        let (rows, cols) = (30, 8);
        let mut rng = Rng::seed_from_u64(3);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let policy = SparsePolicy {
            top_k: 10,
            threshold: 0.0,
            auto_topk: false,
        };
        let dec = decode(&encode(&data, rows, cols, Precision::F32, &policy).unwrap()).unwrap();
        let norm = |d: &[f32], r: usize| -> f64 {
            d[r * cols..(r + 1) * cols]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum()
        };
        let mut kept_norms = Vec::new();
        let mut dropped_norms = Vec::new();
        for r in 0..rows {
            let out = &dec.data[r * cols..(r + 1) * cols];
            if out.iter().all(|&v| v == 0.0) {
                dropped_norms.push(norm(&data, r));
            } else {
                assert_eq!(out, &data[r * cols..(r + 1) * cols], "row {r} altered");
                kept_norms.push(norm(&data, r));
            }
        }
        assert_eq!(kept_norms.len(), 10);
        let min_kept = kept_norms.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_dropped = dropped_norms.iter().cloned().fold(0.0, f64::max);
        assert!(min_kept >= max_dropped, "{min_kept} < {max_dropped}");
    }

    #[test]
    fn threshold_drops_small_rows() {
        let (rows, cols) = (4, 2);
        #[rustfmt::skip]
        let data = vec![
            0.001, 0.001,   // tiny -> dropped at threshold 0.1
            1.0, 1.0,       // kept
            0.0, 0.0,       // zero -> always dropped
            0.5, -0.5,      // kept
        ];
        let policy = SparsePolicy {
            top_k: 0,
            threshold: 0.1,
            auto_topk: false,
        };
        let dec = decode(&encode(&data, rows, cols, Precision::F32, &policy).unwrap()).unwrap();
        assert_eq!(&dec.data[0..2], &[0.0, 0.0]);
        assert_eq!(&dec.data[2..4], &[1.0, 1.0]);
        assert_eq!(&dec.data[4..6], &[0.0, 0.0]);
        assert_eq!(&dec.data[6..8], &[0.5, -0.5]);
    }

    #[test]
    fn kept_rows_matches_encoded_frame() {
        let data = gradient_like(40, 5, 0.4, 7);
        for policy in [
            SparsePolicy::default(),
            SparsePolicy {
                top_k: 8,
                threshold: 0.0,
                auto_topk: false,
            },
            SparsePolicy {
                top_k: 0,
                threshold: 0.05,
                auto_topk: false,
            },
        ] {
            let kept = kept_rows(&data, 40, 5, &policy);
            assert!(kept.windows(2).all(|w| w[0] < w[1]), "not ascending");
            let frame = encode(&data, 40, 5, Precision::F32, &policy).unwrap();
            assert_eq!(
                frame.len(),
                crate::wire::encoded_sparse_len(kept.len(), 5, Precision::F32)
            );
        }
    }

    #[test]
    fn empty_matrix_roundtrips() {
        let buf = encode(&[], 0, 5, Precision::F32, &SparsePolicy::default()).unwrap();
        let dec = decode(&buf).unwrap();
        assert_eq!(dec.rows, 0);
        assert!(dec.data.is_empty());
    }

    #[test]
    fn every_entropy_mode_decodes_to_identical_matrices() {
        let data = gradient_like(60, 25, 0.4, 11);
        for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
            let base = decode(
                &encode_with(&data, 60, 25, p, EntropyMode::None, &SparsePolicy::default())
                    .unwrap(),
            )
            .unwrap();
            for e in [EntropyMode::Varint, EntropyMode::Range, EntropyMode::Full] {
                let frame =
                    encode_with(&data, 60, 25, p, e, &SparsePolicy::default()).unwrap();
                let dec = decode(&frame).unwrap();
                // the entropy layer is transparent: identical decode bits
                for (a, b) in base.data.iter().zip(&dec.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} {}", p.name(), e.name());
                }
            }
        }
    }

    #[test]
    fn varint_indices_shrink_the_frame() {
        let data = gradient_like(200, 25, 0.3, 12);
        let plain = encode_with(
            &data,
            200,
            25,
            Precision::Int8,
            EntropyMode::None,
            &SparsePolicy::default(),
        )
        .unwrap();
        let varint = encode_with(
            &data,
            200,
            25,
            Precision::Int8,
            EntropyMode::Varint,
            &SparsePolicy::default(),
        )
        .unwrap();
        // ascending small deltas cost ~1 byte instead of 4 per index
        assert!(
            varint.len() < plain.len(),
            "varint {} !< plain {}",
            varint.len(),
            plain.len()
        );
    }

    #[test]
    fn vq_uploads_carry_int8_value_planes() {
        let data = gradient_like(40, 25, 0.4, 21);
        for p in [Precision::Vq8, Precision::Vq4, Precision::Vq8r] {
            let frame = encode(&data, 40, 25, p, &SparsePolicy::default()).unwrap();
            let (header, _) = frame::open(&frame).unwrap();
            assert_eq!(
                header.codec_id,
                Precision::Int8.id(),
                "{}: sparse frame should carry the int8 upload plane",
                p.name()
            );
            // ... and therefore decodes exactly like an int8 frame
            let a = decode(&frame).unwrap();
            let int8 = encode(&data, 40, 25, Precision::Int8, &SparsePolicy::default()).unwrap();
            let b = decode(&int8).unwrap();
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn auto_topk_keeps_all_when_energy_is_spread() {
        // near-equal row norms: no small-k prefix holds 99.5% of the
        // energy, so auto keeps everything
        let (rows, cols) = (40, 8);
        let mut rng = Rng::seed_from_u64(31);
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            let mag = 1.0 + 0.01 * rng.normal() as f32;
            let sign = if rng.chance(0.5) { 1.0 } else { -1.0 };
            data.push(mag * sign);
        }
        let k = auto_top_k(
            &data,
            rows,
            cols,
            Precision::Int8,
            EntropyMode::None,
            &SparsePolicy::default(),
        )
        .unwrap();
        assert_eq!(k, 0, "spread energy must keep all rows");
    }

    #[test]
    fn auto_topk_trims_concentrated_energy() {
        // 4 huge rows + a long near-zero (but nonzero) tail: the energy
        // curve saturates at k = 4 and trimming saves real plain bytes
        let (rows, cols) = (64, 8);
        let mut rng = Rng::seed_from_u64(32);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let scale = if r < 4 { 10.0 } else { 1e-4 };
            for c in 0..cols {
                data[r * cols + c] = rng.normal() as f32 * scale;
            }
        }
        let k = auto_top_k(
            &data,
            rows,
            cols,
            Precision::Int8,
            EntropyMode::None,
            &SparsePolicy::default(),
        )
        .unwrap();
        assert_eq!(k, 4, "energy concentrates in the 4 large rows");
        // the policy round-trips end to end and actually shrinks frames
        let auto = SparsePolicy {
            auto_topk: true,
            ..SparsePolicy::default()
        };
        let keep_all = SparsePolicy::default();
        let none = EntropyMode::None;
        let frame_auto = encode_with(&data, rows, cols, Precision::Int8, none, &auto).unwrap();
        let frame_all = encode(&data, rows, cols, Precision::Int8, &keep_all).unwrap();
        assert!(frame_auto.len() < frame_all.len());
        let dec = decode(&frame_auto).unwrap();
        // the 4 large rows survive, the tail decodes to zeros
        for r in 0..4 {
            assert!(dec.data[r * cols..(r + 1) * cols].iter().any(|&v| v != 0.0));
        }
        assert!(dec.data[4 * cols..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn auto_topk_is_deterministic() {
        let data = gradient_like(80, 16, 0.3, 33);
        let auto = SparsePolicy {
            auto_topk: true,
            ..SparsePolicy::default()
        };
        for e in [EntropyMode::None, EntropyMode::Full] {
            let a = encode_with(&data, 80, 16, Precision::Int8, e, &auto).unwrap();
            let b = encode_with(&data, 80, 16, Precision::Int8, e, &auto).unwrap();
            assert_eq!(a, b, "{}", e.name());
        }
    }

    #[test]
    fn entropy_modes_handle_empty_single_and_all_rows() {
        for e in [EntropyMode::Varint, EntropyMode::Range, EntropyMode::Full] {
            // empty matrix
            let buf = encode_with(&[], 0, 5, Precision::Int8, e, &SparsePolicy::default())
                .unwrap();
            assert!(decode(&buf).unwrap().data.is_empty(), "{}", e.name());
            // single surviving row
            let one = vec![0.0f32, 0.0, 1.5, -0.5, 0.0, 0.0];
            let buf =
                encode_with(&one, 3, 2, Precision::F32, e, &SparsePolicy::default()).unwrap();
            let dec = decode(&buf).unwrap();
            assert_eq!(dec.data, one, "{}", e.name());
            // all rows survive (no zero rows anywhere)
            let full = gradient_like(30, 8, 0.0, 13);
            let buf =
                encode_with(&full, 30, 8, Precision::F32, e, &SparsePolicy::default()).unwrap();
            assert_eq!(decode(&buf).unwrap().data, full, "{}", e.name());
        }
    }
}
