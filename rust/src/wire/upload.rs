//! SecEmb-style per-client **upload delta sessions**: ship each client's
//! sparse ∇Q* as byte deltas against that client's previous upload.
//!
//! The fleet executor already round-trips one sparse frame per *batch*
//! (the server trains on its decoded gradient — see `runtime::fleet`).
//! This module operates strictly downstream of that decode, on the raw
//! quantized **symbol plane** of the batch frame: the per-row
//! `[f16 scale | int8 symbols]` bytes the quantizer produced, keyed by
//! global item id. Because the plane is carried as raw bytes, a delta
//! frame reconstructs the full plane **bit-exactly** (wrapping u8
//! arithmetic is lossless), so delta uploads can never change training —
//! only the ledger's measured per-client frame lengths.
//!
//! Frame format: version-2 session frames (`frame::seal_session`,
//! `PayloadKind::Sparse`) with the sparse payload layout of
//! `wire::sparse` — `nnz | index block | value block` — where indices
//! are **item ids** (not selected positions) and the value block holds
//! either the raw plane rows (`SessionMode::Full`) or, for rows whose
//! item the reference also holds, the wrapping byte difference against
//! the reference row (`SessionMode::Delta`). A delta row and a full row
//! are the same length in plain bytes — int8 symbols are already one
//! byte — so deltas only *win* under a range-coding entropy mode, where
//! the near-zero difference bytes compress hard; the encoder measures
//! both candidates and ships the smaller, mirroring the download-side
//! codebook session's measured-bytes rationale (PR 5).
//!
//! Staleness mirrors `wire::vq::session::SessionDecode::Stale`: a delta
//! frame is decodable only against reference generation `g − 1`; any
//! other state yields the typed [`UploadDecode::Stale`] (never garbage),
//! and the caller re-encodes as `Full` — the upload-side resync.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use super::entropy::{self, EntropyMode};
use super::frame::{self, PayloadKind, SessionMode};
use super::quant::{self, Precision};

/// The raw quantized symbol plane of one sparse upload, keyed by global
/// item id: `indices[i]` owns `values[i*stride .. (i+1)*stride]` where
/// the stride is `precision.row_bytes(cols)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UploadPlane {
    /// Latent dimension K.
    pub cols: usize,
    /// Value-plane precision (the *upload* precision — int8 under every
    /// vq download codec, see `Precision::for_uploads`).
    pub precision: Precision,
    /// Surviving rows' item ids, ascending.
    pub indices: Vec<u32>,
    /// Raw quantized row bytes, `indices.len() * precision.row_bytes(cols)`.
    pub values: Vec<u8>,
}

impl UploadPlane {
    /// Bytes per row in the value plane.
    pub fn stride(&self) -> usize {
        self.precision.row_bytes(self.cols)
    }

    /// One row's raw bytes.
    fn row(&self, i: usize) -> &[u8] {
        let s = self.stride();
        &self.values[i * s..(i + 1) * s]
    }

    /// Order/content digest of the plane (test + journal evidence).
    pub fn digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_u8(self.precision.id());
        h.write_u64(self.cols as u64);
        h.write_u64(self.indices.len() as u64);
        for &id in &self.indices {
            h.write_u64(u64::from(id));
        }
        h.write(&self.values);
        h.finish()
    }
}

/// Parse a version-1 sparse batch frame (`wire::sparse::encode_with`
/// output) into its raw symbol plane, mapping the frame's
/// selected-position indices to global item ids via `selected` (the
/// round's sorted selection). This is the coordinator-side entry point:
/// the batch frame the executor already produced carries every byte the
/// per-client delta encoder needs.
pub fn plane_of_batch_frame(buf: &[u8], selected: &[u32]) -> Result<UploadPlane> {
    let (header, payload) = frame::open(buf)?;
    ensure!(
        header.kind == PayloadKind::Sparse,
        "upload plane: expected a sparse frame, got {:?}",
        header.kind
    );
    let precision = Precision::from_id(header.codec_id)?;
    let entropy = EntropyMode::from_id(header.entropy_id)?;
    let (rows, cols) = (header.rows as usize, header.cols as usize);
    ensure!(
        rows == selected.len(),
        "upload plane: frame covers {rows} selected rows but {} items were selected",
        selected.len()
    );
    let (positions, values) = parse_sparse_payload(payload, rows, cols, precision, entropy)?;
    let indices = positions
        .iter()
        .map(|&p| {
            ensure!(
                (p as usize) < selected.len(),
                "upload plane: row position {p} out of range ({} selected)",
                selected.len()
            );
            Ok(selected[p as usize])
        })
        .collect::<Result<Vec<u32>>>()?;
    Ok(UploadPlane {
        cols,
        precision,
        indices,
        values,
    })
}

/// Shared payload walk of the sparse layout: `nnz | index block | value
/// block`, returning the indices and the **raw** (entropy-opened) value
/// bytes.
fn parse_sparse_payload(
    payload: &[u8],
    rows: usize,
    cols: usize,
    precision: Precision,
    entropy: EntropyMode,
) -> Result<(Vec<u32>, Vec<u8>)> {
    ensure!(payload.len() >= 4, "sparse payload missing row count");
    let nnz = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    ensure!(nnz <= rows, "sparse payload claims {nnz} rows of {rows}");
    let mut pos = 4usize;
    let indices: Vec<u32> = if entropy.varint_indices() {
        ensure!(payload.len() >= pos + 4, "index block length missing");
        let idx_len = u32::from_le_bytes(payload[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        ensure!(payload.len() >= pos + idx_len, "varint index block truncated");
        let idx = entropy::decode_indices(&payload[pos..pos + idx_len], nnz)?;
        pos += idx_len;
        idx
    } else {
        ensure!(payload.len() >= pos + nnz * 4, "index block truncated");
        let idx = (0..nnz)
            .map(|i| u32::from_le_bytes(payload[pos + i * 4..pos + (i + 1) * 4].try_into().unwrap()))
            .collect();
        pos += nnz * 4;
        idx
    };
    let raw_len = quant::encoded_len(nnz, cols, precision);
    let values = if entropy.range_values() {
        entropy::open_block(&payload[pos..], raw_len, precision, cols, nnz)?
    } else {
        ensure!(
            payload.len() == pos + raw_len,
            "sparse value block length mismatch (nnz={nnz})"
        );
        payload[pos..].to_vec()
    };
    Ok((indices, values))
}

/// Emit the sparse payload (`nnz | index block | value block`) for a set
/// of indices and raw value bytes under `entropy`.
fn emit_sparse_payload(
    indices: &[u32],
    values: &[u8],
    cols: usize,
    precision: Precision,
    entropy: EntropyMode,
) -> Result<Vec<u8>> {
    let mut payload = Vec::with_capacity(4 + indices.len() * 4 + values.len());
    payload.extend_from_slice(&(indices.len() as u32).to_le_bytes());
    if entropy.varint_indices() {
        let idx = entropy::encode_indices(indices);
        ensure!(idx.len() <= u32::MAX as usize, "index block exceeds u32");
        payload.extend_from_slice(&(idx.len() as u32).to_le_bytes());
        payload.extend_from_slice(&idx);
    } else {
        for &r in indices {
            payload.extend_from_slice(&r.to_le_bytes());
        }
    }
    if entropy.range_values() {
        payload.extend_from_slice(&entropy::seal_block(values, precision, cols, indices.len())?);
    } else {
        payload.extend_from_slice(values);
    }
    Ok(payload)
}

/// One client's upload reference: the plane its previous session frame
/// established, upserted item by item (SecEmb deltas are against the
/// *last upload of that embedding row*, however many rounds ago).
#[derive(Debug, Clone, Default)]
pub struct UploadRef {
    /// Generation of the client's last accepted upload frame.
    pub generation: u32,
    /// Latent dimension of the stored rows.
    pub cols: usize,
    /// Value-plane precision of the stored rows.
    pub precision: Option<Precision>,
    /// item id → raw row bytes of that item's last upload.
    pub rows: BTreeMap<u32, Vec<u8>>,
}

/// What the encoder produced for one client, with the measured-bytes
/// rationale for the mode it picked.
#[derive(Debug, Clone)]
pub struct EncodedUpload {
    /// The sealed version-2 session frame to account for.
    pub frame: Vec<u8>,
    /// `Full` or `Delta` (uploads never `Reuse` — a gradient is never
    /// verbatim-identical across rounds).
    pub mode: SessionMode,
    /// Generation this frame establishes on both ends.
    pub generation: u32,
    /// Measured length of the full-frame candidate.
    pub full_bytes: u64,
    /// Measured length of the delta candidate (`None` without a usable
    /// reference).
    pub delta_bytes: Option<u64>,
}

/// Typed decode outcome, mirroring the download session's
/// `SessionDecode`: either the bit-exact reconstructed plane or a
/// `Stale` describing exactly which reference generation is required.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UploadDecode {
    /// The reconstructed absolute plane.
    Data(UploadPlane),
    /// A delta frame arrived against reference state we do not hold.
    Stale {
        /// Generation of the reference we do hold (`None` = none).
        cached: Option<u32>,
        /// Generation the delta requires.
        required: u32,
    },
}

impl UploadDecode {
    /// The plane, if the decode succeeded.
    pub fn into_data(self) -> Option<UploadPlane> {
        match self {
            UploadDecode::Data(p) => Some(p),
            UploadDecode::Stale { .. } => None,
        }
    }
}

/// Can a delta against `reference` encode `plane`? Requires matching
/// generation discipline to be enforced by the caller; here we check
/// shape compatibility only.
fn ref_compatible(reference: &UploadRef, plane: &UploadPlane) -> bool {
    reference.cols == plane.cols && reference.precision == Some(plane.precision)
}

/// Encode one client's upload plane: always builds the `Full` candidate,
/// additionally builds the `Delta` candidate when a compatible reference
/// exists, and ships whichever measures smaller (ties go to `Full` —
/// without range coding the two are the same length and `Full` needs no
/// reference to decode).
pub fn encode_upload(
    plane: &UploadPlane,
    entropy: EntropyMode,
    reference: Option<&UploadRef>,
) -> Result<EncodedUpload> {
    let generation = reference.map_or(1, |r| r.generation.wrapping_add(1).max(1));
    let seal = |mode: SessionMode, payload: &[u8]| {
        frame::seal_session(
            plane.precision.id(),
            entropy.id(),
            PayloadKind::Sparse,
            plane.indices.len(),
            plane.cols,
            generation,
            mode,
            payload,
        )
    };
    let full_payload =
        emit_sparse_payload(&plane.indices, &plane.values, plane.cols, plane.precision, entropy)?;
    let full_frame = seal(SessionMode::Full, &full_payload)?;
    let full_bytes = full_frame.len() as u64;
    let delta = match reference {
        Some(r) if ref_compatible(r, plane) => {
            let stride = plane.stride();
            let mut diff = Vec::with_capacity(plane.values.len());
            for (i, &id) in plane.indices.iter().enumerate() {
                let row = plane.row(i);
                match r.rows.get(&id) {
                    Some(prev) if prev.len() == stride => {
                        diff.extend(row.iter().zip(prev).map(|(&a, &b)| a.wrapping_sub(b)));
                    }
                    _ => diff.extend_from_slice(row),
                }
            }
            let payload =
                emit_sparse_payload(&plane.indices, &diff, plane.cols, plane.precision, entropy)?;
            Some(seal(SessionMode::Delta, &payload)?)
        }
        _ => None,
    };
    let delta_bytes = delta.as_ref().map(|f| f.len() as u64);
    match delta {
        Some(frame) if (frame.len() as u64) < full_bytes => Ok(EncodedUpload {
            frame,
            mode: SessionMode::Delta,
            generation,
            full_bytes,
            delta_bytes,
        }),
        _ => Ok(EncodedUpload {
            frame: full_frame,
            mode: SessionMode::Full,
            generation,
            full_bytes,
            delta_bytes,
        }),
    }
}

/// Decode one upload session frame against the reference we hold for its
/// client. `Full` frames need no reference; `Delta` frames require the
/// reference at exactly `generation − 1` and otherwise return the typed
/// [`UploadDecode::Stale`] — never a silently wrong plane.
pub fn decode_upload(buf: &[u8], reference: Option<&UploadRef>) -> Result<UploadDecode> {
    let (header, payload) = frame::open_session(buf)?;
    ensure!(
        header.kind == PayloadKind::Sparse,
        "upload session frame: expected sparse, got {:?}",
        header.kind
    );
    let precision = Precision::from_id(header.codec_id)?;
    let entropy = EntropyMode::from_id(header.entropy_id)?;
    let (rows, cols) = (header.rows as usize, header.cols as usize);
    let (indices, raw) = parse_sparse_payload(payload, rows, cols, precision, entropy)?;
    let plane = UploadPlane {
        cols,
        precision,
        indices,
        values: raw,
    };
    match header.mode {
        SessionMode::Full => Ok(UploadDecode::Data(plane)),
        SessionMode::Reuse => bail!("upload session frames never use Reuse mode"),
        SessionMode::Delta => {
            let required = header.generation.wrapping_sub(1);
            let r = match reference {
                None => {
                    return Ok(UploadDecode::Stale {
                        cached: None,
                        required,
                    })
                }
                Some(r) if r.generation != required => {
                    return Ok(UploadDecode::Stale {
                        cached: Some(r.generation),
                        required,
                    })
                }
                Some(r) => r,
            };
            ensure!(
                ref_compatible(r, &plane),
                "upload delta frame shape mismatch: reference is {}x{:?}, frame is {}x{}",
                r.cols,
                r.precision,
                plane.cols,
                precision.name()
            );
            let stride = plane.stride();
            let mut values = Vec::with_capacity(plane.values.len());
            for (i, &id) in plane.indices.iter().enumerate() {
                let row = plane.row(i);
                match r.rows.get(&id) {
                    Some(prev) if prev.len() == stride => {
                        values.extend(row.iter().zip(prev).map(|(&a, &b)| a.wrapping_add(b)));
                    }
                    _ => values.extend_from_slice(row),
                }
            }
            Ok(UploadDecode::Data(UploadPlane { values, ..plane }))
        }
    }
}

/// Per-run counters of the upload session (reported next to the
/// download-side [`crate::server::SessionStats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct UploadStats {
    /// Per-client frames shipped as full planes.
    pub full_frames: u64,
    /// Per-client frames shipped as deltas.
    pub delta_frames: u64,
    /// Forced full frames for clients whose device/server reference
    /// state diverged (eviction, first contact after invalidation).
    pub resyncs: u64,
    /// Σ (full candidate − shipped frame) over delta frames: the
    /// measured upload bytes the deltas saved.
    pub delta_saved_bytes: u64,
}

/// The coordinator's per-client upload reference store: the server half
/// of the upload session (the device half is the `client::Fleet`
/// upload-generation table). Owns one [`UploadRef`] per client that has
/// ever uploaded, upserted after every accepted frame.
#[derive(Debug, Clone, Default)]
pub struct UploadStore {
    refs: BTreeMap<usize, UploadRef>,
    /// Running counters for reports/traces.
    pub stats: UploadStats,
}

impl UploadStore {
    /// Empty store.
    pub fn new() -> UploadStore {
        UploadStore::default()
    }

    /// The reference we hold for `client`, if any.
    pub fn reference(&self, client: usize) -> Option<&UploadRef> {
        self.refs.get(&client)
    }

    /// The generation `client`'s reference is at.
    pub fn generation(&self, client: usize) -> Option<u32> {
        self.refs.get(&client).map(|r| r.generation)
    }

    /// Drop a client's server-side reference (e.g. storage reclaim).
    /// Its next upload is forced `Full`.
    pub fn invalidate(&mut self, client: usize) {
        self.refs.remove(&client);
    }

    /// Install an accepted plane as `client`'s new reference at
    /// `generation`: rows upsert item by item; a shape change rebases
    /// the reference wholesale.
    pub fn install(&mut self, client: usize, plane: &UploadPlane, generation: u32) {
        let r = self.refs.entry(client).or_default();
        if r.cols != plane.cols || r.precision != Some(plane.precision) {
            r.rows.clear();
            r.cols = plane.cols;
            r.precision = Some(plane.precision);
        }
        r.generation = generation;
        let stride = plane.stride();
        for (i, &id) in plane.indices.iter().enumerate() {
            r.rows
                .insert(id, plane.values[i * stride..(i + 1) * stride].to_vec());
        }
    }

    /// Order-stable digest over every client's reference state — the
    /// journal/replay evidence for the upload session.
    pub fn state_digest(&self) -> u64 {
        let mut h = crate::telemetry::Fnv64::new();
        h.write_u64(self.refs.len() as u64);
        for (client, r) in &self.refs {
            h.write_u64(*client as u64);
            h.write_u64(u64::from(r.generation));
            h.write_u64(r.cols as u64);
            h.write_u8(r.precision.map_or(0xff, |p| p.id()));
            h.write_u64(r.rows.len() as u64);
            for (id, row) in &r.rows {
                h.write_u64(u64::from(*id));
                h.write(row);
            }
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::wire::sparse::{self, SparsePolicy};

    fn gradient_like(rows: usize, cols: usize, zero_frac: f64, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            if rng.chance(zero_frac) {
                continue;
            }
            for c in 0..cols {
                data[r * cols + c] = rng.normal() as f32 * 0.3;
            }
        }
        data
    }

    fn plane_for(seed: u64, entropy: EntropyMode) -> UploadPlane {
        let (rows, cols) = (12usize, 8usize);
        let data = gradient_like(rows, cols, 0.3, seed);
        let frame = sparse::encode_with(
            &data,
            rows,
            cols,
            Precision::Int8,
            entropy,
            &SparsePolicy::default(),
        )
        .unwrap();
        let selected: Vec<u32> = (0..rows as u32).map(|i| i * 7).collect();
        plane_of_batch_frame(&frame, &selected).unwrap()
    }

    #[test]
    fn batch_frame_plane_maps_positions_to_item_ids() {
        let plane = plane_for(1, EntropyMode::None);
        assert_eq!(plane.cols, 8);
        assert_eq!(plane.precision, Precision::Int8);
        assert!(!plane.indices.is_empty());
        for &id in &plane.indices {
            assert_eq!(id % 7, 0, "item ids come from the selected list");
        }
        assert_eq!(plane.values.len(), plane.indices.len() * plane.stride());
        // every entropy layout parses to the identical plane
        for mode in [EntropyMode::Varint, EntropyMode::Range, EntropyMode::Full] {
            assert_eq!(plane_for(1, mode), plane, "{}", mode.name());
        }
    }

    #[test]
    fn full_roundtrip_is_identity_per_entropy_mode() {
        for mode in [
            EntropyMode::None,
            EntropyMode::Varint,
            EntropyMode::Range,
            EntropyMode::Full,
        ] {
            let plane = plane_for(2, EntropyMode::None);
            let enc = encode_upload(&plane, mode, None).unwrap();
            assert_eq!(enc.mode, SessionMode::Full);
            assert_eq!(enc.generation, 1);
            assert_eq!(enc.delta_bytes, None);
            let dec = decode_upload(&enc.frame, None).unwrap().into_data().unwrap();
            assert_eq!(dec, plane, "{}", mode.name());
        }
    }

    #[test]
    fn delta_roundtrip_reconstructs_exactly_and_saves_under_range_coding() {
        let mut store = UploadStore::new();
        let p1 = plane_for(3, EntropyMode::None);
        let e1 = encode_upload(&p1, EntropyMode::Full, None).unwrap();
        store.install(0, &p1, e1.generation);
        // round 2: a nearby plane (same items, slightly moved values)
        let mut p2 = plane_for(4, EntropyMode::None);
        p2.indices = p1.indices.clone();
        p2.values = p1
            .values
            .iter()
            .enumerate()
            .map(|(i, &b)| if i % 9 == 0 { b.wrapping_add(1) } else { b })
            .collect();
        let e2 = encode_upload(&p2, EntropyMode::Full, store.reference(0)).unwrap();
        assert_eq!(e2.mode, SessionMode::Delta, "near-identical plane must delta");
        assert_eq!(e2.generation, 2);
        assert!(e2.delta_bytes.unwrap() < e2.full_bytes);
        let dec = decode_upload(&e2.frame, store.reference(0))
            .unwrap()
            .into_data()
            .unwrap();
        assert_eq!(dec, p2, "delta decode must be bit-exact");
    }

    #[test]
    fn plain_entropy_ties_go_to_full() {
        let mut store = UploadStore::new();
        let p1 = plane_for(5, EntropyMode::None);
        store.install(0, &p1, 1);
        let e2 = encode_upload(&p1, EntropyMode::None, store.reference(0)).unwrap();
        // identical plain lengths: Full wins the tie (reference-free decode)
        assert_eq!(e2.delta_bytes, Some(e2.full_bytes));
        assert_eq!(e2.mode, SessionMode::Full);
    }

    #[test]
    fn stale_references_are_typed_not_garbage() {
        let mut store = UploadStore::new();
        let p1 = plane_for(6, EntropyMode::None);
        store.install(0, &p1, 7);
        let e = encode_upload(&p1, EntropyMode::Full, store.reference(0)).unwrap();
        // force the delta candidate frame regardless of measured choice
        let stride = p1.stride();
        let diff: Vec<u8> = p1
            .indices
            .iter()
            .enumerate()
            .flat_map(|(i, &id)| {
                let row = &p1.values[i * stride..(i + 1) * stride];
                match store.reference(0).unwrap().rows.get(&id) {
                    Some(prev) => row.iter().zip(prev).map(|(&a, &b)| a.wrapping_sub(b)).collect(),
                    None => row.to_vec(),
                }
            })
            .collect();
        let payload =
            emit_sparse_payload(&p1.indices, &diff, p1.cols, p1.precision, EntropyMode::Full)
                .unwrap();
        let delta_frame = frame::seal_session(
            p1.precision.id(),
            EntropyMode::Full.id(),
            PayloadKind::Sparse,
            p1.indices.len(),
            p1.cols,
            e.generation,
            SessionMode::Delta,
            &payload,
        )
        .unwrap();
        // no reference at all
        assert_eq!(
            decode_upload(&delta_frame, None).unwrap(),
            UploadDecode::Stale {
                cached: None,
                required: 7
            }
        );
        // wrong generation
        let mut wrong = store.reference(0).unwrap().clone();
        wrong.generation = 3;
        assert_eq!(
            decode_upload(&delta_frame, Some(&wrong)).unwrap(),
            UploadDecode::Stale {
                cached: Some(3),
                required: 7
            }
        );
        // right generation decodes
        assert!(matches!(
            decode_upload(&delta_frame, store.reference(0)).unwrap(),
            UploadDecode::Data(_)
        ));
    }

    #[test]
    fn store_upserts_and_digest_tracks_state() {
        let mut store = UploadStore::new();
        let d0 = store.state_digest();
        let p1 = plane_for(8, EntropyMode::None);
        store.install(3, &p1, 1);
        let d1 = store.state_digest();
        assert_ne!(d0, d1);
        assert_eq!(store.generation(3), Some(1));
        // upsert: rows accumulate across rounds, generation advances
        let mut p2 = p1.clone();
        for id in p2.indices.iter_mut() {
            *id += 1; // disjoint item set
        }
        store.install(3, &p2, 2);
        assert_eq!(store.generation(3), Some(2));
        let r = store.reference(3).unwrap();
        assert_eq!(r.rows.len(), p1.indices.len() + p2.indices.len());
        store.invalidate(3);
        assert_eq!(store.generation(3), None);
    }
}
