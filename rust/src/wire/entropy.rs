//! Entropy coding on top of the quantized payloads: the third payload
//! axis, squeezing redundancy out of the bytes the other two codec layers
//! produce — losslessly, so training dynamics are bit-identical to the
//! non-entropy path.
//!
//! Two primitives, selected per frame by [`EntropyMode`]:
//!
//! * **Varint index coding** ([`encode_indices`]) — the sparse ∇Q* frame
//!   stores its surviving row indices sorted ascending, so consecutive
//!   deltas are small. Delta + zigzag + LEB128 turns the fixed 4-byte
//!   `u32` per index into ~1 byte for typical catalogs (indices < 2^14
//!   apart), cutting the index block ~4×.
//! * **Adaptive binary range coding** ([`range_encode`]) — an order-0
//!   byte model, factorized as a 256-leaf bit tree of adaptive 11-bit
//!   probabilities (the LZMA construction), driven through a carry-less
//!   32-bit range coder. One probability tree per *byte role* — an int8
//!   row is `[scale-lo, scale-hi, value × cols]`, a float row cycles
//!   through its element bytes — so the highly predictable f16 row-scale
//!   exponents never pollute the value-byte statistics. The bit-tree
//!   model adapts per bit instead of per 256-symbol table, which is what
//!   keeps near-incompressible frames from *expanding* (worst case is
//!   the ~6-byte coder preamble, not a misfit frequency table).
//!
//! Both transforms are bijective on the quantized bytes: `decode ∘
//! encode` is the identity (pinned by the `prop_entropy_*` property
//! tests), so any loss is still exactly the loss the element codec
//! chose — the entropy layer only changes how many bytes the
//! [`TrafficLedger`](crate::simnet::TrafficLedger) sees on the wire.
//!
//! Measured on the synthetic workloads (see `benches/bench_codec.rs`,
//! `BENCH_codec.json`): int8 downloads shrink ~2–12% (more once training
//! concentrates the factor distribution), f16/f32 downloads ~10–15%, and
//! sparse int8 uploads ~10–20% (varint indices + range-coded values).

use anyhow::{bail, ensure, Result};

use super::quant::Precision;

/// Which entropy transforms a codec applies on top of the element
/// quantization. Decode is self-describing: the frame header carries the
/// mode id, so any codec can decode any frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EntropyMode {
    /// No entropy coding (the PR 1 wire format, byte for byte).
    #[default]
    None,
    /// Delta + zigzag + LEB128 varint coding of sparse row indices only.
    Varint,
    /// Adaptive range coding of the quantized payload bytes only.
    Range,
    /// Both: varint indices and range-coded payload bytes.
    Full,
}

impl EntropyMode {
    /// Parse a mode name (`none|varint|range|full`).
    pub fn parse(s: &str) -> Result<EntropyMode> {
        Ok(match s {
            "none" => EntropyMode::None,
            "varint" => EntropyMode::Varint,
            "range" => EntropyMode::Range,
            "full" => EntropyMode::Full,
            other => bail!("unknown entropy mode `{other}` (none|varint|range|full)"),
        })
    }

    /// Mode name for logs/CSV.
    pub fn name(&self) -> &'static str {
        match self {
            EntropyMode::None => "none",
            EntropyMode::Varint => "varint",
            EntropyMode::Range => "range",
            EntropyMode::Full => "full",
        }
    }

    /// Mode id stored in the frame header (byte 7).
    pub fn id(&self) -> u8 {
        match self {
            EntropyMode::None => 0,
            EntropyMode::Varint => 1,
            EntropyMode::Range => 2,
            EntropyMode::Full => 3,
        }
    }

    /// Inverse of [`EntropyMode::id`].
    pub fn from_id(id: u8) -> Result<EntropyMode> {
        Ok(match id {
            0 => EntropyMode::None,
            1 => EntropyMode::Varint,
            2 => EntropyMode::Range,
            3 => EntropyMode::Full,
            other => bail!("unknown entropy mode id {other}"),
        })
    }

    /// Does this mode varint-code the sparse row-index block?
    pub fn varint_indices(&self) -> bool {
        matches!(self, EntropyMode::Varint | EntropyMode::Full)
    }

    /// Does this mode range-code the quantized payload bytes?
    pub fn range_values(&self) -> bool {
        matches!(self, EntropyMode::Range | EntropyMode::Full)
    }
}

// ---------------------------------------------------------------------------
// Varint index coding: delta + zigzag + LEB128

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Encode a row-index list as delta + zigzag + LEB128 varints. The sparse
/// encoder always passes ascending indices (small positive deltas → one
/// byte each), but the coding round-trips any `u32` sequence.
pub fn encode_indices(indices: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(indices.len() + 4);
    let mut prev = 0i64;
    for &i in indices {
        let mut u = zigzag(i as i64 - prev);
        prev = i as i64;
        loop {
            let b = (u & 0x7f) as u8;
            u >>= 7;
            if u != 0 {
                out.push(b | 0x80);
            } else {
                out.push(b);
                break;
            }
        }
    }
    out
}

/// Decode exactly `count` indices from a varint block produced by
/// [`encode_indices`]. The block must be consumed exactly — truncation,
/// trailing garbage, and out-of-`u32`-range deltas are decode errors.
pub fn decode_indices(buf: &[u8], count: usize) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(count);
    let mut prev = 0i64;
    let mut pos = 0usize;
    for n in 0..count {
        let mut u = 0u64;
        let mut shift = 0u32;
        loop {
            ensure!(pos < buf.len(), "varint index block truncated at index {n}");
            let b = buf[pos];
            pos += 1;
            // the 10th byte lands at shift 63: only its low bit fits the
            // accumulator — higher bits would be silently discarded
            ensure!(
                shift < 63 || (b & 0x7f) <= 1,
                "varint index {n} overflows 64 bits"
            );
            u |= ((b & 0x7f) as u64) << shift;
            shift += 7;
            if b & 0x80 == 0 {
                break;
            }
            ensure!(shift <= 63, "varint index {n} overflows 64 bits");
        }
        prev = prev
            .checked_add(unzigzag(u))
            .filter(|p| (0..=u32::MAX as i64).contains(p))
            .ok_or_else(|| anyhow::anyhow!("varint index {n} decodes out of u32 range"))?;
        out.push(prev as u32);
    }
    ensure!(
        pos == buf.len(),
        "varint index block has {} trailing bytes",
        buf.len() - pos
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Adaptive binary range coder (LZMA-style bit tree, one tree per byte role)

const KTOP: u32 = 1 << 24;
/// Probabilities live on an 11-bit scale; 1024 is p = 0.5.
const PROB_INIT: u16 = 1024;
/// Adaptation rate: each observed bit moves its probability by
/// `(2048 - p) >> 5` resp. `p >> 5` — the standard LZMA step.
const MOVE_BITS: u8 = 5;

/// One probability tree decodes/encodes one byte: 255 internal nodes of a
/// binary tree over the 256-symbol alphabet (index 0 unused).
type BitTree = Vec<u16>;

fn new_tree() -> BitTree {
    vec![PROB_INIT; 256]
}

/// Byte-role assignment of one encoded payload: which probability tree
/// each byte position trains. Scalar payloads are purely cyclic — int8
/// rows are `[scale-lo, scale-hi, cols × value]`, float rows cycle
/// through their element's byte positions. The vq payloads add a
/// **prefix segment** for the per-frame codebook block (scales +
/// entries share one tree), then cycle per row record: f16 row-scale
/// roles, one role per index byte position, and — for `vq8r` — residual
/// scale/value roles. Keeping the planes in separate trees is what lets
/// the near-uniform codebook bytes coexist with the low-entropy index
/// plane without diluting either model.
struct RoleMap {
    /// The first `prefix_len` bytes all train tree 0 (the vq codebook
    /// block; zero for scalar precisions).
    prefix_len: usize,
    /// Role of each byte position inside a row record, cycled.
    cycle: Vec<u8>,
    /// Total number of probability trees.
    n_roles: usize,
}

impl RoleMap {
    /// Role map of a payload of `precision` with `cols`-wide rows.
    /// `rows` sizes the vq codebook prefix (ignored for scalar
    /// precisions, where the payload is purely cyclic).
    fn new(precision: Precision, cols: usize, rows: usize) -> RoleMap {
        let prefix = if precision.is_vq() {
            super::vq::prefix_len(precision, rows, cols)
        } else {
            0
        };
        RoleMap::with_prefix(precision, cols, prefix)
    }

    /// Role map with an explicit prefix length. The session payloads
    /// reuse the vq row cycle but vary the prefix: a `reuse` frame has
    /// none, while `full` and `delta` frames train tree 0 on the
    /// codebook (resp. centroid-delta) block — the delta plane gets the
    /// same dedicated segment the codebook block always had, which is
    /// exactly what lets near-zero stable-Q deltas compress hard
    /// without diluting the index-plane statistics.
    fn with_prefix(precision: Precision, cols: usize, prefix_len: usize) -> RoleMap {
        match precision {
            Precision::Int8 => {
                let mut cycle = Vec::with_capacity(cols + 2);
                cycle.push(0);
                cycle.push(1);
                cycle.resize(cols + 2, 2);
                RoleMap {
                    prefix_len,
                    cycle,
                    n_roles: 3,
                }
            }
            Precision::F16 => RoleMap {
                prefix_len,
                cycle: vec![0, 1],
                n_roles: 2,
            },
            Precision::F32 => RoleMap {
                prefix_len,
                cycle: vec![0, 1, 2, 3],
                n_roles: 4,
            },
            Precision::F64 => RoleMap {
                prefix_len,
                cycle: (0..8).collect(),
                n_roles: 8,
            },
            Precision::Vq8 | Precision::Vq4 | Precision::Vq8r => {
                let ib = super::vq::index_bytes(precision, cols);
                // roles: 0 codebook block, 1/2 row-scale bytes, then one
                // per index byte position (capped to keep roles compact)
                let mut cycle = vec![1u8, 2];
                let idx_roles = ib.min(200);
                for j in 0..ib {
                    cycle.push(3 + (j % idx_roles.max(1)) as u8);
                }
                let mut n = 3 + idx_roles.max(1);
                if precision == Precision::Vq8r {
                    let (rs_lo, rs_hi, rv) = (n as u8, n as u8 + 1, n as u8 + 2);
                    cycle.push(rs_lo);
                    cycle.push(rs_hi);
                    cycle.resize(cycle.len() + cols, rv);
                    n += 3;
                }
                RoleMap {
                    prefix_len,
                    cycle,
                    n_roles: n,
                }
            }
        }
    }

    /// Tree index of byte position `i`.
    fn role(&self, i: usize) -> usize {
        if i < self.prefix_len {
            0
        } else {
            self.cycle[(i - self.prefix_len) % self.cycle.len()] as usize
        }
    }
}

struct RangeEncoder {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl RangeEncoder {
    fn new(capacity: usize) -> RangeEncoder {
        RangeEncoder {
            low: 0,
            range: u32::MAX,
            cache: 0,
            cache_size: 1,
            out: Vec::with_capacity(capacity),
        }
    }

    fn shift_low(&mut self) {
        if self.low < 0xff00_0000 || self.low > 0xffff_ffff {
            let carry = (self.low >> 32) as u8;
            self.out.push(self.cache.wrapping_add(carry));
            for _ in 1..self.cache_size {
                self.out.push(0xffu8.wrapping_add(carry));
            }
            self.cache_size = 0;
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xffff_ffff;
    }

    fn encode_bit(&mut self, probs: &mut BitTree, node: usize, bit: u32) {
        let p = probs[node] as u32;
        let bound = (self.range >> 11) * p;
        if bit == 0 {
            self.range = bound;
            probs[node] = (p + ((2048 - p) >> MOVE_BITS)) as u16;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            probs[node] = (p - (p >> MOVE_BITS)) as u16;
        }
        if self.range < KTOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    fn encode_byte(&mut self, probs: &mut BitTree, byte: u8) {
        let mut node = 1usize;
        for k in (0..8).rev() {
            let bit = ((byte >> k) & 1) as u32;
            self.encode_bit(probs, node, bit);
            node = (node << 1) | bit as usize;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

struct RangeDecoder<'a> {
    buf: &'a [u8],
    pos: usize,
    range: u32,
    code: u32,
}

impl<'a> RangeDecoder<'a> {
    fn new(buf: &'a [u8]) -> RangeDecoder<'a> {
        let mut d = RangeDecoder {
            buf,
            pos: 0,
            range: u32::MAX,
            code: 0,
        };
        d.next_byte(); // the encoder's leading cache byte (always 0)
        for _ in 0..4 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    /// Reads past-the-end as zero bytes: a truncated stream decodes to
    /// *wrong* bytes, never out-of-bounds — and truncation cannot reach
    /// this layer anyway, because the frame checksum covers the block.
    fn next_byte(&mut self) -> u8 {
        let b = self.buf.get(self.pos).copied().unwrap_or(0);
        self.pos += 1;
        b
    }

    fn decode_bit(&mut self, probs: &mut BitTree, node: usize) -> u32 {
        let p = probs[node] as u32;
        let bound = (self.range >> 11) * p;
        let bit = if self.code < bound {
            self.range = bound;
            probs[node] = (p + ((2048 - p) >> MOVE_BITS)) as u16;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            probs[node] = (p - (p >> MOVE_BITS)) as u16;
            1
        };
        if self.range < KTOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }

    fn decode_byte(&mut self, probs: &mut BitTree) -> u8 {
        let mut node = 1usize;
        for _ in 0..8 {
            node = (node << 1) | self.decode_bit(probs, node) as usize;
        }
        node as u8
    }
}

/// Range-code a quantized payload. `precision`, `cols` and `rows` only
/// select the byte-role map (which adaptive tree each byte trains —
/// `rows` sizes the vq codebook prefix and is ignored for the scalar
/// precisions); the bytes themselves are copied verbatim into the
/// model, so the transform is lossless for any input.
pub fn range_encode(payload: &[u8], precision: Precision, cols: usize, rows: usize) -> Vec<u8> {
    range_encode_map(payload, RoleMap::new(precision, cols, rows))
}

/// [`range_encode`] with an explicit prefix length instead of the
/// rows-derived vq codebook prefix — the session payloads' entry point
/// (`full`/`delta` frames prefix the codebook or centroid-delta block,
/// `reuse` frames have no prefix at all).
pub fn range_encode_prefixed(
    payload: &[u8],
    precision: Precision,
    cols: usize,
    prefix_len: usize,
) -> Vec<u8> {
    range_encode_map(payload, RoleMap::with_prefix(precision, cols, prefix_len))
}

fn range_encode_map(payload: &[u8], roles: RoleMap) -> Vec<u8> {
    let mut trees: Vec<BitTree> = (0..roles.n_roles).map(|_| new_tree()).collect();
    let mut enc = RangeEncoder::new(payload.len() / 2 + 16);
    for (i, &b) in payload.iter().enumerate() {
        enc.encode_byte(&mut trees[roles.role(i)], b);
    }
    enc.finish()
}

/// Decode exactly `raw_len` bytes from a [`range_encode`] stream.
/// `precision`/`cols`/`rows` must match the encode call (they are
/// recovered from the frame header). The stream must be consumed
/// exactly: bytes left unread after the last symbol are trailing
/// garbage and a decode error, preserving the plain path's exact
/// payload-length validation.
pub fn range_decode(
    buf: &[u8],
    raw_len: usize,
    precision: Precision,
    cols: usize,
    rows: usize,
) -> Result<Vec<u8>> {
    range_decode_map(buf, raw_len, RoleMap::new(precision, cols, rows))
}

/// [`range_decode`] with an explicit prefix length — the inverse of
/// [`range_encode_prefixed`], with the same exact-consumption contract.
pub fn range_decode_prefixed(
    buf: &[u8],
    raw_len: usize,
    precision: Precision,
    cols: usize,
    prefix_len: usize,
) -> Result<Vec<u8>> {
    range_decode_map(buf, raw_len, RoleMap::with_prefix(precision, cols, prefix_len))
}

fn range_decode_map(buf: &[u8], raw_len: usize, roles: RoleMap) -> Result<Vec<u8>> {
    let mut trees: Vec<BitTree> = (0..roles.n_roles).map(|_| new_tree()).collect();
    let mut dec = RangeDecoder::new(buf);
    let mut out = Vec::with_capacity(raw_len);
    for i in 0..raw_len {
        out.push(dec.decode_byte(&mut trees[roles.role(i)]));
    }
    ensure!(
        dec.pos >= buf.len(),
        "range-coded block has {} unread trailing bytes",
        buf.len() - dec.pos
    );
    Ok(out)
}

// ---------------------------------------------------------------------------
// Length-prefixed entropy blocks (the frame-payload building block)

/// Wrap a raw quantized payload into a length-prefixed entropy block:
/// `u32 raw_len (LE) | range-coded bytes` (an empty payload is just its
/// zero length prefix). `rows` sizes the vq role-map prefix, matching
/// the frame header's row count.
pub fn seal_block(raw: &[u8], precision: Precision, cols: usize, rows: usize) -> Result<Vec<u8>> {
    let prefix = if precision.is_vq() {
        super::vq::prefix_len(precision, rows, cols)
    } else {
        0
    };
    seal_block_prefixed(raw, precision, cols, prefix)
}

/// [`seal_block`] with an explicit role-map prefix length — used by the
/// session frames, whose prefix depends on the session mode rather
/// than the row count.
pub fn seal_block_prefixed(
    raw: &[u8],
    precision: Precision,
    cols: usize,
    prefix_len: usize,
) -> Result<Vec<u8>> {
    ensure!(
        raw.len() <= u32::MAX as usize,
        "entropy block of {} raw bytes exceeds u32",
        raw.len()
    );
    let mut out = Vec::with_capacity(8 + raw.len() / 2);
    out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
    if !raw.is_empty() {
        out.extend_from_slice(&range_encode_prefixed(raw, precision, cols, prefix_len));
    }
    Ok(out)
}

/// Open a [`seal_block`] block, validating its declared raw length against
/// the length the frame geometry implies.
pub fn open_block(
    block: &[u8],
    expected_len: usize,
    precision: Precision,
    cols: usize,
    rows: usize,
) -> Result<Vec<u8>> {
    let prefix = if precision.is_vq() {
        super::vq::prefix_len(precision, rows, cols)
    } else {
        0
    };
    open_block_prefixed(block, expected_len, precision, cols, prefix)
}

/// [`open_block`] with an explicit role-map prefix length — the inverse
/// of [`seal_block_prefixed`].
pub fn open_block_prefixed(
    block: &[u8],
    expected_len: usize,
    precision: Precision,
    cols: usize,
    prefix_len: usize,
) -> Result<Vec<u8>> {
    ensure!(block.len() >= 4, "entropy block missing its length prefix");
    let raw_len = u32::from_le_bytes(block[0..4].try_into().unwrap()) as usize;
    ensure!(
        raw_len == expected_len,
        "entropy block declares {raw_len} raw bytes, geometry implies {expected_len}"
    );
    if raw_len == 0 {
        ensure!(
            block.len() == 4,
            "empty entropy block carries {} trailing bytes",
            block.len() - 4
        );
        return Ok(Vec::new());
    }
    range_decode_prefixed(&block[4..], raw_len, precision, cols, prefix_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn mode_registry_roundtrips() {
        for m in [
            EntropyMode::None,
            EntropyMode::Varint,
            EntropyMode::Range,
            EntropyMode::Full,
        ] {
            assert_eq!(EntropyMode::parse(m.name()).unwrap(), m);
            assert_eq!(EntropyMode::from_id(m.id()).unwrap(), m);
        }
        assert!(EntropyMode::parse("huffman").is_err());
        assert!(EntropyMode::from_id(9).is_err());
        assert_eq!(EntropyMode::default(), EntropyMode::None);
        assert!(EntropyMode::Full.varint_indices() && EntropyMode::Full.range_values());
        assert!(EntropyMode::Varint.varint_indices() && !EntropyMode::Varint.range_values());
        assert!(!EntropyMode::Range.varint_indices() && EntropyMode::Range.range_values());
    }

    #[test]
    fn varint_roundtrips_edge_cases() {
        for idx in [
            vec![],
            vec![0],
            vec![u32::MAX],
            (0..100).collect::<Vec<u32>>(),
            vec![0, 1, 2, 1_000_000, u32::MAX],
            vec![5, 5, 5], // duplicates (zero deltas) are representable
            vec![9, 3, 7], // non-monotonic (negative deltas zigzag fine)
        ] {
            let buf = encode_indices(&idx);
            assert_eq!(decode_indices(&buf, idx.len()).unwrap(), idx, "{idx:?}");
        }
    }

    #[test]
    fn varint_sorted_indices_cost_about_one_byte_each() {
        let idx: Vec<u32> = (0..1763).collect();
        let buf = encode_indices(&idx);
        // dense ascending deltas are all 1 -> exactly one byte per index,
        // vs 4 bytes each in the raw u32 block
        assert_eq!(buf.len(), idx.len());
    }

    #[test]
    fn varint_rejects_malformed_blocks() {
        let buf = encode_indices(&[1, 2, 3]);
        assert!(decode_indices(&buf[..buf.len() - 1], 3).is_err(), "truncation");
        assert!(decode_indices(&buf, 2).is_err(), "trailing bytes");
        // an unterminated continuation chain
        assert!(decode_indices(&[0x80, 0x80, 0x80], 1).is_err());
        // a 10-byte chain overflows the 64-bit accumulator budget
        assert!(decode_indices(&[0xff; 12], 1).is_err());
        // a 10th byte whose payload exceeds the one remaining bit would
        // silently drop bits — must error, not decode wrong
        let tenth_byte_overflow = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x02];
        assert!(decode_indices(&tenth_byte_overflow, 1).is_err());
    }

    #[test]
    fn range_roundtrips_structured_and_random_bytes() {
        let mut rng = Rng::seed_from_u64(42);
        for case in 0..40u64 {
            let n = rng.below(3000);
            let data: Vec<u8> = match case % 4 {
                0 => (0..n).map(|_| rng.below(256) as u8).collect(),
                1 => vec![0u8; n],
                2 => (0..n)
                    .map(|_| if rng.chance(0.9) { 0 } else { rng.below(256) as u8 })
                    .collect(),
                _ => (0..n).map(|i| (i % 7) as u8).collect(),
            };
            for p in [Precision::Int8, Precision::F16, Precision::F32, Precision::F64] {
                let cols = 1 + rng.below(40);
                let enc = range_encode(&data, p, cols, 0);
                let dec = range_decode(&enc, data.len(), p, cols, 0).unwrap();
                assert_eq!(dec, data, "case {case} {} cols={cols}", p.name());
            }
        }
    }

    #[test]
    fn range_compresses_skewed_bytes_and_barely_expands_uniform() {
        let mut rng = Rng::seed_from_u64(7);
        let skewed: Vec<u8> = (0..4000)
            .map(|_| if rng.chance(0.85) { 0 } else { rng.below(16) as u8 })
            .collect();
        let enc = range_encode(&skewed, Precision::Int8, 25, 0);
        assert!(
            enc.len() * 3 < skewed.len(),
            "skewed bytes should compress >3x, got {} -> {}",
            skewed.len(),
            enc.len()
        );
        let uniform: Vec<u8> = (0..4000).map(|_| rng.below(256) as u8).collect();
        let enc = range_encode(&uniform, Precision::Int8, 25, 0);
        // incompressible input costs at most ~2% + the coder preamble
        assert!(
            enc.len() <= uniform.len() + uniform.len() / 50 + 8,
            "uniform bytes expanded too much: {} -> {}",
            uniform.len(),
            enc.len()
        );
    }

    #[test]
    fn trailing_garbage_after_coded_stream_is_rejected() {
        let data: Vec<u8> = (0..500).map(|i| (i % 11) as u8).collect();
        let enc = range_encode(&data, Precision::Int8, 25, 0);
        // the decoder consumes the stream exactly...
        assert_eq!(range_decode(&enc, 500, Precision::Int8, 25, 0).unwrap(), data);
        // ...so appended bytes inside a (checksummed) payload are caught
        let mut padded = enc.clone();
        padded.extend_from_slice(&[0xab, 0xcd]);
        assert!(range_decode(&padded, 500, Precision::Int8, 25, 0).is_err());
    }

    #[test]
    fn blocks_validate_lengths() {
        let raw = vec![1u8, 2, 3, 4, 5, 6];
        let blk = seal_block(&raw, Precision::F16, 3, 1).unwrap();
        assert_eq!(open_block(&blk, 6, Precision::F16, 3, 1).unwrap(), raw);
        // geometry mismatch is an error, not garbage
        assert!(open_block(&blk, 7, Precision::F16, 3, 1).is_err());
        assert!(open_block(&blk[..3], 6, Precision::F16, 3, 1).is_err());
        // empty payload: just the zero-length prefix
        let blk = seal_block(&[], Precision::Int8, 25, 0).unwrap();
        assert_eq!(blk, vec![0u8, 0, 0, 0]);
        assert!(open_block(&blk, 0, Precision::Int8, 25, 0).unwrap().is_empty());
        assert!(open_block(&[0, 0, 0, 0, 9], 0, Precision::Int8, 25, 0).is_err());
    }

    #[test]
    fn role_maps_cover_row_strides() {
        let m = RoleMap::new(Precision::Int8, 25, 0);
        assert_eq!(m.prefix_len, 0);
        assert_eq!(m.cycle.len(), 27);
        assert_eq!(m.n_roles, 3);
        assert_eq!(&m.cycle[..3], &[0, 1, 2]);
        for (p, stride, roles) in [
            (Precision::F16, 2usize, 2usize),
            (Precision::F32, 4, 4),
            (Precision::F64, 8, 8),
        ] {
            let m = RoleMap::new(p, 25, 0);
            assert_eq!(m.cycle.len(), stride, "{}", p.name());
            assert_eq!(m.n_roles, roles);
        }
    }

    #[test]
    fn vq_role_maps_have_codebook_prefix_and_row_cycle() {
        // 64 rows, K = 25: 10 scale bytes + 32×25 codebook entries
        let m = RoleMap::new(Precision::Vq8, 25, 64);
        assert_eq!(m.prefix_len, super::super::vq::prefix_len(Precision::Vq8, 64, 25));
        assert_eq!(m.cycle.len(), 7); // f16 scale + 5 index bytes
        assert_eq!(m.n_roles, 8);
        assert_eq!(m.role(0), 0); // codebook byte
        assert_eq!(m.role(m.prefix_len), 1); // first row-scale byte
        assert_eq!(m.role(m.prefix_len + 2), 3); // first index byte
        // vq8r appends residual scale + value roles
        let m = RoleMap::new(Precision::Vq8r, 25, 64);
        assert_eq!(m.cycle.len(), 7 + 27);
        assert_eq!(m.n_roles, 11);
        // vq4 packs two indices per byte
        let m = RoleMap::new(Precision::Vq4, 25, 64);
        assert_eq!(m.cycle.len(), 2 + 3);
        // vq round-trip through the coder with the prefix in play
        let mut rng = Rng::seed_from_u64(99);
        let data: Vec<f32> = (0..64 * 25).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut payload = Vec::new();
        super::super::vq::encode_plane(&mut payload, &data, 64, 25, Precision::Vq8);
        let enc = range_encode(&payload, Precision::Vq8, 25, 64);
        let dec = range_decode(&enc, payload.len(), Precision::Vq8, 25, 64).unwrap();
        assert_eq!(dec, payload);
        // the index plane is low-entropy: coded vq frames shrink
        assert!(
            enc.len() < payload.len(),
            "vq payload did not compress: {} -> {}",
            payload.len(),
            enc.len()
        );
    }

    #[test]
    fn prefixed_role_maps_roundtrip_session_payload_shapes() {
        let mut rng = Rng::seed_from_u64(101);
        let data: Vec<f32> = (0..64 * 25).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut full = Vec::new();
        super::super::vq::encode_plane(&mut full, &data, 64, 25, Precision::Vq8);
        let prefix = super::super::vq::prefix_len(Precision::Vq8, 64, 25);
        // "reuse" shape: row records only, prefix 0
        let records = &full[prefix..];
        let enc = range_encode_prefixed(records, Precision::Vq8, 25, 0);
        let dec = range_decode_prefixed(&enc, records.len(), Precision::Vq8, 25, 0).unwrap();
        assert_eq!(dec, records);
        // explicit-prefix coding of the full payload matches the
        // rows-derived role map byte for byte
        let a = range_encode(&full, Precision::Vq8, 25, 64);
        let b = range_encode_prefixed(&full, Precision::Vq8, 25, prefix);
        assert_eq!(a, b);
        // "delta" shape: near-zero prefix plane compresses much harder
        // than the codebook it replaces
        let mut delta = full.clone();
        for byte in delta[2 * 5..prefix].iter_mut() {
            *byte = if *byte % 7 == 0 { 1 } else { 0 };
        }
        let coded_delta = range_encode_prefixed(&delta, Precision::Vq8, 25, prefix);
        let coded_full = range_encode_prefixed(&full, Precision::Vq8, 25, prefix);
        assert!(
            coded_delta.len() < coded_full.len(),
            "near-zero delta plane should compress below the codebook: {} vs {}",
            coded_delta.len(),
            coded_full.len()
        );
        let dec = range_decode_prefixed(&coded_delta, delta.len(), Precision::Vq8, 25, prefix)
            .unwrap();
        assert_eq!(dec, delta);
        // prefixed blocks validate lengths like the plain ones
        let blk = seal_block_prefixed(records, Precision::Vq8, 25, 0).unwrap();
        assert_eq!(
            open_block_prefixed(&blk, records.len(), Precision::Vq8, 25, 0).unwrap(),
            records
        );
        assert!(open_block_prefixed(&blk, records.len() + 1, Precision::Vq8, 25, 0).is_err());
    }
}
