//! The payload codec + transport subsystem: the bytes that actually move.
//!
//! The paper reduces the per-round payload by *selecting* M_s of M item
//! rows (the bandit axis); this module adds the orthogonal wire axes —
//! *how each selected row is put on the wire*:
//!
//! * [`frame`] — versioned binary envelope (magic, dims, codec id,
//!   entropy id, checksum) around every transmission,
//! * [`quant`] — element codecs: `f64`, `f32` (exact), `f16`, and per-row
//!   symmetric `int8` quantization with a bounded round-trip error,
//! * [`vq`] — product (codebook) quantization for dense downloads:
//!   `vq8` / `vq4` / `vq8r` replace each row's subvectors with indices
//!   into a per-frame, coordinator-learned codebook — the quantizer
//!   change that cuts *below* the int8 floor (uploads fall back to
//!   int8 rows; see [`Precision::for_uploads`]),
//! * [`vq::session`] — cross-round codebook **sessions** (`[codec]
//!   codebook_reuse = delta|auto`): generation-tagged version-2 frames
//!   that reuse the previous round's codebook verbatim or ship int8
//!   centroid deltas once Q stabilizes, with a typed stale-generation
//!   signal and a full-frame resync path for clients that missed
//!   rounds,
//! * [`sparse`] — index+value encoding for ∇Q* uploads with optional
//!   top-k row sparsification, including the entropy-aware
//!   `--sparse-topk auto` tuner ([`sparse::auto_top_k`]),
//! * [`entropy`] — lossless entropy coding layered under the checksum:
//!   delta+zigzag+LEB128 varints for the sparse row indices and an
//!   adaptive binary range coder (order-0 bit-tree byte model, one tree
//!   per byte role, with a dedicated codebook-prefix segment for the vq
//!   payloads) over the quantized payload bytes.
//!
//! The trainer encodes Q* before "transmitting", the simulated clients
//! train against the **decoded** (possibly lossy) factors, gradient
//! uploads round-trip through the sparse encoder, and the
//! [`TrafficLedger`](crate::simnet::TrafficLedger) records the encoded
//! frame lengths — so payload reduction is *measured*, not assumed
//! (`simnet::payload_bytes` keeps the paper's analytic Table 1 formula
//! for the reproduction only).
//!
//! Total payload per round and direction is therefore
//! `Θ × frame_len(M_s, K, precision, entropy)`; with K = 25 the int8
//! codec is ~3.7× smaller than f32 at identical M_s, `vq8` cuts the
//! download a further ~3.4× below int8 (codebook indices instead of
//! value bytes), entropy coding shaves a measured slice off each (the
//! low-entropy vq index plane is where `range` finally bites on
//! downloads), and everything multiplies with whatever reduction the
//! bandit achieves.
//!
//! [`PayloadCodec`] is the strategy trait and [`make_codec`] /
//! [`make_codec_with`] the registry, mirroring
//! [`bandit::make_selector`](crate::bandit::make_selector).
//!
//! The README's codec example, runnable:
//!
//! ```
//! use fedpayload::wire::{make_codec_with, EntropyMode, Precision};
//!
//! // 2 item rows x 3 factors, int8-quantized, fully entropy-coded
//! let q = vec![0.5f32, -0.25, 0.125, 1.0, 0.75, -0.5];
//! let codec = make_codec_with(Precision::Int8, EntropyMode::Full);
//! let frame = codec.encode_dense(&q, 2, 3).unwrap();
//! let decoded = codec.decode_dense(&frame).unwrap();
//! assert_eq!((decoded.rows, decoded.cols), (2, 3));
//! // int8 is lossy but bounded; entropy coding adds no loss at all
//! for (a, b) in q.iter().zip(&decoded.data) {
//!     assert!((a - b).abs() <= 0.01);
//! }
//! ```

pub mod entropy;
pub mod frame;
pub mod quant;
pub mod sparse;
pub mod upload;
pub mod vq;

pub use entropy::EntropyMode;
pub use frame::{FrameHeader, PayloadKind, SessionMode, HEADER_LEN, SESSION_HEADER_LEN};
pub use quant::{f16_to_f32, f32_to_f16, Precision};
pub use sparse::SparsePolicy;
pub use upload::{
    plane_of_batch_frame, EncodedUpload, UploadDecode, UploadPlane, UploadRef, UploadStats,
    UploadStore,
};
pub use vq::session::{
    EncodedDownload, ReuseMode, SessionDecode, SessionRationale, VqClientState, VqSession,
};

use anyhow::{ensure, Result};

/// A decoded row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    /// Row-major values (`rows × cols`).
    pub data: Vec<f32>,
    /// Number of matrix rows.
    pub rows: usize,
    /// Number of matrix columns.
    pub cols: usize,
}

/// Encode/decode strategy for round-trip payloads (one per training run).
pub trait PayloadCodec: Send {
    /// Codec name for logs/CSV (the precision name).
    fn name(&self) -> &'static str;

    /// Element precision this codec writes.
    fn precision(&self) -> Precision;

    /// Entropy coding mode this codec applies on top of the quantizer.
    fn entropy(&self) -> EntropyMode;

    /// Encode a dense row-major `rows × cols` matrix (Q* downloads).
    fn encode_dense(&self, data: &[f32], rows: usize, cols: usize) -> Result<Vec<u8>>;

    /// Decode a dense frame. The frame is self-describing: precision is
    /// read from the header, so any codec can decode any frame.
    fn decode_dense(&self, buf: &[u8]) -> Result<Dense>;

    /// Encode a sparse frame for a gradient upload.
    fn encode_sparse(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        policy: &SparsePolicy,
    ) -> Result<Vec<u8>>;

    /// Decode a sparse frame back to dense (dropped rows are zero).
    fn decode_sparse(&self, buf: &[u8]) -> Result<Dense>;
}

/// The standard codec: quantized dense downloads + sparse uploads at one
/// element precision, optionally entropy-coded.
struct QuantCodec {
    precision: Precision,
    entropy: EntropyMode,
}

impl PayloadCodec for QuantCodec {
    fn name(&self) -> &'static str {
        self.precision.name()
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn entropy(&self) -> EntropyMode {
        self.entropy
    }

    fn encode_dense(&self, data: &[f32], rows: usize, cols: usize) -> Result<Vec<u8>> {
        ensure!(
            data.len() == rows * cols,
            "dense encode: {} values for {rows}x{cols}",
            data.len()
        );
        let mut payload = Vec::with_capacity(quant::encoded_len(rows, cols, self.precision));
        quant::encode_rows(&mut payload, data, rows, cols, self.precision);
        // a dense frame has no index stream, so only the range-coding
        // half of the mode applies; the header records the mode as-is
        let payload = if self.entropy.range_values() {
            entropy::seal_block(&payload, self.precision, cols, rows)?
        } else {
            payload
        };
        frame::seal(
            self.precision.id(),
            self.entropy.id(),
            PayloadKind::Dense,
            rows,
            cols,
            &payload,
        )
    }

    fn decode_dense(&self, buf: &[u8]) -> Result<Dense> {
        let (header, payload) = frame::open(buf)?;
        ensure!(
            header.kind == PayloadKind::Dense,
            "expected a dense frame, got {:?}",
            header.kind
        );
        let precision = Precision::from_id(header.codec_id)?;
        let entropy = EntropyMode::from_id(header.entropy_id)?;
        let (rows, cols) = (header.rows as usize, header.cols as usize);
        let raw;
        let payload: &[u8] = if entropy.range_values() {
            raw = entropy::open_block(
                payload,
                quant::encoded_len(rows, cols, precision),
                precision,
                cols,
                rows,
            )?;
            &raw
        } else {
            payload
        };
        let data = quant::decode_rows(payload, rows, cols, precision)?;
        Ok(Dense { data, rows, cols })
    }

    fn encode_sparse(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        policy: &SparsePolicy,
    ) -> Result<Vec<u8>> {
        sparse::encode_with(data, rows, cols, self.precision, self.entropy, policy)
    }

    fn decode_sparse(&self, buf: &[u8]) -> Result<Dense> {
        sparse::decode(buf)
    }
}

/// Construct the payload codec for a precision with no entropy coding
/// (the codec registry, mirroring
/// [`bandit::make_selector`](crate::bandit::make_selector)).
pub fn make_codec(precision: Precision) -> Box<dyn PayloadCodec> {
    make_codec_with(precision, EntropyMode::None)
}

/// Construct the payload codec for a precision and entropy mode.
pub fn make_codec_with(precision: Precision, entropy: EntropyMode) -> Box<dyn PayloadCodec> {
    Box::new(QuantCodec { precision, entropy })
}

/// Exact frame length of a dense `rows × cols` payload at a precision,
/// with entropy coding off (entropy-coded frame lengths are
/// data-dependent — read them off the encoded frame).
pub fn encoded_dense_len(rows: usize, cols: usize, precision: Precision) -> usize {
    HEADER_LEN + quant::encoded_len(rows, cols, precision)
}

/// Exact frame length of a sparse payload keeping `nnz` rows of `cols`,
/// with entropy coding off (entropy-coded frame lengths are
/// data-dependent — read them off the encoded frame). Applies
/// [`Precision::for_uploads`] internally — sparse frames under the vq
/// modes carry int8 value planes, so passing a vq precision here
/// accounts for the int8 plane the encoder actually emits.
pub fn encoded_sparse_len(nnz: usize, cols: usize, precision: Precision) -> usize {
    let precision = precision.for_uploads();
    HEADER_LEN + 4 + nnz * 4 + quant::encoded_len(nnz, cols, precision)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn factors(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    const ALL_PRECISIONS: [Precision; 7] = [
        Precision::F64,
        Precision::F32,
        Precision::F16,
        Precision::Int8,
        Precision::Vq8,
        Precision::Vq4,
        Precision::Vq8r,
    ];

    #[test]
    fn registry_builds_every_precision() {
        for p in ALL_PRECISIONS {
            let codec = make_codec(p);
            assert_eq!(codec.precision(), p);
            assert_eq!(codec.name(), p.name());
            assert_eq!(codec.entropy(), EntropyMode::None);
            for e in [EntropyMode::Varint, EntropyMode::Range, EntropyMode::Full] {
                let codec = make_codec_with(p, e);
                assert_eq!(codec.precision(), p);
                assert_eq!(codec.entropy(), e);
            }
        }
    }

    #[test]
    fn dense_entropy_modes_decode_bit_identically_to_plain() {
        let (rows, cols) = (48, 25);
        let q = factors(rows, cols, 21);
        for p in ALL_PRECISIONS {
            let base = make_codec(p)
                .decode_dense(&make_codec(p).encode_dense(&q, rows, cols).unwrap())
                .unwrap();
            for e in [EntropyMode::Varint, EntropyMode::Range, EntropyMode::Full] {
                let codec = make_codec_with(p, e);
                let dec = codec
                    .decode_dense(&codec.encode_dense(&q, rows, cols).unwrap())
                    .unwrap();
                for (a, b) in base.data.iter().zip(&dec.data) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} {}", p.name(), e.name());
                }
            }
        }
    }

    #[test]
    fn range_coded_dense_frames_never_blow_up_and_shrink_structured_data() {
        // worst case (near-incompressible random factors): bounded overhead
        let (rows, cols) = (256, 25);
        let q = factors(rows, cols, 22);
        let plain = make_codec(Precision::Int8).encode_dense(&q, rows, cols).unwrap();
        let coded = make_codec_with(Precision::Int8, EntropyMode::Range)
            .encode_dense(&q, rows, cols)
            .unwrap();
        assert!(
            coded.len() <= plain.len() + plain.len() / 50 + 16,
            "range-coded {} vs plain {}",
            coded.len(),
            plain.len()
        );
        // structured factors (low-rank-ish: most columns near zero, the
        // shape trained Q converges to) compress well
        let mut structured = vec![0.0f32; rows * cols];
        let mut rng = crate::rng::Rng::seed_from_u64(23);
        for r in 0..rows {
            for c in 0..cols {
                structured[r * cols + c] = if c < 6 {
                    rng.normal() as f32 * 0.3
                } else {
                    rng.normal() as f32 * 0.003
                };
            }
        }
        let plain = make_codec(Precision::Int8)
            .encode_dense(&structured, rows, cols)
            .unwrap();
        let coded = make_codec_with(Precision::Int8, EntropyMode::Range)
            .encode_dense(&structured, rows, cols)
            .unwrap();
        assert!(
            (coded.len() as f64) < plain.len() as f64 * 0.8,
            "structured int8 should shrink >20%: {} vs {}",
            coded.len(),
            plain.len()
        );
    }

    #[test]
    fn dense_frame_lengths_match_helper() {
        let (rows, cols) = (24, 25);
        let q = factors(rows, cols, 1);
        for p in ALL_PRECISIONS {
            let frame = make_codec(p).encode_dense(&q, rows, cols).unwrap();
            assert_eq!(frame.len(), encoded_dense_len(rows, cols, p), "{}", p.name());
        }
    }

    #[test]
    fn vq8_dense_is_smaller_than_int8_and_compresses_under_range() {
        let (rows, cols) = (64, 25);
        let q = factors(rows, cols, 24);
        let int8 = make_codec(Precision::Int8).encode_dense(&q, rows, cols).unwrap();
        let vq8 = make_codec(Precision::Vq8).encode_dense(&q, rows, cols).unwrap();
        assert!(vq8.len() < int8.len(), "vq8 {} !< int8 {}", vq8.len(), int8.len());
        // ... and the coded vq frame (low-entropy indices) is smaller
        // than the coded int8 frame (near-incompressible values)
        let int8_full = make_codec_with(Precision::Int8, EntropyMode::Full)
            .encode_dense(&q, rows, cols)
            .unwrap();
        let vq8_full = make_codec_with(Precision::Vq8, EntropyMode::Full)
            .encode_dense(&q, rows, cols)
            .unwrap();
        assert!(
            vq8_full.len() < int8_full.len(),
            "vq8+full {} !< int8+full {}",
            vq8_full.len(),
            int8_full.len()
        );
        // any codec decodes a vq frame (self-describing header)
        let dec = make_codec(Precision::F32).decode_dense(&vq8).unwrap();
        assert_eq!((dec.rows, dec.cols), (rows, cols));
    }

    #[test]
    fn int8_dense_is_about_4x_smaller_than_f32() {
        let (rows, cols) = (1763, 25);
        let q = factors(rows, cols, 2);
        let f32_len = make_codec(Precision::F32)
            .encode_dense(&q, rows, cols)
            .unwrap()
            .len();
        let int8_len = make_codec(Precision::Int8)
            .encode_dense(&q, rows, cols)
            .unwrap()
            .len();
        let ratio = f32_len as f64 / int8_len as f64;
        assert!((3.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn f32_dense_roundtrip_is_bit_exact() {
        let (rows, cols) = (40, 25);
        let q = factors(rows, cols, 3);
        let codec = make_codec(Precision::F32);
        let dec = codec.decode_dense(&codec.encode_dense(&q, rows, cols).unwrap()).unwrap();
        assert_eq!(dec.data, q);
        assert_eq!((dec.rows, dec.cols), (rows, cols));
    }

    #[test]
    fn any_codec_decodes_any_frame() {
        let q = factors(10, 25, 4);
        let frame = make_codec(Precision::F16).encode_dense(&q, 10, 25).unwrap();
        // the decoder reads precision from the header, not from self
        let dec = make_codec(Precision::Int8).decode_dense(&frame).unwrap();
        assert_eq!(dec.rows, 10);
        for (a, b) in q.iter().zip(&dec.data) {
            assert!((a - b).abs() <= quant::max_roundtrip_error(Precision::F16, a.abs()));
        }
    }

    #[test]
    fn dense_decode_rejects_sparse_frames_and_vice_versa() {
        let q = factors(6, 5, 5);
        let codec = make_codec(Precision::F32);
        let dense = codec.encode_dense(&q, 6, 5).unwrap();
        let sparse = codec
            .encode_sparse(&q, 6, 5, &SparsePolicy::default())
            .unwrap();
        assert!(codec.decode_dense(&sparse).is_err());
        assert!(codec.decode_sparse(&dense).is_err());
    }
}
