//! The payload codec + transport subsystem: the bytes that actually move.
//!
//! The paper reduces the per-round payload by *selecting* M_s of M item
//! rows (the bandit axis); this module adds the second, orthogonal axis —
//! *how each selected row is put on the wire*:
//!
//! * [`frame`] — versioned binary envelope (magic, dims, codec id,
//!   checksum) around every transmission,
//! * [`quant`] — element codecs: `f64`, `f32` (exact), `f16`, and per-row
//!   symmetric `int8` quantization with a bounded round-trip error,
//! * [`sparse`] — index+value encoding for ∇Q* uploads with optional
//!   top-k row sparsification.
//!
//! The trainer encodes Q* before "transmitting", the simulated clients
//! train against the **decoded** (possibly lossy) factors, gradient
//! uploads round-trip through the sparse encoder, and the
//! [`TrafficLedger`](crate::simnet::TrafficLedger) records the encoded
//! frame lengths — so payload reduction is *measured*, not assumed
//! (`simnet::payload_bytes` keeps the paper's analytic Table 1 formula
//! for the reproduction only).
//!
//! Total payload per round and direction is therefore
//! `Θ × frame_len(M_s, K, precision)`; with K = 25 the int8 codec is
//! ~3.7× smaller than f32 at identical M_s, multiplying with whatever
//! reduction the bandit achieves.
//!
//! [`PayloadCodec`] is the strategy trait and [`make_codec`] the registry,
//! mirroring [`bandit::make_selector`](crate::bandit::make_selector).

pub mod frame;
pub mod quant;
pub mod sparse;

pub use frame::{FrameHeader, PayloadKind, HEADER_LEN};
pub use quant::{f16_to_f32, f32_to_f16, Precision};
pub use sparse::SparsePolicy;

use anyhow::{ensure, Result};

/// A decoded row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    pub data: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

/// Encode/decode strategy for round-trip payloads (one per training run).
pub trait PayloadCodec: Send {
    /// Codec name for logs/CSV (the precision name).
    fn name(&self) -> &'static str;

    /// Element precision this codec writes.
    fn precision(&self) -> Precision;

    /// Encode a dense row-major `rows × cols` matrix (Q* downloads).
    fn encode_dense(&self, data: &[f32], rows: usize, cols: usize) -> Result<Vec<u8>>;

    /// Decode a dense frame. The frame is self-describing: precision is
    /// read from the header, so any codec can decode any frame.
    fn decode_dense(&self, buf: &[u8]) -> Result<Dense>;

    /// Encode a sparse frame for a gradient upload.
    fn encode_sparse(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        policy: &SparsePolicy,
    ) -> Result<Vec<u8>>;

    /// Decode a sparse frame back to dense (dropped rows are zero).
    fn decode_sparse(&self, buf: &[u8]) -> Result<Dense>;
}

/// The standard codec: quantized dense downloads + sparse uploads at one
/// element precision.
struct QuantCodec {
    precision: Precision,
}

impl PayloadCodec for QuantCodec {
    fn name(&self) -> &'static str {
        self.precision.name()
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn encode_dense(&self, data: &[f32], rows: usize, cols: usize) -> Result<Vec<u8>> {
        ensure!(
            data.len() == rows * cols,
            "dense encode: {} values for {rows}x{cols}",
            data.len()
        );
        let mut payload = Vec::with_capacity(quant::encoded_len(rows, cols, self.precision));
        quant::encode_rows(&mut payload, data, rows, cols, self.precision);
        frame::seal(
            self.precision.id(),
            PayloadKind::Dense,
            rows,
            cols,
            &payload,
        )
    }

    fn decode_dense(&self, buf: &[u8]) -> Result<Dense> {
        let (header, payload) = frame::open(buf)?;
        ensure!(
            header.kind == PayloadKind::Dense,
            "expected a dense frame, got {:?}",
            header.kind
        );
        let precision = Precision::from_id(header.codec_id)?;
        let (rows, cols) = (header.rows as usize, header.cols as usize);
        let data = quant::decode_rows(payload, rows, cols, precision)?;
        Ok(Dense { data, rows, cols })
    }

    fn encode_sparse(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        policy: &SparsePolicy,
    ) -> Result<Vec<u8>> {
        sparse::encode(data, rows, cols, self.precision, policy)
    }

    fn decode_sparse(&self, buf: &[u8]) -> Result<Dense> {
        sparse::decode(buf)
    }
}

/// Construct the payload codec for a precision (the codec registry,
/// mirroring [`bandit::make_selector`](crate::bandit::make_selector)).
pub fn make_codec(precision: Precision) -> Box<dyn PayloadCodec> {
    Box::new(QuantCodec { precision })
}

/// Exact frame length of a dense `rows × cols` payload at a precision.
pub fn encoded_dense_len(rows: usize, cols: usize, precision: Precision) -> usize {
    HEADER_LEN + quant::encoded_len(rows, cols, precision)
}

/// Exact frame length of a sparse payload keeping `nnz` rows of `cols`.
pub fn encoded_sparse_len(nnz: usize, cols: usize, precision: Precision) -> usize {
    HEADER_LEN + 4 + nnz * 4 + quant::encoded_len(nnz, cols, precision)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn factors(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect()
    }

    #[test]
    fn registry_builds_every_precision() {
        for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
            let codec = make_codec(p);
            assert_eq!(codec.precision(), p);
            assert_eq!(codec.name(), p.name());
        }
    }

    #[test]
    fn dense_frame_lengths_match_helper() {
        let (rows, cols) = (24, 25);
        let q = factors(rows, cols, 1);
        for p in [Precision::F64, Precision::F32, Precision::F16, Precision::Int8] {
            let frame = make_codec(p).encode_dense(&q, rows, cols).unwrap();
            assert_eq!(frame.len(), encoded_dense_len(rows, cols, p), "{}", p.name());
        }
    }

    #[test]
    fn int8_dense_is_about_4x_smaller_than_f32() {
        let (rows, cols) = (1763, 25);
        let q = factors(rows, cols, 2);
        let f32_len = make_codec(Precision::F32)
            .encode_dense(&q, rows, cols)
            .unwrap()
            .len();
        let int8_len = make_codec(Precision::Int8)
            .encode_dense(&q, rows, cols)
            .unwrap()
            .len();
        let ratio = f32_len as f64 / int8_len as f64;
        assert!((3.0..4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn f32_dense_roundtrip_is_bit_exact() {
        let (rows, cols) = (40, 25);
        let q = factors(rows, cols, 3);
        let codec = make_codec(Precision::F32);
        let dec = codec.decode_dense(&codec.encode_dense(&q, rows, cols).unwrap()).unwrap();
        assert_eq!(dec.data, q);
        assert_eq!((dec.rows, dec.cols), (rows, cols));
    }

    #[test]
    fn any_codec_decodes_any_frame() {
        let q = factors(10, 25, 4);
        let frame = make_codec(Precision::F16).encode_dense(&q, 10, 25).unwrap();
        // the decoder reads precision from the header, not from self
        let dec = make_codec(Precision::Int8).decode_dense(&frame).unwrap();
        assert_eq!(dec.rows, 10);
        for (a, b) in q.iter().zip(&dec.data) {
            assert!((a - b).abs() <= quant::max_roundtrip_error(Precision::F16, a.abs()));
        }
    }

    #[test]
    fn dense_decode_rejects_sparse_frames_and_vice_versa() {
        let q = factors(6, 5, 5);
        let codec = make_codec(Precision::F32);
        let dense = codec.encode_dense(&q, 6, 5).unwrap();
        let sparse = codec
            .encode_sparse(&q, 6, 5, &SparsePolicy::default())
            .unwrap();
        assert!(codec.decode_dense(&sparse).is_err());
        assert!(codec.decode_sparse(&dense).is_err());
    }
}
