//! Prometheus text exposition for the [`Registry`] and the
//! `--metrics-out` snapshot writer.
//!
//! The output is the plain text format every Prometheus scraper and
//! `promtool` accept: `# TYPE` lines per family, samples sorted by
//! name (the registry's `BTreeMap` order), histogram `_bucket` series
//! cumulative with a final `le="+Inf"`. Snapshots are rewritten whole
//! (truncate + write) each round — node-exporter textfile-collector
//! style — so the file is always one complete, parseable scrape.

use std::path::Path;

use super::registry::Registry;
use crate::Result;

/// Render a float the way Prometheus text format expects (shortest
/// round-trip decimal; non-finite values have spelled-out names).
fn prom_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Family name of a sample key: everything before the label block.
fn family(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Render the whole registry as Prometheus text. Deterministic for a
/// deterministic registry: sorted sample order, fixed bucket bounds,
/// shortest-roundtrip floats.
pub fn render_prometheus(reg: &Registry) -> String {
    let mut out = String::with_capacity(1024);
    let mut last_family = String::new();
    let mut type_line = |out: &mut String, name: &str, kind: &str| {
        let fam = family(name);
        if fam != last_family {
            out.push_str("# TYPE ");
            out.push_str(fam);
            out.push(' ');
            out.push_str(kind);
            out.push('\n');
            last_family = fam.to_string();
        }
    };
    for (name, v) in &reg.counters {
        type_line(&mut out, name, "counter");
        out.push_str(&format!("{name} {v}\n"));
    }
    for (name, v) in &reg.gauges {
        type_line(&mut out, name, "gauge");
        out.push_str(&format!("{name} {}\n", prom_f64(*v)));
    }
    for (name, h) in &reg.histograms {
        type_line(&mut out, name, "histogram");
        let mut cum = 0u64;
        for (i, c) in h.counts.iter().enumerate() {
            cum += c;
            let le = match h.bounds.get(i) {
                Some(b) => prom_f64(*b),
                None => "+Inf".to_string(),
            };
            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
        }
        out.push_str(&format!("{name}_sum {}\n", prom_f64(h.sum)));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

/// Write one `--metrics-out` snapshot: truncate `path` and emit the
/// registry preceded by a round-stamp comment. Called once per round;
/// the last write is the end-of-run state.
pub fn write_metrics_snapshot(path: &Path, reg: &Registry, iter: usize) -> Result<()> {
    let mut text = format!("# fedpayload metrics snapshot, round {iter}\n");
    text.push_str(&render_prometheus(reg));
    std::fs::write(path, text)
        .map_err(|e| anyhow::anyhow!("cannot write metrics snapshot {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::super::registry::{Registry, BYTE_BUCKETS};
    use super::*;

    #[test]
    fn renders_families_in_sorted_order_with_types() {
        let mut r = Registry::new();
        r.inc("fp_frames_total{mode=\"full\"}", 2);
        r.inc("fp_frames_total{mode=\"reuse\"}", 1);
        r.set_gauge("fp_generation", 3.0);
        r.observe("fp_frame_bytes", BYTE_BUCKETS, 100.0);
        r.observe("fp_frame_bytes", BYTE_BUCKETS, 5000.0);
        let text = render_prometheus(&r);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "# TYPE fp_frames_total counter");
        assert_eq!(lines[1], "fp_frames_total{mode=\"full\"} 2");
        assert_eq!(lines[2], "fp_frames_total{mode=\"reuse\"} 1");
        assert!(lines.contains(&"# TYPE fp_generation gauge"));
        assert!(lines.contains(&"fp_generation 3"));
        // buckets are cumulative and end at +Inf
        assert!(text.contains("fp_frame_bytes_bucket{le=\"256\"} 1\n"));
        assert!(text.contains("fp_frame_bytes_bucket{le=\"16384\"} 2\n"));
        assert!(text.contains("fp_frame_bytes_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("fp_frame_bytes_sum 5100\n"));
        assert!(text.contains("fp_frame_bytes_count 2\n"));
        // one TYPE line per family, no repeats for the second label
        assert_eq!(
            text.matches("# TYPE fp_frames_total").count(),
            1,
            "{text}"
        );
    }

    #[test]
    fn snapshot_is_deterministic_for_equal_registries() {
        let fill = |r: &mut Registry| {
            r.inc("a_total", 7);
            r.set_gauge("g", 0.125);
            r.observe("h", BYTE_BUCKETS, 300.0);
        };
        let (mut r1, mut r2) = (Registry::new(), Registry::new());
        fill(&mut r1);
        fill(&mut r2);
        assert_eq!(render_prometheus(&r1), render_prometheus(&r2));
    }
}
