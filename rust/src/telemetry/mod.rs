//! Telemetry substrate: leveled logging, counters, wall-clock timers, CSV
//! writers and the bench harness (criterion is unavailable offline).

mod bench;
mod csv;
pub mod export;
pub mod registry;
pub mod trace;

pub use bench::{bench, BenchResult, Bencher};
pub use csv::CsvWriter;
pub use registry::Registry;
pub use trace::{parse_trace_level, trace_enabled, TraceEvent, TraceLevel, Tracer};

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log levels, lowest to highest priority.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Verbose per-round diagnostics.
    Debug = 0,
    /// Run-level progress (the default threshold).
    Info = 1,
    /// Recoverable misconfigurations.
    Warn = 2,
    /// Failures.
    Error = 3,
}

static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log threshold.
pub fn set_log_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse `debug|info|warn|error`, case-insensitively (`INFO` and
/// `Info` are as valid as `info` — CLI input shouldn't be shouting-
/// sensitive). See [`LEVEL_NAMES`] for the accepted set.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "debug" => Some(Level::Debug),
        "info" => Some(Level::Info),
        "warn" => Some(Level::Warn),
        "error" => Some(Level::Error),
        _ => None,
    }
}

/// The accepted `--log-level` values, for error messages.
pub const LEVEL_NAMES: &str = "debug|info|warn|error";

#[doc(hidden)]
pub fn log_enabled(level: Level) -> bool {
    level as u8 >= LOG_LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn log_emit(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if log_enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

/// `log!(Level::Info, "training {} rounds", n)` — leveled logging macro.
#[macro_export]
macro_rules! log {
    ($level:expr, $($arg:tt)*) => {
        $crate::telemetry::log_emit($level, module_path!(), format_args!($($arg)*))
    };
}

/// Info-level logging.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log!($crate::telemetry::Level::Info, $($arg)*) };
}

/// Debug-level logging.
#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => { $crate::log!($crate::telemetry::Level::Debug, $($arg)*) };
}

/// Warn-level logging.
#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => { $crate::log!($crate::telemetry::Level::Warn, $($arg)*) };
}

/// Streaming FNV-1a 64 digest builder — the 64-bit sibling of
/// `wire::frame::checksum`. The round journal (`server::journal`) uses
/// it to fingerprint mutable coordinator state (RNG stream position,
/// bandit posteriors, codebook sessions) so a `--resume` replay can
/// detect divergence at the round where it happens rather than at the
/// final dump diff. Not cryptographic: a drift detector, not a MAC.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    /// Digest at the FNV-1a 64 offset basis.
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Fold raw bytes in.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// Fold one byte in.
    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    /// Fold a u64 in (little-endian bytes).
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Fold a u128 in (little-endian bytes).
    pub fn write_u128(&mut self, v: u128) {
        self.write(&v.to_le_bytes());
    }

    /// Fold an f64 in by exact bit pattern (never by value — `-0.0`
    /// and `0.0` must digest differently for replay verification).
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// The digest so far.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// A named wall-clock stopwatch accumulating across start/stop cycles.
/// The trainer keeps one per phase (select/transmit/compute/aggregate)
/// so EXPERIMENTS.md §Perf can attribute time per stage.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    /// Phase name this stopwatch reports under.
    pub name: &'static str,
    total_ns: u128,
    count: u64,
    started: Option<Instant>,
}

impl Stopwatch {
    /// A stopped stopwatch with zero accumulated time.
    pub fn new(name: &'static str) -> Self {
        Stopwatch {
            name,
            total_ns: 0,
            count: 0,
            started: None,
        }
    }

    /// Start one timing cycle (must not already be running).
    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch {} already running", self.name);
        self.started = Some(Instant::now());
    }

    /// Stop the running cycle and accumulate it (no-op when stopped).
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total_ns += t0.elapsed().as_nanos();
            self.count += 1;
        }
    }

    /// Fold externally measured time in: per-shard stopwatches from the
    /// parallel fleet workers are absorbed into the coordinator's phase
    /// stopwatches at the round barrier. Busy time summed across lanes
    /// can exceed wall-clock.
    pub fn absorb_ns(&mut self, ns: u128, count: u64) {
        self.total_ns += ns;
        self.count += count;
    }

    /// Time one closure.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    /// Accumulated seconds across all cycles.
    pub fn total_secs(&self) -> f64 {
        self.total_ns as f64 / 1e9
    }

    /// Completed timing cycles.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean milliseconds per cycle (0 when never run).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64 / 1e6
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new("t");
        for _ in 0..3 {
            sw.time(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        }
        assert_eq!(sw.count(), 3);
        assert!(sw.total_secs() >= 0.006);
        assert!(sw.mean_ms() >= 2.0);
    }

    #[test]
    fn stopwatch_absorbs_external_time() {
        let mut sw = Stopwatch::new("t");
        sw.absorb_ns(2_000_000, 4); // 2ms over 4 worker batches
        sw.absorb_ns(1_000_000, 2);
        assert_eq!(sw.count(), 6);
        assert!((sw.total_secs() - 0.003).abs() < 1e-12);
        assert!((sw.mean_ms() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fnv64_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors (draft-eastlake-fnv).
        let mut h = Fnv64::new();
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325, "offset basis");
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv64::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn fnv64_field_writes_are_position_sensitive() {
        let digest = |f: &dyn Fn(&mut Fnv64)| {
            let mut h = Fnv64::new();
            f(&mut h);
            h.finish()
        };
        assert_ne!(
            digest(&|h| {
                h.write_u64(1);
                h.write_u64(2);
            }),
            digest(&|h| {
                h.write_u64(2);
                h.write_u64(1);
            })
        );
        assert_ne!(digest(&|h| h.write_f64(0.0)), digest(&|h| h.write_f64(-0.0)));
        assert_ne!(digest(&|h| h.write_u128(7)), digest(&|h| h.write_u64(7)));
    }

    #[test]
    fn levels_parse() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("loud"), None);
    }

    #[test]
    fn levels_parse_case_insensitive() {
        assert_eq!(parse_level("INFO"), Some(Level::Info));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level("WaRn"), Some(Level::Warn));
        assert_eq!(parse_level("DEBUG "), None, "whitespace is not trimmed");
    }

    #[test]
    fn log_threshold_respected() {
        set_log_level(Level::Warn);
        assert!(!log_enabled(Level::Info));
        assert!(log_enabled(Level::Error));
        set_log_level(Level::Info);
    }
}
