//! Minimal criterion-replacement bench harness (criterion unavailable
//! offline). Warms up, runs timed batches until a wall-clock budget or
//! iteration cap, reports mean / p50 / p95 and a throughput line.
//!
//! Used by every target under `benches/` (`harness = false`).

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Timed iterations executed.
    pub iters: u64,
    /// Mean time per iteration in nanoseconds.
    pub mean_ns: f64,
    /// Median time per iteration in nanoseconds.
    pub p50_ns: f64,
    /// 95th-percentile time per iteration in nanoseconds.
    pub p95_ns: f64,
}

impl BenchResult {
    /// Print the one-line human summary.
    pub fn report(&self) {
        println!(
            "bench {:<40} iters={:<7} mean={:>12} p50={:>12} p95={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Bench runner with a wall-clock budget.
pub struct Bencher {
    budget: Duration,
    max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(3),
            max_iters: 1_000_000,
        }
    }
}

impl Bencher {
    /// Runner with an explicit wall-clock budget and iteration cap.
    pub fn new(budget: Duration, max_iters: u64) -> Self {
        Bencher { budget, max_iters }
    }

    /// Honour `FEDPAYLOAD_BENCH_BUDGET_SECS` so CI can shrink runtimes.
    pub fn from_env() -> Self {
        let secs = std::env::var("FEDPAYLOAD_BENCH_BUDGET_SECS")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(3.0);
        Bencher::new(Duration::from_secs_f64(secs), 1_000_000)
    }

    /// Time `f` repeatedly; the closure's output is black-boxed.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup: a few runs or 10% of budget.
        let warmup_deadline = Instant::now() + self.budget / 10;
        let mut warmups = 0;
        while warmups < 3 || (Instant::now() < warmup_deadline && warmups < 100) {
            black_box(f());
            warmups += 1;
        }

        let mut samples_ns: Vec<u128> = Vec::new();
        let deadline = Instant::now() + self.budget;
        let mut iters = 0u64;
        while Instant::now() < deadline && iters < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos());
            iters += 1;
        }
        samples_ns.sort_unstable();
        let mean = samples_ns.iter().sum::<u128>() as f64 / samples_ns.len() as f64;
        let p = |q: f64| samples_ns[((samples_ns.len() - 1) as f64 * q) as usize] as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: p(0.50),
            p95_ns: p(0.95),
        };
        result.report();
        result
    }
}

/// One-shot convenience: bench with the env-configured budget.
pub fn bench<T>(name: &str, f: impl FnMut() -> T) -> BenchResult {
    Bencher::from_env().run(name, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let b = Bencher::new(Duration::from_millis(50), 10_000);
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
    }
}
