//! Tiny CSV writer for experiment outputs (results/*.csv).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Buffered CSV writer with a fixed header written up front.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create/truncate `path` and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            columns: header.len(),
        })
    }

    /// Write one row of stringified fields (must match header arity).
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        anyhow::ensure!(
            fields.len() == self.columns,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.columns
        );
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Format helper: fixed-precision float field.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("fedpayload_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn arity_mismatch_errors() {
        let dir = std::env::temp_dir().join("fedpayload_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
