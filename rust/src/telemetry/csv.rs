//! Tiny CSV writer for experiment outputs (results/*.csv).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Buffered CSV writer with a fixed header written up front.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create/truncate `path` and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        let quoted: Vec<String> = header.iter().map(|h| quote_field(h)).collect();
        writeln!(out, "{}", quoted.join(","))?;
        Ok(CsvWriter {
            out,
            columns: header.len(),
        })
    }

    /// Write one row of stringified fields (must match header arity).
    /// Fields containing commas, quotes or line breaks are RFC-4180
    /// quoted; everything else is written verbatim.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        anyhow::ensure!(
            fields.len() == self.columns,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.columns
        );
        let quoted: Vec<String> = fields.iter().map(|f| quote_field(f)).collect();
        writeln!(self.out, "{}", quoted.join(","))?;
        Ok(())
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Format helper: fixed-precision float field.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// RFC-4180 quoting: a field containing a comma, double quote, CR or
/// LF is wrapped in double quotes with embedded quotes doubled; clean
/// fields pass through untouched (so the numeric outputs every
/// existing consumer parses stay byte-identical).
fn quote_field(field: &str) -> String {
    if field.contains(&[',', '"', '\n', '\r'][..]) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("fedpayload_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rfc4180_quotes_special_fields() {
        let dir = std::env::temp_dir().join("fedpayload_csv_test_quoting");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["name", "note"]).unwrap();
        w.row(&["plain".into(), "a,b".into()]).unwrap();
        w.row(&["say \"hi\"".into(), "line1\nline2".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "name,note\nplain,\"a,b\"\n\"say \"\"hi\"\"\",\"line1\nline2\"\n"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn arity_mismatch_errors() {
        let dir = std::env::temp_dir().join("fedpayload_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
