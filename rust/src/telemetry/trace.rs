//! Structured round-event flight recorder.
//!
//! Every decision the round loop already makes — bandit arm selection,
//! codec/session mode choice, per-client resyncs, ledger movement — is
//! emitted as one self-describing JSON object per line (JSONL), in
//! coordinator order (and, for fleet-lane spans, batch-index order), so
//! a trace is replayable and diffable the same way `--dump-rounds` is.
//!
//! **Determinism contract.** A trace line has two parts: decision
//! fields, which are pure functions of (config, seed) and therefore
//! bit-identical across `--threads` values, and a trailing `"t":{...}`
//! object holding everything wall-clock or execution-environment
//! dependent (nanosecond timings, lane ids, thread counts). The `t`
//! object is always the **last** top-level key and contains only flat
//! numeric fields — that invariant is what lets [`trace_digest`] strip
//! it textually, yielding a decision-only digest that CI diffs across
//! thread counts (`ci/determinism.sh` §6).
//!
//! **Cost when off.** Emission sites are gated the same way as
//! [`log_enabled`](super::log_enabled): one relaxed atomic load and a
//! branch ([`trace_enabled`]). No event is formatted, no allocation
//! happens, unless the global level admits it *and* a [`Tracer`] is
//! installed.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};

use crate::Result;

/// Trace verbosity levels, lowest to highest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// No events.
    Off = 0,
    /// Decision events only (bandit, codec/session, resync, round/run
    /// boundaries) — everything the determinism digest covers.
    Decision = 1,
    /// Decision events plus per-batch fleet-lane spans.
    Full = 2,
}

impl TraceLevel {
    /// Canonical name, as accepted by [`parse_trace_level`].
    pub fn name(&self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Decision => "decision",
            TraceLevel::Full => "full",
        }
    }
}

/// Parse `off|decision|full` (case-insensitive).
pub fn parse_trace_level(s: &str) -> Option<TraceLevel> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(TraceLevel::Off),
        "decision" => Some(TraceLevel::Decision),
        "full" => Some(TraceLevel::Full),
        _ => None,
    }
}

static TRACE_LEVEL: AtomicU8 = AtomicU8::new(TraceLevel::Off as u8);

/// Set the process-wide trace threshold (the fast-path gate).
pub fn set_trace_level(level: TraceLevel) {
    TRACE_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Raise the process-wide threshold to at least `level` (never lowers
/// it — installing a tracer in one trainer must not mute another's).
pub fn raise_trace_level(level: TraceLevel) {
    TRACE_LEVEL.fetch_max(level as u8, Ordering::Relaxed);
}

/// One relaxed load + compare: the per-event cost when tracing is off.
/// Same pattern as [`log_enabled`](super::log_enabled).
#[inline]
pub fn trace_enabled(level: TraceLevel) -> bool {
    level as u8 <= TRACE_LEVEL.load(Ordering::Relaxed) && level != TraceLevel::Off
}

/// The f64 bit-pattern renderer shared with
/// [`round_dump_string`](crate::server::round_dump_string): 16 hex
/// digits of `to_bits`, so exact-value fields survive text round-trips.
pub fn f64_bits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Escape a string for a JSON string literal (quotes not included).
fn json_escape_into(buf: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => buf.push_str("\\\""),
            '\\' => buf.push_str("\\\\"),
            '\n' => buf.push_str("\\n"),
            '\r' => buf.push_str("\\r"),
            '\t' => buf.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                buf.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => buf.push(c),
        }
    }
}

/// Append a JSON number (finite shortest-roundtrip, else `null` — JSON
/// has no NaN/Inf). Rust's `Display` for floats never uses exponent
/// notation and round-trips exactly, so plain numbers are both
/// jq-friendly and bit-deterministic.
fn json_f64_into(buf: &mut String, v: f64) {
    if v.is_finite() {
        // Integral values would print without a dot and change the
        // JSON type; keep them numbers either way (jq doesn't care),
        // but make 1.0 render as "1.0" for schema stability.
        if v == v.trunc() && v.abs() < 1e15 {
            buf.push_str(&format!("{v:.1}"));
        } else {
            buf.push_str(&format!("{v}"));
        }
    } else {
        buf.push_str("null");
    }
}

/// Builder for one trace line. Decision fields accumulate in emission
/// order; timing fields (`t_*`) accumulate into the trailing `"t"`
/// object, which [`render`](TraceEvent::render) always emits last.
/// Timing values are numeric only — the flatness invariant
/// [`trace_digest`] relies on.
#[derive(Debug)]
pub struct TraceEvent {
    body: String,
    timing: String,
}

impl TraceEvent {
    /// Start an event of kind `ev` (the `"ev"` discriminator field).
    pub fn new(ev: &str) -> TraceEvent {
        let mut body = String::with_capacity(160);
        body.push_str("{\"ev\":\"");
        json_escape_into(&mut body, ev);
        body.push('"');
        TraceEvent {
            body,
            timing: String::new(),
        }
    }

    fn key(&mut self, key: &str) {
        self.body.push_str(",\"");
        json_escape_into(&mut self.body, key);
        self.body.push_str("\":");
    }

    /// Unsigned integer decision field.
    pub fn u64(mut self, key: &str, v: u64) -> TraceEvent {
        self.key(key);
        self.body.push_str(&v.to_string());
        self
    }

    /// Signed integer decision field.
    pub fn i64(mut self, key: &str, v: i64) -> TraceEvent {
        self.key(key);
        self.body.push_str(&v.to_string());
        self
    }

    /// Float decision field (shortest-roundtrip; non-finite → `null`).
    pub fn f64(mut self, key: &str, v: f64) -> TraceEvent {
        self.key(key);
        json_f64_into(&mut self.body, v);
        self
    }

    /// Exact-bits float decision field (16-hex-digit string, the
    /// [`f64_bits`] rendering golden dumps use).
    pub fn bits(mut self, key: &str, v: f64) -> TraceEvent {
        self.key(key);
        self.body.push('"');
        self.body.push_str(&f64_bits(v));
        self.body.push('"');
        self
    }

    /// String decision field (JSON-escaped).
    pub fn str(mut self, key: &str, v: &str) -> TraceEvent {
        self.key(key);
        self.body.push('"');
        json_escape_into(&mut self.body, v);
        self.body.push('"');
        self
    }

    /// Boolean decision field.
    pub fn bool(mut self, key: &str, v: bool) -> TraceEvent {
        self.key(key);
        self.body.push_str(if v { "true" } else { "false" });
        self
    }

    /// Optional unsigned field (`None` → `null`).
    pub fn opt_u64(mut self, key: &str, v: Option<u64>) -> TraceEvent {
        self.key(key);
        match v {
            Some(v) => self.body.push_str(&v.to_string()),
            None => self.body.push_str("null"),
        }
        self
    }

    /// Optional float field (`None` → `null`).
    pub fn opt_f64(mut self, key: &str, v: Option<f64>) -> TraceEvent {
        self.key(key);
        match v {
            Some(v) => json_f64_into(&mut self.body, v),
            None => self.body.push_str("null"),
        }
        self
    }

    /// Optional boolean field (`None` → `null`).
    pub fn opt_bool(mut self, key: &str, v: Option<bool>) -> TraceEvent {
        self.key(key);
        match v {
            Some(v) => self.body.push_str(if v { "true" } else { "false" }),
            None => self.body.push_str("null"),
        }
        self
    }

    fn t_key(&mut self, key: &str) {
        if !self.timing.is_empty() {
            self.timing.push(',');
        }
        self.timing.push('"');
        json_escape_into(&mut self.timing, key);
        self.timing.push_str("\":");
    }

    /// Unsigned timing/environment field (lands in the `"t"` object,
    /// excluded from the digest). Nanosecond totals are `u128`
    /// upstream; saturate into `u64` (584 years of nanoseconds).
    pub fn t_u128(mut self, key: &str, v: u128) -> TraceEvent {
        self.t_key(key);
        self.timing
            .push_str(&u64::try_from(v).unwrap_or(u64::MAX).to_string());
        self
    }

    /// Unsigned timing/environment field.
    pub fn t_u64(mut self, key: &str, v: u64) -> TraceEvent {
        self.t_key(key);
        self.timing.push_str(&v.to_string());
        self
    }

    /// Float timing/environment field.
    pub fn t_f64(mut self, key: &str, v: f64) -> TraceEvent {
        self.t_key(key);
        json_f64_into(&mut self.timing, v);
        self
    }

    /// Finish the line: decision fields, then the `"t"` object (when
    /// any timing field was set) as the final key.
    pub fn render(self) -> String {
        let mut line = self.body;
        if !self.timing.is_empty() {
            line.push_str(",\"t\":{");
            line.push_str(&self.timing);
            line.push('}');
        }
        line.push('}');
        line
    }
}

/// Reduce a JSONL trace to its decision-only digest: per line, strip
/// the trailing `,"t":{...}` object (the timing fields) and keep
/// everything else byte-for-byte. Lines without a `t` object pass
/// through unchanged. Two runs that differ only in thread count or
/// wall-clock must digest identically — `ci/determinism.sh` §6
/// enforces exactly that via the `trace-digest` subcommand.
pub fn trace_digest(jsonl: &str) -> String {
    let mut out = String::with_capacity(jsonl.len());
    for line in jsonl.lines() {
        match strip_timing(line) {
            Some(prefix) => {
                out.push_str(prefix);
                out.push('}');
            }
            None => out.push_str(line),
        }
        out.push('\n');
    }
    out
}

/// The per-line digest rule: when the line ends in the emitter-shaped
/// flat `,"t":{...}}` suffix, return the decision prefix (caller
/// re-closes the object); otherwise `None` — a line that doesn't match
/// the invariant is passed through unchanged rather than guessed at.
/// Flat means no nested braces inside the timing object (its values
/// are numeric by construction), which keeps the rule purely textual.
fn strip_timing(line: &str) -> Option<&str> {
    let pos = line.rfind(",\"t\":{")?;
    let inner = line.get(pos + 6..line.len().checked_sub(2)?)?;
    if !line.ends_with("}}") || inner.contains('{') || inner.contains('}') {
        return None;
    }
    Some(&line[..pos])
}

/// Where trace lines go.
#[derive(Debug)]
enum Sink {
    /// JSONL file (the `--trace-out` path).
    File(BufWriter<File>),
    /// In-memory buffer for tests and programmatic inspection.
    Memory(Vec<String>),
}

/// A handle that owns the trace sink. The trainer holds at most one;
/// emission goes through [`Tracer::emit`], which re-checks the
/// tracer-local level so concurrently running trainers (e.g. the test
/// suite) never write into each other's sinks.
#[derive(Debug)]
pub struct Tracer {
    level: TraceLevel,
    sink: Sink,
    events: u64,
}

impl Tracer {
    /// Open (truncate) a JSONL trace file at `path`.
    pub fn to_file(path: &Path, level: TraceLevel) -> Result<Tracer> {
        let file = File::create(path)
            .map_err(|e| anyhow::anyhow!("cannot create trace file {}: {e}", path.display()))?;
        raise_trace_level(level);
        Ok(Tracer {
            level,
            sink: Sink::File(BufWriter::new(file)),
            events: 0,
        })
    }

    /// Collect lines in memory (tests, tooling).
    pub fn in_memory(level: TraceLevel) -> Tracer {
        raise_trace_level(level);
        Tracer {
            level,
            sink: Sink::Memory(Vec::new()),
            events: 0,
        }
    }

    /// This tracer's own threshold.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// Would an event at `level` be recorded by this tracer?
    #[inline]
    pub fn enabled(&self, level: TraceLevel) -> bool {
        level != TraceLevel::Off && level <= self.level
    }

    /// Record one event (no-op when `level` is above the threshold).
    pub fn emit(&mut self, level: TraceLevel, event: TraceEvent) {
        if !self.enabled(level) {
            return;
        }
        let line = event.render();
        match &mut self.sink {
            Sink::File(w) => {
                // ignore I/O errors mid-round; flush() surfaces them
                let _ = writeln!(w, "{line}");
            }
            Sink::Memory(buf) => buf.push(line),
        }
        self.events += 1;
    }

    /// Events recorded so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Recorded lines (empty for file sinks — read the file instead).
    pub fn lines(&self) -> &[String] {
        match &self.sink {
            Sink::Memory(buf) => buf,
            Sink::File(_) => &[],
        }
    }

    /// Flush a file sink (no-op in memory).
    pub fn flush(&mut self) -> Result<()> {
        if let Sink::File(w) = &mut self.sink {
            w.flush()
                .map_err(|e| anyhow::anyhow!("trace flush failed: {e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_case_insensitive() {
        assert_eq!(parse_trace_level("off"), Some(TraceLevel::Off));
        assert_eq!(parse_trace_level("Decision"), Some(TraceLevel::Decision));
        assert_eq!(parse_trace_level("FULL"), Some(TraceLevel::Full));
        assert_eq!(parse_trace_level("loud"), None);
        assert_eq!(TraceLevel::Full.name(), "full");
    }

    #[test]
    fn event_renders_timing_last_and_digest_strips_it() {
        let line = TraceEvent::new("codec_choice")
            .u64("iter", 7)
            .str("mode", "delta")
            .f64("sse_fresh", 0.25)
            .opt_f64("sse_reuse", None)
            .bool("within_budget", true)
            .t_u128("encode_ns", 12345)
            .t_u64("lane", 2)
            .render();
        assert_eq!(
            line,
            "{\"ev\":\"codec_choice\",\"iter\":7,\"mode\":\"delta\",\
             \"sse_fresh\":0.25,\"sse_reuse\":null,\"within_budget\":true,\
             \"t\":{\"encode_ns\":12345,\"lane\":2}}"
        );
        let digest = trace_digest(&format!("{line}\n"));
        assert_eq!(
            digest,
            "{\"ev\":\"codec_choice\",\"iter\":7,\"mode\":\"delta\",\
             \"sse_fresh\":0.25,\"sse_reuse\":null,\"within_budget\":true}\n"
        );
        assert!(!digest.contains("\"t\":{"));
    }

    #[test]
    fn digest_passes_through_lines_without_timing() {
        let line = TraceEvent::new("round_start").u64("iter", 1).render();
        assert_eq!(trace_digest(&line), format!("{line}\n"));
        // a string field that merely *mentions* the t-shape is kept:
        // rfind only matches the genuine trailing flat object
        let tricky = "{\"ev\":\"x\",\"note\":\"has ,\\\"t\\\":{ inside\"}";
        assert_eq!(trace_digest(tricky).trim_end(), tricky);
    }

    #[test]
    fn float_rendering_is_json_safe() {
        let line = TraceEvent::new("e")
            .f64("a", 1.0)
            .f64("b", 0.1)
            .f64("c", f64::NAN)
            .f64("d", -3.5e-7)
            .render();
        assert_eq!(
            line,
            "{\"ev\":\"e\",\"a\":1.0,\"b\":0.1,\"c\":null,\"d\":-0.00000035}"
        );
    }

    #[test]
    fn strings_are_escaped() {
        let line = TraceEvent::new("e").str("s", "a\"b\\c\nd").render();
        assert_eq!(line, "{\"ev\":\"e\",\"s\":\"a\\\"b\\\\c\\nd\"}");
    }

    #[test]
    fn memory_tracer_respects_its_own_level() {
        let mut tr = Tracer::in_memory(TraceLevel::Decision);
        tr.emit(TraceLevel::Decision, TraceEvent::new("keep"));
        tr.emit(TraceLevel::Full, TraceEvent::new("drop"));
        tr.emit(TraceLevel::Off, TraceEvent::new("never"));
        assert_eq!(tr.events(), 1);
        assert_eq!(tr.lines().len(), 1);
        assert!(tr.lines()[0].contains("\"keep\""));
    }

    #[test]
    fn bits_field_matches_round_dump_rendering() {
        let v = 0.123456789f64;
        let line = TraceEvent::new("e").bits("map", v).render();
        assert!(line.contains(&format!("\"map\":\"{:016x}\"", v.to_bits())));
    }
}
