//! Named-metric registry: counters, gauges and fixed-bucket histograms.
//!
//! The registry is deliberately **decision-side only**: the trainer
//! feeds it bytes, frame modes, resync counts, rewards and generations
//! — never wall-clock time — so a snapshot is a pure function of
//! (config, seed) and `--metrics-out` files diff clean across thread
//! counts, exactly like the trace digest. Histogram bucket bounds are
//! hardcoded constants for the same reason: no data-dependent bucket
//! layout, so two runs disagree only if the *observations* disagree.
//!
//! Keys are full Prometheus sample names, labels included (e.g.
//! `fedpayload_session_frames_total{mode="reuse"}`); a `BTreeMap`
//! keeps rendering order stable. Text exposition lives in
//! [`export`](super::export).

use std::collections::BTreeMap;

/// Download/upload frame and round byte sizes: powers of four from
/// 64 B to 16 MiB (11 buckets + overflow).
pub const BYTE_BUCKETS: &[f64] = &[
    64.0,
    256.0,
    1024.0,
    4096.0,
    16384.0,
    65536.0,
    262144.0,
    1048576.0,
    4194304.0,
    16777216.0,
];

/// Bandit reward magnitudes: decades from 1e-6 to 1e2 (Eq. 13 rewards
/// are squared-gradient traces, usually far below 1).
pub const REWARD_BUCKETS: &[f64] = &[
    1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0,
];

/// A fixed-bound histogram: per-bucket counts (`bounds.len() + 1`
/// entries, the last being overflow), plus sum and count for the
/// Prometheus `_sum`/`_count` series.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub(super) bounds: &'static [f64],
    pub(super) counts: Vec<u64>,
    pub(super) sum: f64,
    pub(super) count: u64,
}

impl Histogram {
    fn new(bounds: &'static [f64]) -> Histogram {
        Histogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }
}

/// The registry a trainer owns for the lifetime of a run.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub(super) counters: BTreeMap<String, u64>,
    pub(super) gauges: BTreeMap<String, f64>,
    pub(super) histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Nothing recorded yet?
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Increment (and create on first touch) a monotonic counter.
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current counter value (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to its latest value.
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Current gauge value.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Observe `v` into the named histogram, creating it with `bounds`
    /// on first touch. Bounds are `'static` so every histogram's bucket
    /// layout is one of the hardcoded constants above — re-observing
    /// with different bounds is a programming error and panics in
    /// debug builds (release keeps the original layout).
    pub fn observe(&mut self, name: &str, bounds: &'static [f64], v: f64) {
        let h = self
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds));
        debug_assert!(
            std::ptr::eq(h.bounds.as_ptr(), bounds.as_ptr()),
            "histogram {name} re-registered with different bounds"
        );
        h.observe(v);
    }

    /// Look up a histogram.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_accumulate() {
        let mut r = Registry::new();
        assert!(r.is_empty());
        r.inc("a_total", 2);
        r.inc("a_total", 3);
        r.set_gauge("g", 1.5);
        r.set_gauge("g", 2.5);
        assert_eq!(r.counter("a_total"), 5);
        assert_eq!(r.counter("missing"), 0);
        assert_eq!(r.gauge("g"), Some(2.5));
        assert!(!r.is_empty());
    }

    #[test]
    fn histogram_buckets_split_at_bounds() {
        let mut r = Registry::new();
        for v in [10.0, 64.0, 65.0, 1e9] {
            r.observe("bytes", BYTE_BUCKETS, v);
        }
        let h = r.histogram("bytes").unwrap();
        assert_eq!(h.count(), 4);
        assert_eq!(h.counts[0], 2, "10 and 64 land in le=64");
        assert_eq!(h.counts[1], 1, "65 lands in le=256");
        assert_eq!(*h.counts.last().unwrap(), 1, "1e9 overflows to +Inf");
        assert!((h.sum() - (10.0 + 64.0 + 65.0 + 1e9)).abs() < 1e-6);
    }
}
